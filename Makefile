PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

# serving tier: scheduler/engine/packed-path tests (CI runs these as their
# own matrix entry with a 120s per-test ceiling)
SERVING_TESTS := tests/test_scheduler.py tests/test_packed_serving.py \
                 tests/test_serving_e2e.py tests/test_chunked_prefill.py \
                 tests/test_paged_cache.py tests/test_serving_fuzz.py \
                 tests/test_speculative.py tests/test_autotune.py \
                 tests/test_multitenant.py tests/test_scorecard.py

.PHONY: test test-unit test-serving test-fuzz test-spec test-sharded \
        test-multitenant bench-smoke bench-smoke-continuous bench-serving \
        bench-smoke-sharded bench-smoke-autotune scorecard-smoke \
        scorecard-baseline

test:            ## tier-1 test suite
	$(PYTHON) -m pytest -x -q

test-unit:       ## everything except the serving tier
	$(PYTHON) -m pytest -x -q \
	  $(foreach t,$(SERVING_TESTS),--ignore=$(t))

test-serving:    ## serving tier: timings reported, >120s per test fails
	$(PYTHON) -m pytest -q --durations=10 --max-test-seconds=120 \
	  $(SERVING_TESTS)

test-fuzz:       ## cross-mode differential serving fuzzer, bigger budget
	FUZZ_EXAMPLES=8 $(PYTHON) -m pytest -q --durations=10 \
	  tests/test_serving_fuzz.py

test-spec:       ## speculative decoding suite (parity, EOS, host syncs)
	$(PYTHON) -m pytest -q --durations=10 tests/test_speculative.py

test-sharded:    ## tensor-parallel parity + fuzzer on a forced 4-device CPU mesh
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	  $(PYTHON) -m pytest -q --durations=10 \
	  tests/test_sharded_serving.py tests/test_serving_fuzz.py

test-multitenant:  ## multi-tenant control plane: policies, quotas, preemption, TTFT
	$(PYTHON) -m pytest -q --durations=10 tests/test_multitenant.py

bench-smoke:     ## serving latency benchmark, tiny shapes (CI)
	$(PYTHON) benchmarks/serving_latency.py --smoke

bench-smoke-continuous:  ## continuous + prefill-heavy + paged + shared + spec + MT
	$(PYTHON) benchmarks/serving_latency.py --smoke --mode continuous \
	  --prefill-heavy --paged --share-prefix --speculative --multi-tenant

bench-smoke-sharded:  ## sharded continuous section (forces a 4-device CPU mesh)
	$(PYTHON) benchmarks/serving_latency.py --smoke --mode continuous \
	  --sharded

bench-smoke-autotune:  ## tiny-budget autotuner search + before/after replay
	$(PYTHON) benchmarks/serving_latency.py --smoke --mode autotune

scorecard-smoke:  ## serving-path quality scorecard, drift gate armed (CI)
	$(PYTHON) benchmarks/serving_latency.py --smoke --mode scorecard \
	  --scorecard-gate

scorecard-baseline:  ## regenerate + adopt the committed smoke baseline
	$(PYTHON) benchmarks/serving_latency.py --smoke --mode scorecard \
	  --scorecard-out experiments/scorecard_baseline.json

bench-serving:   ## full serving latency benchmark -> BENCH_serving.json
	$(PYTHON) benchmarks/serving_latency.py
