PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test bench-smoke bench-serving

test:            ## tier-1 test suite
	$(PYTHON) -m pytest -x -q

bench-smoke:     ## serving latency benchmark, tiny shapes (CI)
	$(PYTHON) benchmarks/serving_latency.py --smoke

bench-serving:   ## full serving latency benchmark -> BENCH_serving.json
	$(PYTHON) benchmarks/serving_latency.py
