"""Quantizer ablations (EXPERIMENTS.md SAccuracy point 4): which knobs close
the log-codebook gap to uniform INT4 on the reference model.

  PYTHONPATH=src python -m benchmarks.ablations
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core.apply import dequantize_params, quantize_params
from repro.core.quantize import HaloConfig
from repro.quant import rtn

from . import common

DENSE_GRID = tuple(float(x) for x in np.geomspace(0.12, 1.15, 48))


def variants():
    return {
        "tile-scale,24pt-grid": HaloConfig(
            tile=64, scale_granularity="tile",
            scale_grid=tuple(float(x) for x in np.geomspace(0.2, 1.1, 24))),
        "tile-scale,dense-grid": HaloConfig(
            tile=64, scale_granularity="tile", scale_grid=DENSE_GRID),
        "col-scale (default)": HaloConfig(tile=64),
        "col-scale+fisher-mse": HaloConfig(tile=64,
                                           fisher_weighted_scale=True),
        "col-scale+2.5sigma": HaloConfig(tile=64, n_sigma=2.5),
        "col-scale+fisher+2.5sigma": HaloConfig(
            tile=64, n_sigma=2.5, fisher_weighted_scale=True),
    }


def run(steps: int = 1000) -> List[dict]:
    cfg, params = common.train_reference("llama", steps=steps)
    fisher, _ = common.collect_calibration(params, cfg, with_gram=False)
    fp = common.eval_ppl(params, cfg, act_bits=8)
    rows = [{"variant": "fp32(A8)", "ppl": fp, "delta": 0.0}]
    r4 = common.eval_ppl(rtn.rtn_quantize_params(params, 4), cfg, act_bits=8)
    rows.append({"variant": "rtn-w4 (reference point)", "ppl": r4,
                 "delta": r4 - fp})
    for name, hc in variants().items():
        q = quantize_params(params, fisher, hc, theta=0.995)
        ppl = common.eval_ppl(dequantize_params(q), cfg, act_bits=8)
        rows.append({"variant": f"halo-acc {name}", "ppl": ppl,
                     "delta": ppl - fp})
        print(f"  {rows[-1]['variant']:38s} ppl={ppl:9.3f} "
              f"d={ppl - fp:+8.3f}")
    return rows


def main():
    print("quantizer ablations (scale granularity / grid / fisher / sigma)")
    print("name,us_per_call,derived")
    for r in run():
        print(f"ablation/{r['variant'].replace(' ', '_')},0,"
              f"ppl={r['ppl']:.4f};delta={r['delta']:+.4f}")


if __name__ == "__main__":
    main()
