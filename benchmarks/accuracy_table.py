"""Table II analogue: PPL for FP32 / RTN / SmoothQuant / GPTQ / ZQ-Local /
ZQ-Global / HALO (perf-opt, bal, acc-opt; tiles 128/64/32) on small
reference models of the paper's two families.  All weight methods run with
A8 activations, matching the paper's WxA8 setting."""

from __future__ import annotations

from typing import Dict, List

from repro.core.apply import dequantize_params, quantize_params
from repro.core.pareto import VARIANT_THETA
from repro.core.quantize import HaloConfig
from repro.quant import gptq, rtn, smoothquant, zeroquant

from . import common


def quantize_all_methods(cfg, params, fisher, act_stats,
                         halo_tile: int = 64) -> Dict[str, object]:
    out = {"fp32": params}
    for bits in (8, 4, 3):
        out[f"rtn-w{bits}"] = rtn.rtn_quantize_params(params, bits)
        out[f"smooth-w{bits}"] = smoothquant.smoothquant_params(
            params, act_stats, bits)
    out["gptq-w4"] = gptq.gptq_params(params, act_stats, 4)
    out["zq-local-w4"] = zeroquant.zq_local_params(params, 4, tile=64)
    out["zq-global-w4"] = zeroquant.zq_global_params(params, 4)
    for variant, theta in VARIANT_THETA.items():
        q = quantize_params(params, fisher, HaloConfig(tile=halo_tile),
                            theta=theta)
        out[f"halo-{variant}"] = q
    return out


def effective_bits_of(qparams) -> float:
    # single implementation in core/apply.py, shared with the scorecard
    from repro.core.apply import effective_bits_of as _eb
    return _eb(qparams)


def run(families=("llama", "opt"), steps: int = 400) -> List[dict]:
    rows = []
    for family in families:
        cfg, params = common.train_reference(family, steps=steps)
        fisher, act_stats = common.collect_calibration(params, cfg)
        methods = quantize_all_methods(cfg, params, fisher, act_stats)
        fp_ppl = common.eval_ppl(params, cfg)
        for name, q in methods.items():
            dense = dequantize_params(q) if name.startswith("halo") else q
            act_bits = None if name == "fp32" else 8
            ppl = common.eval_ppl(dense, cfg, act_bits=act_bits)
            row = {"family": family, "method": name, "ppl": ppl,
                   "delta_vs_fp": ppl - fp_ppl}
            if name.startswith("halo"):
                row["eff_bits"] = effective_bits_of(q)
            rows.append(row)
            print(f"  {family:6s} {name:14s} ppl={ppl:9.3f} "
                  f"d={ppl - fp_ppl:+8.3f} "
                  + (f"bw={row.get('eff_bits'):.2f}" if "eff_bits" in row
                     else ""))
    return rows


def main():
    print("accuracy_table (Table II analogue)")
    print("name,us_per_call,derived")
    rows = run()
    for r in rows:
        print(f"accuracy/{r['family']}/{r['method']},0,"
              f"ppl={r['ppl']:.4f};delta={r['delta_vs_fp']:+.4f}"
              + (f";bw={r['eff_bits']:.2f}" if "eff_bits" in r else ""))


if __name__ == "__main__":
    main()
