"""Shared benchmark harness: train small reference models on the synthetic
corpus, collect calibration (Fisher + activation stats), evaluate PPL.

No C4/WikiText/LLaMA weights exist in this offline container, so Table-II
style comparisons train ~4-15M-parameter models of the paper's families
(llama-like, opt-like) to convergence on the synthetic corpus and compare
PTQ methods *relative to the fp32 baseline* -- the paper's claims we verify
are ordinal (see EXPERIMENTS.md SAccuracy).  Trained models are cached under
experiments/bench_cache so benchmark modules share one training run.
"""

from __future__ import annotations

import dataclasses
import functools
import os
import sys
from typing import Dict, Optional, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from repro.checkpoint.manager import CheckpointManager       # noqa: E402
from repro.configs.base import ModelConfig                   # noqa: E402
from repro.data.synthetic import CorpusConfig, SyntheticCorpus  # noqa: E402
from repro.launch.train import (TrainConfig, TrainState,     # noqa: E402
                                make_train_step)
from repro.models import module as M                         # noqa: E402
from repro.models import transformer as T                    # noqa: E402
from repro.optim import adamw                                # noqa: E402
from repro.quant import calibrate                            # noqa: E402
from repro.quant.common import activations_quantized         # noqa: E402

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "bench_cache")

BENCH_VOCAB = 2048
BENCH_SEQ = 128
BENCH_BATCH = 16


def bench_config(family: str = "llama", scale: int = 1) -> ModelConfig:
    if family == "llama":
        return ModelConfig(
            name=f"bench-llama-x{scale}", family="dense",
            n_layers=4 * scale, d_model=256, n_heads=4, n_kv_heads=4,
            head_dim=64, d_ff=1024, vocab=BENCH_VOCAB,
            activation="silu", gated_mlp=True, dtype=jnp.float32,
            attn_chunk=64, scan_chunk=32, vocab_pad_multiple=64)
    if family == "opt":
        return ModelConfig(
            name=f"bench-opt-x{scale}", family="dense",
            n_layers=4 * scale, d_model=256, n_heads=4, n_kv_heads=4,
            head_dim=64, d_ff=1024, vocab=BENCH_VOCAB,
            activation="relu", gated_mlp=False, norm_type="layernorm",
            use_bias=True, pos_emb="learned", max_position=BENCH_SEQ,
            tied_embeddings=True, dtype=jnp.float32,
            attn_chunk=64, scan_chunk=32, vocab_pad_multiple=64)
    raise KeyError(family)


def bench_corpus() -> SyntheticCorpus:
    return SyntheticCorpus(CorpusConfig(vocab=BENCH_VOCAB, seq_len=BENCH_SEQ,
                                        batch=BENCH_BATCH))


def train_reference(family: str, steps: int = 400, scale: int = 1,
                    force: bool = False):
    """Train (or load cached) reference model.  Returns (cfg, params)."""
    cfg = bench_config(family, scale)
    ckpt_dir = os.path.join(CACHE_DIR, f"{cfg.name}_{steps}")
    mgr = CheckpointManager(ckpt_dir, keep=1)
    specs = T.model_specs(cfg)
    if not force and mgr.latest_step() is not None:
        ref = M.init_params(specs, jax.random.PRNGKey(0))
        return cfg, mgr.restore(ref)

    corpus = bench_corpus()
    tcfg = TrainConfig(peak_lr=1e-3, warmup_steps=steps // 10,
                       total_steps=steps, grad_accum=1,
                       ckpt_dir=ckpt_dir)
    params = M.init_params(specs, jax.random.PRNGKey(0))
    state = TrainState(params, adamw.init(params, tcfg.adamw))
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    for step in range(steps):
        batch = jax.tree.map(jnp.asarray, corpus.batch_at(step))
        state, metrics = step_fn(state, batch)
        if step % 100 == 0:
            print(f"  [{cfg.name}] step {step} loss "
                  f"{float(metrics['loss']):.4f}")
    mgr.save(steps, state.params)
    mgr.wait()
    return cfg, state.params


def eval_ppl(params, cfg: ModelConfig, n_batches: int = 8,
             act_bits: Optional[int] = None) -> float:
    """Held-out perplexity; optional A8 fake-quant on every dense input."""
    corpus = bench_corpus()
    loss_fn = jax.jit(functools.partial(T.loss_fn, cfg=cfg))
    total = 0.0
    ctx = activations_quantized(act_bits) if act_bits else _null()
    with ctx:
        for batch in corpus.eval_batches(n_batches):
            b = jax.tree.map(jnp.asarray, batch)
            total += float(loss_fn(params, batch=b))
    return float(np.exp(total / n_batches))


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def stamp_section(section: Dict) -> Dict:
    """Stamp a BENCH_*.json section with provenance at WRITE time: the
    git SHA and UTC timestamp of the run that produced it.  Merged
    reports keep stale sections' original stamps, which is what lets
    ``staleness_note`` detect a report mixing runs of different SHAs."""
    from repro.eval.scorecard import git_sha, utc_now
    section["git_sha"] = git_sha()
    section["written_at"] = utc_now()
    return section


def staleness_note(report: Dict, keys=None) -> str:
    """Non-empty iff the merged report mixes sections produced at
    different git SHAs (or carries unstamped sections).  ``keys`` names
    the section keys to audit (default: every dict-valued entry).  The
    returned note is meant to be stored IN the report and printed
    loudly -- a silent mix is exactly how a stale number gets quoted as
    current."""
    shas: Dict[str, list] = {}
    for key, sec in report.items():
        if keys is not None and key not in keys:
            continue
        if not isinstance(sec, dict):
            continue
        shas.setdefault(sec.get("git_sha", "<unstamped>"), []).append(key)
    if len(shas) <= 1:
        return ""
    parts = [f"{sha}: {', '.join(sorted(keys))}"
             for sha, keys in sorted(shas.items())]
    return ("MIXED-SHA REPORT: sections were produced by different "
            "commits -- re-run the stale ones before quoting deltas "
            "[" + "; ".join(parts) + "]")


def collect_calibration(params, cfg: ModelConfig, n_batches: int = 4,
                        with_gram: bool = True):
    """Fisher diag + activation stats over calibration batches
    (paper: 100-128 random samples; we use n_batches x 16 sequences)."""
    corpus = bench_corpus()

    def loss(p, batch):
        return T.loss_fn(p, cfg, batch)

    batches = [jax.tree.map(jnp.asarray, corpus.batch_at(10_000 + i))
               for i in range(n_batches)]
    from repro.core.sensitivity import fisher_diag
    fisher = fisher_diag(loss, params, batches)

    with calibrate.recording(collect_gram=with_gram) as rec:
        for b in batches[:2]:
            # python-unrolled forward: the recorder sees concrete weights
            calibrate.calibrated_forward(params, cfg, b)
    act_stats = calibrate.stats_by_path(rec, params)
    return fisher, act_stats


def class_mix_from_quantized(qparams) -> Tuple[float, float]:
    """(f3_fraction, f2_fraction) over all HALO-quantized tiles."""
    from repro.core.apply import StackedHalo
    from repro.core.quantize import HaloQuantized
    from repro.core import codebooks
    f3 = total = 0
    for leaf in jax.tree.leaves(
            qparams, is_leaf=lambda x: isinstance(x, (HaloQuantized,
                                                      StackedHalo))):
        hqs = []
        if isinstance(leaf, HaloQuantized):
            hqs = [leaf]
        elif isinstance(leaf, StackedHalo):
            hqs = list(leaf.slices)
        for hq in hqs:
            cls = np.asarray(jax.device_get(hq.classes))
            f3 += int((cls == codebooks.TILE_CLASS_F3).sum())
            total += cls.size
    if total == 0:
        return 0.0, 1.0
    return f3 / total, 1.0 - f3 / total
