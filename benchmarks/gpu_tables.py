"""Figs. 12-13 analogues: GPU execution time & energy (analytic model with
the paper's Table-I GPU DVFS levels), HALO vs FP16/W8A8/W4A8."""

from __future__ import annotations

from typing import List

from repro.hw import gpu as G
from repro.hw import systolic as sy

from .systolic_tables import PAPER_DIMS, measured_class_mixes


def run(seq: int = 2048, steps: int = 400) -> List[dict]:
    mixes = measured_class_mixes(steps)
    rows = []
    for model, dims in PAPER_DIMS.items():
        shapes = sy.decoder_layer_shapes(seq=seq, batch=1, **dims)
        res = {n: G.simulate_matmuls(shapes, G.gpu_baseline(n))
               for n in ("fp16", "w8a8", "w4a8")}
        for variant, (f3, f2) in mixes.items():
            res[f"halo-{variant}"] = G.simulate_matmuls(
                shapes, G.gpu_halo(f3, f2, name=f"halo-{variant}"))
        ref = res["w8a8"]
        for name, r in res.items():
            rows.append({"model": model, "scheme": name,
                         "time_ms": r.time_s * 1e3,
                         "norm_time": r.time_s / ref.time_s,
                         "energy_j": r.energy_j,
                         "norm_energy": r.energy_j / ref.energy_j})
    return rows


def main():
    print("gpu perf/energy (Figs. 12-13) -- normalized to W8A8")
    print("name,us_per_call,derived")
    for r in run():
        print(f"gpu/{r['model']}/{r['scheme']},{r['time_ms']*1e3:.1f},"
              f"norm_time={r['norm_time']:.4f};"
              f"norm_energy={r['norm_energy']:.4f}")


if __name__ == "__main__":
    main()
