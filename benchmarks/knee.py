"""Fig. 9 analogue: normalized performance vs perplexity across theta --
the knee point marks the bal variant's efficiency-accuracy tradeoff."""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core.apply import dequantize_params, quantize_params
from repro.core.pareto import _class_mix_speedup, knee_point, ParetoPoint
from repro.core.quantize import HaloConfig

from . import common


def run(steps: int = 400,
        thetas=(0.3, 0.5, 0.7, 0.85, 0.95, 0.99, 0.999)) -> List[dict]:
    cfg, params = common.train_reference("llama", steps=steps)
    fisher, _ = common.collect_calibration(params, cfg, with_gram=False)
    rows = []
    pts = []
    for theta in thetas:
        q = quantize_params(params, fisher, HaloConfig(tile=64), theta=theta)
        f3, f2 = common.class_mix_from_quantized(q)
        ppl = common.eval_ppl(dequantize_params(q), cfg, act_bits=8)
        speedup = _class_mix_speedup(f3)
        rows.append({"theta": theta, "f3_frac": f3, "ppl": ppl,
                     "speedup_vs_f1": speedup})
        pts.append(ParetoPoint(theta=theta, f3_fraction=f3,
                               effective_bits=0.0, error_proxy=ppl,
                               est_speedup_vs_f1=speedup))
    knee = knee_point(pts)
    for r in rows:
        r["is_knee"] = (r["theta"] == knee.theta)
    return rows


def main():
    print("performance-vs-ppl knee (Fig. 9)")
    print("name,us_per_call,derived")
    for r in run():
        print(f"knee/theta={r['theta']},0,ppl={r['ppl']:.3f};"
              f"speedup={r['speedup_vs_f1']:.3f};f3={r['f3_frac']:.3f};"
              f"knee={int(r['is_knee'])}")


if __name__ == "__main__":
    main()
