"""SRoofline deliverable: the 3-term table for every dry-run cell, read from
experiments/dryrun/*.json (run `python -m repro.launch.dryrun --all` first)."""

from __future__ import annotations

import os

from repro.analysis import roofline as RL

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
HILLCLIMB_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                             "hillclimb")


def main():
    reports = RL.load_reports(DRYRUN_DIR)
    if not reports:
        print("no dry-run artifacts found; run "
              "`PYTHONPATH=src python -m repro.launch.dryrun --all`")
        return
    print(RL.format_table(reports))
    hc = RL.load_reports(HILLCLIMB_DIR)
    if hc:
        print("\nSPerf hillclimb variants (tag after '@'):")
        print(RL.format_table(hc))
    print("\nname,us_per_call,derived")
    for r in reports:
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
              f"{bound*1e6:.0f},"
              f"dominant={r['dominant']};"
              f"roofline_frac={r['roofline_fraction']:.4f};"
              f"useful={r['useful_ratio']:.3f};fits={int(r['fits_hbm'])}")


if __name__ == "__main__":
    main()
