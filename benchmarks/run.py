"""Benchmark entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only NAME] [--steps N]

Prints ``name,us_per_call,derived`` CSV per benchmark (harness convention).
"""

from __future__ import annotations

import argparse


def _print_rows(rows):
    print("name,us_per_call,derived")
    for r in rows:
        extra = f";bw={r['eff_bits']:.2f}" if "eff_bits" in r else ""
        print(f"accuracy/{r['family']}/{r['method']},0,"
              f"ppl={r['ppl']:.4f};delta={r['delta_vs_fp']:+.4f}{extra}")
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single module (accuracy|systolic|gpu|knee|"
                         "roofline)")
    ap.add_argument("--steps", type=int, default=400,
                    help="reference-model training steps")
    args = ap.parse_args()

    from . import ablations, accuracy_table, gpu_tables, knee, \
        roofline_table, systolic_tables

    # note: reference-model training is cached per (family, steps) under
    # experiments/bench_cache; modules below all honor --steps.
    import functools

    def with_steps(fn):
        return functools.partial(fn, steps=args.steps) \
            if "steps" in fn.__code__.co_varnames else fn

    modules = {
        "accuracy": lambda: _print_rows(accuracy_table.run(
            steps=args.steps)),               # Table II
        "systolic": systolic_tables.main,     # Figs. 8, 10, 11
        "gpu": gpu_tables.main,               # Figs. 12, 13
        "knee": knee.main,                    # Fig. 9
        "ablations": ablations.main,          # SAccuracy quantizer knobs
        "roofline": roofline_table.main,      # SRoofline
    }
    selected = {args.only: modules[args.only]} if args.only else modules

    failures = []
    for name, fn in selected.items():
        print(f"\n===== benchmark: {name} =====")
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            traceback.print_exc()
        print(f"===== {name} done in {time.time()-t0:.1f}s =====")
    if failures:
        print("\nFAILURES:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
