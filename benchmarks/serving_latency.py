"""Serving latency: dense vs XLA-dequant vs packed-kernel fast path, plus
continuous batching vs the one-shot padded batch.

``--mode paths`` measures prefill and decode tokens/s on the bench-llama
config for the three weight formats the engine serves:

  dense        fp32 weights, scan decode loop
  xla_dequant  DeployQuantWeight, legacy per-token loop with per-call XLA
               dequantization -- the pre-fast-path serving behavior
  packed       HaloPacked via core.deploy.pack_params: pack-at-load,
               jitted lax.scan decode, halo_matmul/SpMV kernels (Pallas on
               TPU; interpret on this CPU container), single host sync

``--mode continuous`` replays a Poisson-ish synthetic arrival trace of
mixed-length requests through the continuous-batching scheduler
(serving/scheduler.py) and through the one-shot padded-batch baseline
(wait for the full batch, pad everything to the longest prompt and the
largest max_new, run one generate).  Both walls start at the first
arrival, so the continuous speedup reflects what the scheduler actually
buys: prefill/decode overlapped with arrivals, and early-finishing slots
recycled for queued requests instead of idling until the batch max.

``--prefill-heavy`` adds a second continuous trace of LONG prompts --
several times the prefill window width, so every admission streams
chunk-by-chunk through the PREFILLING phase interleaved with decode
ticks -- recorded as the ``continuous_prefill_heavy`` section.  This is
the traffic shape the chunked-prefill refactor exists for: without it,
one monolithic prefill per admission stalls the resident decode batch
for the whole prompt.

``--paged`` replays a LONG-CONTEXT trace (prompts up to near ``max_seq``,
mixed with short ones) through the contiguous slot layout and through the
block-paged KV cache at EQUAL cache memory but 2x the slot capacity
(admission reserves pages, not whole ``max_seq`` rows) -- recorded as the
``continuous_paged`` section.  The paged run completing the trace at
double the seat count is the acceptance headline for gather-free
long-context slots.

``--share-prefix`` replays a trace of N requests over K SYSTEM PROMPTS
(every request = one of K page-aligned prefixes + a unique suffix)
through the paged cache with and without copy-on-write prefix sharing,
on a pool deliberately sized at HALF capacity -- recorded as the
``continuous_shared`` section.  Without sharing the duplicated prefix
pages exhaust the pool and admission blocks; with sharing each prefix
is charged once (refcount > 1) and its prefill windows are skipped, so
the shared run admits more seats concurrently and streams fewer prefill
windows at equal cache memory.

``--speculative`` replays a SINGLE-STREAM greedy trace (capacity 1 --
the latency-bound regime speculation exists for) through the continuous
engine with and without self-speculative decoding -- recorded as the
``continuous_speculative`` section.  The verifier is the packed model
over weights whose deep layers' residual contributions are damped,
modeling the trained-model regime where a truncated-layer draft agrees
with the full model most of the time (random init gives a useless ~0%
draft agreement; see the section's ``draft_acceptance_rate`` for what
was actually measured).  The draft is the engine's default 1-layer
truncated self-draft; both runs must emit token-identical greedy
output.

All traces derive from ``--seed`` (default 0), which is recorded in the
JSON -- so cross-PR deltas in BENCH_serving.json compare identical
workloads instead of mixing trace noise with real regressions.

Writes BENCH_serving.json at the repo root so the perf trajectory tracks
both headlines (packed decode speedup_vs_dequant, continuous
speedup_vs_oneshot).

  PYTHONPATH=src python benchmarks/serving_latency.py [--smoke] [--mode M]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

if "--sharded" in sys.argv and "XLA_FLAGS" not in os.environ:
    # the sharded section needs a real multi-device runtime; the flag
    # must land before jax initializes, hence this pre-import peek
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from benchmarks.common import (bench_config, stamp_section,   # noqa: E402
                               staleness_note, train_reference)
from repro.core import deploy                                 # noqa: E402
from repro.core.apply import effective_bits_of, quantize_params  # noqa: E402
from repro.core.pareto import VARIANT_THETA                   # noqa: E402
from repro.core.quantize import HaloConfig                    # noqa: E402
from repro.models import module as M                          # noqa: E402
from repro.models import transformer as T                     # noqa: E402
from repro.serving.engine import Engine                       # noqa: E402
from repro.serving.scheduler import Scheduler                 # noqa: E402

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_serving.json")

# every section key this bench can write; the staleness audit only looks
# at these (other top-level dicts, e.g. ``host``, are not sections)
SECTION_KEYS = ("paths", "continuous", "continuous_prefill_heavy",
                "continuous_paged", "continuous_shared",
                "continuous_speculative", "continuous_multitenant",
                "continuous_sharded", "autotuned", "scorecard")


# ---------------------------------------------------------------------------
# weight-format paths (one-shot loops)
# ---------------------------------------------------------------------------

def _prefill_once(eng: Engine, prompts, max_new: int, legacy: bool):
    """Run exactly the prefill the timed generate path runs (the legacy
    loop prefills unbucketed; the scan path pads to the bucket)."""
    if legacy:
        b, s = prompts["tokens"].shape
        return eng._prefill(eng.params, batch=dict(prompts),
                            max_seq=s + max_new)
    return eng.run_prefill(dict(prompts), max_new)


def _time_generate(eng: Engine, prompts, max_new: int, legacy: bool,
                   repeats: int) -> dict:
    """Prefill and end-to-end decode timings (post-warmup best of N)."""
    b = prompts["tokens"].shape[0]
    mode = "legacy" if legacy else "batch"
    # warmup compiles both stages
    eng.generate(dict(prompts), max_new=max_new, mode=mode)

    pre_ts, dec_ts = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        logits, cache, lengths = _prefill_once(eng, prompts, max_new, legacy)
        jax.block_until_ready(logits)
        pre_ts.append(time.perf_counter() - t0)

        t0 = time.perf_counter()
        toks = eng.generate(dict(prompts), max_new=max_new, mode=mode)
        dec_ts.append(time.perf_counter() - t0)
        assert toks.shape == (b, max_new)

    s = prompts["tokens"].shape[1]
    pre, gen = min(pre_ts), min(dec_ts)
    # generate() times prefill + decode; subtract the separately measured
    # prefill so decode_tokens_per_s tracks the decode stage alone
    dec = max(gen - pre, 1e-9)
    return {
        "loop": "legacy_per_token" if legacy else "jit_scan",
        "prefill_s": pre,
        "prefill_tokens_per_s": b * s / pre,
        "generate_s": gen,
        "decode_s": dec,
        "decode_tokens_per_s": b * max_new / dec,
    }


def run_paths(cfg, params, q, args) -> dict:
    # seed + fixed per-section offset: --seed 0 (the default) reproduces
    # the historical traces exactly, so BENCH_serving.json stays
    # comparable across the PRs that predate seeding
    rng = np.random.default_rng(args.seed + 0)
    prompts = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt))
        .astype(np.int32))}
    paths = {
        "dense": (Engine(params, cfg), False),
        "xla_dequant": (Engine(deploy.deploy_params(q), cfg), True),
        "packed": (Engine(deploy.pack_params(q), cfg), False),
    }
    results = {}
    for name, (eng, legacy) in paths.items():
        print(f"[{name}] warm up + {args.repeats} timed runs ...")
        results[name] = _time_generate(eng, prompts, args.max_new, legacy,
                                       args.repeats)
        print(f"  prefill {results[name]['prefill_tokens_per_s']:8.1f} tok/s"
              f"  decode {results[name]['decode_tokens_per_s']:8.1f} tok/s")
    return results


# ---------------------------------------------------------------------------
# continuous batching vs one-shot padded batch
# ---------------------------------------------------------------------------

def _make_trace(rng, cfg, n: int, prompt_lens, max_new_range,
                mean_gap_s: float) -> list:
    """Poisson-ish synthetic arrivals: exponential gaps, mixed prompt
    lengths (two buckets) and mixed max_new."""
    gaps = rng.exponential(mean_gap_s, n)
    arrivals = np.cumsum(gaps) - gaps[0]        # first request at t=0
    lo, hi = max_new_range
    return [{
        "arrival": float(arrivals[i]),
        "prompt": rng.integers(
            0, cfg.vocab, (1, int(prompt_lens[i % len(prompt_lens)])),
            dtype=np.int64).astype(np.int32),
        "max_new": int(rng.integers(lo, hi + 1)),
    } for i in range(n)]


def _submit_trace(sched: Scheduler, trace, with_arrivals: bool) -> None:
    for r in trace:
        # prompts stay host arrays: the executor ships one window per
        # prefill call (a device-resident prompt would round-trip on
        # every window)
        sched.submit({"tokens": r["prompt"]},
                     prompt_len=r["prompt"].shape[1],
                     max_new=r["max_new"],
                     arrival=r["arrival"] if with_arrivals else 0.0)


def _continuous_once(ex, trace, realtime: bool) -> tuple:
    """Replay the trace through a fresh scheduler over a warm executor.
    ``realtime=False`` ignores arrival times (used for the compile
    warmup); otherwise requests become admissible as the wall clock
    passes their arrival stamps.  Returns (wall, tokens, occupancy,
    peak resident seats)."""
    sched = Scheduler(ex)
    _submit_trace(sched, trace, with_arrivals=realtime)
    peak = 0
    t0 = time.perf_counter()
    while sched.pending:
        now = time.perf_counter() - t0
        if sched.n_active == 0:
            nxt = sched.next_arrival()
            if nxt is not None and nxt > now:
                time.sleep(nxt - now)
                now = nxt
        sched.tick(now)
        peak = max(peak, sched.n_active)
    wall = time.perf_counter() - t0
    n_toks = sum(len(r.tokens) for r in sched.requests.values())
    return wall, n_toks, sched.occupancy(), peak


def _oneshot_once(eng: Engine, trace) -> tuple:
    """The padded-batch baseline: wait for every request to arrive, pad
    all prompts to the longest and decode everyone to the largest
    max_new.  Only the tokens requests actually asked for count."""
    s_max = max(r["prompt"].shape[1] for r in trace)
    batch = np.zeros((len(trace), s_max), np.int32)
    for i, r in enumerate(trace):
        batch[i, :r["prompt"].shape[1]] = r["prompt"][0]
    max_new = max(r["max_new"] for r in trace)
    last_arrival = max(r["arrival"] for r in trace)
    t0 = time.perf_counter()
    toks = eng.generate({"tokens": jnp.asarray(batch)}, max_new=max_new,
                        mode="batch")
    gen = time.perf_counter() - t0
    assert toks.shape == (len(trace), max_new)
    useful = sum(r["max_new"] for r in trace)
    return last_arrival + gen, useful


def _measure_trace(eng: Engine, ex, trace, repeats: int, label: str) -> dict:
    """Shared measurement protocol: warm both paths on the trace, then
    best-of-``repeats`` walls for the one-shot padded-batch baseline and
    the realtime continuous replay (both starting at the first arrival)."""
    total_requested = sum(r["max_new"] for r in trace)
    # warmup: compile every prompt window/bucket, the chunk scan,
    # append/evict, and the baseline's padded batch shapes
    _continuous_once(ex, trace, realtime=False)
    _oneshot_once(eng, trace)

    one_wall, one_tokens = min(
        (_oneshot_once(eng, trace) for _ in range(repeats)),
        key=lambda t: t[0])
    cont = [_continuous_once(ex, trace, realtime=True)
            for _ in range(repeats)]
    cont_wall, cont_tokens, occupancy, _ = min(cont, key=lambda t: t[0])
    assert cont_tokens == total_requested, \
        f"{label}: continuous emitted {cont_tokens}, " \
        f"requested {total_requested}"

    one_tps = one_tokens / one_wall
    cont_tps = cont_tokens / cont_wall
    print(f"  one-shot   {one_wall:6.3f}s  {one_tps:8.1f} tok/s")
    print(f"  continuous {cont_wall:6.3f}s  {cont_tps:8.1f} tok/s  "
          f"(occupancy {occupancy:.2f})  -> {cont_tps / one_tps:.2f}x")
    return {
        "total_new_tokens": total_requested,
        "oneshot": {"wall_s": one_wall, "decode_tokens_per_s": one_tps},
        "continuous": {"wall_s": cont_wall, "decode_tokens_per_s": cont_tps,
                       "slot_occupancy": occupancy},
        "continuous_speedup_vs_oneshot": cont_tps / one_tps,
    }


def run_continuous(cfg, q, args) -> dict:
    # trace derived from --seed (+ section offset; recorded in the report
    # so cross-PR deltas replay the identical workload)
    rng = np.random.default_rng(args.seed + 7)
    if args.smoke:
        n, capacity, chunk = 6, 3, 4
        prompt_lens, max_new_range, mean_gap = (8, 20), (4, 12), 0.02
        prefill_bucket = 16
    else:
        n, capacity, chunk = 16, 8, 8
        prompt_lens, max_new_range, mean_gap = (12, 40), (8, 64), 0.07
        prefill_bucket = 32
    trace = _make_trace(rng, cfg, n, prompt_lens, max_new_range, mean_gap)
    s_cap = max(prompt_lens) + max_new_range[1]

    packed = deploy.pack_params(q)
    eng = Engine(packed, cfg, prefill_bucket=prefill_bucket,
                 decode_bucket=16, capacity=capacity, chunk=chunk)
    ex = eng._executor(capacity=capacity, max_seq=s_cap)

    print(f"[continuous] {n} requests, capacity {capacity}, chunk {chunk}, "
          f"prompts {prompt_lens}, max_new {max_new_range}, "
          f"mean gap {mean_gap * 1e3:.0f}ms")
    report = {
        "seed": args.seed,
        "n_requests": n,
        "capacity": capacity,
        "chunk": chunk,
        "prompt_lens": list(prompt_lens),
        "max_new_range": list(max_new_range),
        "arrival_mean_gap_s": mean_gap,
    }
    report.update(_measure_trace(eng, ex, trace, args.repeats,
                                 "continuous"))
    return report


def run_prefill_heavy(cfg, q, args) -> dict:
    """Long-prompt trace: every prompt spans several prefill windows, so
    admission exercises the chunked PREFILLING phase while resident slots
    decode.  Same measurement protocol as ``run_continuous``."""
    rng = np.random.default_rng(args.seed + 13)
    if args.smoke:
        n, capacity, chunk = 4, 2, 4
        prompt_lens, max_new_range, mean_gap = (40, 72), (4, 8), 0.02
        prefill_bucket, chunk_width, admit_k = 16, 16, 2
    else:
        n, capacity, chunk = 8, 4, 8
        prompt_lens, max_new_range, mean_gap = (96, 160), (8, 16), 0.05
        prefill_bucket, chunk_width, admit_k = 32, 32, 4
    trace = _make_trace(rng, cfg, n, prompt_lens, max_new_range, mean_gap)
    s_cap = max(prompt_lens) + max_new_range[1]

    packed = deploy.pack_params(q)
    eng = Engine(packed, cfg, prefill_bucket=prefill_bucket,
                 decode_bucket=16, capacity=capacity, chunk=chunk,
                 prefill_chunk_width=chunk_width, admit_k=admit_k)
    ex = eng._executor(capacity=capacity, max_seq=s_cap)

    print(f"[prefill-heavy] {n} requests, capacity {capacity}, "
          f"chunk {chunk}, prompts {prompt_lens} "
          f"(window {ex.chunk_width}), max_new {max_new_range}, "
          f"mean gap {mean_gap * 1e3:.0f}ms")
    report = {
        "seed": args.seed,
        "n_requests": n,
        "capacity": capacity,
        "chunk": chunk,
        "prompt_lens": list(prompt_lens),
        "prefill_chunk_width": ex.chunk_width,
        "admit_k": ex.admit_k,
        "max_new_range": list(max_new_range),
        "arrival_mean_gap_s": mean_gap,
        "total_prompt_tokens": sum(r["prompt"].shape[1] for r in trace),
    }
    report.update(_measure_trace(eng, ex, trace, args.repeats,
                                 "prefill-heavy"))
    return report


def run_paged(cfg, q, args) -> dict:
    """Long-context trace: prompts up to near ``max_seq`` mixed with
    short ones, replayed through (a) the contiguous slot layout and (b)
    the block-paged cache at EQUAL KV memory but 2x the slot capacity --
    paged admission reserves ceil((prompt+max_new)/page_size) frames
    from the shared pool instead of a whole ``max_seq`` row, so the
    extra seats are real concurrency, not extra memory.  Completing the
    trace at the doubled seat count is the paged acceptance headline."""
    rng = np.random.default_rng(args.seed + 29)
    if args.smoke:
        n, cap_c, chunk, page_size = 6, 2, 4, 16
        max_seq, prompt_lens, max_new_range = 96, (80, 16, 24, 40), (4, 8)
        prefill_bucket, chunk_width, mean_gap = 16, 32, 0.01
    else:
        n, cap_c, chunk, page_size = 12, 3, 8, 16
        max_seq, prompt_lens, max_new_range = 192, (160, 32, 48, 64), (8, 16)
        prefill_bucket, chunk_width, mean_gap = 32, 64, 0.03
    cap_p = 2 * cap_c
    pool = cap_c * (max_seq // page_size)      # == contiguous KV memory
    trace = _make_trace(rng, cfg, n, prompt_lens, max_new_range, mean_gap)
    for r in trace:                            # cap at the slot cache
        r["max_new"] = min(r["max_new"],
                           max_seq - r["prompt"].shape[1])

    packed = deploy.pack_params(q)
    kw = dict(prefill_bucket=prefill_bucket, decode_bucket=16, chunk=chunk,
              prefill_chunk_width=chunk_width)
    eng_c = Engine(packed, cfg, capacity=cap_c, **kw)
    ex_c = eng_c._executor(capacity=cap_c, max_seq=max_seq)
    eng_p = Engine(packed, cfg, capacity=cap_p, paged=True,
                   page_size=page_size, cache_pages=pool, **kw)
    ex_p = eng_p._executor(capacity=cap_p, max_seq=max_seq)

    print(f"[paged] {n} long-context requests, max_seq {max_seq}, "
          f"prompts {prompt_lens}; contiguous {cap_c} slots vs paged "
          f"{cap_p} slots over {pool} x {page_size}-token pages "
          f"(equal cache memory)")
    total = sum(r["max_new"] for r in trace)
    _continuous_once(ex_c, trace, realtime=False)      # warm compiles
    _continuous_once(ex_p, trace, realtime=False)
    cont = [_continuous_once(ex_c, trace, realtime=True)
            for _ in range(args.repeats)]
    c_wall, c_tokens, c_occ, _ = min(cont, key=lambda t: t[0])
    pag = [_continuous_once(ex_p, trace, realtime=True)
           for _ in range(args.repeats)]
    p_wall, p_tokens, p_occ, _ = min(pag, key=lambda t: t[0])
    assert c_tokens == total and p_tokens == total, \
        f"paged trace dropped tokens: {c_tokens}/{p_tokens}/{total}"
    assert ex_p.allocator.n_free == ex_p.n_pages, "pages leaked"
    c_tps, p_tps = total / c_wall, total / p_wall
    print(f"  contiguous {c_wall:6.3f}s  {c_tps:8.1f} tok/s  "
          f"(occupancy {c_occ:.2f}, {cap_c} slots)")
    print(f"  paged      {p_wall:6.3f}s  {p_tps:8.1f} tok/s  "
          f"(occupancy {p_occ:.2f}, {cap_p} slots)  "
          f"-> {p_tps / c_tps:.2f}x")
    return {
        "seed": args.seed,
        "n_requests": n,
        "max_seq": max_seq,
        "page_size": page_size,
        "n_pages": pool,
        "prompt_lens": list(prompt_lens),
        "max_new_range": list(max_new_range),
        "contiguous_capacity": cap_c,
        "paged_capacity": cap_p,
        "slot_capacity_ratio": cap_p / cap_c,
        "total_new_tokens": total,
        "contiguous": {"wall_s": c_wall, "decode_tokens_per_s": c_tps,
                       "slot_occupancy": c_occ},
        "paged": {"wall_s": p_wall, "decode_tokens_per_s": p_tps,
                  "slot_occupancy": p_occ},
        "paged_speedup_vs_contiguous": p_tps / c_tps,
    }


def run_shared(cfg, q, args) -> dict:
    """Shared-prefix trace: N requests over K system prompts (the
    dominant real-traffic shape), replayed through the paged cache with
    and without ``share_prefix`` at EQUAL capacity and cache memory.
    Sharing maps each repeated system prefix's pages at refcount + 1
    instead of re-reserving and re-prefilling them, so the shared run
    must admit more requests concurrently (a tight pool no longer blocks
    on duplicated prefix pages) and/or stream fewer prefill windows --
    the ``continuous_shared`` acceptance headline."""
    rng = np.random.default_rng(args.seed + 41)
    if args.smoke:
        n, n_sys, capacity, chunk, page_size = 6, 2, 4, 4, 16
        max_seq, sys_len, sfx_hi, max_new_range = 96, 48, 12, (4, 8)
        prefill_bucket, chunk_width, mean_gap = 16, 16, 0.005
    else:
        n, n_sys, capacity, chunk, page_size = 12, 3, 6, 8, 16
        max_seq, sys_len, sfx_hi, max_new_range = 192, 96, 24, (8, 16)
        prefill_bucket, chunk_width, mean_gap = 32, 32, 0.02
    # pool sized for HALF the seats at full length: without sharing the
    # duplicated system prefixes exhaust it and admission blocks; with
    # sharing the prefix pages are charged once
    pool = (capacity // 2) * (max_seq // page_size)
    systems = [rng.integers(0, cfg.vocab, (sys_len,), dtype=np.int64)
               for _ in range(n_sys)]
    gaps = rng.exponential(mean_gap, n)
    arrivals = np.cumsum(gaps) - gaps[0]
    lo, hi = max_new_range
    trace = []
    for i in range(n):
        sfx = rng.integers(0, cfg.vocab, (int(rng.integers(1, sfx_hi + 1)),),
                           dtype=np.int64)
        prompt = np.concatenate([systems[i % n_sys], sfx])
        trace.append({"arrival": float(arrivals[i]),
                      "prompt": prompt.astype(np.int32)[None],
                      "max_new": int(rng.integers(lo, hi + 1))})

    packed = deploy.pack_params(q)
    kw = dict(prefill_bucket=prefill_bucket, decode_bucket=16, chunk=chunk,
              prefill_chunk_width=chunk_width, capacity=capacity,
              paged=True, page_size=page_size, cache_pages=pool)
    eng_p = Engine(packed, cfg, **kw)
    ex_p = eng_p._executor(capacity=capacity, max_seq=max_seq)
    eng_s = Engine(packed, cfg, share_prefix=True, **kw)
    ex_s = eng_s._executor(capacity=capacity, max_seq=max_seq)

    print(f"[shared-prefix] {n} requests over {n_sys} system prompts "
          f"({sys_len} tokens each), {capacity} seats over {pool} x "
          f"{page_size}-token pages (half-capacity pool)")

    total_prompt = sum(r["prompt"].shape[1] for r in trace)

    def measure(ex):
        """One realtime replay plus the sharing headlines, all as
        PER-REPLAY deltas (the executor's counters are cumulative across
        warmup and repeats; deltas are what one trace actually did)."""
        windows0 = ex.append_calls        # monotonic (append_log caps)
        skipped0 = ex.skipped_tokens if ex.share else 0
        forks0 = ex.forks if ex.share else 0
        wall, toks, occ, peak = _continuous_once(ex, trace, realtime=True)
        skipped = (ex.skipped_tokens if ex.share else 0) - skipped0
        return {"wall_s": wall, "tokens": toks,
                "peak_resident": peak,
                "prefill_windows": ex.append_calls - windows0,
                # exact: every prompt token is either appended by a
                # prefill window or skipped via a shared mapping
                "prompt_tokens_appended": total_prompt - skipped,
                "prompt_tokens_skipped": skipped,
                "forks": (ex.forks if ex.share else 0) - forks0,
                "slot_occupancy": occ}

    total = sum(r["max_new"] for r in trace)
    for ex in (ex_p, ex_s):                     # warm compiles + index
        _continuous_once(ex, trace, realtime=False)
    p = min((measure(ex_p) for _ in range(args.repeats)),
            key=lambda r: r["wall_s"])
    s = min((measure(ex_s) for _ in range(args.repeats)),
            key=lambda r: r["wall_s"])
    assert p["tokens"] == total and s["tokens"] == total, \
        f"shared trace dropped tokens: {p['tokens']}/{s['tokens']}/{total}"
    for name, ex in (("paged", ex_p), ("shared", ex_s)):
        live = ex.allocator.n_live
        pins = len(ex.prefix) if ex.share else 0
        assert live == pins, f"{name}: {live} frames leaked ({pins} pins)"
    p_tps, s_tps = total / p["wall_s"], total / s["wall_s"]
    print(f"  paged      {p['wall_s']:6.3f}s  {p_tps:8.1f} tok/s  "
          f"(peak {p['peak_resident']} seats, "
          f"{p['prefill_windows']} prefill windows)")
    print(f"  +share     {s['wall_s']:6.3f}s  {s_tps:8.1f} tok/s  "
          f"(peak {s['peak_resident']} seats, "
          f"{s['prefill_windows']} prefill windows, "
          f"{s['prompt_tokens_skipped']}/{total_prompt} prompt tokens "
          f"skipped)  -> {s_tps / p_tps:.2f}x")
    keys = ("wall_s", "peak_resident", "prefill_windows",
            "prompt_tokens_appended", "prompt_tokens_skipped", "forks",
            "slot_occupancy")
    return {
        "seed": args.seed,
        "n_requests": n,
        "n_system_prompts": n_sys,
        "system_prompt_len": sys_len,
        "max_seq": max_seq,
        "page_size": page_size,
        "n_pages": pool,
        "capacity": capacity,
        "max_new_range": list(max_new_range),
        "total_new_tokens": total,
        "total_prompt_tokens": total_prompt,
        "paged": {k: p[k] for k in keys},
        "shared": {k: s[k] for k in keys},
        "shared_speedup_vs_paged": s_tps / p_tps,
        "shared_admits_more": (s["peak_resident"] > p["peak_resident"]
                               or s["prefill_windows"]
                               < p["prefill_windows"]),
    }


def run_multitenant(cfg, q, args) -> dict:
    """Two-tenant contention trace through the multi-tenant control
    plane: a batch tenant floods the seats at t=0 with long low-priority
    requests, and a latency tenant trickles short high-priority requests
    in while every seat is busy.  The SAME trace replays under (a) the
    default FIFO policy (the latency requests queue behind the flood)
    and (b) priority + preemption (they jump the queue, swapping a
    batch victim's KV pages out to host memory and back).  Recorded as
    the ``continuous_multitenant`` section: per-tenant TTFT p50/p95
    (wall seconds from each request's ARRIVAL to its first token),
    preemption/swap counts, and aggregate tokens/s -- the acceptance
    shape is the latency tenant's TTFT p95 collapsing under priority
    while aggregate throughput stays within a few percent (preemption
    moves work, it doesn't add much)."""
    rng = np.random.default_rng(args.seed + 83)
    if args.smoke:
        capacity, chunk, page_size, max_seq = 2, 4, 16, 64
        n_batch, batch_prompt, batch_new = 4, 16, 32
        n_lat, lat_prompt, lat_new = 4, 8, 4
        lat_start, lat_gap = 0.05, 0.008
        prefill_bucket = 16
    else:
        capacity, chunk, page_size, max_seq = 4, 8, 16, 128
        n_batch, batch_prompt, batch_new = 6, 32, 64
        n_lat, lat_prompt, lat_new = 6, 12, 8
        lat_start, lat_gap = 0.1, 0.1
        prefill_bucket = 32
    trace = [{
        "arrival": 0.0, "tenant": "batch", "priority": 0,
        "prompt": rng.integers(0, cfg.vocab, (1, batch_prompt),
                               dtype=np.int64).astype(np.int32),
        "max_new": batch_new,
    } for _ in range(n_batch)]
    arrivals = lat_start + np.cumsum(rng.exponential(lat_gap, n_lat))
    trace += [{
        "arrival": float(arrivals[i]), "tenant": "lat", "priority": 1,
        "prompt": rng.integers(0, cfg.vocab, (1, lat_prompt),
                               dtype=np.int64).astype(np.int32),
        "max_new": lat_new,
    } for i in range(n_lat)]

    packed = deploy.pack_params(q)
    eng = Engine(packed, cfg, prefill_bucket=prefill_bucket,
                 decode_bucket=16, capacity=capacity, chunk=chunk,
                 paged=True, page_size=page_size)
    ex = eng._executor(capacity=capacity, max_seq=max_seq)

    from repro.serving.scheduler import PriorityAdmission

    def replay(priority: bool) -> dict:
        """Realtime replay of the trace through a fresh scheduler over
        the shared warm executor.  TTFT is measured from each request's
        ARRIVAL stamp (submit_wall is t0 for everyone here), which is
        what a client actually waits."""
        sched = Scheduler(ex, policy=(
            PriorityAdmission(levels=2, preempt=True) if priority
            else None))
        for r in trace:
            sched.submit({"tokens": r["prompt"]},
                         prompt_len=r["prompt"].shape[1],
                         max_new=r["max_new"], arrival=r["arrival"],
                         tenant=r["tenant"],
                         priority=r["priority"] if priority else 0)
        swaps0 = ex.swap_outs
        t0 = time.perf_counter()
        while sched.pending:
            now = time.perf_counter() - t0
            if not sched.n_active and not sched.preempted:
                nxt = sched.next_arrival()
                if nxt is not None and nxt > now:
                    time.sleep(nxt - now)
                    now = nxt
            sched.tick(now)
        wall = time.perf_counter() - t0
        ttft = {"batch": [], "lat": []}
        toks = 0
        for req in sched.requests.values():
            toks += len(req.tokens)
            ttft[req.tenant].append(
                req.first_token_wall - t0 - req.arrival)
        # end state: no live pages, empty host swap pool.  Frames a
        # preempted request vacated stay in the allocator's swapped list
        # (reusable capacity -- alloc drains free first), so conservation
        # is free + swapped == n_pages, not free == n_pages.
        s = ex.allocator.stats()
        assert (s["live"] == 0 and s["free"] + s["swapped"] == s["n_pages"]
                and not ex._swap), \
            f"multitenant replay leaked pages/swap state: {s}"
        return {"wall_s": wall, "tokens": toks,
                "preemptions": sched.preemptions,
                "swap_outs": ex.swap_outs - swaps0,
                "occupancy": sched.occupancy(),
                "ttft": ttft, "pages": s}

    total = sum(r["max_new"] for r in trace)
    print(f"[multi-tenant] {n_batch} batch x {batch_new} tokens at t=0 "
          f"vs {n_lat} latency x {lat_new} tokens arriving mid-run, "
          f"capacity {capacity}, {ex.n_pages} x {page_size}-token pages")
    replay(True)                               # warm compiles (incl. swap)
    replay(False)
    runs_f = [replay(False) for _ in range(args.repeats)]
    runs_p = [replay(True) for _ in range(args.repeats)]
    fifo = min(runs_f, key=lambda r: r["wall_s"])
    prio = min(runs_p, key=lambda r: r["wall_s"])
    assert fifo["tokens"] == total and prio["tokens"] == total, \
        f"multitenant trace dropped tokens: " \
        f"{fifo['tokens']}/{prio['tokens']}/{total}"

    def pct(run):
        return {t: {"ttft_p50_s": float(np.percentile(v, 50)),
                    "ttft_p95_s": float(np.percentile(v, 95)),
                    "n": len(v)}
                for t, v in run["ttft"].items()}

    f_tps, p_tps = total / fifo["wall_s"], total / prio["wall_s"]
    f_pct, p_pct = pct(fifo), pct(prio)
    gain = (f_pct["lat"]["ttft_p95_s"]
            / max(p_pct["lat"]["ttft_p95_s"], 1e-9))
    print(f"  fifo       {fifo['wall_s']:6.3f}s  {f_tps:8.1f} tok/s  "
          f"lat TTFT p95 {f_pct['lat']['ttft_p95_s'] * 1e3:7.1f}ms")
    print(f"  priority   {prio['wall_s']:6.3f}s  {p_tps:8.1f} tok/s  "
          f"lat TTFT p95 {p_pct['lat']['ttft_p95_s'] * 1e3:7.1f}ms  "
          f"({prio['preemptions']} preemptions)  -> {gain:.2f}x faster "
          f"first token")
    return {
        "seed": args.seed,
        "capacity": capacity,
        "chunk": chunk,
        "page_size": page_size,
        "n_pages": ex.n_pages,
        "max_seq": max_seq,
        "batch_tenant": {"n": n_batch, "prompt_len": batch_prompt,
                         "max_new": batch_new},
        "latency_tenant": {"n": n_lat, "prompt_len": lat_prompt,
                           "max_new": lat_new,
                           "arrival_start_s": lat_start,
                           "arrival_mean_gap_s": lat_gap},
        "total_new_tokens": total,
        "fifo": {"wall_s": fifo["wall_s"], "decode_tokens_per_s": f_tps,
                 "slot_occupancy": fifo["occupancy"],
                 "preemptions": fifo["preemptions"],
                 "tenants": f_pct},
        "priority": {"wall_s": prio["wall_s"],
                     "decode_tokens_per_s": p_tps,
                     "slot_occupancy": prio["occupancy"],
                     "preemptions": prio["preemptions"],
                     "swap_outs": prio["swap_outs"],
                     "tenants": p_pct},
        "latency_ttft_p95_speedup_vs_fifo": gain,
        "aggregate_tps_ratio": p_tps / f_tps,
    }


def _damp_deep_layers(params, keep: int, eps: float):
    """Scale the residual-branch output projections (``attn.wo``,
    ``mlp.wo``) of layers >= ``keep`` by ``eps``.

    A randomly initialized model gives a truncated-layer draft nothing
    to agree with (~0% acceptance): every layer's residual update is
    full-magnitude noise, so dropping layers scrambles the argmax.  In
    a trained model the early layers dominate next-token identity and
    deep layers refine -- damping the deep residual outputs reproduces
    that regime synthetically (bench-llama, pattern ``('attn',)``:
    stack index == layer index), giving the 1-layer self-draft a
    realistic ~80% agreement.  Only the *speedup* depends on this;
    correctness never does -- emitted tokens are always the
    verifier's, and the bench asserts spec/plain token equality."""
    out = dict(params)
    new_per = []
    for t in params["period"]:
        n = jax.tree.leaves(t)[0].shape[0]
        sc = np.where(np.arange(n) >= keep, eps, 1.0).astype(np.float32)

        def s(w):
            return w * sc.reshape((n,) + (1,) * (w.ndim - 1))

        t = dict(t)
        t["attn"] = {**t["attn"], "wo": s(t["attn"]["wo"])}
        t["mlp"] = {**t["mlp"], "wo": s(t["mlp"]["wo"])}
        new_per.append(t)
    out["period"] = tuple(new_per)
    return out


def run_speculative(cfg, params, args) -> dict:
    """Single-stream greedy trace (capacity 1) through the continuous
    engine with and without self-speculative decoding, same damped
    packed weights, token-identical outputs asserted.  Capacity 1 is
    the regime speculation targets: batching can't hide decode's
    memory-bound weight stream, so committing several verified tokens
    per tick is the only remaining single-stream latency lever."""
    rng = np.random.default_rng(args.seed + 53)
    if args.smoke:
        n, chunk, k, keep = 3, 4, 3, 1
        prompt_len, max_new, prefill_bucket, eps = 12, 24, 16, 0.05
    else:
        n, chunk, k, keep = 4, 4, 3, 1
        prompt_len, max_new, prefill_bucket, eps = 24, 48, 32, 0.05
    trace = [{
        "arrival": 0.0,
        "prompt": rng.integers(0, cfg.vocab, (1, prompt_len),
                               dtype=np.int64).astype(np.int32),
        "max_new": max_new,
    } for _ in range(n)]
    s_cap = prompt_len + max_new

    damped = _damp_deep_layers(params, keep, eps)
    packed = deploy.pack_params(
        quantize_params(damped, None, HaloConfig(tile=128)))
    kw = dict(prefill_bucket=prefill_bucket, decode_bucket=16,
              capacity=1, chunk=chunk)
    eng_n = Engine(packed, cfg, **kw)
    ex_n = eng_n._executor(capacity=1, max_seq=s_cap)
    eng_s = Engine(packed, cfg, speculative=True, draft_layers=keep,
                   k=k, **kw)
    ex_s = eng_s._executor(capacity=1, max_seq=s_cap)
    assert ex_s.spec, "speculation gated off on a pure-attention config?"

    def replay(ex):
        """Capacity-1 drain of the whole trace; returns the wall,
        per-request tokens, and this replay's spec counter deltas."""
        t0_ticks, t0_slots, t0_toks = (
            (ex.spec_ticks, ex.spec_slots, ex.spec_tokens)
            if getattr(ex, "spec", False) else (0, 0, 0))
        sched = Scheduler(ex)
        _submit_trace(sched, trace, with_arrivals=False)
        t0 = time.perf_counter()
        while sched.pending:
            sched.tick()
        wall = time.perf_counter() - t0
        toks = {rid: list(r.tokens) for rid, r in sched.requests.items()}
        if getattr(ex, "spec", False):
            dticks = ex.spec_ticks - t0_ticks
            dslots = ex.spec_slots - t0_slots
            dtoks = ex.spec_tokens - t0_toks
        else:
            dticks = dslots = dtoks = 0
        return wall, toks, (dticks, dslots, dtoks)

    print(f"[speculative] {n} x {max_new}-token single-stream greedy "
          f"requests, capacity 1, draft_layers {keep}/{cfg.n_layers}, "
          f"k {k} (deep layers damped x{eps})")
    total = n * max_new
    _, toks_n, _ = replay(ex_n)                 # warm compiles + parity
    _, toks_s, _ = replay(ex_s)
    assert toks_n == toks_s, \
        "speculative greedy output diverged from the plain engine"
    n_wall, _, _ = min((replay(ex_n) for _ in range(args.repeats)),
                       key=lambda t: t[0])
    s_wall, _, (dticks, dslots, dtoks) = min(
        (replay(ex_s) for _ in range(args.repeats)), key=lambda t: t[0])
    n_tps, s_tps = total / n_wall, total / s_wall
    accept = (dtoks - dslots) / (dslots * k) if dslots else 0.0
    per_tick = dtoks / dslots if dslots else 0.0
    print(f"  plain      {n_wall:6.3f}s  {n_tps:8.1f} tok/s")
    print(f"  speculative{s_wall:6.3f}s  {s_tps:8.1f} tok/s  "
          f"(acceptance {accept:.2f}, {per_tick:.2f} tok/tick)  "
          f"-> {s_tps / n_tps:.2f}x")
    return {
        "seed": args.seed,
        "n_requests": n,
        "capacity": 1,
        "chunk": chunk,
        "k": k,
        "draft_layers": keep,
        "n_layers": cfg.n_layers,
        "deep_layer_damping": eps,
        "prompt_len": prompt_len,
        "max_new": max_new,
        "total_new_tokens": total,
        "greedy_outputs_identical": True,
        "plain": {"wall_s": n_wall, "decode_tokens_per_s": n_tps},
        "speculative": {"wall_s": s_wall, "decode_tokens_per_s": s_tps,
                        "spec_ticks": dticks,
                        "mean_tokens_per_tick": per_tick,
                        "draft_acceptance_rate": accept},
        "speculative_speedup_vs_plain": s_tps / n_tps,
    }


def run_sharded(cfg, q, args) -> dict:
    """The same continuous trace through the single-device engine and a
    tensor-parallel engine over a (1, N) device mesh, token parity
    asserted request-by-request.  Reports both throughputs, the mesh
    shape, and the collectives GSPMD placed inside ONE decode-chunk jit
    (counted from the compiled HLO) -- all of them run inside the tick's
    single device call, so the host-sync budget is unchanged."""
    from repro.analysis.hlo import collective_stats
    from repro.launch.mesh import make_mesh_compat

    n_dev = jax.device_count()
    if n_dev < 2:
        print("[sharded] skipped: single-device runtime "
              "(set XLA_FLAGS=--xla_force_host_platform_device_count=4)")
        return {"skipped": f"needs >= 2 devices, have {n_dev}"}
    mesh = make_mesh_compat((1, n_dev), ("data", "model"))

    rng = np.random.default_rng(args.seed + 71)
    if args.smoke:
        n, capacity, chunk = 4, 2, 4
        prompt_lens, max_new_range, mean_gap = (8, 20), (4, 10), 0.02
        prefill_bucket = 16
    else:
        n, capacity, chunk = 12, 6, 8
        prompt_lens, max_new_range, mean_gap = (12, 40), (8, 48), 0.05
        prefill_bucket = 32
    trace = _make_trace(rng, cfg, n, prompt_lens, max_new_range, mean_gap)
    s_cap = max(prompt_lens) + max_new_range[1]

    kw = dict(prefill_bucket=prefill_bucket, decode_bucket=16,
              capacity=capacity, chunk=chunk)
    packed = deploy.pack_params(q)
    eng_1 = Engine(packed, cfg, **kw)
    ex_1 = eng_1._executor(capacity=capacity, max_seq=s_cap)
    eng_m = Engine(packed, cfg, mesh=mesh, **kw)
    ex_m = eng_m._executor(capacity=capacity, max_seq=s_cap)

    def replay(ex):
        sched = Scheduler(ex)
        _submit_trace(sched, trace, with_arrivals=False)
        t0 = time.perf_counter()
        while sched.pending:
            sched.tick()
        wall = time.perf_counter() - t0
        toks = {rid: list(r.tokens) for rid, r in sched.requests.items()}
        return wall, toks

    print(f"[sharded] {n} requests, capacity {capacity}, chunk {chunk}, "
          f"mesh {dict(mesh.shape)}")
    _, toks_1 = replay(ex_1)                     # warm compiles + parity
    _, toks_m = replay(ex_m)
    assert toks_1 == toks_m, \
        "sharded serving diverged from the single-device engine"
    total = sum(len(t) for t in toks_1.values())
    w1, _ = min((replay(ex_1) for _ in range(args.repeats)),
                key=lambda t: t[0])
    wm, _ = min((replay(ex_m) for _ in range(args.repeats)),
                key=lambda t: t[0])
    tps_1, tps_m = total / w1, total / wm
    counts = collective_stats(ex_m.decode_hlo()).count_by_op
    per_tick = {op: c for op, c in counts.items() if c}
    print(f"  single-dev {w1:6.3f}s  {tps_1:8.1f} tok/s")
    print(f"  sharded    {wm:6.3f}s  {tps_m:8.1f} tok/s  "
          f"(collectives/tick {per_tick})")
    return {
        "seed": args.seed,
        "n_requests": n,
        "capacity": capacity,
        "chunk": chunk,
        "mesh_shape": dict(mesh.shape),
        "n_devices": n_dev,
        "total_new_tokens": total,
        "tokens_identical": True,
        "single_device": {"wall_s": w1, "decode_tokens_per_s": tps_1},
        "sharded": {"wall_s": wm, "decode_tokens_per_s": tps_m,
                    "decode_chunk_collectives": per_tick},
    }


def run_autotune(cfg, q, args) -> dict:
    """Hardware-in-the-loop autotune of the serving knobs, reported as the
    ``autotuned`` section: the tuner searches the EngineKnobs space (model
    pruned, then measured on its own seeded probe trace through the real
    submit/drain path), persists a versioned TunedConfig artifact, and the
    artifact is then RELOADED and replayed against the default config on
    the standard continuous trace (seed + 7) -- produce and consume, with
    token identity asserted and a never-regress fallback to the default
    knobs if the final trace disagrees with the probe."""
    from repro.serving.autotune import (ProbeSpec, SearchSpace, autotune,
                                        host_info)
    from repro.serving.tuning import EngineKnobs, TunedConfig

    rng = np.random.default_rng(args.seed + 7)   # the standard trace
    if args.smoke:
        n, capacity = 6, 3
        prompt_lens, max_new_range, mean_gap = (8, 20), (4, 12), 0.02
        prefill_bucket = 16
        space, probe, n_probe = SearchSpace.smoke(), ProbeSpec.smoke(), 3
    else:
        n, capacity = 16, 8
        prompt_lens, max_new_range, mean_gap = (12, 40), (8, 64), 0.07
        prefill_bucket = 32
        space, probe, n_probe = SearchSpace(), ProbeSpec(), 4
    trace = _make_trace(rng, cfg, n, prompt_lens, max_new_range, mean_gap)
    s_cap = max(prompt_lens) + max_new_range[1]

    packed = deploy.pack_params(q)
    print(f"[autotune] searching the knob space (capacity {capacity}, "
          f"probe seed {probe.seed}) ...")
    tc = autotune(packed, cfg, capacity=capacity, max_seq=s_cap,
                  prefill_bucket=prefill_bucket, space=space, probe=probe,
                  n_probe=n_probe, verbose=True)
    # the probe-trace guarantee the tuner enforces by construction
    assert tc.probe["speedup_vs_default"] >= 1.0, \
        "autotuner returned a config slower than defaults on its probe"
    path = tc.save(args.tuned_out)
    print(f"[autotune] winner {tc.probe['winner']} "
          f"({tc.probe['speedup_vs_default']:.2f}x on the probe) "
          f"-> {os.path.abspath(path)}")

    # consume the artifact: reload from disk and serve the standard trace
    tc2 = TunedConfig.load(path)
    assert tc2.knobs == tc.knobs, "TunedConfig did not round-trip"
    eng_d = Engine(packed, cfg, prefill_bucket=prefill_bucket,
                   decode_bucket=16, capacity=capacity, max_seq=s_cap)
    eng_t = Engine.from_tuned(packed, cfg, path, decode_bucket=16)

    def replay(eng):
        t0 = time.perf_counter()
        rids = [eng.submit({"tokens": r["prompt"][0]},
                           max_new=r["max_new"]) for r in trace]
        # fresh_only: drain()'s default result is cumulative, so a repeat
        # loop that ever skipped pop_finished() would silently re-count
        # earlier replays' tokens here
        done = eng.drain(fresh_only=True)
        wall = time.perf_counter() - t0
        toks = [np.asarray(done[r]).tolist() for r in rids]
        eng.pop_finished()
        return wall, toks

    _, toks_d = replay(eng_d)                   # warm compiles + parity
    _, toks_t = replay(eng_t)
    assert toks_d == toks_t, \
        "autotuned engine diverged from the default-config engine"
    w_d, _ = min((replay(eng_d) for _ in range(args.repeats)),
                 key=lambda t: t[0])
    w_t, _ = min((replay(eng_t) for _ in range(args.repeats)),
                 key=lambda t: t[0])
    total = sum(len(t) for t in toks_d)
    d_tps, t_tps = total / w_d, total / w_t

    # never-regress guard: measured noise on the final trace cannot make
    # the shipped config slower than defaults -- fall back and re-save
    fallback = t_tps < d_tps
    if fallback:
        print("[autotune] tuned config slower on the final trace; "
              "falling back to the default knobs")
        tc.knobs = EngineKnobs()
        tc.probe["final_trace_fallback"] = True
        tc.save(path)
        w_t, t_tps = w_d, d_tps
    assert t_tps >= d_tps

    print(f"  default    {w_d:6.3f}s  {d_tps:8.1f} tok/s")
    print(f"  autotuned  {w_t:6.3f}s  {t_tps:8.1f} tok/s  "
          f"-> {t_tps / d_tps:.2f}x  "
          f"(modeled {tc.dvfs['totals']['mean_freq_headroom']:.2f}x clock "
          f"headroom, {tc.dvfs['totals']['dvfs_transitions']} DVFS "
          f"transitions)")
    dv = tc.dvfs
    return {
        "seed": args.seed,
        "n_requests": n,
        "capacity": capacity,
        "prompt_lens": list(prompt_lens),
        "max_new_range": list(max_new_range),
        "tuned_config_path": os.path.relpath(
            path, os.path.join(os.path.dirname(__file__), "..")),
        "tuned_config_version": tc.version,
        "knobs": tc.knobs.to_dict(),
        "fallback_to_default": fallback,
        "tokens_identical": True,
        "total_new_tokens": total,
        "default": {"wall_s": w_d, "decode_tokens_per_s": d_tps},
        "autotuned": {"wall_s": w_t, "decode_tokens_per_s": t_tps},
        "autotuned_speedup_vs_default": t_tps / d_tps,
        "probe": {k: tc.probe[k] for k in
                  ("protocol", "trace", "n_candidates", "n_measured",
                   "winner", "default", "measured_tokens_per_s",
                   "speedup_vs_default", "class_counts")},
        "dvfs": {
            "domain": dv["domain"],
            "nominal_freq_ghz": dv["nominal_freq_ghz"],
            "totals": dv["totals"],
            "layers": [{
                "layer": l["layer"],
                "n_tiles": l["n_tiles"],
                "counts": l["counts"],
                "dvfs_transitions": l["dvfs_transitions"],
                "achievable_freq_ghz": l.get("achievable_freq_ghz"),
                "freq_headroom": l.get("freq_headroom"),
                "modeled_energy_j_per_token":
                    l.get("modeled_energy_j_per_token"),
            } for l in dv["layers"]],
        },
    }


# ---------------------------------------------------------------------------
# accuracy + perf scorecard (src/repro/eval) with optional drift gate
# ---------------------------------------------------------------------------

def run_scorecard_section(args) -> dict:
    """Quality-next-to-throughput through the REAL serving path: train
    (or reload) the reference llama, quantize two HALO operating points,
    and measure PPL / tiny-MMLU accuracy / tokens/s per (variant,
    engine-mode) via ``Engine.score`` -- see src/repro/eval/.  Persists
    the versioned Scorecard artifact; with ``--scorecard-gate`` compares
    it against the committed baseline and records violations (main()
    exits non-zero on any)."""
    from repro.eval import (EvalProtocol, Scorecard, run_scorecard)
    from repro.eval.harness import Variant

    steps = 120 if args.smoke else 400
    if args.smoke:
        protocol = EvalProtocol(
            ppl_seq_len=32, n_ppl_sequences=2, mc_question_len=16,
            mc_option_len=4, n_mc_items=6, tps_requests=3,
            tps_prompt_len=12, tps_max_new=8, tps_repeats=2)
        modes = ("contiguous", "paged")
    else:
        protocol = EvalProtocol()
        modes = ("contiguous", "paged", "paged_share", "spec")

    print(f"[scorecard] training/loading reference llama ({steps} steps)")
    cfg, params = train_reference("llama", steps=steps)

    variants = [Variant("dense", params)]
    for vname in ("perf-opt", "acc-opt"):
        theta = VARIANT_THETA[vname]
        print(f"[scorecard] quantizing halo-{vname} (theta={theta}) ...")
        q = quantize_params(params, None, HaloConfig(tile=128), theta=theta)
        variants.append(Variant(f"halo-{vname}", deploy.pack_params(q),
                                effective_bits=effective_bits_of(q),
                                quantized=True))

    card = run_scorecard(variants, cfg, modes=modes, protocol=protocol,
                         model=cfg.name, backend=jax.default_backend(),
                         oracle_params=params,
                         progress=lambda s: print(f"[scorecard] {s}"))
    card.save(args.scorecard_out)
    print(f"[scorecard] artifact -> {os.path.abspath(args.scorecard_out)}")

    gate, violations = "not-armed", []
    if args.scorecard_gate:
        if not os.path.exists(args.scorecard_baseline):
            gate = "fail"
            violations = [f"no committed baseline at "
                          f"{args.scorecard_baseline}: generate one with "
                          f"--scorecard (no gate) and commit it"]
        else:
            baseline = Scorecard.load(args.scorecard_baseline)
            violations = card.compare(baseline)
            gate = "fail" if violations else "pass"
        for v in violations:
            print(f"[scorecard] DRIFT: {v}")
        if gate == "pass":
            print(f"[scorecard] drift gate PASS vs "
                  f"{args.scorecard_baseline}")

    return {
        "train_steps": steps,
        "protocol": protocol.asdict(),
        "modes": list(modes),
        "artifact": os.path.relpath(
            args.scorecard_out, os.path.join(os.path.dirname(__file__),
                                             "..")),
        "gate": gate,
        "violations": violations,
        "entries": [{
            "variant": e.variant, "engine_mode": e.engine_mode,
            "ppl": e.ppl, "mc_accuracy": e.mc_accuracy,
            "effective_bits": e.effective_bits, "packed": e.packed,
            "n_packed_leaves": e.n_packed_leaves,
            "tokens_per_s": e.tokens_per_s,
            "oracle_ppl_rel_err": e.oracle_ppl_rel_err,
            "note": e.note,
        } for e in card.entries],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--mode",
                    choices=("all", "paths", "continuous", "autotune",
                             "scorecard"),
                    default="all")
    ap.add_argument("--autotune", action="store_true",
                    help="also run the hardware-in-the-loop autotuner "
                         "(model-pruned knob search measured on a seeded "
                         "probe trace), persist the TunedConfig artifact, "
                         "and replay the standard continuous trace "
                         "default-vs-tuned -> autotuned section")
    ap.add_argument("--tuned-out",
                    default=os.path.join(os.path.dirname(__file__), "..",
                                         "experiments",
                                         "tuned_serving.json"),
                    help="path for the versioned TunedConfig artifact "
                         "written by --autotune / --mode autotune")
    ap.add_argument("--prefill-heavy", action="store_true",
                    help="also replay the long-prompt (chunked-prefill) "
                         "trace -> continuous_prefill_heavy section")
    ap.add_argument("--paged", action="store_true",
                    help="also replay the long-context trace through the "
                         "block-paged cache at 2x slot capacity / equal "
                         "memory -> continuous_paged section")
    ap.add_argument("--share-prefix", action="store_true",
                    help="also replay a K-system-prompt trace through the "
                         "paged cache with copy-on-write prefix sharing "
                         "on a half-capacity pool -> continuous_shared "
                         "section")
    ap.add_argument("--speculative", action="store_true",
                    help="also replay a capacity-1 greedy single-stream "
                         "trace with and without self-speculative "
                         "decoding (damped deep layers) -> "
                         "continuous_speculative section")
    ap.add_argument("--multi-tenant", action="store_true",
                    help="also replay a two-tenant contention trace "
                         "(batch flood vs latency trickle) under FIFO "
                         "and under priority + preemption -> "
                         "continuous_multitenant section (per-tenant "
                         "TTFT p50/p95, preemption count, tokens/s)")
    ap.add_argument("--sharded", action="store_true",
                    help="also replay the continuous trace through a "
                         "tensor-parallel engine on a (1, N) device mesh "
                         "(forces a 4-device host-CPU runtime when no "
                         "XLA_FLAGS are set) -> continuous_sharded "
                         "section")
    ap.add_argument("--scorecard", action="store_true",
                    help="also run the serving-path accuracy + perf "
                         "scorecard (PPL / tiny-MMLU accuracy / tokens/s "
                         "for dense vs HALO variants through "
                         "Engine.submit/step/drain on multiple engine "
                         "modes) -> scorecard section + versioned "
                         "artifact")
    ap.add_argument("--scorecard-out",
                    default=os.path.join(os.path.dirname(__file__), "..",
                                         "experiments", "scorecard.json"),
                    help="path for the Scorecard artifact")
    ap.add_argument("--scorecard-baseline",
                    default=os.path.join(os.path.dirname(__file__), "..",
                                         "experiments",
                                         "scorecard_baseline.json"),
                    help="committed baseline the drift gate compares "
                         "against")
    ap.add_argument("--scorecard-gate", action="store_true",
                    help="arm the quality-drift gate: exit non-zero if "
                         "PPL / accuracy drift beyond the baseline's "
                         "stored tolerances")
    ap.add_argument("--seed", type=int, default=0,
                    help="root seed for every synthetic trace (recorded "
                         "in the JSON so cross-PR deltas replay the same "
                         "workload)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (fast compile)")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    if args.smoke:
        args.batch, args.prompt, args.max_new, args.repeats = 2, 16, 16, 2

    cfg = bench_config("llama")
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(0))
    print(f"quantizing {cfg.name} (tile=128) ...")
    q = quantize_params(params, None, HaloConfig(tile=128))

    # start from the previous report so one --mode run doesn't drop the
    # other section's numbers
    report = {}
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                report = json.load(f)
        except (OSError, ValueError):
            report = {}
    from repro.serving.autotune import host_info
    report.update({
        "bench": "serving_latency",
        "config": cfg.name,
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "host": host_info(),
        "batch": args.batch,
        "prompt_len": args.prompt,
        "max_new": args.max_new,
        "seed": args.seed,
    })

    if args.mode in ("all", "paths"):
        results = run_paths(cfg, params, q, args)
        speedup = (results["packed"]["decode_tokens_per_s"]
                   / results["xla_dequant"]["decode_tokens_per_s"])
        report["paths"] = stamp_section(results)
        report["packed_decode_speedup_vs_dequant"] = speedup
        print(f"packed decode speedup vs XLA-dequant: {speedup:.2f}x")

    if args.mode in ("all", "continuous"):
        report["continuous"] = stamp_section(run_continuous(cfg, q, args))
        if args.prefill_heavy:
            report["continuous_prefill_heavy"] = stamp_section(
                run_prefill_heavy(cfg, q, args))
        if args.paged:
            report["continuous_paged"] = stamp_section(
                run_paged(cfg, q, args))
        if args.share_prefix:
            report["continuous_shared"] = stamp_section(
                run_shared(cfg, q, args))
        if args.speculative:
            report["continuous_speculative"] = stamp_section(
                run_speculative(cfg, params, args))
        if args.multi_tenant:
            report["continuous_multitenant"] = stamp_section(
                run_multitenant(cfg, q, args))
        if args.sharded:
            report["continuous_sharded"] = stamp_section(
                run_sharded(cfg, q, args))

    if args.mode == "autotune" or (args.autotune
                                   and args.mode in ("all", "continuous")):
        report["autotuned"] = stamp_section(run_autotune(cfg, q, args))

    if args.mode == "scorecard" or (args.scorecard
                                    and args.mode in ("all", "continuous")):
        report["scorecard"] = stamp_section(run_scorecard_section(args))

    # staleness audit: the merge above deliberately preserves sections a
    # partial --mode run didn't refresh, so a report can mix commits --
    # record that loudly instead of letting stale numbers pass as current
    note = staleness_note(report, keys=SECTION_KEYS)
    report["staleness"] = note
    if note:
        print(f"WARNING: {note}")

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"-> {os.path.abspath(args.out)}")

    sc = report.get("scorecard", {})
    if args.scorecard_gate and sc.get("gate") == "fail":
        print("[scorecard] drift gate FAILED")
        sys.exit(2)


if __name__ == "__main__":
    main()
