"""Serving latency: dense vs XLA-dequant vs packed-kernel fast path.

Measures prefill and decode tokens/s on the bench-llama config for the
three weight formats the engine serves:

  dense        fp32 weights, scan decode loop
  xla_dequant  DeployQuantWeight, legacy per-token loop with per-call XLA
               dequantization -- the pre-fast-path serving behavior
  packed       HaloPacked via core.deploy.pack_params: pack-at-load,
               jitted lax.scan decode, halo_matmul/SpMV kernels (Pallas on
               TPU; interpret on this CPU container), single host sync

Writes BENCH_serving.json at the repo root so the perf trajectory tracks
the packed-path speedup (decode speedup_vs_dequant is the headline).

  PYTHONPATH=src python benchmarks/serving_latency.py [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from benchmarks.common import bench_config                    # noqa: E402
from repro.core import deploy                                 # noqa: E402
from repro.core.apply import quantize_params                  # noqa: E402
from repro.core.quantize import HaloConfig                    # noqa: E402
from repro.models import module as M                          # noqa: E402
from repro.models import transformer as T                     # noqa: E402
from repro.serving.engine import Engine                       # noqa: E402

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_serving.json")


def _prefill_once(eng: Engine, prompts, max_new: int, legacy: bool):
    """Run exactly the prefill the timed generate path runs (the legacy
    loop prefills unbucketed; the scan path pads to the bucket)."""
    if legacy:
        b, s = prompts["tokens"].shape
        return eng._prefill(eng.params, batch=dict(prompts),
                            max_seq=s + max_new)
    return eng.run_prefill(dict(prompts), max_new)


def _time_generate(eng: Engine, prompts, max_new: int, legacy: bool,
                   repeats: int) -> dict:
    """Prefill and end-to-end decode timings (post-warmup best of N)."""
    b = prompts["tokens"].shape[0]
    # warmup compiles both stages
    eng.generate(dict(prompts), max_new=max_new, legacy_loop=legacy)

    pre_ts, dec_ts = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        logits, cache, lengths = _prefill_once(eng, prompts, max_new, legacy)
        jax.block_until_ready(logits)
        pre_ts.append(time.perf_counter() - t0)

        t0 = time.perf_counter()
        toks = eng.generate(dict(prompts), max_new=max_new,
                            legacy_loop=legacy)
        dec_ts.append(time.perf_counter() - t0)
        assert toks.shape == (b, max_new)

    s = prompts["tokens"].shape[1]
    pre, gen = min(pre_ts), min(dec_ts)
    # generate() times prefill + decode; subtract the separately measured
    # prefill so decode_tokens_per_s tracks the decode stage alone
    dec = max(gen - pre, 1e-9)
    return {
        "loop": "legacy_per_token" if legacy else "jit_scan",
        "prefill_s": pre,
        "prefill_tokens_per_s": b * s / pre,
        "generate_s": gen,
        "decode_s": dec,
        "decode_tokens_per_s": b * max_new / dec,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (fast compile)")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    if args.smoke:
        args.batch, args.prompt, args.max_new, args.repeats = 2, 16, 16, 2

    cfg = bench_config("llama")
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(0))
    print(f"quantizing {cfg.name} (tile=128) ...")
    q = quantize_params(params, None, HaloConfig(tile=128))

    rng = np.random.default_rng(0)
    prompts = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt))
        .astype(np.int32))}

    paths = {
        "dense": (Engine(params, cfg), False),
        "xla_dequant": (Engine(deploy.deploy_params(q), cfg), True),
        "packed": (Engine(deploy.pack_params(q), cfg), False),
    }
    results = {}
    for name, (eng, legacy) in paths.items():
        print(f"[{name}] warm up + {args.repeats} timed runs ...")
        results[name] = _time_generate(eng, prompts, args.max_new, legacy,
                                       args.repeats)
        print(f"  prefill {results[name]['prefill_tokens_per_s']:8.1f} tok/s"
              f"  decode {results[name]['decode_tokens_per_s']:8.1f} tok/s")

    speedup = (results["packed"]["decode_tokens_per_s"]
               / results["xla_dequant"]["decode_tokens_per_s"])
    report = {
        "bench": "serving_latency",
        "config": cfg.name,
        "backend": jax.default_backend(),
        "batch": args.batch,
        "prompt_len": args.prompt,
        "max_new": args.max_new,
        "paths": results,
        "packed_decode_speedup_vs_dequant": speedup,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"packed decode speedup vs XLA-dequant: {speedup:.2f}x "
          f"-> {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
