"""Figs. 8-11 analogues: systolic-array execution time & energy for the
paper's own model dims under FP16 / W8A8 / W4A8 / W3A8 / HALO variants,
plus the tile-size sweep.  Class mixes come from actually quantizing the
reference model at each variant's theta (not assumed)."""

from __future__ import annotations

from typing import Dict, List

from repro.core.apply import quantize_params
from repro.core.pareto import VARIANT_THETA
from repro.core.quantize import HaloConfig
from repro.hw import systolic as sy

from . import common

PAPER_DIMS = {
    "llama2-7b": dict(d_model=4096, d_ff=11008, n_layers=32, vocab=32000),
    "llama2-13b": dict(d_model=5120, d_ff=13824, n_layers=40, vocab=32000),
    "opt-1.3b": dict(d_model=2048, d_ff=8192, n_layers=24, vocab=50272,
                     gated=False),
    "opt-30b": dict(d_model=7168, d_ff=28672, n_layers=48, vocab=50272,
                    gated=False),
}


def measured_class_mixes(steps: int = 400) -> Dict[str, tuple]:
    cfg, params = common.train_reference("llama", steps=steps)
    fisher, _ = common.collect_calibration(params, cfg, with_gram=False)
    mixes = {}
    for variant, theta in VARIANT_THETA.items():
        q = quantize_params(params, fisher, HaloConfig(tile=64), theta=theta)
        mixes[variant] = common.class_mix_from_quantized(q)
    return mixes


def run(seq: int = 2048, steps: int = 400) -> List[dict]:
    mixes = measured_class_mixes(steps)
    rows = []
    for model, dims in PAPER_DIMS.items():
        shapes = sy.decoder_layer_shapes(seq=seq, batch=1, **dims)
        base = {n: sy.simulate_layers(shapes, sy.baseline_scheme(n))
                for n in ("fp16", "w8a8", "w4a8", "w3a8")}
        res = dict(base)
        for variant, (f3, f2) in mixes.items():
            res[f"halo-{variant}"] = sy.simulate_layers(
                shapes, sy.halo_scheme(f3, f2, name=f"halo-{variant}"))
        ref = base["fp16"]
        for name, r in res.items():
            rows.append({
                "model": model, "scheme": name,
                "time_ms": r.time_s * 1e3,
                "norm_time": r.time_s / ref.time_s,
                "energy_j": r.energy_j,
                "norm_energy": r.energy_j / ref.energy_j,
                "dvfs_transitions": r.dvfs_transitions,
                "spmv_frac": r.spmv_time_s / r.time_s,
            })
    return rows


def tile_sweep(seq: int = 2048, steps: int = 400) -> List[dict]:
    """Fig. 11: HALO-128 / 64 / 32 execution time (bal variant)."""
    cfg, params = common.train_reference("llama", steps=steps)
    fisher, _ = common.collect_calibration(params, cfg, with_gram=False)
    rows = []
    for tile in (128, 64, 32):
        q = quantize_params(params, fisher, HaloConfig(tile=tile),
                            theta=VARIANT_THETA["bal"])
        f3, f2 = common.class_mix_from_quantized(q)
        dims = PAPER_DIMS["llama2-7b"]
        shapes = sy.decoder_layer_shapes(seq=seq, batch=1, **dims)
        # the physical array stays 128x128 (the MXU); the HALO tile size
        # only changes the DVFS-class granularity -> the class mix
        r = sy.simulate_layers(shapes, sy.halo_scheme(f3, f2), tile=128)
        rows.append({"tile": tile, "f3_frac": f3, "time_ms": r.time_s * 1e3,
                     "energy_j": r.energy_j})
    return rows


def main():
    print("systolic perf/energy (Figs. 8, 10) -- normalized to FP16")
    print("name,us_per_call,derived")
    for r in run():
        print(f"systolic/{r['model']}/{r['scheme']},"
              f"{r['time_ms']*1e3:.1f},"
              f"norm_time={r['norm_time']:.4f};"
              f"norm_energy={r['norm_energy']:.4f};"
              f"dvfs={r['dvfs_transitions']};"
              f"spmv={r['spmv_frac']:.4f}")
    print("\ntile sweep (Fig. 11)")
    for r in tile_sweep():
        print(f"tile_sweep/halo-{r['tile']},{r['time_ms']*1e3:.1f},"
              f"f3_frac={r['f3_frac']:.3f};energy_j={r['energy_j']:.3f}")


if __name__ == "__main__":
    main()
