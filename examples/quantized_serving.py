"""End-to-end quantized serving: train a small LM, HALO-quantize, pack to
the 4-bit deployment format (core.deploy.pack_params) and serve batched
requests through the engine's device-resident decode loop with int8 KV
caches -- the paper's deployment scenario in miniature.  See
docs/serving.md for the pack-at-load flow and the two serving paths.

  PYTHONPATH=src python examples/quantized_serving.py
"""

import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks import common  # noqa: E402
from repro.core.apply import quantize_params  # noqa: E402
from repro.core.deploy import pack_params  # noqa: E402
from repro.core.quantize import HaloConfig  # noqa: E402
from repro.serving.engine import Engine, SamplerConfig  # noqa: E402


def main():
    print("=== train + calibrate + quantize (bal) ===")
    cfg, params = common.train_reference("llama", steps=300)
    fisher, _ = common.collect_calibration(params, cfg, with_gram=False)
    qparams = quantize_params(params, fisher, HaloConfig(tile=128),
                              theta=0.95)
    served = pack_params(qparams)     # 4-bit kernel-ready tree, pack once

    print("=== serve batched requests (greedy + int8 KV) ===")
    cfg_srv = dataclasses.replace(cfg, kv_cache_dtype="int8")
    rng = np.random.default_rng(0)
    prompts = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (4, 24)).astype(np.int32))}
    eng_fp = Engine(params, cfg)
    eng_q = Engine(served, cfg_srv, SamplerConfig(temperature=0.0))
    out_fp = eng_fp.generate(dict(prompts), max_new=16)
    out_q = eng_q.generate(dict(prompts), max_new=16)
    agree = float((out_fp == out_q).mean())
    print(f"generated {out_q.shape} tokens; greedy agreement with fp32 "
          f"reference: {agree:.0%}")
    print("sample (quantized):", out_q[0].tolist())


if __name__ == "__main__":
    main()
