"""Quickstart: train a tiny LM, HALO-quantize it, compare against baselines,
and report the simulated systolic-array deployment win.

  PYTHONPATH=src python examples/quickstart.py [--steps 300]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402

from benchmarks import common  # noqa: E402
from repro.core.apply import dequantize_params, quantize_params  # noqa: E402
from repro.core.pareto import VARIANT_THETA  # noqa: E402
from repro.core.quantize import HaloConfig  # noqa: E402
from repro.core.schedule import schedule_model  # noqa: E402
from repro.core.apply import StackedHalo  # noqa: E402
from repro.core.quantize import HaloQuantized  # noqa: E402
from repro.hw import systolic as sy  # noqa: E402
from repro.quant import rtn  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    print("=== 1. train a small reference LM on the synthetic corpus ===")
    cfg, params = common.train_reference("llama", steps=args.steps)
    fp_ppl = common.eval_ppl(params, cfg)
    print(f"fp32 perplexity: {fp_ppl:.3f}")

    print("\n=== 2. calibrate (diagonal Fisher over 4 batches) ===")
    fisher, act_stats = common.collect_calibration(params, cfg,
                                                   with_gram=False)

    print("\n=== 3. HALO quantization (Algorithm 1) at the three goals ===")
    results = {}
    for variant, theta in VARIANT_THETA.items():
        q = quantize_params(params, fisher, HaloConfig(tile=64), theta=theta)
        ppl = common.eval_ppl(dequantize_params(q), cfg, act_bits=8)
        f3, f2 = common.class_mix_from_quantized(q)
        results[variant] = (q, ppl, f3)
        print(f"halo-{variant:9s} ppl={ppl:8.3f} (d{ppl - fp_ppl:+.3f})  "
              f"f3-tiles={f3:5.1%}")

    ppl_rtn4 = common.eval_ppl(rtn.rtn_quantize_params(params, 4), cfg,
                               act_bits=8)
    ppl_rtn3 = common.eval_ppl(rtn.rtn_quantize_params(params, 3), cfg,
                               act_bits=8)
    print(f"rtn-w4a8       ppl={ppl_rtn4:8.3f} (d{ppl_rtn4 - fp_ppl:+.3f})")
    print(f"rtn-w3a8       ppl={ppl_rtn3:8.3f} (d{ppl_rtn3 - fp_ppl:+.3f})")

    print("\n=== 4. DVFS schedule for the bal model ===")
    q_bal = results["bal"][0]
    quantized_tensors = {}
    i = 0
    for leaf in jax.tree.leaves(
            q_bal, is_leaf=lambda x: isinstance(x, (HaloQuantized,
                                                    StackedHalo))):
        if isinstance(leaf, HaloQuantized):
            quantized_tensors[f"t{i}"] = leaf
            i += 1
        elif isinstance(leaf, StackedHalo):
            for s in leaf.slices:
                quantized_tensors[f"t{i}"] = s
                i += 1
    sched = schedule_model(quantized_tensors)
    print(f"DVFS transitions per inference: {sched['num_transitions']}  "
          f"(overhead {sched['transition_overhead_s']*1e6:.1f} us)")
    print(f"class mix: F3 {sched['f3_fraction']:.1%} / "
          f"F2 {sched['f2_fraction']:.1%}")

    print("\n=== 5. simulated systolic-array deployment (paper Fig. 8) ===")
    shapes = sy.decoder_layer_shapes(4096, 11008, 32, 32000, seq=2048)
    base = sy.simulate_layers(shapes, sy.baseline_scheme("w8a8"))
    halo = sy.simulate_layers(
        shapes, sy.halo_scheme(sched["f3_fraction"], sched["f2_fraction"]))
    print(f"LLaMA2-7B-dims speedup vs W8A8: "
          f"{base.time_s / halo.time_s:.2f}x; "
          f"energy ratio {halo.energy_j / base.energy_j:.2f}")


if __name__ == "__main__":
    main()
