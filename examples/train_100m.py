"""End-to-end training driver: a ~100M-parameter granite-family model with
the full production stack -- grad accumulation, AdamW, warmup-cosine,
async checkpointing, auto-resume, straggler watchdog.

Full size  : PYTHONPATH=src python examples/train_100m.py --full --steps 300
CPU-scaled : PYTHONPATH=src python examples/train_100m.py --steps 100
             (a ~6M model so the example completes in minutes on CPU; the
              training code path is identical)
"""

import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ModelConfig  # noqa: E402
from repro.data.synthetic import CorpusConfig, SyntheticCorpus  # noqa: E402
from repro.launch.train import TrainConfig, train_loop  # noqa: E402
from repro.models import module as M  # noqa: E402
from repro.models import transformer as T  # noqa: E402


def config(full: bool) -> ModelConfig:
    if full:  # ~110M params
        return ModelConfig(
            name="granite-100m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32768,
            activation="silu", gated_mlp=True, dtype=jnp.float32,
            attn_chunk=256, vocab_pad_multiple=128)
    return ModelConfig(
        name="granite-6m", family="dense", n_layers=4, d_model=256,
        n_heads=4, n_kv_heads=2, head_dim=64, d_ff=768, vocab=4096,
        activation="silu", gated_mlp=True, dtype=jnp.float32,
        attn_chunk=128, vocab_pad_multiple=128)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grad-accum", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    cfg = config(args.full)
    n = M.param_count(T.model_specs(cfg))
    print(f"model: {cfg.name} ({n/1e6:.1f}M params)")

    tcfg = TrainConfig(
        peak_lr=6e-4, warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps, grad_accum=args.grad_accum,
        ckpt_every=max(args.steps // 5, 1), ckpt_dir=args.ckpt_dir)
    corpus = SyntheticCorpus(CorpusConfig(
        vocab=cfg.vocab, seq_len=args.seq, batch=args.batch))
    print(f"corpus entropy floor ppl: {corpus.floor_perplexity():.2f}")

    hist = train_loop(cfg, tcfg, corpus, log_every=10)
    first, last = hist["loss"][0][1], hist["loss"][-1][1]
    print(f"\nloss {first:.4f} -> {last:.4f} over {args.steps} steps "
          f"({hist['restarts']} restarts, "
          f"{len(hist['straggler_flags'])} straggler flags)")
    print(f"checkpoints in {args.ckpt_dir} (resumable: rerun to continue)")


if __name__ == "__main__":
    main()
