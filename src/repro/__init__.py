"""repro: HALO (AAAI'26) -- hardware-aware PTQ with low critical-path-delay
weights, built as a multi-pod JAX/TPU training & serving framework.

Subpackages: hw (MAC/DVFS models, simulators), core (Algorithm 1 + deploy),
quant (baselines), models, kernels (Pallas), data, optim, checkpoint, dist,
serving, configs, launch, analysis.  See DESIGN.md / EXPERIMENTS.md.
"""
