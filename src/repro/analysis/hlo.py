"""HLO text analysis: loop-aware collective-communication byte accounting.

``cost_analysis()`` does not report collective bytes, so we parse the
compiled (post-SPMD) HLO and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

Collectives inside ``while`` bodies (layer scans, grad-accum microbatch
loops) appear once in the text but execute ``known_trip_count`` times, so we
build the computation call graph -- ENTRY -> while bodies (x trip count) ->
nested calls -- and weight each computation's collective bytes by its total
execution multiplier.  XLA's CPU/TPU pipelines annotate compiled while ops
with ``backend_config={"known_trip_count":{"n":...}}``; unknown trip counts
conservatively default to 1 (and are reported so the roofline can flag it).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# named scopes whose instruction pipelines live in Pallas-kernel VMEM
_VMEM_SCOPES = ("flash_vmem", "halo_vmem", "kvdec_vmem")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_COLL_RE = re.compile(
    r"%[\w\.\-]+\s*=\s*(\(?[^=]+?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_WHILE_RE = re.compile(
    r"while\(.*?body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALL_RE = re.compile(r"(?:call|fusion)\(.*?(?:to_apply|calls)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"conditional\(.*")
_BRANCH_RE = re.compile(r"(?:branch_computations|true_computation|"
                        r"false_computation)=\{?%?([\w\.\-,% ]+)")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, float]
    count_by_op: Dict[str, float]       # execution-weighted instance count
    total_bytes: float
    unknown_trip_counts: int

    def as_dict(self) -> dict:
        return {"bytes_by_op": self.bytes_by_op,
                "count_by_op": self.count_by_op,
                "total_bytes": self.total_bytes,
                "unknown_trip_counts": self.unknown_trip_counts}


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """computation name -> its instruction lines."""
    comps: Dict[str, List[str]] = {}
    current = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _COMP_HDR_RE.match(line) or _COMP_HDR_RE.match(stripped)
        if m and stripped.endswith("{"):
            current = m.group(1)
            comps[current] = []
            continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is not None:
            comps[current].append(stripped)
    return comps


def _entry_name(hlo_text: str, comps: Dict[str, List[str]]) -> str:
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                return m.group(1)
    # fallback: computation never referenced by others
    called = set()
    for lines in comps.values():
        for ln in lines:
            for mm in re.finditer(r"(?:to_apply|body|condition|calls)=%?"
                                  r"([\w\.\-]+)", ln):
                called.add(mm.group(1))
    for name in comps:
        if name not in called:
            return name
    return next(iter(comps))


def collective_stats(hlo_text: str) -> CollectiveStats:
    comps = _split_computations(hlo_text)
    entry = _entry_name(hlo_text, comps)

    # computation execution multipliers, propagated from ENTRY
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    unknown_trips = 0

    def visit(name: str, m: float, depth: int = 0) -> None:
        nonlocal unknown_trips
        if name not in comps or depth > 64:
            return
        mult[name] = mult.get(name, 0.0) + m
        for ln in comps[name]:
            wm = _WHILE_RE.search(ln)
            if wm:
                body = wm.group(1)
                tm = _TRIP_RE.search(ln)
                trips = float(tm.group(1)) if tm else 1.0
                if not tm:
                    unknown_trips += 1
                visit(body, m * trips, depth + 1)
                continue
            cm = _CALL_RE.search(ln)
            if cm:
                visit(cm.group(1), m, depth + 1)
                continue
            bm = _BRANCH_RE.search(ln)
            if bm:
                for branch in re.findall(r"[\w\.\-]+", bm.group(1)):
                    visit(branch, m, depth + 1)

    visit(entry, 1.0)

    bytes_by_op: Dict[str, float] = {op: 0.0 for op in COLLECTIVE_OPS}
    count_by_op: Dict[str, float] = {op: 0.0 for op in COLLECTIVE_OPS}
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0.0:
            continue
        for ln in lines:
            cm = _COLL_RE.search(ln)
            if not cm:
                continue
            type_str, op, phase = cm.group(1), cm.group(2), cm.group(3)
            if phase == "-done":
                continue
            b = _shape_bytes(type_str)
            if phase == "-start":
                b = b / 2.0          # tuple type carries operand + result
            bytes_by_op[op] += b * m
            count_by_op[op] += m
    total = sum(bytes_by_op.values())
    return CollectiveStats(bytes_by_op=bytes_by_op, count_by_op=count_by_op,
                           total_bytes=total,
                           unknown_trip_counts=unknown_trips)


def while_loop_trip_counts(hlo_text: str) -> List[int]:
    return [int(x) for x in _TRIP_RE.findall(hlo_text)]


# ---------------------------------------------------------------------------
# loop-aware FLOP / byte accounting
# ---------------------------------------------------------------------------
#
# XLA's compiled.cost_analysis() counts each while body ONCE -- a 96-layer
# scan or a 32-microbatch accumulation loop is undercounted by its trip
# count.  We therefore re-derive FLOPs and HBM bytes from the HLO text with
# the same execution-multiplier propagation used for collectives:
#   * dot ops: 2 * prod(output dims) * prod(contracting dims)   [per device]
#   * elementwise/transcendental ops: prod(shape) flops
#   * bytes: operands + outputs of instructions at fusion boundaries only
#     (inside kLoop/kInput fusions intermediates never touch HBM)

_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?.+?\)?)\s+"
                       r"([\w\-]+)\(")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*((?:\([^()]*\)|[a-z0-9]+\[[\d,]*\]"
                       r"(?:\{[\d,]*\})?))")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_FUSION_CALLS_RE = re.compile(r"fusion\(.*?calls=%?([\w\.\-]+)")

ELEMENTWISE_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "power", "select", "compare", "and", "or",
    "convert", "floor", "ceil", "round-nearest-afz", "sign", "clamp",
    "cosine", "sine", "logistic", "erf", "cbrt", "atan2", "remainder",
}


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _prod(xs) -> float:
    p = 1.0
    for x in xs:
        p *= x
    return p


@dataclasses.dataclass
class HloCosts:
    flops: float                 # per-device, loop-weighted
    dot_flops: float
    elementwise_flops: float
    hbm_bytes: float             # per-device, loop-weighted, fusion-boundary
    collectives: CollectiveStats
    rows: Optional[list] = None  # (bytes, mult, op, line) when collected

    def as_dict(self) -> dict:
        return {"flops": self.flops, "dot_flops": self.dot_flops,
                "elementwise_flops": self.elementwise_flops,
                "hbm_bytes": self.hbm_bytes,
                "collectives": self.collectives.as_dict()}


def analyze_hlo(hlo_text: str, collect_rows: bool = False) -> HloCosts:
    comps = _split_computations(hlo_text)
    entry = _entry_name(hlo_text, comps)

    # header parameter types per computation (symbol table seed)
    header_types: Dict[str, Dict[str, str]] = {}
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m:
            name = m.group(1)
            header_types[name] = {pname: ptype for pname, ptype
                                  in _PARAM_RE.findall(line)}

    # per-computation: symbol tables, op records
    sym: Dict[str, Dict[str, str]] = {}
    for name, lines in comps.items():
        table = dict(header_types.get(name, {}))
        for ln in lines:
            im = _INSTR_RE.match(ln)
            if im:
                table[im.group(1)] = im.group(2)
        sym[name] = table

    # classify call edges to know fusion bodies
    fused_bodies = set()
    for name, lines in comps.items():
        for ln in lines:
            fm = _FUSION_CALLS_RE.search(ln)
            if fm:
                fused_bodies.add(fm.group(1))

    # multipliers (same walk as collective_stats)
    mult: Dict[str, float] = {}

    def visit(name: str, m: float, depth: int = 0) -> None:
        if name not in comps or depth > 64:
            return
        mult[name] = mult.get(name, 0.0) + m
        for ln in comps[name]:
            wm = _WHILE_RE.search(ln)
            if wm:
                tm = _TRIP_RE.search(ln)
                trips = float(tm.group(1)) if tm else 1.0
                visit(wm.group(1), m * trips, depth + 1)
                continue
            fm = _FUSION_CALLS_RE.search(ln)
            if fm:
                visit(fm.group(1), m, depth + 1)
                continue
            cm = _CALL_RE.search(ln)
            if cm:
                visit(cm.group(1), m, depth + 1)
                continue
            bm = _BRANCH_RE.search(ln)
            if bm:
                for branch in re.findall(r"[\w\.\-]+", bm.group(1)):
                    visit(branch, m, depth + 1)

    visit(entry, 1.0)

    # --- per-fusion summaries: effective output bytes (in-place DUS roots)
    # and per-parameter effective read bytes (params only dynamic-sliced
    # inside the fusion charge the slice, not the whole array) -------------
    # TPU-semantics modeling inside fused computations: pure type/layout
    # chains (convert/bitcast/copy/reshape) are free, dynamic-update-slice
    # buffers are updated in place, dynamic-slice reads only the slice.
    _PASS_OPS = ("convert", "bitcast", "copy", "reshape", "transpose")

    def _fusion_summary(body: str):
        lines = comps.get(body, [])
        table = sym.get(body, {})
        # def map: name -> (op, type, operands); use map: name -> users
        defs: Dict[str, Tuple[str, str, List[str]]] = {}
        users: Dict[str, List[str]] = {}
        root_name = None
        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            nm, typ, op = im.groups()
            args = ln.split("(", 1)[1] if "(" in ln else ""
            operands = _OPERAND_RE.findall(args.split(")", 1)[0])
            defs[nm] = (op, typ, operands)
            for o in operands:
                users.setdefault(o, []).append(nm)
            if ln.startswith("ROOT"):
                root_name = nm
        for ln in lines:
            pm = re.match(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\S+)\s+"
                          r"parameter\((\d+)\)", ln)
            if pm:
                defs[pm.group(1)] = ("parameter", pm.group(2), [])

        def _resolve_fwd(nm: str, depth=0) -> str:
            """Follow pure chains downstream (single user) from nm."""
            while depth < 16:
                us = users.get(nm, [])
                if len(us) == 1 and defs.get(us[0], ("",))[0] in _PASS_OPS:
                    nm = us[0]
                    depth += 1
                    continue
                return nm
            return nm

        def _resolve_back(nm: str, depth=0) -> str:
            """Follow pure chains upstream from nm."""
            while depth < 16:
                d = defs.get(nm)
                if d and d[0] in _PASS_OPS and d[2]:
                    nm = d[2][0]
                    depth += 1
                    continue
                return nm
            return nm

        def _dus_update_bytes(nm: str) -> float:
            d = defs.get(nm)
            if d and len(d[2]) > 1:
                upd = d[2][1]
                return _shape_bytes(defs.get(upd, ("", "", []))[1])
            return 0.0

        # --- effective write bytes
        out_override = None
        if root_name is not None:
            rroot = _resolve_back(root_name)
            rop = defs.get(rroot, ("",))[0]
            if rop == "dynamic-update-slice":
                out_override = 2.0 * _dus_update_bytes(rroot)
            elif rop == "tuple":
                total = 0.0
                for el in defs[rroot][2]:
                    rel = _resolve_back(el)
                    if defs.get(rel, ("",))[0] == "dynamic-update-slice":
                        total += 2.0 * _dus_update_bytes(rel)
                    else:
                        total += _shape_bytes(defs.get(el, ("", "", []))[1])
                out_override = total

        # --- effective read bytes per parameter
        param_names = list(header_types.get(body, {}).keys())
        param_read: Dict[str, float] = {}
        for pn in param_names:
            eff = _resolve_fwd(pn)
            consumers = users.get(eff, [])
            if not consumers:
                param_read[pn] = 0.0
                continue
            b, simple = 0.0, True
            for c in consumers:
                cop, ctyp, coper = defs.get(c, ("", "", []))
                if cop == "dynamic-slice":
                    b += _shape_bytes(ctyp)
                elif cop == "dynamic-update-slice" and coper \
                        and coper[0] == eff:
                    b += 0.0              # aliased in-place buffer
                else:
                    simple = False
                    break
            if simple:
                param_read[pn] = b
        return out_override, param_names, param_read

    fusion_info = {b: _fusion_summary(b) for b in fused_bodies}

    rows = [] if collect_rows else None
    dot_flops = ew_flops = hbm_bytes = 0.0
    NO_CHARGE = ("parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "iota", "after-all", "while", "conditional",
                 "call", "custom-call", "partition-id", "replica-id")
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0.0:
            continue
        table = sym[name]
        in_fusion = name in fused_bodies
        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            out_name, out_type, op = im.groups()
            shapes = _shape_dims(out_type)
            out_elems = sum(_prod(d) for _, d in shapes)
            if op == "dot":
                cm = _CONTRACT_RE.search(ln)
                contract = 1.0
                if cm:
                    ops = _OPERAND_RE.findall(ln.split("dot(", 1)[1])
                    lhs_type = table.get(ops[0], "") if ops else ""
                    lhs_shapes = _shape_dims(lhs_type)
                    if lhs_shapes and cm.group(1):
                        dims = [int(x) for x in cm.group(1).split(",") if x]
                        lhs_dims = lhs_shapes[0][1]
                        contract = _prod(lhs_dims[d] for d in dims
                                         if d < len(lhs_dims))
                dot_flops += m * 2.0 * out_elems * contract
            elif op in ELEMENTWISE_OPS:
                ew_flops += m * out_elems
            # HBM bytes: fusion-boundary instructions only
            if in_fusion or op in NO_CHARGE:
                continue
            # flash_vmem / halo_vmem / kvdec_vmem scopes: resident in the
            # Pallas kernels' VMEM (kernels/flash_attention.py,
            # kernels/halo_matmul.py, kernels/flash_decode.py); only the
            # block DMAs (dynamic-slice loads) touch HBM.  XLA may merge
            # scoped ops into fusions whose root carries an unscoped
            # op_name, so fusion bodies are inspected for scope tags too.
            scoped = any(t in ln for t in _VMEM_SCOPES)
            if not scoped and op == "fusion":
                fm = _FUSION_CALLS_RE.search(ln)
                body_lines = comps.get(fm.group(1), []) if fm else []
                scoped = any(any(t in bl for t in _VMEM_SCOPES)
                             for bl in body_lines)
            if scoped:
                if op in ("dynamic-slice",):
                    hbm_bytes += m * 2.0 * _shape_bytes(out_type)
                    if rows is not None:
                        rows.append((m * 2.0 * _shape_bytes(out_type), m,
                                     op, ln[:140]))
                elif op == "fusion":
                    fm = _FUSION_CALLS_RE.search(ln)
                    body_lines = comps.get(fm.group(1), []) if fm else []
                    ds_out = 0.0
                    for bl in body_lines:
                        bim = _INSTR_RE.match(bl)
                        if bim and bim.group(3) == "dynamic-slice":
                            ds_out += _shape_bytes(bim.group(2))
                    if ds_out:
                        hbm_bytes += m * 2.0 * ds_out
                        if rows is not None:
                            rows.append((m * 2.0 * ds_out, m,
                                         "fusion-ds", ln[:140]))
                continue

            def _charge(b):
                nonlocal hbm_bytes
                hbm_bytes += m * b
                if rows is not None:
                    rows.append((m * b, m, op, ln[:140]))

            b_out = _shape_bytes(out_type)
            if op in ("dynamic-slice", "gather", "slice"):
                _charge(2.0 * b_out)               # reads only the slice
            elif op in ("dynamic-update-slice", "scatter"):
                args = ln.split("(", 1)[1] if "(" in ln else ""
                ops_ = _OPERAND_RE.findall(args.split("),", 1)[0])
                upd = _shape_bytes(table.get(ops_[1], "")) \
                    if len(ops_) > 1 else b_out
                _charge(2.0 * upd)                  # in-place update
            elif op == "fusion":
                fm = _FUSION_CALLS_RE.search(ln)
                info = fusion_info.get(fm.group(1)) if fm else None
                args = ln.split("(", 1)[1] if "(" in ln else ""
                operands = _OPERAND_RE.findall(args.split("),", 1)[0])
                if info is not None:
                    out_override, pnames, pread = info
                    b = out_override if out_override is not None else b_out
                    for i, opnd in enumerate(operands):
                        pn = pnames[i] if i < len(pnames) else None
                        if pn is not None and pn in pread:
                            b += pread[pn]          # only sliced inside
                        else:
                            b += _shape_bytes(table.get(opnd, ""))
                    _charge(b)
                else:
                    b_in = sum(_shape_bytes(table.get(o, ""))
                               for o in operands)
                    _charge(b_out + b_in)
            elif op == "copy":
                args = ln.split("(", 1)[1] if "(" in ln else ""
                ops_ = _OPERAND_RE.findall(args.split("),", 1)[0])
                src_t = table.get(ops_[0], "") if ops_ else ""
                if src_t.strip() == out_type.strip():
                    # same type+layout: loop-carry copy, aliased on TPU
                    _charge(0.0)
                else:
                    _charge(2.0 * b_out)      # layout-changing copy
            else:
                b_in = 0.0
                args = ln.split("(", 1)[1] if "(" in ln else ""
                args = args.split("),", 1)[0]
                for opnd in _OPERAND_RE.findall(args):
                    b_in += _shape_bytes(table.get(opnd, ""))
                _charge(b_out + b_in)

    colls = collective_stats(hlo_text)
    if rows is not None:
        rows.sort(reverse=True)
    return HloCosts(flops=dot_flops + ew_flops, dot_flops=dot_flops,
                    elementwise_flops=ew_flops, hbm_bytes=hbm_bytes,
                    collectives=colls, rows=rows)
