"""Three-term roofline from a compiled dry-run artifact (TPU v5e target).

  compute term    = HLO_FLOPs    / (chips * 197 TFLOP/s)
  memory term     = HLO_bytes    / (chips * 819 GB/s)
  collective term = coll_bytes   / (chips * 50 GB/s/link)

Our HLO parser reports *per-device* loop-weighted quantities (post-SPMD
shapes are shards), so the division by `chips` is already folded in --
terms below divide per-device quantities by per-chip peaks.  We report
XLA's raw cost_analysis alongside for transparency: it counts while bodies
once, so for scanned models it undercounts by the trip count (documented
in EXPERIMENTS.md).

MODEL_FLOPS uses the brief's convention: 6*N*D for training (N params,
D tokens), 2*N_active*D for single forward/decode steps; MoE uses active
params.  The ratio MODEL_FLOPS / HLO_FLOPS measures how much compiled
compute is "useful" (catches remat recompute, attention waste, dispatch
overhead).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional

from ..hw.tpu_specs import V5E, ChipSpec
from . import hlo as hlo_mod


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    step_kind: str
    # per-device, loop-weighted
    hlo_flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    # terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    # useful-work accounting
    model_flops_global: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPS * chips)
    roofline_fraction: float     # bound_term / sum-ish: see below
    # memory fit
    argument_bytes: float
    temp_bytes: float
    donated_bytes: float      # per-device bytes of donated inputs (aliased
    fits_hbm: bool            # in place on TPU; XLA:CPU cannot alias them)
    analytic_peak_bytes: float = 0.0   # structural TPU-residency estimate
    fits_hbm_analytic: bool = True     # (see EXPERIMENTS.md SDry-run)
    # raw XLA numbers for transparency
    xla_cost_flops: Optional[float] = None
    xla_cost_bytes: Optional[float] = None
    collectives_by_op: Optional[Dict[str, float]] = None
    notes: str = ""

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def build_report(arch: str, shape: str, mesh_name: str, chips: int,
                 step_kind: str, hlo_text: str,
                 memory_stats, cost_analysis: Optional[dict],
                 model_flops_global: float,
                 donated_bytes: float = 0.0,
                 analytic_peak_bytes: float = 0.0,
                 spec: ChipSpec = V5E, notes: str = "") -> RooflineReport:
    costs = hlo_mod.analyze_hlo(hlo_text)
    compute_s = costs.flops / spec.peak_bf16_flops
    memory_s = costs.hbm_bytes / spec.hbm_bandwidth
    coll_s = costs.collectives.total_bytes / spec.ici_link_bandwidth
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)

    useful = model_flops_global / max(costs.flops * chips, 1.0)
    # roofline fraction: useful compute time / modeled step time (the three
    # terms overlap on real hardware; we report the pessimistic no-overlap
    # denominator AND the optimistic max-term one -- fraction uses max-term,
    # i.e. "if perfectly overlapped, what share of the binding resource
    # does useful compute occupy".
    ideal_s = model_flops_global / (chips * spec.peak_bf16_flops)
    bound_s = max(terms.values())
    frac = ideal_s / bound_s if bound_s > 0 else 0.0

    arg_b = float(memory_stats.argument_size_in_bytes)
    tmp_b = float(memory_stats.temp_size_in_bytes)
    out_b = float(memory_stats.output_size_in_bytes)
    alias_b = float(memory_stats.alias_size_in_bytes)
    # XLA:CPU cannot alias donated buffers, so its `temp` includes a full
    # second copy of every donated input (train state, KV caches) that a TPU
    # executable updates in place.  Model TPU residency by crediting the
    # donated bytes once against the temp side (never below zero).
    tmp_eff = max(tmp_b - donated_bytes, 0.0)
    peak = arg_b + tmp_eff + max(out_b - alias_b - donated_bytes, 0.0)
    fits = peak <= spec.hbm_bytes

    xf = xb = None
    if cost_analysis:
        xf = float(cost_analysis.get("flops", 0.0))
        xb = float(cost_analysis.get("bytes accessed", 0.0))
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        step_kind=step_kind,
        hlo_flops_per_device=costs.flops,
        hbm_bytes_per_device=costs.hbm_bytes,
        collective_bytes_per_device=costs.collectives.total_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dominant,
        model_flops_global=model_flops_global,
        useful_ratio=min(useful, 10.0),
        roofline_fraction=frac,
        argument_bytes=arg_b, temp_bytes=tmp_b,
        donated_bytes=donated_bytes, fits_hbm=fits,
        analytic_peak_bytes=analytic_peak_bytes,
        fits_hbm_analytic=(analytic_peak_bytes <= spec.hbm_bytes
                           if analytic_peak_bytes else fits),
        xla_cost_flops=xf, xla_cost_bytes=xb,
        collectives_by_op=costs.collectives.bytes_by_op,
        notes=notes)


def model_flops(n_params_dense: float, n_params_active: float,
                tokens: float, step_kind: str) -> float:
    """Brief convention: train 6*N*D; forward-only (prefill) 2*N*D;
    decode 2*N per token * batch."""
    n = n_params_active
    if step_kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def save_report(report: RooflineReport, directory: str) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(
        directory, f"{report.arch}__{report.shape}__{report.mesh}.json")
    with open(path, "w") as f:
        json.dump(report.as_dict(), f, indent=1)
    return path


def load_reports(directory: str):
    out = []
    if not os.path.isdir(directory):
        return out
    for name in sorted(os.listdir(directory)):
        if name.endswith(".json"):
            with open(os.path.join(directory, name)) as f:
                out.append(json.load(f))
    return out


def format_table(reports) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':9s} {'kind':7s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'dominant':>10s} {'useful':>7s} {'roofline%':>9s} {'fits':>5s}")
    lines = [hdr, "-" * len(hdr)]
    for r in reports:
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:9s} "
            f"{r['step_kind']:7s} {r['compute_s']:10.4g} "
            f"{r['memory_s']:10.4g} {r['collective_s']:10.4g} "
            f"{r['dominant']:>10s} {r['useful_ratio']:7.3f} "
            f"{100*r['roofline_fraction']:8.1f}% "
            f"{'Y' if r['fits_hbm'] else 'N':>5s}")
    return "\n".join(lines)
