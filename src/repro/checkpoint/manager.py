"""Fault-tolerant checkpointing: atomic commits, async writes, rotation,
auto-resume, and elastic (mesh-independent) restore.

Layout per step:  <dir>/step_<N>/arrays.npz + meta.json, committed by
writing to ``step_<N>.tmp`` and ``os.replace`` -- a crash mid-write leaves
only a .tmp that restore ignores.  ``save_async`` snapshots to host memory
synchronously (cheap) and writes on a background thread, so the train loop
never blocks on disk.  Arrays are stored by tree-path key with the treedef
recovered from a reference pytree at load, which makes restore independent
of mesh/device layout: `restore` places leaves with whatever shardings the
caller passes (elastic reshard = restore onto a different mesh).
"""

from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np


import ml_dtypes

# numpy's npz cannot serialize bf16/fp8; store them as raw uint views with a
# dtype tag and view back at load.
_VIEW_DTYPES = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
                "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
                "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _flatten_with_keys(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out: Dict[str, np.ndarray] = {}
    dtypes: Dict[str, str] = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(jax.device_get(leaf))
        name = arr.dtype.name if hasattr(arr.dtype, "name") else str(arr.dtype)
        for tag, (real, view) in _VIEW_DTYPES.items():
            if arr.dtype == real:
                arr = arr.view(view)
                dtypes[key] = tag
                break
        out[key] = arr
    return out, dtypes


def _unflatten_like(reference, arrays: Dict[str, np.ndarray],
                    dtypes: Dict[str, str]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(reference)
    leaves = []
    for path, ref_leaf in flat:
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if key in dtypes:
            arr = arr.view(_VIEW_DTYPES[dtypes[key]][0])
        ref_shape = tuple(getattr(ref_leaf, "shape", ()))
        if tuple(arr.shape) != ref_shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {ref_shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = cf.ThreadPoolExecutor(max_workers=1,
                                           thread_name_prefix="ckpt")
        self._pending: List[cf.Future] = []
        self._lock = threading.Lock()

    # -------------------------------------------------- save
    def save(self, step: int, tree: Any, extra_meta: Optional[dict] = None
             ) -> None:
        arrays, dtypes = _flatten_with_keys(tree)
        self._write(step, arrays, {**(extra_meta or {}), "dtypes": dtypes})

    def save_async(self, step: int, tree: Any,
                   extra_meta: Optional[dict] = None) -> None:
        arrays, dtypes = _flatten_with_keys(tree)   # sync host snapshot
        fut = self._pool.submit(self._write, step, arrays,
                                {**(extra_meta or {}), "dtypes": dtypes})
        with self._lock:
            self._pending.append(fut)
            self._pending = [f for f in self._pending if not f.done()]

    def wait(self) -> None:
        with self._lock:
            pending = list(self._pending)
        for f in pending:
            f.result()

    def _write(self, step: int, arrays: Dict[str, np.ndarray],
               meta: dict) -> None:
        final = os.path.join(self.directory, f"step_{step:012d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in arrays.items()})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **meta}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                 # atomic commit
        self._rotate()

    def _rotate(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:012d}"),
                          ignore_errors=True)

    # -------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, reference: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore onto host, then (optionally) place with `shardings` --
        which may target a different mesh than the one that saved (elastic).
        `reference` supplies the treedef + expected shapes (abstract ok)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:012d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        dtypes = self.meta(step).get("dtypes", {})
        tree = _unflatten_like(reference, arrays, dtypes)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s) if s is not None
                else jax.device_put(a), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree

    def meta(self, step: Optional[int] = None) -> dict:
        step = self.latest_step() if step is None else step
        path = os.path.join(self.directory, f"step_{step:012d}", "meta.json")
        with open(path) as f:
            return json.load(f)
