"""Architecture registry: the 10 assigned archs + the paper's own models.

``get_config(name)`` returns the full-size ModelConfig; ``get_smoke_config``
returns a reduced same-family config for CPU tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from .base import ModelConfig

ARCH_MODULES = {
    "dbrx-132b": "dbrx_132b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "nemotron-4-340b": "nemotron_4_340b",
    "granite-8b": "granite_8b",
    "gemma2-2b": "gemma2_2b",
    "mistral-large-123b": "mistral_large_123b",
    "musicgen-medium": "musicgen_medium",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "internvl2-26b": "internvl2_26b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    # the paper's own evaluation models
    "llama2-7b": "llama2_7b",
    "llama2-13b": "llama2_13b",
    "opt-1.3b": "opt_1_3b",
    "opt-30b": "opt_30b",
}

ASSIGNED_ARCHS: List[str] = list(ARCH_MODULES)[:10]
PAPER_ARCHS: List[str] = list(ARCH_MODULES)[10:]


def get_config(name: str) -> ModelConfig:
    try:
        mod = importlib.import_module(f".{ARCH_MODULES[name]}", __package__)
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCH_MODULES)}"
                       ) from None
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{ARCH_MODULES[name]}", __package__)
    return mod.SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {name: get_config(name) for name in ARCH_MODULES}
