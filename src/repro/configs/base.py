"""Model/shape configuration schema shared by all architectures.

Every assigned architecture is expressed as a ``ModelConfig``; the unified
decoder in ``models/transformer.py`` consumes it.  ``block_pattern`` is the
periodic layer program, e.g. ``("attn",)`` for uniform dense stacks,
``("attn_local", "attn")`` for gemma-2 alternation, ``("rec", "rec",
"attn_local")`` for recurrentgemma, ``("mamba",)`` for falcon-mamba.
Layers = n_periods * len(pattern) + remainder (remainder layers reuse the
pattern prefix and are unrolled outside the scan).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    gated: bool = True                 # GLU experts (dbrx/llama4 use SwiGLU)
    act: str = "silu"
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell: (name, seq_len, global_batch, kind)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


# The assigned LM shape set (identical across the 10 archs).
LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int                      # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # --- layer program ---
    block_pattern: Tuple[str, ...] = ("attn",)
    local_window: Optional[int] = None
    # --- flavor knobs ---
    activation: str = "silu"
    gated_mlp: bool = True
    norm_type: str = "rmsnorm"        # rmsnorm | layernorm
    norm_plus_one: bool = False       # gemma-style (1 + scale)
    use_bias: bool = False            # OPT-style biases
    pos_emb: str = "rope"             # rope | learned | none
    rope_theta: float = 10_000.0
    logit_softcap: Optional[float] = None
    attn_softcap: Optional[float] = None
    qk_norm: bool = False
    moe: Optional[MoeConfig] = None
    # --- ssm / recurrent dims ---
    ssm_state: int = 16
    ssm_expand: int = 2
    d_rnn: Optional[int] = None
    conv_k: int = 4
    # --- io ---
    embeds_input: bool = False        # audio/vlm stub frontends feed embeds
    tied_embeddings: bool = False
    embed_scale: bool = False
    max_position: int = 1_048_576     # learned pos-emb table size cap
    # --- numerics / structure ---
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    scan_layers: bool = True
    remat: bool = True
    remat_policy: str = "full"   # full | dots (save matmul outputs)
    scan_chunk: int = 256
    attn_chunk: int = 1024
    flash_vjp: bool = True     # custom-VJP flash attention (recompute-p bwd)
    vocab_pad_multiple: int = 256
    # --- distribution defaults (per-arch overrides) ---
    shard_heads: bool = True          # False -> replicate attention over TP
    grad_accum: int = 1               # microbatch count for train_4k
    moe_token_chunks: int = 1         # sequential MoE dispatch chunks
    moe_impl: str = "gspmd"           # gspmd | a2a (shard_map all-to-all EP)
    prefill_microbatch: int = 1       # batch slices per prefill pass
    # "tp": Megatron TP activations.  "zero": batch sharded over every mesh
    # axis, no TP activations, 2D-sharded weights gathered per layer --
    # measured 5.3x lower collective time for <=10B dense models (SPerf).
    train_layout: str = "tp"
    kv_cache_dtype: str = "bf16"      # bf16 | int8 (per-position scales)
    # shapes this arch runs (long_500k dropped for pure full-attention archs)
    shapes: Tuple[ShapeConfig, ...] = LM_SHAPES
    notes: str = ""

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def remainder_pattern(self) -> Tuple[str, ...]:
        rem = self.n_layers - self.n_periods * len(self.block_pattern)
        return self.block_pattern[:rem]

    def shape(self, name: str) -> ShapeConfig:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.name} does not run shape {name!r} "
                       f"(available: {[s.name for s in self.shapes]})")

    def supports_shape(self, name: str) -> bool:
        return any(s.name == name for s in self.shapes)


FULL_ATTENTION_SHAPES = tuple(s for s in LM_SHAPES if s.name != "long_500k")
