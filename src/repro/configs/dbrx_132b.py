"""DBRX-132B: fine-grained MoE, 16 experts top-4, GQA.
[hf:databricks/dbrx-base; unverified]"""

import dataclasses

from .base import MoeConfig
from .base import FULL_ATTENTION_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab=100352,
    activation="silu",
    gated_mlp=True,
    moe=MoeConfig(n_experts=16, top_k=4, capacity_factor=1.25),
    rope_theta=500_000.0,
    shapes=FULL_ATTENTION_SHAPES,        # pure full attention -> no long_500k
    grad_accum=16,
    moe_token_chunks=8,
    prefill_microbatch=4,
    notes="fine-grained 16e top-4 MoE; HALO quantizes per-expert weights",
)

SMOKE = dataclasses.replace(
    CONFIG, name="dbrx-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=96, vocab=256,
    moe=MoeConfig(n_experts=4, top_k=2, capacity_factor=4.0),
    grad_accum=1, attn_chunk=64, scan_chunk=32)
