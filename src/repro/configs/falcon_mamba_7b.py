"""Falcon-Mamba-7B: pure Mamba-1 SSM stack (attention-free).
[arXiv:2410.05355]

Sub-quadratic: runs long_500k (decode state is O(1) in context length).
HALO applies to in/x/dt/out projections; the selective-scan recurrence
itself has no weight-stationary MAC matmul (DESIGN.md S3.2).
"""

import dataclasses

from .base import LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                     # attention-free, no separate MLP
    vocab=65024,
    block_pattern=("mamba",),
    ssm_state=16,
    ssm_expand=2,
    conv_k=4,
    pos_emb="none",
    shapes=LM_SHAPES,
    grad_accum=8,
    notes="mamba1; d_inner=8192, dt_rank=256; chunked associative scan",
)

SMOKE = dataclasses.replace(
    CONFIG, name="falcon-mamba-smoke", n_layers=2, d_model=64,
    ssm_state=8, vocab=256, grad_accum=1, scan_chunk=32, attn_chunk=64)
