"""Gemma-2-2B: local/global alternating attention, logit softcaps, tied
embeddings. [arXiv:2408.00118]

long_500k note (DESIGN.md S3.2): half the layers are 4k sliding-window (ring
KV cache); global layers hold the full 500k cache, sharded along kv_seq, and
decode is O(S) per step -- runnable, so this arch keeps all four shapes.
"""

import dataclasses

from .base import LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256000,
    block_pattern=("attn_local", "attn"),
    local_window=4096,
    activation="gelu",
    gated_mlp=True,
    norm_plus_one=True,
    logit_softcap=30.0,
    attn_softcap=50.0,
    tied_embeddings=True,
    embed_scale=True,
    rope_theta=10_000.0,
    shapes=LM_SHAPES,
    shard_heads=False,          # 8 heads cannot split 16-way TP
    grad_accum=8,
    notes="alternating local(4096)/global; softcaps; tied embeddings",
)

SMOKE = dataclasses.replace(
    CONFIG, name="gemma2-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, local_window=64,
    grad_accum=1, attn_chunk=32, scan_chunk=32)
