"""Granite-8B-Code: llama-architecture dense code model. [arXiv:2405.04324]"""

import dataclasses

from .base import FULL_ATTENTION_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=49152,
    activation="silu",
    gated_mlp=True,
    rope_theta=10_000_000.0,
    shapes=FULL_ATTENTION_SHAPES,
    grad_accum=4,
    notes="llama-arch; the ~100M-train example uses this family reduced",
)

SMOKE = dataclasses.replace(
    CONFIG, name="granite-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=192, vocab=256,
    grad_accum=1, attn_chunk=64, scan_chunk=32)
