"""InternVL2-26B: InternViT-6B vision frontend (stubbed) + InternLM2-20B
language backbone. [arXiv:2404.16821]

Backbone only: input_specs() provides precomputed patch/text embeddings
(B, S, d_model); the decoder is the InternLM2-20B stack.
"""

import dataclasses

from .base import FULL_ATTENTION_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92553,                # padded to 92928 for 16-way TP
    activation="silu",
    gated_mlp=True,
    rope_theta=1_000_000.0,
    embeds_input=True,
    shapes=FULL_ATTENTION_SHAPES,
    grad_accum=16,
    prefill_microbatch=2,
    notes="VLM backbone; InternViT frontend stubbed to patch embeddings",
)

SMOKE = dataclasses.replace(
    CONFIG, name="internvl2-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=160, vocab=250,
    grad_accum=1, attn_chunk=64, scan_chunk=32)
