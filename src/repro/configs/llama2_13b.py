"""LLaMA2-13B (paper's own evaluation model). [arXiv:2307.09288]"""

import dataclasses

from .base import FULL_ATTENTION_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="llama2-13b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=13824,
    vocab=32000,
    activation="silu",
    gated_mlp=True,
    shapes=FULL_ATTENTION_SHAPES,
    grad_accum=4,
)

SMOKE = dataclasses.replace(
    CONFIG, name="llama2-13b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=176, vocab=256,
    grad_accum=1, attn_chunk=64, scan_chunk=32)
