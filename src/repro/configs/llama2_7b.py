"""LLaMA2-7B (paper's own evaluation model). [arXiv:2307.09288]"""

import dataclasses

from .base import FULL_ATTENTION_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab=32000,
    activation="silu",
    gated_mlp=True,
    shapes=FULL_ATTENTION_SHAPES,
    grad_accum=4,
)

SMOKE = dataclasses.replace(
    CONFIG, name="llama2-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=176, vocab=256,
    grad_accum=1, attn_chunk=64, scan_chunk=32)
