"""Llama-4-Scout-17B-16E: MoE top-1, early fusion (text path modeled).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

import dataclasses

from .base import MoeConfig
from .base import FULL_ATTENTION_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    activation="silu",
    gated_mlp=True,
    moe=MoeConfig(n_experts=16, top_k=1, capacity_factor=2.0),
    rope_theta=500_000.0,
    shapes=FULL_ATTENTION_SHAPES,
    grad_accum=16,
    moe_token_chunks=8,
    prefill_microbatch=4,
    notes="top-1 routed MoE (17B active); capacity factor 2.0 for top-1 skew",
)

SMOKE = dataclasses.replace(
    CONFIG, name="llama4-scout-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
    moe=MoeConfig(n_experts=4, top_k=1, capacity_factor=4.0),
    grad_accum=1, attn_chunk=64, scan_chunk=32)
