"""Mistral-Large-123B (2407): dense GQA.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""

import dataclasses

from .base import FULL_ATTENTION_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=32768,
    activation="silu",
    gated_mlp=True,
    rope_theta=1_000_000.0,
    shapes=FULL_ATTENTION_SHAPES,
    grad_accum=32,
    prefill_microbatch=4,
    notes="deep dense stack; decode_32k KV cache dominates serve memory",
)

SMOKE = dataclasses.replace(
    CONFIG, name="mistral-large-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=160, vocab=256,
    grad_accum=1, attn_chunk=64, scan_chunk=32)
