"""MusicGen-medium: decoder-only transformer over EnCodec tokens.
[arXiv:2306.05284]

Backbone only: the EnCodec frontend is a stub -- input_specs() provides
precomputed frame embeddings (B, S, d_model); the decoder predicts the next
codec token over a 2048-entry codebook vocabulary.
"""

import dataclasses

from .base import FULL_ATTENTION_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,              # MHA
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    activation="gelu",
    gated_mlp=False,
    norm_type="layernorm",
    use_bias=True,
    pos_emb="none",             # sinusoidal in the original; stub provides it
    embeds_input=True,
    shapes=FULL_ATTENTION_SHAPES,
    shard_heads=True,           # 24 heads / 8-way ok; 16-way falls back
    grad_accum=4,
    notes="audio backbone; EnCodec frontend stubbed to frame embeddings",
)

SMOKE = dataclasses.replace(
    CONFIG, name="musicgen-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, vocab=128,
    grad_accum=1, attn_chunk=64, scan_chunk=32)
