"""Nemotron-4-340B: dense, GQA, squared-ReLU MLP. [arXiv:2402.16819]"""

import dataclasses

from .base import FULL_ATTENTION_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab=256000,
    activation="squared_relu",
    gated_mlp=False,
    rope_theta=10_000.0,
    shapes=FULL_ATTENTION_SHAPES,
    grad_accum=64,
    prefill_microbatch=8,              # 340B needs deep microbatching at 1M tokens
    notes="largest assigned arch; exercises FSDP+TP memory limits",
)

SMOKE = dataclasses.replace(
    CONFIG, name="nemotron-smoke", n_layers=2, d_model=96, n_heads=4,
    n_kv_heads=2, head_dim=24, d_ff=384, vocab=512,
    grad_accum=1, attn_chunk=64, scan_chunk=32)
