"""OPT-1.3B (paper's own evaluation model). [arXiv:2205.01068]"""

import dataclasses

from .base import FULL_ATTENTION_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="opt-1.3b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=50272,
    activation="relu",
    gated_mlp=False,
    norm_type="layernorm",
    use_bias=True,
    pos_emb="learned",
    max_position=2048,
    tied_embeddings=True,
    shapes=FULL_ATTENTION_SHAPES,
    grad_accum=2,
)

SMOKE = dataclasses.replace(
    CONFIG, name="opt-1.3b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=256, vocab=256, max_position=512,
    grad_accum=1, attn_chunk=64, scan_chunk=32)
