"""RecurrentGemma-2B: RG-LRU + local attention, 1 attention per 2 recurrent
blocks (Griffin). [arXiv:2402.19427]

Sub-quadratic (hybrid): runs long_500k -- RG-LRU state is O(1); the local
attention keeps a 2048-token ring KV cache.
26 layers = 8 x (rec, rec, attn_local) + (rec, rec) remainder.
"""

import dataclasses

from .base import LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,               # MQA
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    block_pattern=("rec", "rec", "attn_local"),
    local_window=2048,
    d_rnn=2560,
    conv_k=4,
    activation="gelu",
    gated_mlp=True,
    norm_plus_one=True,
    tied_embeddings=True,
    embed_scale=True,
    rope_theta=10_000.0,
    shapes=LM_SHAPES,
    shard_heads=False,          # 10 heads cannot split 16-way TP
    grad_accum=4,
    notes="Griffin 1:2 hybrid; per-type parameter stacks + scan over periods",
)

SMOKE = dataclasses.replace(
    CONFIG, name="recurrentgemma-smoke", n_layers=8, d_model=64, n_heads=4,
    n_kv_heads=1, head_dim=16, d_ff=128, vocab=256, d_rnn=64,
    local_window=64, grad_accum=1, attn_chunk=32, scan_chunk=32)
