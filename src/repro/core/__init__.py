"""HALO core: the paper's contribution as a composable JAX library."""

from . import apply, assign, codebooks, outliers, pareto, quantize, schedule, sensitivity, tiling  # noqa: F401
from .quantize import HaloConfig, HaloQuantized, halo_quantize_tensor  # noqa: F401
