"""Quantize a whole model's parameter pytree with HALO (or leave some dense).

Selection policy (paper SIV-A: "attention and linear layers"): every 2-D
(or stacked 3-D/4-D, e.g. scan-over-layers or per-expert) matmul weight is
quantized; embeddings, norm scales, biases, convs, and recurrence diagonals
(Mamba A/dt, RG-LRU gates) stay dense.  Stacked leading axes (layers,
experts) are quantized independently per slice -- each slice is its own
matrix with its own tiles, classes, and sparse part, matching how the
hardware sees them.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .quantize import HaloConfig, HaloQuantized, halo_quantize_tensor

# param path regexes excluded from quantization
DEFAULT_EXCLUDE = (
    r".*norm.*", r".*scale.*", r".*bias.*", r".*embed.*", r".*pos_emb.*",
    r".*A_log.*", r".*dt_.*", r".*conv.*", r".*rglru.*gate.*", r".*lambda.*",
)


def default_should_quantize(path: str, x: jnp.ndarray,
                            quantize_lm_head: bool = False) -> bool:
    if x.ndim < 2 or x.dtype not in (jnp.float32, jnp.bfloat16, jnp.float16):
        return False
    if not quantize_lm_head and re.search(r".*(lm_head|output_proj_vocab).*", path):
        return False
    for pat in DEFAULT_EXCLUDE:
        if re.fullmatch(pat, path):
            return False
    # must look like a matmul weight: last two dims both >= one tile? no --
    # small eval models use small dims; require both >= 8 to skip vectors.
    return x.shape[-1] >= 8 and x.shape[-2] >= 8


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def quantize_params(params: Any,
                    fisher: Optional[Any] = None,
                    cfg: HaloConfig = HaloConfig(),
                    theta: Optional[float] = None,
                    should_quantize: Optional[Callable] = None) -> Any:
    """Return a pytree where selected weights are HaloQuantized.

    Leaves with >2 dims are quantized per leading-axis slice (layers stacked
    by scan, experts, etc.), preserving the stacked structure via vmap-free
    explicit slicing (quantization is offline; clarity > speed here).
    """
    sq = should_quantize or default_should_quantize
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    fisher_flat = None
    if fisher is not None:
        fisher_flat = [f for _, f in jax.tree_util.tree_flatten_with_path(fisher)[0]]

    out = []
    for i, (path, leaf) in enumerate(flat):
        pstr = _path_str(path)
        g2 = fisher_flat[i] if fisher_flat is not None else None
        if not sq(pstr, leaf):
            out.append(leaf)
            continue
        out.append(_quantize_leaf(leaf, g2, cfg, theta))
    return jax.tree_util.tree_unflatten(treedef, out)


def _quantize_leaf(leaf: jnp.ndarray, g2, cfg: HaloConfig, theta) -> Any:
    if leaf.ndim == 2:
        return halo_quantize_tensor(leaf, g2, cfg, theta=theta)
    # stacked: quantize each slice of the leading axes independently
    lead = leaf.shape[:-2]
    flat_lead = int(jnp.prod(jnp.asarray(lead)))
    w2 = leaf.reshape((flat_lead,) + leaf.shape[-2:])
    g22 = g2.reshape((flat_lead,) + leaf.shape[-2:]) if g2 is not None else None
    slices = [halo_quantize_tensor(w2[j], None if g22 is None else g22[j],
                                   cfg, theta=theta)
              for j in range(flat_lead)]
    return StackedHalo(slices=tuple(slices), lead_shape=lead)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StackedHalo:
    """Independently quantized slices of a stacked (L..., K, N) weight."""

    slices: Tuple[HaloQuantized, ...]
    lead_shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True),
                                                    default=())

    def dequantize(self) -> jnp.ndarray:
        mats = jnp.stack([s.dequantize() for s in self.slices])
        return mats.reshape(self.lead_shape + mats.shape[-2:])


def dequantize_params(qparams: Any, dtype=jnp.float32) -> Any:
    """Replace HaloQuantized/StackedHalo leaves with dense arrays."""

    def deq(x):
        if isinstance(x, (HaloQuantized, StackedHalo)):
            return x.dequantize().astype(dtype)
        return x

    return jax.tree.map(deq, qparams,
                        is_leaf=lambda x: isinstance(x, (HaloQuantized, StackedHalo)))


def effective_bits_of(qparams: Any) -> float:
    """Weight-population mean effective bits over every HALO-quantized
    leaf (paper SIV-B's B_eff, aggregated tree-wide).

    Dense leaves are excluded from the average -- an all-dense tree
    reports 16.0 (the fp16 deployment baseline).  Shared by the accuracy
    table and the serving scorecard so both report the same number for
    the same tree."""
    from .quantize import effective_bits
    bits = n = 0.0
    for leaf in jax.tree.leaves(
            qparams, is_leaf=lambda x: isinstance(x, (HaloQuantized,
                                                      StackedHalo))):
        hqs = ([leaf] if isinstance(leaf, HaloQuantized)
               else list(leaf.slices) if isinstance(leaf, StackedHalo)
               else [])
        for hq in hqs:
            sz = hq.shape[0] * hq.shape[1]
            bits += effective_bits(hq) * sz
            n += sz
    return bits / n if n else 16.0
