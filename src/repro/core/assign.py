"""Tile sensitivity mapping: the adaptive low/high-sensitivity split (SIII-B).

Per layer, tiles are ranked by their Fisher score.  Low-sensitivity tiles are
the largest prefix of the *ascending* ranking whose cumulative score stays
within ``1 - theta`` of the layer's total sensitivity -- i.e. the classes
retain at least ``theta`` (default 95%) of the layer's sensitivity mass at
high precision.  ``k`` (the low-sensitive fraction) therefore adapts to each
layer's sensitivity skew instead of using a fixed per-layer threshold.

Class semantics (paper SIII-C2):
  low-sensitivity  -> F3 codebook (9 values),  3.7 GHz tiles
  high-sensitivity -> F2 codebook (16 values), 2.4 GHz tiles
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from .codebooks import TILE_CLASS_F2, TILE_CLASS_F3


@dataclasses.dataclass(frozen=True)
class AssignResult:
    classes: jnp.ndarray   # (n_tiles,) int8 in {TILE_CLASS_F2, TILE_CLASS_F3}
    k: float               # realized low-sensitive fraction
    theta: float           # sensitivity retention target used


def compute_adaptive_k(scores: jnp.ndarray, theta: float) -> Tuple[jnp.ndarray, float]:
    """Boolean low-sensitivity mask + realized fraction k.

    scores: (n_tiles,) per-tile Fisher scores (Eq. 2).
    """
    total = scores.sum()
    order = jnp.argsort(scores)                    # ascending
    csum = jnp.cumsum(scores[order])
    budget = (1.0 - theta) * total
    n_low = jnp.sum(csum <= budget + 1e-30)        # largest prefix within budget
    low_sorted = jnp.arange(scores.shape[0]) < n_low
    low_mask = jnp.zeros_like(low_sorted).at[order].set(low_sorted)
    k = n_low / max(scores.shape[0], 1)
    return low_mask, k


def assign_classes(scores: jnp.ndarray, theta: float = 0.95) -> AssignResult:
    """Map per-tile scores to frequency classes for one layer."""
    low_mask, k = compute_adaptive_k(scores, theta)
    classes = jnp.where(low_mask, TILE_CLASS_F3, TILE_CLASS_F2).astype(jnp.int8)
    return AssignResult(classes=classes, k=float(k), theta=theta)
