"""Frequency-class codebooks derived from the MAC timing model.

HALO's non-uniform quantizer maps tile weights onto *codebooks of low
critical-path-delay values* (paper SIII-B).  From ``hw.mac_model``:

  F3 (3.7 GHz, 9 values):  {0, +-1, +-2, +-4, +-8}
  F2 (2.4 GHz, 16 values): F3  +  {+-16, +-32, +-64, -128}

Both books live in one shared 16-entry ascending table; the F3 subset is the
contiguous index range [F3_LO, F3_HI].  A tile's class therefore constrains
only which *indices* the assignment may use -- deployment keeps a single
16-entry LUT and uses the class purely for DVFS/grid scheduling, and every
stored index fits in 4 bits regardless of class.
"""

from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

from ..hw import mac_model

TILE_CLASS_F1, TILE_CLASS_F2, TILE_CLASS_F3 = 0, 1, 2
CLASS_NAMES = {TILE_CLASS_F1: "F1", TILE_CLASS_F2: "F2", TILE_CLASS_F3: "F3"}
CLASS_FREQ_GHZ = {TILE_CLASS_F1: mac_model.F1_GHZ,
                  TILE_CLASS_F2: mac_model.F2_GHZ,
                  TILE_CLASS_F3: mac_model.F3_GHZ}


@functools.lru_cache(maxsize=None)
def shared_table() -> np.ndarray:
    """(16,) int32 ascending: the F2 codebook; F3 is a contiguous slice."""
    classes = mac_model.frequency_classes()
    table = np.sort(classes["F2"]).astype(np.int32)
    assert table.size == 16
    return table


@functools.lru_cache(maxsize=None)
def f3_index_range() -> Tuple[int, int]:
    """[lo, hi] inclusive index range of F3 values inside the shared table."""
    table = shared_table()
    f3 = set(int(v) for v in mac_model.frequency_classes()["F3"])
    idx = [i for i, v in enumerate(table) if int(v) in f3]
    lo, hi = min(idx), max(idx)
    assert idx == list(range(lo, hi + 1)), "F3 must be contiguous in the table"
    assert hi - lo + 1 == 9
    return lo, hi


def class_codebook(cls: int) -> np.ndarray:
    """Codebook values available to a tile of frequency class `cls`."""
    table = shared_table()
    if cls == TILE_CLASS_F3:
        lo, hi = f3_index_range()
        return table[lo:hi + 1]
    if cls == TILE_CLASS_F2:
        return table
    if cls == TILE_CLASS_F1:
        return mac_model.WEIGHT_VALUES.copy()
    raise ValueError(cls)


def effective_bits(cls: int) -> float:
    """Stored bits per weight for a tile of this class (index width)."""
    return float(np.log2(class_codebook(cls).size))


def class_max_freq_ghz(cls: int) -> float:
    return CLASS_FREQ_GHZ[cls]
