"""Deployment-format HALO weights for serving (4-bit packed, XLA path).

For the multi-pod dry-run we cannot compile Pallas kernels on the CPU
backend, so the serving path also has a pure-XLA dequant: weights stored as
packed 4-bit codebook indices (two per uint8 byte) + per-tile-column fp32
scales, decoded arithmetically (the codebook is sign*2^k, so index->value is
+-exp2 -- no gather) and fed to the MXU.  HBM sees the 4-bit tensor, so the
dry-run's memory/collective terms reflect the paper's deployment: weight
read traffic /4 vs bf16.  On real TPU the Pallas `halo_matmul` kernel
replaces dequant+dot (kernels/halo_matmul.py; same layout).

The sparse outlier stream is <0.5% of weights; serving folds it with
kernels/spmv.py -- the dry-run's deploy path omits it (sub-1% traffic,
noted in EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.module import ParamSpec, tree_map_specs
from . import codebooks, tiling
from .quantize import HaloQuantized

TILE = 128


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeployQuantWeight:
    """4-bit-packed HALO weight (possibly layer-stacked)."""

    idx_packed: jnp.ndarray   # (..., K, N//2) uint8
    scale: jnp.ndarray        # (..., kt, nt, TILE) f32 per-tile-column
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True),
                                               default=())

    def dequantize(self, dtype=jnp.bfloat16) -> jnp.ndarray:
        lo = self.idx_packed & jnp.uint8(0xF)
        hi = self.idx_packed >> jnp.uint8(4)
        idx = jnp.stack([lo, hi], axis=-1).reshape(
            self.idx_packed.shape[:-1] + (self.idx_packed.shape[-1] * 2,))
        idxf = idx.astype(jnp.float32)
        val = jnp.where(idx < 8, -jnp.exp2(7.0 - idxf),
                        jnp.where(idx == 8, 0.0, jnp.exp2(idxf - 9.0)))
        kp, npk = val.shape[-2], val.shape[-1]
        kt, nt = kp // TILE, npk // TILE
        lead = val.shape[:-2]
        sc = self.scale
        v = val.reshape(lead + (kt, TILE, nt, TILE))
        v = v * sc[..., :, None, :, :]
        w = v.reshape(lead + (kp, npk))
        k, n = self.shape[-2], self.shape[-1]
        return w[..., :k, :n].astype(dtype)


def deploy_spec_of(spec: ParamSpec) -> Any:
    """ParamSpec of a matmul weight -> DeployQuantWeight of ParamSpecs.

    The scale tensor is laid out (kt, nt, TILE) carrying the weight's own
    logical axes on (kt, nt), so TP sharding of the weight shards its
    scales identically (no replicated multi-GiB scale arrays)."""
    *lead, k, n = spec.shape
    kp, npk = tiling.padded_dims(k, n, TILE)
    kt, nt = kp // TILE, npk // TILE
    lead_axes = spec.logical_axes[:-2]
    return DeployQuantWeight(
        idx_packed=ParamSpec(tuple(lead) + (kp, npk // 2),
                             lead_axes + spec.logical_axes[-2:],
                             jnp.uint8, "zeros"),
        scale=ParamSpec(tuple(lead) + (kt, nt, TILE),
                        lead_axes + spec.logical_axes[-2:] + (None,),
                        jnp.float32, "ones"),
        shape=tuple(spec.shape))


def deploy_model_specs(specs: Any, should_quantize=None) -> Any:
    """Replace quantizable matmul ParamSpecs with DeployQuantWeight specs.

    Selection shares ``default_should_quantize`` (path exclusions, dtype,
    min matmul dims) -- the only deploy-specific extra is the kernel's
    tile-size floor: both matmul dims must cover one 128x128 tile."""
    from .apply import _path_str, default_should_quantize
    sq = should_quantize or default_should_quantize

    flat, treedef = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    out = []
    for path, leaf in flat:
        tiled = (isinstance(leaf, ParamSpec) and len(leaf.shape) >= 2
                 and leaf.shape[-1] >= TILE and leaf.shape[-2] >= TILE)
        if tiled and sq(_path_str(path), leaf.abstract()):
            out.append(deploy_spec_of(leaf))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def pack_from_quantized(hq: HaloQuantized) -> DeployQuantWeight:
    """Runtime packing of a quantized 2-D tensor (for real serving)."""
    from ..kernels.ops import pack_halo
    packed = pack_halo(hq)
    kp, npk = packed.padded_shape
    kt, nt = kp // TILE, npk // TILE
    return DeployQuantWeight(idx_packed=packed.idx_packed,
                             scale=packed.scale.reshape(kt, nt, TILE),
                             shape=tuple(hq.shape))


# ---------------------------------------------------------------------------
# load-time pytree packing (the serving fast path)
# ---------------------------------------------------------------------------

def _is_quantized(x) -> bool:
    from .apply import StackedHalo
    return isinstance(x, (HaloQuantized, StackedHalo))


def _packable(hq: HaloQuantized) -> bool:
    return (hq.tile == TILE and hq.shape[0] >= TILE and hq.shape[1] >= TILE)


# one-time signal for pack_params calls that pack NOTHING (every quantized
# leaf under the 128-tile kernel floor, e.g. d_model=64 smoke configs) --
# without it such engines silently serve fully dense while callers report
# "packed" numbers.  Tests reset this to re-assert the warning.
_warned_all_dense = False


def n_packed_leaves(tree: Any) -> int:
    """Count ``HaloPacked`` leaves in a served weight tree.

    The scorecard/bench gate on this before labeling a run "packed": a
    quantized tree whose every leaf fell below the 128-tile kernel floor
    packs to zero ``HaloPacked`` leaves and serves fully dense."""
    from ..kernels.ops import HaloPacked

    def is_packed(x):
        return isinstance(x, HaloPacked)

    return sum(1 for leaf in jax.tree.leaves(tree, is_leaf=is_packed)
               if is_packed(leaf))


def pack_params(qparams: Any, scheduled: bool = True, *,
                specs: Any = None, mesh: Any = None,
                rules: Any = None) -> Any:
    """HaloQuantized/StackedHalo leaves -> kernel-ready ``HaloPacked``.

    Done ONCE at model load: packs 4-bit codebook indices, precomputes the
    class-grouped tile schedule, and buckets the sparse outlier stream into
    SpMV chunks.  Stacked (scan-over-layers / per-expert) weights become a
    single stacked ``HaloPacked`` whose leaves carry the stack dims, so the
    jitted decode scan slices them with zero per-token Python work.

    Leaves quantized with a non-kernel tile (tile != 128) or smaller than
    one tile fall back to dense bf16 -- they are the rare small matrices
    where the 4-bit stream buys nothing.  If EVERY quantized leaf falls
    back this way the result serves fully dense; a one-time warning fires
    so smoke-sized configs can't masquerade as packed runs (callers that
    must know for sure count ``n_packed_leaves`` on the result).

    Passing ``mesh`` (plus the matching ``model_specs`` tree as ``specs``)
    lays the packed leaves out tensor-parallel at pack time via
    ``shard_params`` -- the multi-device engines never hold a replicated
    copy of the 4-bit stream.
    """
    import warnings

    from ..kernels.ops import pack_halo, stack_packed
    from .apply import StackedHalo

    stats = {"quantized": 0, "packed": 0}

    def pack(leaf):
        if isinstance(leaf, HaloQuantized):
            stats["quantized"] += 1
            if _packable(leaf):
                stats["packed"] += 1
                return pack_halo(leaf, scheduled=scheduled)
            return leaf.dequantize().astype(jnp.bfloat16)
        if isinstance(leaf, StackedHalo):
            stats["quantized"] += 1
            if all(_packable(s) for s in leaf.slices):
                stats["packed"] += 1
                return stack_packed([pack_halo(s, scheduled=scheduled)
                                     for s in leaf.slices], leaf.lead_shape)
            return leaf.dequantize().astype(jnp.bfloat16)
        return leaf

    packed = jax.tree.map(pack, qparams, is_leaf=_is_quantized)
    global _warned_all_dense
    if stats["quantized"] and not stats["packed"] and not _warned_all_dense:
        _warned_all_dense = True
        warnings.warn(
            f"pack_params: 0 of {stats['quantized']} quantized leaves met "
            f"the {TILE}x{TILE} kernel tile floor (tile == {TILE} and both "
            f"matmul dims >= {TILE}); every leaf fell back to dense bf16, "
            f"so this model serves with NO packed kernels. Widen the "
            f"config or quantize with HaloConfig(tile={TILE}). "
            f"(warned once per process)", UserWarning, stacklevel=2)
    if mesh is not None:
        if specs is None:
            raise ValueError(
                "pack_params(mesh=...) needs the model_specs tree as "
                "specs= to resolve each leaf's logical axes")
        packed = shard_params(packed, specs, mesh, rules)
    return packed


def shard_params(params: Any, specs: Any, mesh, rules=None) -> Any:
    """Place a served weight tree on a device mesh by its logical axes.

    ``specs`` is the matching ``models.transformer.model_specs`` tree
    (ParamSpec leaves).  Dense leaves shard directly on their spec axes;
    ``HaloPacked`` / ``DeployQuantWeight`` leaves shard their packed
    4-bit index stream on the weight's own (K, N) axes via
    ``deploy_spec_of`` -- tensor-parallel sharding of a packed weight
    shards its stream identically -- while the small side tensors
    (schedules, outlier chunks, the kernel scale layout whose (kt*nt)
    fusion has no per-axis mapping) replicate.  A dense leaf whose shape
    no longer matches its spec also replicates: correct, just not
    distributed."""
    from ..dist import sharding as sh
    from ..kernels import ops as kops

    def _put(x, axes):
        return sh.shard_array(jnp.asarray(x), axes, mesh, rules)

    def _replicate(x):
        x = jnp.asarray(x)
        return _put(x, (None,) * x.ndim)

    def place(spec, leaf):
        if isinstance(leaf, kops.HaloPacked):
            d = deploy_spec_of(spec)
            return dataclasses.replace(
                leaf,
                idx_packed=_put(leaf.idx_packed, d.idx_packed.logical_axes),
                scale=_replicate(leaf.scale),
                order_kt=_replicate(leaf.order_kt),
                order_nt=_replicate(leaf.order_nt),
                order_first=_replicate(leaf.order_first),
                order_last=_replicate(leaf.order_last),
                chunks=(None if leaf.chunks is None
                        else jax.tree.map(_replicate, leaf.chunks)))
        if isinstance(leaf, DeployQuantWeight):
            d = deploy_spec_of(spec)
            return dataclasses.replace(
                leaf,
                idx_packed=_put(leaf.idx_packed, d.idx_packed.logical_axes),
                scale=_put(leaf.scale, d.scale.logical_axes))
        x = jnp.asarray(leaf)
        axes = (spec.logical_axes if x.shape == tuple(spec.shape)
                else (None,) * x.ndim)
        return _put(x, axes)

    return jax.tree.map(place, specs, params,
                        is_leaf=lambda s: isinstance(s, ParamSpec))


# ---------------------------------------------------------------------------
# slot-sliceable cache helpers (continuous-batching serving)
# ---------------------------------------------------------------------------
#
# The continuous scheduler (serving/scheduler.py + serving/batch.py) keeps
# one capacity-sized cache resident on device and admits/evicts requests by
# batch row.  Cache pytrees mix layouts (layer-stacked KV, SSM/RG-LRU
# states), so the row ops key off ``cache_logical_axes`` to find each
# leaf's batch axis.  All three are jit-safe with a traced ``slot``: one
# compilation serves every slot.
#
# Paged mode (the cache dict carries a ``"page_table"`` leaf) changes the
# ownership story: a slot owns a page-table ROW, not KV data rows.  The
# row ops become page-table remaps -- pools pass through gathers
# untouched (appends write the seats' physical frames in place), eviction
# resets the slot's page-table row to the sentinel in O(pages) with no
# gather or zeroing of KV data (freed frames are recycled by the host
# allocator; a new tenant overwrites every frame position it can read).

def _is_paged(cache: Any) -> bool:
    return isinstance(cache, dict) and "page_table" in cache


def _cache_axes(cfg, cache: Any):
    from ..models.transformer import cache_logical_axes
    return cache_logical_axes(cfg, paged=_is_paged(cache))


def cache_slot_insert(cfg, cache: Any, sub: Any, slot) -> Any:
    """Write a batch-1 sub-cache (same max_seq) into batch row ``slot``.
    Contiguous-only: paged slots are populated through ``prefill_append``
    (frames are written in place; there is no dense row to insert)."""
    if _is_paged(cache):
        raise NotImplementedError(
            "cache_slot_insert is contiguous-only; paged slots are "
            "populated via serving.batch.prefill_append")

    def ins(big, small, axes):
        bpos = axes.index("batch")
        start = [0] * big.ndim
        start[bpos] = slot
        return jax.lax.dynamic_update_slice(big, small.astype(big.dtype),
                                            start)

    return jax.tree.map(ins, cache, sub, _cache_axes(cfg, cache))


def cache_slot_evict(cfg, cache: Any, slot) -> Any:
    """Free batch row ``slot``.

    Contiguous: zero the row (hygiene on request completion: a recycled
    slot never observes the previous tenant's state even if an admission
    bug skipped the insert).  Paged: reset the slot's page-table row to
    the sentinel -- O(pages) int32 writes, the pools are untouched (a
    recycled frame's stale data is unreachable: every position a new
    tenant can attend is written by its own prefill/decode first) -- and
    zero the batch-major leaves (SSM/RG-LRU/ring state) as before."""
    if _is_paged(cache):
        from ..models.transformer import PAGE_SENTINEL
        body = {k: v for k, v in cache.items() if k != "page_table"}
        axes = _cache_axes(cfg, cache)

        def clr(big, leaf_axes):
            if "pages" in leaf_axes:
                return big
            bpos = leaf_axes.index("batch")
            row = big.shape[:bpos] + (1,) + big.shape[bpos + 1:]
            start = [0] * big.ndim
            start[bpos] = slot
            return jax.lax.dynamic_update_slice(
                big, jnp.zeros(row, big.dtype), start)

        out = jax.tree.map(clr, body,
                           {k: v for k, v in axes.items()
                            if k != "page_table"})
        pt = cache["page_table"]
        out["page_table"] = jax.lax.dynamic_update_slice(
            pt, jnp.full((1, pt.shape[1]), PAGE_SENTINEL, pt.dtype),
            [slot, 0])
        return out

    def clr(big, axes):
        bpos = axes.index("batch")
        row = big.shape[:bpos] + (1,) + big.shape[bpos + 1:]
        start = [0] * big.ndim
        start[bpos] = slot
        return jax.lax.dynamic_update_slice(big, jnp.zeros(row, big.dtype),
                                            start)

    return jax.tree.map(clr, cache, _cache_axes(cfg, cache))


def cache_slot_slice(cfg, cache: Any, slot) -> Any:
    """Read batch row ``slot`` back as a batch-1 sub-cache.
    Contiguous-only (a paged slot's KV lives in shared pools; use
    ``cache_rows_gather``, which hands pools through by reference)."""
    if _is_paged(cache):
        raise NotImplementedError(
            "cache_slot_slice is contiguous-only; paged callers read "
            "through the page table (cache_rows_gather)")

    def rd(big, axes):
        bpos = axes.index("batch")
        start = [0] * big.ndim
        start[bpos] = slot
        sizes = list(big.shape)
        sizes[bpos] = 1
        return jax.lax.dynamic_slice(big, start, sizes)

    return jax.tree.map(rd, cache, _cache_axes(cfg, cache))


def cache_rows_gather(cfg, cache: Any, slots: jnp.ndarray) -> Any:
    """Read batch rows ``slots`` ((K,) int32) as a batch-K sub-cache.

    The k-way generalization of ``cache_slot_slice`` backing the fused
    admission path (serving/batch.prefill_append): one gather pulls every
    seat's cache row so a K-seat prefill window runs as one batch-K model
    call instead of K batch-1 calls.  Out-of-range slot ids (the padded
    seats of a partially filled admission group) clamp to the last row --
    callers mask those seats, so the garbage row is never consumed.

    Paged leaves ("pages" axis) pass through UNgathered: the sub-cache
    carries the shared pools by reference plus the K seats' page-table
    rows, so a K-seat append still costs O(K) rows of bookkeeping, never
    a copy of anyone's KV data."""
    axes_tree = _cache_axes(cfg, cache)

    def rd(big, axes):
        if "pages" in axes:
            return big
        bpos = axes.index("batch")
        return jnp.take(big, slots, axis=bpos, mode="clip")

    return jax.tree.map(rd, cache, axes_tree)


def cache_rows_scatter(cfg, cache: Any, sub: Any, slots: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None) -> Any:
    """Write a batch-K sub-cache back into batch rows ``slots``.

    The k-way generalization of ``cache_slot_insert``.  Seats with
    ``mask`` False (or an out-of-range slot id) are routed out of bounds,
    where scatter's drop semantics discard the update wholesale -- the
    order-safe way to no-op padded seats (substituting "old" values for
    masked seats would race a live write when a padded seat duplicates a
    live seat's slot id).  Live seats must hold distinct slots.

    Paged leaves take the sub-cache's pool wholesale: the append already
    scattered the seats' frames in place (masked window slots dropped at
    the sentinel), so "scatter back" is the identity on KV data."""
    axes_tree = _cache_axes(cfg, cache)

    def wr(big, small, axes):
        if "pages" in axes:
            return small.astype(big.dtype)
        bpos = axes.index("batch")
        sl = slots if mask is None else jnp.where(mask, slots,
                                                  big.shape[bpos])
        x = jnp.moveaxis(big, bpos, 0)
        s = jnp.moveaxis(small.astype(big.dtype), bpos, 0)
        return jnp.moveaxis(x.at[sl].set(s), 0, bpos)

    return jax.tree.map(wr, cache, sub, axes_tree)


def cache_page_copy(cfg, cache: Any, src, dst) -> Any:
    """Duplicate physical frame ``src`` into frame ``dst`` in EVERY paged
    pool leaf (K/V and, in int8 mode, their scale pools) -- the
    fork-on-write data move: before a write may land in a refcount-shared
    frame, the frame is copied to a private one and the single page-table
    entry remapped (serving.batch.fork_page / the admission-time fork in
    serving.engine).  The page table and every batch-major leaf pass
    through untouched; non-paged caches are returned as-is.

    On TPU each leaf's frame is copied through the Pallas DMA primitive
    (kernels.paged_decode.page_copy -- one frame of VMEM residency, no
    dense gather); the XLA lowering ``pool.at[dst].set(pool[src])`` is
    bitwise-identical and serves everywhere else."""
    if not _is_paged(cache):
        return cache
    from ..kernels.ops import default_interpret
    use_kernel = not default_interpret()

    def cp(leaf, axes):
        if "pages" not in axes:
            return leaf
        ppos = axes.index("pages")                    # 0 or 1 (layers)
        if use_kernel:
            from ..kernels.paged_decode import page_copy
            return page_copy(leaf, src, dst, stacked=ppos == 1,
                             interpret=False)
        if ppos == 0:
            return leaf.at[dst].set(leaf[src])
        return leaf.at[:, dst].set(leaf[:, src])

    return jax.tree.map(cp, cache, _cache_axes(cfg, cache))


def cache_frames_gather(cfg, cache: Any, frames: jnp.ndarray) -> list:
    """Read physical frames ``frames`` ((N,) int32) out of every paged
    pool leaf as compact per-leaf buffers -- the device half of
    preemption swap-OUT: a victim's private frames are gathered into
    (N, page, ...) / (layers, N, page, ...) arrays the host then pulls
    into its swap pool (O(pages) data, never a dense row).

    Returns a LIST of arrays in the cache's flatten order (pool leaves
    only); ``cache_frames_scatter`` consumes the same order.  Callers
    pad ``frames`` to a bounded width set (out-of-range ids clamp, the
    garbage rows are dropped on scatter), so compilations stay bounded
    regardless of how many frames each preemption happens to move."""
    out: list = []

    def rd(leaf, axes):
        if "pages" in axes:
            out.append(jnp.take(leaf, frames, axis=axes.index("pages"),
                                mode="clip"))
        return leaf

    jax.tree.map(rd, cache, _cache_axes(cfg, cache))
    return out


def cache_frames_scatter(cfg, cache: Any, data: list,
                         frames: jnp.ndarray) -> Any:
    """Write ``cache_frames_gather``-shaped buffers back into physical
    frames ``frames`` -- the device half of preemption swap-IN (resume
    scatters the host pool's copy into freshly allocated frames).
    Out-of-range frame ids (the padding lanes) drop their rows, so the
    padded tail of a bucketed transfer is a no-op."""
    it = iter(data)

    def wr(leaf, axes):
        if "pages" not in axes:
            return leaf
        d = next(it)
        if axes.index("pages") == 0:
            return leaf.at[frames].set(d.astype(leaf.dtype), mode="drop")
        return leaf.at[:, frames].set(d.astype(leaf.dtype), mode="drop")

    return jax.tree.map(wr, cache, _cache_axes(cfg, cache))


def cache_hostrow_gather(cfg, cache: Any, slot) -> list:
    """Read batch row ``slot`` of every BATCH-major cache leaf (SSM /
    RG-LRU / ring state -- and, in mixed paged architectures, the
    contiguous KV rows) as a list in flatten order, each leaf keeping a
    size-1 batch axis.  Page pools and the page table are excluded: a
    preempted slot's paged KV travels per-frame (``cache_frames_*``)
    and its page-table row is rebuilt host-side on resume.  Fully
    pageable architectures return an empty list (preemption then moves
    only frames)."""
    out: list = []

    def rd(leaf, axes):
        if "pages" in axes:
            return leaf
        bpos = axes.index("batch")
        start = [0] * leaf.ndim
        start[bpos] = slot
        sizes = list(leaf.shape)
        sizes[bpos] = 1
        out.append(jax.lax.dynamic_slice(leaf, start, sizes))
        return leaf

    body = {k: v for k, v in cache.items() if k != "page_table"}
    axes = {k: v for k, v in _cache_axes(cfg, cache).items()
            if k != "page_table"}
    jax.tree.map(rd, body, axes)
    return out


def cache_hostrow_scatter(cfg, cache: Any, data: list, slot) -> Any:
    """Write ``cache_hostrow_gather``-shaped rows back into batch row
    ``slot`` (page pools and the page table pass through untouched)."""
    it = iter(data)

    def wr(leaf, axes):
        if "pages" in axes:
            return leaf
        d = next(it)
        bpos = axes.index("batch")
        start = [0] * leaf.ndim
        start[bpos] = slot
        return jax.lax.dynamic_update_slice(leaf, d.astype(leaf.dtype),
                                            start)

    body = {k: v for k, v in cache.items() if k != "page_table"}
    axes = {k: v for k, v in _cache_axes(cfg, cache).items()
            if k != "page_table"}
    out = jax.tree.map(wr, body, axes)
    if "page_table" in cache:
        out["page_table"] = cache["page_table"]
    return out


def cache_rows_scatter_dense(cfg, cache: Any, sub: Any, slots: jnp.ndarray,
                             mask: Optional[jnp.ndarray] = None) -> Any:
    """Write a CONTIGUOUS batch-K sub-cache (the ``T.prefill`` layout:
    dense (K, max_seq, ...) KV rows, no page table) into ``cache``.

    Contiguous caches: identical to ``cache_rows_scatter``.  Paged
    caches: each dense row is split into page_size strips and scattered
    to the seat's physical frames through its page-table row -- the
    bridge that lets the ``fresh`` fast path (blockwise one-shot prefill
    of whole short prompts) stay numerically identical in paged mode.
    Strips beyond a seat's reservation hit sentinel entries and drop."""
    if not _is_paged(cache):
        return cache_rows_scatter(cfg, cache, sub, slots, mask=mask)

    from ..models.transformer import PAGE_SENTINEL
    pt = cache["page_table"]
    cap = pt.shape[0]
    slots_c = jnp.clip(slots, 0, cap - 1)
    rows = pt[slots_c]                                    # (K, P)
    seat_ok = (slots >= 0) & (slots < cap)
    if mask is not None:
        seat_ok &= mask
    rows = jnp.where(seat_ok[:, None], rows, jnp.int32(PAGE_SENTINEL))
    axes_tree = _cache_axes(cfg, cache)
    body = {k: v for k, v in cache.items() if k != "page_table"}
    body_axes = {k: v for k, v in axes_tree.items() if k != "page_table"}

    def wr(big, small, axes):
        if "pages" in axes:
            ppos = axes.index("pages")                    # 0 or 1 (layers)
            ps = big.shape[ppos + 1]
            if ppos == 0:
                k, s = small.shape[0], small.shape[1]
                strips = small.reshape((k, s // ps, ps) + small.shape[2:])
                return big.at[rows].set(strips.astype(big.dtype))
            lyr, k, s = small.shape[0], small.shape[1], small.shape[2]
            strips = small.reshape((lyr, k, s // ps, ps) + small.shape[3:])
            return big.at[:, rows].set(strips.astype(big.dtype))
        bpos = axes.index("batch")
        sl = jnp.where(seat_ok, slots, big.shape[bpos])
        x = jnp.moveaxis(big, bpos, 0)
        s = jnp.moveaxis(small.astype(big.dtype), bpos, 0)
        return jnp.moveaxis(x.at[sl].set(s), 0, bpos)

    out = jax.tree.map(wr, body, sub, body_axes)
    out["page_table"] = pt
    return out


def truncate_params(params: Any, cfg, n_layers: int) -> Tuple[Any, Any]:
    """Slice a truncated-layer draft model out of a full param tree.

    Returns ``(draft_params, draft_cfg)`` where the draft runs the FIRST
    ``n_layers`` blocks of the full model and shares every weight with it
    (slices view the stacked period leaves; nothing is re-packed or
    copied, so a resident engine pays no extra weight HBM for its
    drafter).  Works on any leaf type the period stacks hold -- dense
    arrays, ``HaloPacked``, ``DeployQuantWeight`` -- because all of them
    are pytrees whose array leaves carry the layer stack on axis 0 and
    whose static ``shape`` metadata is per-slice (or only consumed via
    its trailing (K, N) dims).

    The self-speculative drafter in serving/engine.py is the consumer:
    the draft's early-layer pass approximates the full model's next-token
    argmax well on trained weights (logit-lens regime), and any
    disagreement only costs acceptance rate, never correctness."""
    if not 1 <= n_layers < cfg.n_layers:
        raise ValueError(
            f"draft n_layers must be in [1, {cfg.n_layers - 1}], "
            f"got {n_layers}")
    pat = len(cfg.block_pattern)
    dp, leftover = divmod(n_layers, pat)
    out = {k: v for k, v in params.items()
           if k not in ("period", "remainder")}
    out["period"] = tuple(jax.tree.map(lambda x: x[:dp], stack)
                          for stack in params["period"])
    if dp < cfg.n_periods:
        rem = tuple(jax.tree.map(lambda x: x[dp], params["period"][j])
                    for j in range(leftover))
    else:
        rem = tuple(params["remainder"][:leftover])
    out["remainder"] = rem
    draft_cfg = dataclasses.replace(cfg, n_layers=n_layers)
    return out, draft_cfg


def packed_tile_classes(packed) -> np.ndarray:
    """Per-tile frequency class, read off a packed 4-bit index stream.

    Returns int8 ``(..., kt*nt)`` of ``codebooks.TILE_CLASS_*`` ids, leading
    dims mirroring the leaf's stack dims.  A tile is F3 iff every index it
    stores lies in the contiguous F3 sub-range of the shared 16-entry table
    (``codebooks.f3_index_range``); zero-padded tiles quantize to the F3
    "0" entry and so admit the fastest clock -- correct, those MACs
    multiply by zero.  The packed stream is the deployment ground truth:
    ``HaloQuantized.classes`` is not retained by ``pack_params``, so DVFS
    planning reads classes back from what the kernel actually executes.

    Note the read-back is conservative-in-reverse: an F2-*labeled* tile
    whose assignment happened to use only F3-range indices reads back as
    F3.  That is the right answer for DVFS (the executed index stream is
    what bounds the critical path), so labeled-F3 implies read-back-F3 but
    not conversely."""
    from .codebooks import TILE_CLASS_F2, TILE_CLASS_F3, f3_index_range

    idx = np.asarray(jax.device_get(packed.idx_packed))
    full = np.stack([idx & 0xF, idx >> 4], axis=-1).reshape(
        idx.shape[:-1] + (2 * idx.shape[-1],))
    kp, npk = full.shape[-2], full.shape[-1]
    kt, nt = kp // TILE, npk // TILE
    lead = full.shape[:-2]
    tiles = full.reshape(lead + (kt, TILE, nt, TILE))
    f3_lo, f3_hi = f3_index_range()
    is_f3 = ((tiles.min(axis=(-3, -1)) >= f3_lo)
             & (tiles.max(axis=(-3, -1)) <= f3_hi))
    cls = np.where(is_f3, TILE_CLASS_F3, TILE_CLASS_F2).astype(np.int8)
    return cls.reshape(lead + (kt * nt,))


def layer_class_composition(params: Any, cfg) -> List[Dict[str, Any]]:
    """Per-layer weight-class composition of a packed serving tree.

    Walks the period/remainder layer layout (the same slicing as
    ``truncate_params``) and reads each ``HaloPacked`` leaf's tile classes
    off its packed index stream.  Returns one record per transformer layer,
    plus a trailing ``layer=None`` record for packed non-block leaves (the
    unembed head), each::

      {"layer": int | None, "pattern": str | None,
       "leaves": [{"name", "shape", "classes": np.int8 (tiles,)}],
       "counts": {"F3": int, "F2": int, ...}, "n_tiles": int}

    Dense (unpacked) leaves carry no class schedule and do not appear.
    Trees without the period/remainder layout return ``[]``.  This is the
    feed for the serving autotuner's DVFS schedule and cost models
    (serving/autotune.py)."""
    from ..kernels.ops import HaloPacked
    from .codebooks import CLASS_NAMES

    def is_packed(x):
        return isinstance(x, HaloPacked)

    def packed_items(tree):
        flat = jax.tree_util.tree_flatten_with_path(
            tree, is_leaf=is_packed)[0]
        return [(jax.tree_util.keystr(path), leaf)
                for path, leaf in flat if is_packed(leaf)]

    def record(layer, pattern, leaves):
        counts: Dict[str, int] = {}
        recs = []
        for name, shape, cls in leaves:
            cls = np.asarray(cls).reshape(-1)
            ids, cnt = np.unique(cls, return_counts=True)
            for i, c in zip(ids.tolist(), cnt.tolist()):
                nm = CLASS_NAMES[int(i)]
                counts[nm] = counts.get(nm, 0) + int(c)
            recs.append({"name": name, "shape": tuple(shape), "classes": cls})
        return {"layer": layer, "pattern": pattern, "leaves": recs,
                "counts": counts,
                "n_tiles": int(sum(r["classes"].size for r in recs))}

    if not isinstance(params, dict) or "period" not in params:
        return []
    pat = len(cfg.block_pattern)
    period = params.get("period", ())
    remainder = params.get("remainder", ())
    period_cls = [[(name, leaf.shape, packed_tile_classes(leaf))
                   for name, leaf in packed_items(stack)]
                  for stack in period]
    out = []
    for layer in range(cfg.n_layers):
        dp_i, j = divmod(layer, pat)
        if dp_i < cfg.n_periods:
            leaves = [(name, shape, cls[dp_i])
                      for name, shape, cls in period_cls[j]]
        else:
            leaves = [(name, leaf.shape, packed_tile_classes(leaf))
                      for name, leaf in packed_items(
                          remainder[layer - cfg.n_periods * pat])]
        out.append(record(layer, cfg.block_pattern[j], leaves))
    head = {k: v for k, v in params.items()
            if k not in ("period", "remainder")}
    head_leaves = [(name, leaf.shape, packed_tile_classes(leaf))
                   for name, leaf in packed_items(head)]
    if head_leaves:
        out.append(record(None, None, head_leaves))
    return out


def deploy_params(qparams: Any) -> Any:
    """HaloQuantized/StackedHalo leaves -> ``DeployQuantWeight``.

    The XLA-dequant serving path: HBM holds 4-bit weights, every matmul
    rematerializes bf16 via arithmetic decode.  Kept as the portability
    fallback and as the benchmark baseline the packed kernel path is
    measured against (benchmarks/serving_latency.py)."""
    from .apply import StackedHalo

    def pack(leaf):
        if isinstance(leaf, HaloQuantized):
            if _packable(leaf):
                return pack_from_quantized(leaf)
            return leaf.dequantize().astype(jnp.bfloat16)
        if isinstance(leaf, StackedHalo):
            if all(_packable(s) for s in leaf.slices):
                slices = [pack_from_quantized(s) for s in leaf.slices]
                lead = leaf.lead_shape
                return DeployQuantWeight(
                    idx_packed=jnp.stack(
                        [s.idx_packed for s in slices]).reshape(
                            lead + slices[0].idx_packed.shape),
                    scale=jnp.stack([s.scale for s in slices]).reshape(
                        lead + slices[0].scale.shape),
                    shape=lead + tuple(slices[0].shape))
            return leaf.dequantize().astype(jnp.bfloat16)
        return leaf

    return jax.tree.map(pack, qparams, is_leaf=_is_quantized)
