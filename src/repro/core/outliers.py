"""Outlier & salient-weight extraction and hypersparse packaging (SIII-A/C1).

Outliers: values beyond 3 sigma of the tensor's weight distribution (3-sigma
rule / IQR-style extreme-value handling).  Salient: top `salient_frac`
(default 0.05%) by diagonal-Fisher score among the remaining values.
Together <0.5% of weights; they are removed from the dense matrix (zeroed),
uniformly quantized to 8 bits with per-output-channel scales, and stored as a
COO ``(row, col, val_int8)`` triple for the SpMV engine.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SparseWeights:
    """Hypersparse per-channel-int8 weights of one (K, N) matrix."""

    row: jnp.ndarray        # (nnz,) int32 -- K index
    col: jnp.ndarray        # (nnz,) int32 -- N index
    val: jnp.ndarray        # (nnz,) int8
    chan_scale: jnp.ndarray  # (N,) float32 per-output-channel scale
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True),
                                               default=(0, 0))

    @property
    def nnz(self) -> int:
        return int(self.row.shape[0])

    def to_dense(self) -> jnp.ndarray:
        dense = jnp.zeros(self.shape, jnp.float32)
        vals = self.val.astype(jnp.float32) * self.chan_scale[self.col]
        return dense.at[self.row, self.col].add(vals)

    def matmul(self, x: jnp.ndarray) -> jnp.ndarray:
        """x @ W_sparse for x (..., K) -> (..., N); pure-JAX SpMV reference."""
        contrib = x[..., self.row] * (self.val.astype(x.dtype)
                                      * self.chan_scale.astype(x.dtype)[self.col])
        n = self.shape[1]
        return jax.ops.segment_sum(contrib.swapaxes(-1, 0), self.col,
                                   num_segments=n).swapaxes(-1, 0) \
            if contrib.ndim > 1 else jax.ops.segment_sum(contrib, self.col, n)


def outlier_mask(w: jnp.ndarray, n_sigma: float = 3.0) -> jnp.ndarray:
    """Paper: values beyond n_sigma std-devs of the mean are outliers."""
    mu, sd = w.mean(), w.std()
    return jnp.abs(w - mu) > n_sigma * sd


def salient_mask(scores: jnp.ndarray, frac: float = 0.0005,
                 exclude: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Top-`frac` weights by Fisher score, excluding already-extracted ones."""
    s = jnp.where(exclude, -jnp.inf, scores) if exclude is not None else scores
    k = max(int(round(frac * s.size)), 1)
    thresh = jax.lax.top_k(s.reshape(-1), k)[0][-1]
    m = s >= thresh
    if exclude is not None:
        m = m & ~exclude
    return m


def extract_sparse(w: jnp.ndarray, mask: jnp.ndarray,
                   max_nnz: Optional[int] = None) -> Tuple[jnp.ndarray, SparseWeights]:
    """Split `w` into (dense remainder, SparseWeights of masked entries).

    `max_nnz` fixes the buffer size for jit-stability; defaults to the exact
    count (host-computed, so call outside jit or pass it explicitly).
    """
    k, n = w.shape
    flat_mask = mask.reshape(-1)
    if max_nnz is None:
        max_nnz = int(jax.device_get(flat_mask.sum()))
    nnz_idx = jnp.nonzero(flat_mask, size=max_nnz, fill_value=k * n)[0]
    valid = nnz_idx < k * n
    row = jnp.where(valid, nnz_idx // n, 0).astype(jnp.int32)
    col = jnp.where(valid, nnz_idx % n, 0).astype(jnp.int32)
    vals_f = jnp.where(valid, w.reshape(-1)[jnp.clip(nnz_idx, 0, k * n - 1)], 0.0)

    # per-output-channel 8-bit scales over the extracted values
    absmax = jnp.zeros((n,), w.dtype).at[col].max(jnp.abs(vals_f))
    chan_scale = jnp.where(absmax > 0, absmax / 127.0, 1.0).astype(jnp.float32)
    val = jnp.clip(jnp.round(vals_f / chan_scale[col]), -128, 127).astype(jnp.int8)

    dense = jnp.where(mask, 0.0, w)
    sp = SparseWeights(row=row, col=col, val=val, chan_scale=chan_scale,
                       shape=(k, n))
    return dense, sp


def split_salient_and_outliers(
    w: jnp.ndarray,
    fisher_g2: Optional[jnp.ndarray],
    n_sigma: float = 3.0,
    salient_frac: float = 0.0005,
    max_nnz: Optional[int] = None,
) -> Tuple[jnp.ndarray, SparseWeights, jnp.ndarray]:
    """Alg. 1 lines 1-3.  Returns (dense remainder, sparse part, mask)."""
    out_m = outlier_mask(w, n_sigma)
    if fisher_g2 is not None and salient_frac > 0:
        sal_m = salient_mask(fisher_g2, salient_frac, exclude=out_m)
        mask = out_m | sal_m
    else:
        mask = out_m
    dense, sparse = extract_sparse(w, mask, max_nnz=max_nnz)
    return dense, sparse, mask
