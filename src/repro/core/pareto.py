"""Adaptive quantization + DVFS optimization (paper SIII-C / Fig. 1).

HALO exposes user-defined design goals; the feedback optimizer constrains
the number of tiles allocated to each DVFS level by tuning the sensitivity
retention ``theta`` until the model meets the goal.  We expose the paper's
three named variants plus a generic target-driven search:

  perf-opt : minimize latency -- small theta, nearly all tiles in F3
  acc-opt  : minimize quantization error -- large theta, most tiles in F2
  bal      : knee of the (latency, error) curve

The latency estimate comes from the systolic simulator; the error proxy is
the Fisher-weighted quantization MSE  sum_tiles Lambda_T * ||W - Q(W)||^2,
which tracks the loss perturbation to second order (same approximation the
sensitivity analysis itself uses).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..hw import systolic
from . import assign, codebooks
from .quantize import HaloConfig, HaloQuantized, halo_quantize_tensor

VARIANT_THETA = {"perf-opt": 0.60, "bal": 0.95, "acc-opt": 0.995}


@dataclasses.dataclass
class ParetoPoint:
    theta: float
    f3_fraction: float
    effective_bits: float
    error_proxy: float          # Fisher-weighted quant MSE
    est_speedup_vs_f1: float    # compute-bound speedup from class mix

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _class_mix_speedup(f3_frac: float) -> float:
    """Compute-time speedup vs. running everything at the F1 clock."""
    f2_frac = 1.0 - f3_frac
    t = f3_frac / codebooks.CLASS_FREQ_GHZ[2] + f2_frac / codebooks.CLASS_FREQ_GHZ[1]
    return (1.0 / codebooks.CLASS_FREQ_GHZ[0]) / t


def sweep_theta(weights: Dict[str, jnp.ndarray],
                fisher: Dict[str, jnp.ndarray],
                cfg: HaloConfig = HaloConfig(),
                thetas: Sequence[float] = (0.5, 0.7, 0.85, 0.95, 0.99, 0.999),
                ) -> List[ParetoPoint]:
    """Quantize the model at several theta values and report the frontier."""
    from .quantize import effective_bits, quant_error  # local to avoid cycle
    points = []
    for theta in thetas:
        err, bits_num, bits_den, f3_tiles, n_tiles = 0.0, 0.0, 0.0, 0, 0
        for name, w in weights.items():
            hq = halo_quantize_tensor(w, fisher.get(name), cfg, theta=theta)
            g2 = fisher.get(name)
            lam = 1.0 if g2 is None else float(jnp.mean(g2))
            diff = hq.dequantize() - w.astype(jnp.float32)
            err += lam * float(jnp.sum(diff * diff))
            bits_num += effective_bits(hq) * w.size
            bits_den += w.size
            f3_tiles += int((np.asarray(hq.classes) == codebooks.TILE_CLASS_F3).sum())
            n_tiles += hq.n_tiles
        f3f = f3_tiles / max(n_tiles, 1)
        points.append(ParetoPoint(
            theta=theta, f3_fraction=f3f,
            effective_bits=bits_num / max(bits_den, 1),
            error_proxy=err,
            est_speedup_vs_f1=_class_mix_speedup(f3f)))
    return points


def knee_point(points: Sequence[ParetoPoint]) -> ParetoPoint:
    """Max perpendicular distance from the (speedup, -error) chord -- the
    paper's Fig. 9 'knee' selection."""
    xs = np.array([p.est_speedup_vs_f1 for p in points])
    ys = np.array([np.log10(p.error_proxy + 1e-30) for p in points])
    x0, y0, x1, y1 = xs[0], ys[0], xs[-1], ys[-1]
    denom = np.hypot(x1 - x0, y1 - y0) + 1e-12
    d = np.abs((y1 - y0) * xs - (x1 - x0) * ys + x1 * y0 - y1 * x0) / denom
    return points[int(np.argmax(d))]


def theta_for_target_bits(weights: Dict[str, jnp.ndarray],
                          fisher: Dict[str, jnp.ndarray],
                          target_bits: float,
                          cfg: HaloConfig = HaloConfig(),
                          iters: int = 8) -> float:
    """Feedback loop: bisect theta so B_eff hits `target_bits` (3.17..4)."""
    from .quantize import effective_bits
    lo, hi = 0.0, 1.0

    def bits_at(theta: float) -> float:
        num = den = 0.0
        for name, w in weights.items():
            hq = halo_quantize_tensor(w, fisher.get(name), cfg, theta=theta)
            num += effective_bits(hq) * w.size
            den += w.size
        return num / max(den, 1)

    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if bits_at(mid) > target_bits:
            hi = mid       # too many F2 tiles -> lower retention
        else:
            lo = mid
    return 0.5 * (lo + hi)


def variant_theta(variant: str) -> float:
    try:
        return VARIANT_THETA[variant]
    except KeyError:
        raise KeyError(f"unknown HALO variant {variant!r}; "
                       f"options: {sorted(VARIANT_THETA)}") from None
