"""HALO Algorithm 1: critical-path-delay-aware non-uniform quantization.

Per weight matrix ``W (K, N)``:

  1. extract salient (top Fisher) + outlier (3 sigma) weights -> hypersparse
     per-channel-int8 part (lines 1-3),
  2. reshape the remainder into ``t x t`` tiles (line 4),
  3. per-tile Fisher scores -> adaptive low/high-sensitivity classes (5-6),
  4. quantize each tile onto its class codebook (F3: 9 values, F2: 16 values;
     both are sign*2^k "low critical-path" sets) with an MSE-optimal per-tile
     scale found by line search (7-9),
  5. emit ``HaloQuantized``: 4-bit codebook indices + per-tile fp scale +
     per-tile frequency class + the sparse part (10).

The class only *restricts the index range* used by a tile -- all indices live
in one shared 16-entry table, so deployment keeps a single LUT and uses the
class purely for DVFS scheduling (``core.schedule``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import assign, codebooks, outliers, sensitivity, tiling
from .outliers import SparseWeights

DEFAULT_TILE = 128
DEFAULT_THETA = 0.95
SCALE_GRID = np.geomspace(0.12, 1.15, 32).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class HaloConfig:
    tile: int = DEFAULT_TILE
    theta: float = DEFAULT_THETA          # sensitivity retention (SIII-B)
    n_sigma: float = 3.0                  # outlier rule (paper: 3-sigma)
    salient_frac: float = 0.0005          # top 0.05% by Fisher
    scale_grid: Tuple[float, ...] = tuple(float(x) for x in SCALE_GRID)
    # "column": one fp scale per tile column (the paper leaves scale
    # granularity unspecified; per-column is measurably more accurate and
    # costs one VPU broadcast in the kernel).  "tile": single scalar.
    scale_granularity: str = "column"
    fisher_weighted_scale: bool = False   # beyond-paper: Fisher-weighted MSE


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HaloQuantized:
    """One quantized (K, N) weight matrix in HALO format."""

    idx: jnp.ndarray       # (n_tiles, t, t) uint8 -- index into shared table
    scale: jnp.ndarray     # (n_tiles,) or (n_tiles, t) fp32 scales
    classes: jnp.ndarray   # (n_tiles,) int8 -- TILE_CLASS_F2 / F3
    sparse: SparseWeights  # outlier + salient part
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True),
                                               default=(0, 0))
    tile: int = dataclasses.field(metadata=dict(static=True), default=DEFAULT_TILE)

    @property
    def n_tiles(self) -> int:
        return int(self.idx.shape[0])

    def scale_per_column(self) -> jnp.ndarray:
        """(n_tiles, t) view regardless of stored granularity."""
        if self.scale.ndim == 2:
            return self.scale
        return jnp.broadcast_to(self.scale[:, None],
                                (self.n_tiles, self.tile))

    def dense_part(self) -> jnp.ndarray:
        table = jnp.asarray(codebooks.shared_table(), jnp.float32)
        tiles = table[self.idx] * self.scale_per_column()[:, None, :]
        return tiling.from_tiles(tiles, self.shape, self.tile)

    def dequantize(self) -> jnp.ndarray:
        return self.dense_part() + self.sparse.to_dense()


def _nearest_idx(w_over_s: jnp.ndarray, lo: int, hi: int) -> jnp.ndarray:
    """Nearest-codebook index within table[lo:hi+1], returned in global index
    space.  Uses midpoint thresholds (codebook ascending)."""
    table = jnp.asarray(codebooks.shared_table(), jnp.float32)[lo:hi + 1]
    mids = (table[1:] + table[:-1]) / 2.0
    return (jnp.searchsorted(mids, w_over_s) + lo).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("cfg",))
def quantize_tiles(tiles: jnp.ndarray, classes: jnp.ndarray,
                   cfg: HaloConfig,
                   fisher_tiles: Optional[jnp.ndarray] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Assign codebook indices + scales.  tiles: (n, t, t).

    Returns scale (n,) for tile granularity or (n, t) for column
    granularity (one scale per tile column, i.e. per output channel slice).
    """
    n, t, _ = tiles.shape
    per_col = cfg.scale_granularity == "column"
    w = tiles.astype(jnp.float32)                      # (n, t, t)
    fw = None
    if cfg.fisher_weighted_scale and fisher_tiles is not None:
        fw = fisher_tiles.astype(jnp.float32)
        fw = fw / (fw.mean(axis=(1, 2), keepdims=True) + 1e-30)

    table = jnp.asarray(codebooks.shared_table(), jnp.float32)
    f3_lo, f3_hi = codebooks.f3_index_range()
    # scale anchors use the *symmetric* magnitude ceiling (64 for F2): the
    # lone -128 entry is a bonus level, not the coverage bound -- anchoring
    # on 128 would clip positive tails at 0.55*absmax.  With cmax_f2 = 8 *
    # cmax_f3 and a shared relative grid, every F3-achievable scale has an
    # F2 counterpart with strictly denser levels, so F2 error <= F3 error.
    cmax_f2 = 64.0
    cmax_f3 = float(np.abs(codebooks.class_codebook(2)).max())       # 8
    is_f3 = classes == codebooks.TILE_CLASS_F3                       # (n,)

    if per_col:
        absmax = jnp.abs(w).max(axis=1) + 1e-12                      # (n, t)
        base = jnp.where(is_f3[:, None], absmax / cmax_f3,
                         absmax / cmax_f2)                           # (n, t)
        sel = is_f3[:, None, None]
    else:
        absmax = jnp.abs(w).max(axis=(1, 2), keepdims=False) + 1e-12  # (n,)
        base = jnp.where(is_f3, absmax / cmax_f3, absmax / cmax_f2)
        sel = is_f3[:, None, None]

    grid = jnp.asarray(cfg.scale_grid, jnp.float32)

    def eval_candidate(r):
        s = base * r                       # (n, t) or (n,)
        s3 = s[:, None, :] if per_col else s[:, None, None]
        ws = w / s3
        idx3 = _nearest_idx(ws, f3_lo, f3_hi)
        idx2 = _nearest_idx(ws, 0, 15)
        idx = jnp.where(sel, idx3, idx2)
        err = (table[idx] * s3 - w) ** 2
        if fw is not None:
            err = err * fw
        # reduce over rows only (per-column search) or the whole tile
        red = err.sum(axis=1) if per_col else err.sum(axis=(1, 2))
        return red, idx

    errs, idxs = jax.lax.map(eval_candidate, grid)
    best = jnp.argmin(errs, axis=0)        # (n, t) or (n,)
    if per_col:
        idx = jnp.take_along_axis(idxs, best[None, :, None, :], axis=0)[0]
        scale = (base * grid[best]).astype(jnp.float32)       # (n, t)
    else:
        idx = jnp.take_along_axis(idxs, best[None, :, None, None], axis=0)[0]
        scale = (base * grid[best]).astype(jnp.float32)       # (n,)
    return idx.astype(jnp.uint8), scale


def halo_quantize_tensor(w: jnp.ndarray,
                         fisher_g2: Optional[jnp.ndarray],
                         cfg: HaloConfig = HaloConfig(),
                         theta: Optional[float] = None) -> HaloQuantized:
    """Full Algorithm 1 for one (K, N) matrix."""
    if w.ndim != 2:
        raise ValueError(f"expected 2-D weight, got {w.shape}")
    theta = cfg.theta if theta is None else theta
    w = w.astype(jnp.float32)

    dense, sparse, _ = outliers.split_salient_and_outliers(
        w, fisher_g2, n_sigma=cfg.n_sigma, salient_frac=cfg.salient_frac)

    tiles = tiling.to_tiles(dense, cfg.tile)
    if fisher_g2 is not None:
        scores = sensitivity.tile_scores(fisher_g2, cfg.tile)
        fisher_tiles = tiling.to_tiles(fisher_g2, cfg.tile)
    else:  # fall back to magnitude-based scores (calibration-free mode)
        scores = tiling.to_tiles(w * w, cfg.tile).mean(axis=(1, 2))
        fisher_tiles = None
    res = assign.assign_classes(scores, theta)

    idx, scale = quantize_tiles(tiles, res.classes, cfg, fisher_tiles)
    return HaloQuantized(idx=idx, scale=scale, classes=res.classes,
                         sparse=sparse, shape=tuple(w.shape), tile=cfg.tile)


def effective_bits(hq: HaloQuantized) -> float:
    """Paper SIV-B: B_eff = sum_i P_i * b_i over the weight population."""
    n_total = hq.shape[0] * hq.shape[1]
    t2 = hq.tile * hq.tile
    classes = np.asarray(jax.device_get(hq.classes))
    n_f3 = int((classes == codebooks.TILE_CLASS_F3).sum()) * t2
    n_f2 = int((classes == codebooks.TILE_CLASS_F2).sum()) * t2
    # padded tiles overcount; renormalize the class mix onto the true count
    dense_total = min(n_f3 + n_f2, n_total)
    frac = dense_total / (n_f3 + n_f2)
    n_f3, n_f2 = n_f3 * frac, n_f2 * frac
    nnz = hq.sparse.nnz
    bits = (n_f3 * np.log2(9) + n_f2 * 4.0 + nnz * 8.0)
    # fp16 scale overhead (per tile or per tile-column)
    bits += float(np.prod(hq.scale.shape)) * 16.0
    return float(bits / n_total)


def quant_error(hq: HaloQuantized, w: jnp.ndarray) -> float:
    """Relative Frobenius reconstruction error."""
    diff = hq.dequantize() - w.astype(jnp.float32)
    return float(jnp.linalg.norm(diff) / (jnp.linalg.norm(w) + 1e-12))
