"""DVFS transition scheduling across a quantized model (paper SIII-C3).

Tiles sharing a frequency class are clustered into contiguous execution
groups; each class is entered once per layer (or once per model with
cross-layer grouping), so reconfiguration cost is amortized over the group.
The schedule is purely an execution *order* -- quantization decided offline
fixes each tile's class, and reordering independent weight tiles cannot
change results (outputs accumulate per output-tile; ordering of K-tiles only
reorders a sum).

`DvfsSchedule` is what a deployment consumes: per-class tile index lists, the
operating point per class, and the transition count/overhead estimate.  The
Pallas `halo_matmul` kernel realizes the same idea on TPU by iterating its
grid class-major (see kernels/halo_matmul.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from ..hw import mac_model
from ..hw.dvfs import SYSTOLIC_DOMAIN, DvfsDomain, OperatingPoint
from . import codebooks
from .quantize import HaloQuantized


@dataclasses.dataclass(frozen=True)
class ClassGroup:
    class_id: int
    point: OperatingPoint
    tile_indices: np.ndarray       # flat tile ids executed in this group

    @property
    def n_tiles(self) -> int:
        return int(self.tile_indices.size)


@dataclasses.dataclass(frozen=True)
class DvfsSchedule:
    groups: Tuple[ClassGroup, ...]   # slowest class first ("ramp up")
    num_transitions: int
    transition_time_s: float

    def execution_order(self) -> np.ndarray:
        return np.concatenate([g.tile_indices for g in self.groups])

    def class_fractions(self) -> Dict[str, float]:
        total = sum(g.n_tiles for g in self.groups)
        return {codebooks.CLASS_NAMES[g.class_id]: g.n_tiles / max(total, 1)
                for g in self.groups}


def schedule_tensor(hq: HaloQuantized,
                    domain: DvfsDomain = SYSTOLIC_DOMAIN) -> DvfsSchedule:
    """Schedule one quantized tensor's tiles."""
    classes = np.asarray(hq.classes)
    return schedule_classes(classes, domain)


def schedule_classes(classes: np.ndarray,
                     domain: DvfsDomain = SYSTOLIC_DOMAIN) -> DvfsSchedule:
    classes = np.asarray(classes)
    groups: List[ClassGroup] = []
    for cls in sorted(np.unique(classes)):          # slow class first
        crit_ns = 1.0 / codebooks.CLASS_FREQ_GHZ[int(cls)]
        point = domain.fastest_point_for_delay(crit_ns)
        idx = np.nonzero(classes == cls)[0]
        groups.append(ClassGroup(int(cls), point, idx))
    n_trans = max(len(groups) - 1, 0)
    return DvfsSchedule(groups=tuple(groups), num_transitions=n_trans,
                        transition_time_s=n_trans * domain.transition_time_s)


def schedule_model(quantized: Dict[str, HaloQuantized],
                   domain: DvfsDomain = SYSTOLIC_DOMAIN,
                   cross_layer: bool = True) -> Dict[str, object]:
    """Whole-model schedule summary.

    cross_layer=True groups same-class tiles across consecutive layers (the
    paper's "tiles mapped to that level are executed together"): transitions
    then count class *changes* along the concatenated schedule, typically
    2-3 per model.
    """
    per_tensor = {name: schedule_tensor(hq, domain)
                  for name, hq in quantized.items()}
    if cross_layer:
        seq: List[int] = []
        for name in per_tensor:
            seq.extend(int(g.class_id) for g in per_tensor[name].groups)
        # executing all F1 groups, then F2, then F3 across the whole model:
        n_trans = max(len(set(seq)) - 1, 0)
    else:
        n_trans = sum(s.num_transitions for s in per_tensor.values())
    total_tiles = sum(hq.n_tiles for hq in quantized.values())
    f3 = sum(int((np.asarray(hq.classes) == codebooks.TILE_CLASS_F3).sum())
             for hq in quantized.values())
    return {
        "per_tensor": per_tensor,
        "num_transitions": n_trans,
        "transition_overhead_s": n_trans * domain.transition_time_s,
        "f3_fraction": f3 / max(total_tiles, 1),
        "f2_fraction": 1.0 - f3 / max(total_tiles, 1),
    }
