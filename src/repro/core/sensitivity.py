"""Weight-sensitivity analysis via the diagonal Fisher information (Eq. 1-2).

``F = (1/|D|) sum_d g_d g_d^T`` approximated by its diagonal ``E[g^2]`` over a
calibration set -- the SqueezeLLM/paper recipe.  Per-weight scores drive
salient-weight extraction (top 0.05%); per-tile means (Eq. 2) drive the
tile-class assignment.
"""

from __future__ import annotations

from typing import Callable, Iterable, Tuple

import jax
import jax.numpy as jnp

from . import tiling


def fisher_diag(loss_fn: Callable, params, batches: Iterable,
                grad_dtype=jnp.float32):
    """Accumulate E[g^2] over calibration batches.

    loss_fn(params, batch) -> scalar loss.  Returns a pytree shaped like
    `params` holding the running mean of squared gradients.
    """
    grad_fn = jax.jit(jax.grad(loss_fn))

    acc = jax.tree.map(lambda p: jnp.zeros(p.shape, grad_dtype), params)
    count = 0
    for batch in batches:
        g = grad_fn(params, batch)
        acc = jax.tree.map(lambda a, gi: a + gi.astype(grad_dtype) ** 2, acc, g)
        count += 1
    if count == 0:
        raise ValueError("no calibration batches supplied")
    return jax.tree.map(lambda a: a / count, acc)


def weight_scores(g2: jnp.ndarray) -> jnp.ndarray:
    """Per-weight saliency Lambda_W = diag-Fisher (already E[g^2])."""
    return g2


def tile_scores(g2: jnp.ndarray, tile: int) -> jnp.ndarray:
    """Eq. 2: per-tile mean of squared gradients.  (K,N) -> (n_tiles,)."""
    tiles = tiling.to_tiles(g2, tile)
    return tiles.mean(axis=(1, 2))


def empirical_fisher_tensor(g2: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Convenience: (per-weight scores, total mass) for reporting."""
    return g2, g2.sum()
