"""Reshape weight matrices into hardware tiles and back (paper Alg. 1 l.4).

Tiles are ``t x t`` blocks matching the systolic array / MXU; matrices are
zero-padded up to tile multiples.  Layout: ``(K, N) -> (kt*nt, t, t)`` with
tiles ordered row-major over the ``(kt, nt)`` grid, so tile ``i`` covers
``K[t*(i//nt) : ...], N[t*(i%nt) : ...]``.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np


def padded_dims(k: int, n: int, tile: int) -> Tuple[int, int]:
    return (-(-k // tile) * tile, -(-n // tile) * tile)


def grid_dims(k: int, n: int, tile: int) -> Tuple[int, int]:
    return (-(-k // tile), -(-n // tile))


def pad_matrix(w: jnp.ndarray, tile: int) -> jnp.ndarray:
    k, n = w.shape
    kp, np_ = padded_dims(k, n, tile)
    return jnp.pad(w, ((0, kp - k), (0, np_ - n)))


def to_tiles(w: jnp.ndarray, tile: int) -> jnp.ndarray:
    """(K, N) -> (kt*nt, tile, tile); pads with zeros as needed."""
    wp = pad_matrix(w, tile)
    kp, np_ = wp.shape
    kt, nt = kp // tile, np_ // tile
    return (wp.reshape(kt, tile, nt, tile)
              .transpose(0, 2, 1, 3)
              .reshape(kt * nt, tile, tile))


def from_tiles(tiles: jnp.ndarray, shape: Tuple[int, int], tile: int) -> jnp.ndarray:
    """(kt*nt, tile, tile) -> (K, N), dropping padding."""
    k, n = shape
    kt, nt = grid_dims(k, n, tile)
    wp = (tiles.reshape(kt, nt, tile, tile)
               .transpose(0, 2, 1, 3)
               .reshape(kt * tile, nt * tile))
    return wp[:k, :n]


def tile_grid_coords(n_tiles: int, k: int, n: int, tile: int) -> np.ndarray:
    """(n_tiles, 2) int32 (kt_idx, nt_idx) for each flat tile index."""
    kt, nt = grid_dims(k, n, tile)
    assert kt * nt == n_tiles
    idx = np.arange(n_tiles)
    return np.stack([idx // nt, idx % nt], axis=1).astype(np.int32)
