"""Deterministic synthetic corpus with reducible structure (offline stand-in
for C4/WikiText: no internet in this container).

Token stream = mixture of (a) an order-2 multiplicative-hash Markov process
(learnable: a trained model drives its branch of the entropy to ~0) and
(b) Zipf-distributed noise tokens.  The mixture weight sets the floor
perplexity, so FP16-vs-quantized *deltas* are meaningful -- which is what the
paper's Table II compares.  Fully seeded; iterator state is a (seed, step)
pair so checkpoints can resume the pipeline exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    vocab: int
    seq_len: int
    batch: int
    p_structured: float = 0.8      # fraction of deterministic transitions
    zipf_a: float = 1.3
    seed: int = 42


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, vocab + 1) ** a
    return p / p.sum()


class SyntheticCorpus:
    """Seeded batch iterator; state = global step (resumable)."""

    def __init__(self, cfg: CorpusConfig):
        self.cfg = cfg
        self._zipf = _zipf_probs(cfg.vocab, cfg.zipf_a)
        # fixed random mixing constants for the hash transition
        rng = np.random.default_rng(cfg.seed)
        self._a = int(rng.integers(1, cfg.vocab - 1)) | 1
        self._b = int(rng.integers(1, cfg.vocab - 1)) | 1
        self._c = int(rng.integers(1, cfg.vocab - 1)) | 1

    def _gen_sequences(self, rng: np.random.Generator, n: int
                       ) -> np.ndarray:
        cfg = self.cfg
        seq = np.empty((n, cfg.seq_len + 1), np.int64)
        seq[:, 0] = rng.integers(0, cfg.vocab, n)
        seq[:, 1] = rng.integers(0, cfg.vocab, n)
        noise = rng.random((n, cfg.seq_len + 1))
        zipf_draws = rng.choice(cfg.vocab, size=(n, cfg.seq_len + 1),
                                p=self._zipf)
        for t in range(2, cfg.seq_len + 1):
            det = (seq[:, t - 1] * self._a
                   + seq[:, t - 2] * self._b + self._c) % cfg.vocab
            seq[:, t] = np.where(noise[:, t] < cfg.p_structured,
                                 det, zipf_draws[:, t])
        return seq

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a global step (resume == replay)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        seq = self._gen_sequences(rng, cfg.batch)
        tokens = seq[:, :-1].astype(np.int32)
        labels = seq[:, 1:].astype(np.int32)
        positions = np.broadcast_to(np.arange(cfg.seq_len, dtype=np.int32),
                                    tokens.shape)
        return {"tokens": tokens, "labels": labels,
                "positions": np.ascontiguousarray(positions)}

    def batches(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1

    def eval_batches(self, n: int, tag: int = 10_000_000
                     ) -> Iterator[Dict[str, np.ndarray]]:
        """Held-out batches (disjoint seed space from training steps)."""
        for i in range(n):
            yield self.batch_at(tag + i)

    def floor_perplexity(self) -> float:
        """Analytic entropy floor of the generating process (nats -> ppl)."""
        cfg = self.cfg
        p = cfg.p_structured
        h_zipf = -np.sum(self._zipf * np.log(self._zipf))
        # mixture: H = H(b) + (1-p) * H_zipf  (det branch has 0 entropy,
        # but the model must infer the branch -> binary entropy term)
        h_b = -(p * np.log(p) + (1 - p) * np.log(1 - p))
        return float(np.exp(h_b + (1 - p) * h_zipf))


def embedding_batch(cfg_vocab: int, batch: int, seq: int, d_model: int,
                    step: int, seed: int = 7) -> Dict[str, np.ndarray]:
    """Stub frontend batches for [audio]/[vlm] archs: precomputed embeddings
    + token labels (the modality encoder is out of scope by assignment)."""
    rng = np.random.default_rng((seed, step))
    return {
        "embeds": rng.normal(0, 1, (batch, seq, d_model)).astype(np.float32),
        "labels": rng.integers(0, cfg_vocab, (batch, seq)).astype(np.int32),
        "positions": np.broadcast_to(np.arange(seq, dtype=np.int32),
                                     (batch, seq)).copy(),
    }
