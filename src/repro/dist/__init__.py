"""Distribution utilities: logical-axis sharding rules and fault tolerance."""

from . import fault, sharding  # noqa: F401
