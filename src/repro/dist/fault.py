"""Fault tolerance for the preemptible fleet: failure injection (tests),
straggler detection, and elastic-rescale device-count enumeration."""

from __future__ import annotations

import statistics
import time
from typing import Callable, Iterable, List, Optional


class FailureInjector:
    """Deterministically raise at chosen steps -- once each.

    The train loop's recovery contract is exercised by injecting a failure
    the first time a target step runs; after restore the step re-executes
    and must pass, so each target fires exactly once.
    """

    def __init__(self, steps: Iterable[int]):
        self._pending = set(int(s) for s in steps)

    def check(self, step: int) -> None:
        if step in self._pending:
            self._pending.discard(step)
            raise RuntimeError(f"injected failure at step {step}")


class StragglerWatchdog:
    """Flag steps whose wall time exceeds ``threshold`` x the typical step.

    The baseline is the median of previously observed *healthy* step
    durations (flagged stragglers are excluded so one slow host cannot
    poison the baseline).  No flags are raised until ``warmup_steps``
    healthy samples exist.
    """

    def __init__(self, threshold: float = 2.0, warmup_steps: int = 2,
                 clock: Optional[Callable[[], float]] = None):
        self.threshold = float(threshold)
        self.warmup_steps = int(warmup_steps)
        self._clock = clock if clock is not None else time.monotonic
        self._durations: List[float] = []
        self._t0: Optional[float] = None

    def step_start(self) -> None:
        self._t0 = self._clock()

    def step_end(self, step: int) -> bool:
        if self._t0 is None:
            return False
        dur = self._clock() - self._t0
        self._t0 = None
        flagged = False
        if len(self._durations) >= self.warmup_steps:
            baseline = statistics.median(self._durations)
            flagged = dur > self.threshold * baseline
        if not flagged:
            self._durations.append(dur)
        return flagged


def viable_device_counts(n_devices: int, model_parallel: int = 16
                         ) -> List[int]:
    """Descending power-of-two device counts usable after losing hosts.

    A count is viable if it is a power of two <= ``n_devices`` and a
    multiple of ``model_parallel`` (the TP degree the checkpointed weights
    are laid out for).  Empty when fewer than ``model_parallel`` devices
    survive -- the caller falls back to a trivial mesh.
    """
    out: List[int] = []
    p = 1
    while p * 2 <= n_devices:
        p *= 2
    while p >= max(model_parallel, 1):
        if p % max(model_parallel, 1) == 0:
            out.append(p)
        p //= 2
    return out
