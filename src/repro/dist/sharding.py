"""Logical-axis sharding: rules map logical names to mesh axes.

Every ParamSpec / activation carries *logical* axis names ("embed", "mlp",
"act_seq", ...); a rules dict maps each name to zero or more mesh axes.
``logical_to_spec`` resolves a logical tuple into a PartitionSpec, enforcing
the two GSPMD invariants that otherwise surface as cryptic lowering errors:

  * a mesh axis is consumed at most once per spec (first logical axis wins),
  * a dimension is only sharded if its size divides evenly; non-divisible
    axes silently fall back to replication (small smoke models keep working
    on production rule sets).

``use_rules(mesh, rules)`` installs an ambient (mesh, rules) context so model
code can call ``shard_activation(x, axes)`` unconditionally -- with no active
mesh it is an exact no-op (returns ``x`` itself), which is what single-device
tests rely on.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _is_spec(x) -> bool:
    # duck-typed ParamSpec check: models.module imports this module (via
    # models.transformer), so importing ParamSpec here would be circular
    return hasattr(x, "logical_axes") and hasattr(x, "shape")


def _tree_map_specs(fn, specs):
    return jax.tree.map(fn, specs, is_leaf=_is_spec)

# Default production rules (single-pod (data, model) mesh).  Weights keep a
# Megatron-TP axis on "model" plus an FSDP-style "data" shard of the residual
# dim; serving overrides "embed" -> None (weight-resident decode, see
# launch/inputs.arch_rules).  Activations shard batch over "data" and the
# per-layer wide dims over "model".
DEFAULT_RULES: Dict[str, Any] = {
    # --- weight axes ---
    "embed": "data",
    "mlp": "model",
    "heads": "model",
    "kv": "model",
    "vocab": "model",
    "experts": "model",
    "layers": None,
    # --- activation axes ---
    "batch": "data",
    "act_seq": None,
    "act_embed": None,
    "act_mlp": "model",
    "act_heads": "model",
    "act_vocab": "model",
    "kv_seq": None,
}


def make_rules(**overrides) -> Dict[str, Any]:
    """DEFAULT_RULES with per-call overrides (value: None | str | tuple)."""
    rules = dict(DEFAULT_RULES)
    rules.update(overrides)
    return rules


def _as_tuple(v) -> Tuple[str, ...]:
    if v is None:
        return ()
    if isinstance(v, str):
        return (v,)
    return tuple(v)


def logical_to_spec(axes: Sequence[Optional[str]], shape: Sequence[int],
                    mesh: Mesh, rules: Optional[Dict[str, Any]] = None) -> P:
    """Resolve logical axis names into a PartitionSpec for `mesh`.

    Drops mesh axes that are absent from the mesh, already consumed by an
    earlier dimension, or whose size does not divide the dimension.
    """
    rules = DEFAULT_RULES if rules is None else rules
    used: set = set()
    entries = []
    for name, dim in zip(axes, shape):
        want = _as_tuple(rules.get(name) if name is not None else None)
        picked = []
        span = 1
        for ax in want:
            if ax not in mesh.shape or ax in used:
                continue
            if dim % (span * mesh.shape[ax]) != 0:
                continue
            picked.append(ax)
            span *= mesh.shape[ax]
        used.update(picked)
        if not picked:
            entries.append(None)
        elif len(picked) == 1:
            entries.append(picked[0])
        else:
            entries.append(tuple(picked))
    return P(*entries)


def logical_to_sharding(axes: Sequence[Optional[str]], shape: Sequence[int],
                        mesh: Mesh,
                        rules: Optional[Dict[str, Any]] = None
                        ) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(axes, shape, mesh, rules))


# ---------------------------------------------------------------------------
# ambient (mesh, rules) context
# ---------------------------------------------------------------------------

class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, Any]] = None


_CTX = _Ctx()


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def active_rules() -> Optional[Dict[str, Any]]:
    return _CTX.rules


@contextlib.contextmanager
def use_rules(mesh: Optional[Mesh], rules: Optional[Dict[str, Any]] = None):
    """Install (mesh, rules) as the ambient sharding context."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = rules if rules is not None else DEFAULT_RULES
    try:
        yield mesh
    finally:
        _CTX.mesh, _CTX.rules = prev


def shard_activation(x, axes: Sequence[Optional[str]]):
    """Constrain an activation's sharding under the ambient context.

    Exact no-op (returns ``x``) when no mesh is active, so single-device
    tests and eager exploration never pay a transfer.
    """
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = logical_to_spec(axes, x.shape, mesh, _CTX.rules)
    if all(entry is None for entry in spec):
        # an all-None spec pins the value fully replicated -- a no-op
        # layout-wise, but the forced constraint can steer the SPMD
        # partitioner into worse (and on host-CPU meshes, occasionally
        # miscompiled) partitionings of neighboring scatter ops.  Leave
        # GSPMD free instead; it is what every call site did before the
        # constraint existed.
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_array(x, axes: Sequence[Optional[str]], mesh: Mesh,
                rules: Optional[Dict[str, Any]] = None):
    """Place one array on ``mesh`` per its logical axes (device_put).

    The eager companion to ``shard_activation``: used at engine
    construction to lay out weight leaves and KV page pools once, before
    any jitted call runs."""
    return jax.device_put(x, logical_to_sharding(axes, x.shape, mesh,
                                                 rules))


# ---------------------------------------------------------------------------
# parameter trees
# ---------------------------------------------------------------------------

def params_shardings(specs, mesh: Mesh,
                     rules: Optional[Dict[str, Any]] = None):
    """NamedSharding per ParamSpec leaf (structure-preserving)."""
    return _tree_map_specs(
        lambda s: logical_to_sharding(s.logical_axes, s.shape, mesh, rules),
        specs)


def abstract_with_sharding(specs, mesh: Mesh,
                           rules: Optional[Dict[str, Any]] = None):
    """ShapeDtypeStruct tree with shardings attached (dry-run stand-ins)."""
    return _tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=logical_to_sharding(s.logical_axes, s.shape, mesh,
                                         rules)),
        specs)
