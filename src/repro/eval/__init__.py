"""Serving-path evaluation: datasets, harness, and the Scorecard artifact.

Quality numbers here are measured THROUGH ``Engine.submit/step/drain``
(packed ``halo_matmul`` kernels, paged KV, prefix-sharing machinery,
speculative executors), not on the raw model -- see docs/serving.md.
"""

from .datasets import MCItem, MultipleChoiceProbe, PerplexityStream
from .harness import (ENGINE_MODES, EvalProtocol, mc_accuracy,
                      ppl_from_logprobs, raw_sequence_logprobs,
                      run_scorecard)
from .scorecard import (SCORECARD_VERSION, Scorecard, ScorecardEntry,
                        git_sha, utc_now)

__all__ = [
    "ENGINE_MODES", "EvalProtocol", "MCItem", "MultipleChoiceProbe",
    "PerplexityStream", "SCORECARD_VERSION", "Scorecard", "ScorecardEntry",
    "git_sha", "mc_accuracy", "ppl_from_logprobs", "raw_sequence_logprobs",
    "run_scorecard", "utc_now",
]
