"""Eval harness: drive the datasets through the REAL serving path.

Every quality number here flows through ``Engine.submit``/``step``/
``drain`` on a live executor -- fused prefill-append windows over packed
``halo_matmul`` kernels, paged KV pools, prefix-sharing page tables,
speculative executors -- via ``Engine.score``.  The only raw-model
access is the deliberate ORACLE (``raw_sequence_logprobs``, one jitted
``T.forward`` per sequence), kept so a dense-contiguous engine run can
be checked against ground truth: if the serving plumbing ever corrupts
logits, the oracle-parity column catches it before a quantization delta
gets blamed.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from ..core import deploy
from ..models import transformer as T
from ..serving.engine import Engine
from .datasets import MultipleChoiceProbe, PerplexityStream
from .scorecard import (DEFAULT_TOLERANCES, Scorecard, ScorecardEntry,
                        git_sha, utc_now)

# Engine kwarg bundles per mode.  Every mode exercises a genuinely
# different executor/cache layout, which is the point: quality must
# survive each of them unchanged.
ENGINE_MODES: Dict[str, Dict[str, Any]] = {
    "contiguous": {},
    "paged": {"paged": True, "page_size": 16},
    "paged_share": {"paged": True, "page_size": 16, "share_prefix": True},
    "spec": {"speculative": True, "k": 3, "draft_layers": 1},
}


@dataclasses.dataclass(frozen=True)
class EvalProtocol:
    """Everything that makes two scorecards comparable.  Stored verbatim
    in the artifact; ``Scorecard.compare`` refuses cross-protocol
    comparisons."""

    ppl_seq_len: int = 48
    n_ppl_sequences: int = 4
    mc_question_len: int = 24
    mc_option_len: int = 4
    n_mc_items: int = 8
    n_mc_options: int = 4
    tps_requests: int = 4
    tps_prompt_len: int = 16
    tps_max_new: int = 8
    tps_repeats: int = 2
    seed: int = 42

    def asdict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def max_seq(self) -> int:
        """Slot cache length covering every workload in the protocol,
        rounded up to the decode bucket."""
        need = max(self.ppl_seq_len + 2,
                   self.mc_question_len + self.mc_option_len + 1,
                   self.tps_prompt_len + self.tps_max_new)
        return -(-need // 16) * 16


@dataclasses.dataclass(frozen=True)
class Variant:
    """One deployed weight tree to be scored: ``params`` is what the
    Engine serves (post ``deploy.pack_params``); ``effective_bits`` is
    the tree-wide mean B_eff computed on the PRE-deploy quantized tree
    (core/apply.effective_bits_of), since packing erases the HALO
    codebook metadata B_eff is derived from."""

    name: str
    params: Any
    effective_bits: float = 16.0
    quantized: bool = False


def ppl_from_logprobs(logprobs: Sequence[np.ndarray]) -> float:
    """exp(mean token NLL) over all scored positions."""
    flat = np.concatenate([np.asarray(lp, np.float64).reshape(-1)
                           for lp in logprobs])
    if flat.size == 0:
        raise ValueError("no scored tokens")
    return float(np.exp(-flat.mean()))


def raw_sequence_logprobs(params, cfg, seqs: Sequence[np.ndarray]
                          ) -> List[np.ndarray]:
    """ORACLE: per-token log-likelihoods from one plain ``T.forward``
    per sequence -- no scheduler, no windows, no cache.  Same math as
    ``Engine.score`` (float64 log-softmax over the real vocab columns),
    so dense-contiguous engine output must match to float32 tolerance."""
    fwd = jax.jit(lambda p, b: T.forward(p, cfg, b)[0])
    out = []
    for s in seqs:
        s = np.asarray(s).reshape(-1).astype(np.int32)
        batch = {"tokens": s[None, :],
                 "positions": np.arange(len(s), dtype=np.int32)[None]}
        logits = np.asarray(fwd(params, batch), np.float64)[0, :, :cfg.vocab]
        m = logits.max(axis=-1, keepdims=True)
        lsm = logits - (m + np.log(np.exp(logits - m)
                                   .sum(axis=-1, keepdims=True)))
        out.append(lsm[np.arange(len(s) - 1), s[1:]].astype(np.float32))
    return out


def mc_accuracy(score_fn: Callable[[List[np.ndarray]], List[np.ndarray]],
                probe: MultipleChoiceProbe) -> float:
    """Fraction of items whose TRUE continuation gets the highest summed
    continuation log-likelihood given the question.  ``score_fn`` maps
    full sequences to per-token logprob arrays (``Engine.score`` or the
    raw oracle, interchangeably)."""
    q = probe.question_len
    items = probe.items()
    correct = 0
    for item in items:
        lps = score_fn(item.option_sequences())
        # positions q-1 .. q+m-2 of the (q+m-1,) array score the m
        # option tokens given question (+ preceding option tokens)
        scores = [float(lp[q - 1:].sum()) for lp in lps]
        if int(np.argmax(scores)) == item.answer:
            correct += 1
    return correct / len(items)


def measure_tps(eng: Engine, protocol: EvalProtocol) -> float:
    """Decode throughput (generated tokens/s) on this engine: submit a
    small burst, drain, repeat; best of ``tps_repeats`` after one
    untimed warm-up replay (compile + cache-shape warm)."""
    rng = np.random.default_rng(protocol.seed)
    prompts = [rng.integers(0, eng.cfg.vocab,
                            size=protocol.tps_prompt_len).astype(np.int32)
               for _ in range(protocol.tps_requests)]

    def replay() -> float:
        for p in prompts:
            eng.submit({"tokens": p[None, :]}, max_new=protocol.tps_max_new)
        t0 = time.perf_counter()
        res = eng.drain(fresh_only=True)
        dt = time.perf_counter() - t0
        eng.pop_finished()
        n_new = sum(len(toks) for toks in res.values())  # generated only
        return n_new / max(dt, 1e-9)

    replay()                                    # warm-up, untimed
    return max(replay() for _ in range(protocol.tps_repeats))


def _build_engine(variant: Variant, cfg, mode: str,
                  protocol: EvalProtocol) -> Engine:
    kwargs = dict(ENGINE_MODES[mode])
    return Engine(variant.params, cfg,
                  prefill_bucket=16, decode_bucket=16, capacity=2,
                  chunk=4, max_seq=protocol.max_seq(), **kwargs)


def run_scorecard(variants: Sequence[Variant], cfg,
                  modes: Sequence[str] = ("contiguous", "paged"),
                  protocol: EvalProtocol = EvalProtocol(),
                  model: str = "llama", backend: str = "jax_pallas",
                  tolerances: Optional[Dict[str, float]] = None,
                  oracle_params: Any = None,
                  progress: Optional[Callable[[str], None]] = None
                  ) -> Scorecard:
    """Measure every (variant, engine-mode) cell through the serving
    path and assemble the Scorecard artifact.

    ``oracle_params``: a raw (un-deployed) dense tree; when given, dense
    variants additionally record raw-model oracle PPL and the relative
    error of the engine-path PPL against it -- the end-to-end parity
    check that keeps serving-plumbing bugs from masquerading as
    quantization loss."""
    say = progress or (lambda s: None)
    stream = PerplexityStream(cfg.vocab, protocol.ppl_seq_len,
                              protocol.n_ppl_sequences, seed=protocol.seed)
    probe = MultipleChoiceProbe(cfg.vocab, protocol.mc_question_len,
                                protocol.mc_option_len, protocol.n_mc_items,
                                protocol.n_mc_options, seed=protocol.seed)
    ppl_seqs = stream.sequences()
    oracle_ppl = None
    if oracle_params is not None:
        oracle_ppl = ppl_from_logprobs(
            raw_sequence_logprobs(oracle_params, cfg, ppl_seqs))
        say(f"oracle (raw T.forward) ppl={oracle_ppl:.4f}")

    card = Scorecard(model=model, backend=backend, git_sha=git_sha(),
                     written_at=utc_now(), seed=protocol.seed,
                     protocol=protocol.asdict(),
                     tolerances=dict(tolerances or DEFAULT_TOLERANCES))
    for variant in variants:
        n_packed = deploy.n_packed_leaves(variant.params)
        note = ""
        if variant.quantized and n_packed == 0:
            # refuse to label an all-dense fallback run "packed": its
            # numbers say nothing about the packed kernel path
            note = ("NOT PACKED: quantized variant deployed 0 HaloPacked "
                    "leaves (every tensor under the 128x128 tile floor); "
                    "kernel-path quality is NOT being measured")
        for mode in modes:
            say(f"scoring {variant.name}/{mode} ...")
            eng = _build_engine(variant, cfg, mode, protocol)
            ppl = ppl_from_logprobs(eng.score(ppl_seqs))
            acc = mc_accuracy(eng.score, probe)
            tps = measure_tps(eng, protocol)
            entry = ScorecardEntry(
                variant=variant.name, engine_mode=mode, ppl=ppl,
                mc_accuracy=acc, effective_bits=variant.effective_bits,
                n_packed_leaves=n_packed, packed=n_packed > 0,
                tokens_per_s=tps, n_ppl_tokens=stream.n_scored_tokens,
                n_mc_items=protocol.n_mc_items, note=note)
            if not variant.quantized and oracle_ppl is not None:
                entry.oracle_ppl = oracle_ppl
                entry.oracle_ppl_rel_err = abs(ppl - oracle_ppl) / oracle_ppl
            card.entries.append(entry)
            say(f"  {variant.name}/{mode}: ppl={ppl:.4f} acc={acc:.3f} "
                f"tok/s={tps:.1f} packed={n_packed}")
    return card
