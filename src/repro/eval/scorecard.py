"""Versioned Scorecard artifact: quality next to throughput, per
(quantization variant, engine mode), with drift gating against a
committed baseline.

Schema/versioning idiom follows ``serving/tuning.TunedConfig``: a
``version`` field gates ``from_dict`` (unknown versions are rejected
loudly), and unknown keys inside entries are dropped so newer writers
stay readable by older readers within the same major version.
"""

from __future__ import annotations

import dataclasses
import datetime
import json
import subprocess
from pathlib import Path
from typing import Any, Dict, List, Optional, Union

SCORECARD_VERSION = 1

# Default drift tolerances, stored INSIDE the artifact so the gate uses
# whatever the committed baseline was armed with, not the code's current
# defaults.  ppl_rel is two-sided relative PPL drift; mc_acc_abs is
# absolute accuracy drift (0.051 tolerates one flip out of ~20 items
# while catching wholesale collapse).
DEFAULT_TOLERANCES: Dict[str, float] = {"ppl_rel": 0.02, "mc_acc_abs": 0.051}


def git_sha(default: str = "unknown") -> str:
    """Current repo HEAD SHA (short), or ``default`` outside a checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            cwd=Path(__file__).resolve().parent, capture_output=True,
            text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else default
    except (OSError, subprocess.SubprocessError):
        return default


def utc_now() -> str:
    return datetime.datetime.now(datetime.timezone.utc).strftime(
        "%Y-%m-%dT%H:%M:%SZ")


@dataclasses.dataclass
class ScorecardEntry:
    """One (variant, engine-mode) measurement through the serving path."""

    variant: str                 # "dense" | "halo-perf-opt" | ...
    engine_mode: str             # key into harness.ENGINE_MODES
    ppl: float                   # serving-path perplexity (Engine.score)
    mc_accuracy: float           # tiny-MMLU-style probe accuracy
    effective_bits: float        # tree-wide mean B_eff (16.0 for dense)
    n_packed_leaves: int         # HaloPacked leaves in deployed params
    packed: bool                 # True only if kernels actually packed
    tokens_per_s: float          # decode throughput, same engine mode
    n_ppl_tokens: int
    n_mc_items: int
    oracle_ppl: Optional[float] = None      # raw T.forward PPL (dense only)
    oracle_ppl_rel_err: Optional[float] = None
    note: str = ""               # non-empty = loud anomaly (e.g. all-dense
    #                              quantized run that refused "packed")

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ScorecardEntry":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


@dataclasses.dataclass
class Scorecard:
    """The artifact: provenance + protocol + tolerances + entries."""

    model: str
    backend: str
    git_sha: str
    written_at: str
    seed: int
    protocol: Dict[str, Any]            # EvalProtocol.asdict()
    tolerances: Dict[str, float] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_TOLERANCES))
    entries: List[ScorecardEntry] = dataclasses.field(default_factory=list)
    version: int = SCORECARD_VERSION

    def key(self, variant: str, engine_mode: str) -> Optional[ScorecardEntry]:
        for e in self.entries:
            if e.variant == variant and e.engine_mode == engine_mode:
                return e
        return None

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["entries"] = [e.to_dict() for e in self.entries]
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Scorecard":
        ver = d.get("version")
        if ver != SCORECARD_VERSION:
            raise ValueError(
                f"unsupported Scorecard version {ver!r} "
                f"(this reader supports {SCORECARD_VERSION})")
        known = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in known}
        kw["entries"] = [ScorecardEntry.from_dict(e)
                         for e in d.get("entries", [])]
        return cls(**kw)

    def save(self, path: Union[str, Path]) -> None:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.to_dict(), indent=2, sort_keys=True)
                     + "\n")

    @classmethod
    def load(cls, path: Union[str, Path]) -> "Scorecard":
        return cls.from_dict(json.loads(Path(path).read_text()))

    def compare(self, baseline: "Scorecard") -> List[str]:
        """Quality-drift violations of ``self`` vs ``baseline``.

        Gating uses the BASELINE's stored tolerances (the committed
        contract), and gates quality only -- PPL and MC accuracy.
        tokens/s is recorded for visibility but machine/load variance
        makes it unsuitable for a hard CI gate.  A protocol mismatch is
        itself a violation: numbers from different protocols are not
        comparable, and silently comparing them is exactly the staleness
        failure mode this artifact exists to prevent.
        """
        tol = dict(DEFAULT_TOLERANCES)
        tol.update(baseline.tolerances or {})
        bad: List[str] = []
        if self.protocol != baseline.protocol:
            bad.append(
                "protocol mismatch vs baseline -- regenerate the baseline "
                f"(baseline={baseline.protocol} current={self.protocol})")
            return bad
        for be in baseline.entries:
            cur = self.key(be.variant, be.engine_mode)
            tag = f"[{be.variant}/{be.engine_mode}]"
            if cur is None:
                bad.append(f"{tag} missing from current scorecard")
                continue
            if be.ppl > 0:
                rel = abs(cur.ppl - be.ppl) / be.ppl
                if rel > tol["ppl_rel"]:
                    bad.append(
                        f"{tag} ppl drift {rel:.4f} > {tol['ppl_rel']} "
                        f"(baseline {be.ppl:.4f} -> current {cur.ppl:.4f})")
            dacc = abs(cur.mc_accuracy - be.mc_accuracy)
            if dacc > tol["mc_acc_abs"]:
                bad.append(
                    f"{tag} mc_accuracy drift {dacc:.4f} > "
                    f"{tol['mc_acc_abs']} (baseline {be.mc_accuracy:.4f} "
                    f"-> current {cur.mc_accuracy:.4f})")
            if be.packed and not cur.packed:
                bad.append(
                    f"{tag} baseline ran packed kernels but current run "
                    f"is all-dense (n_packed_leaves="
                    f"{cur.n_packed_leaves}): not the same measurement")
        return bad
