"""Hardware models: Booth MAC timing/energy, DVFS domains, accelerator sims,
and TPU v5e roofline constants."""

from . import dvfs, gpu, mac_model, systolic, tpu_specs  # noqa: F401
