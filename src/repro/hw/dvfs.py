"""DVFS operating points and transition-scheduling cost model.

Paper Table I levels, verbatim:

  GPU:            (0.9 V, 1.5 GHz), (1.0 V, 2.0 GHz), (1.1 V, 2.8 GHz)
  Systolic (TPU): (1.0 V, 1.9 GHz), (1.1 V, 2.4 GHz), (1.2 V, 3.7 GHz)

Dynamic power scales as ``P ~ C * V^2 * f`` (activity folded into the MAC
energy LUT); static power scales roughly with V.  DVFS transitions cost tens
of ns to a few us (paper SIII-C3, citing ASPLOS'23 "Predict; don't react");
HALO clusters all tiles of one class into a single contiguous group so each
inference pays only (num distinct classes - 1) transitions.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    name: str
    voltage_v: float
    freq_ghz: float

    @property
    def freq_hz(self) -> float:
        return self.freq_ghz * 1e9

    def energy_scale(self, v_nominal: float) -> float:
        """Dynamic-energy multiplier vs. the nominal-voltage LUT: E ~ V^2."""
        return (self.voltage_v / v_nominal) ** 2


@dataclasses.dataclass(frozen=True)
class DvfsDomain:
    """An accelerator clock/voltage domain with its supported points."""

    name: str
    points: Tuple[OperatingPoint, ...]
    v_nominal: float
    transition_time_s: float = 1e-6   # conservative end of "tens of ns .. few us"
    transition_energy_j: float = 5e-7

    def point(self, name: str) -> OperatingPoint:
        for p in self.points:
            if p.name == name:
                return p
        raise KeyError(name)

    def best_point_for_delay(self, critical_path_ns: float) -> OperatingPoint:
        """Paper SIII-C1: argmin energy s.t. 1/f >= critical path."""
        feasible = [p for p in self.points
                    if 1.0 / p.freq_ghz >= critical_path_ns - 1e-9]
        if not feasible:
            feasible = [min(self.points, key=lambda p: p.freq_ghz)]
        return min(feasible, key=lambda p: p.energy_scale(self.v_nominal) * p.freq_ghz)

    def fastest_point_for_delay(self, critical_path_ns: float) -> OperatingPoint:
        """Highest safe frequency given a class critical path."""
        feasible = [p for p in self.points
                    if 1.0 / p.freq_ghz >= critical_path_ns - 1e-9]
        if not feasible:
            feasible = [min(self.points, key=lambda p: p.freq_ghz)]
        return max(feasible, key=lambda p: p.freq_ghz)


# Paper Table I -------------------------------------------------------------

SYSTOLIC_DOMAIN = DvfsDomain(
    name="systolic",
    points=(
        OperatingPoint("F1", 1.0, 1.9),
        OperatingPoint("F2", 1.1, 2.4),
        OperatingPoint("F3", 1.2, 3.7),
    ),
    v_nominal=1.0,
)

GPU_DOMAIN = DvfsDomain(
    name="gpu",
    points=(
        OperatingPoint("G1", 0.9, 1.5),
        OperatingPoint("G2", 1.0, 2.0),
        OperatingPoint("G3", 1.1, 2.8),
    ),
    v_nominal=0.9,
)


def schedule_transitions(class_per_tile: Sequence[int]) -> Dict[str, object]:
    """Cluster tiles by frequency class into contiguous execution groups.

    Returns the executed order (all tiles of a class together, slowest class
    first so the array "ramps up"), the number of DVFS transitions paid, and
    per-class tile counts.  Reordering is legal because tile programs are
    independent (paper SIII-C3).
    """
    arr = np.asarray(class_per_tile, np.int32)
    order = np.argsort(arr, kind="stable")
    classes, counts = np.unique(arr, return_counts=True)
    return {
        "order": order,
        "classes": classes,
        "counts": counts,
        "num_transitions": max(int(classes.size) - 1, 0),
    }


def plan_for_classes(class_per_tile: Sequence[int],
                     domain: DvfsDomain = SYSTOLIC_DOMAIN) -> Dict[str, object]:
    """Full DVFS plan for one class-grouped tile schedule.

    Extends ``schedule_transitions`` with the operating point each class
    group runs at (``fastest_point_for_delay`` of the class critical path),
    the tile-weighted achievable frequency, and the headroom over the
    domain's slowest point -- the clock a hardware-agnostic deployment of
    the same weights would be stuck at.  This is the paper's claim made
    concrete per layer: low critical-path-delay classes buy higher clocks
    for only (num classes - 1) transitions.
    """
    from . import mac_model

    sched = schedule_transitions(class_per_tile)
    nominal = min(domain.points, key=lambda p: p.freq_ghz)
    points: Dict[str, OperatingPoint] = {}
    total = int(np.asarray(class_per_tile, np.int32).size)
    f_sum = e_sum = 0.0
    for cls_id, count in zip(sched["classes"].tolist(),
                             sched["counts"].tolist()):
        name = mac_model.ID_TO_CLASS[int(cls_id)]
        crit_ns = 1.0 / mac_model.CLASS_FREQ_GHZ[name]
        pt = domain.fastest_point_for_delay(crit_ns)
        points[name] = pt
        f_sum += count * pt.freq_ghz
        e_sum += count * pt.energy_scale(domain.v_nominal)
    out = dict(sched)
    out["points"] = points
    out["nominal_freq_ghz"] = nominal.freq_ghz
    out["achievable_freq_ghz"] = (f_sum / total) if total else nominal.freq_ghz
    out["freq_headroom"] = out["achievable_freq_ghz"] / nominal.freq_ghz
    out["energy_scale"] = (e_sum / total) if total else 1.0
    return out
