"""Analytic GPU performance/energy model (paper SIV-E, Figs. 12-13).

The paper extends AccelSim to an RTX 2080 Ti-class part with the Table I GPU
DVFS levels and evaluates HALO against W8A8.  We model the GPU as a
latency/throughput roofline with a DVFS-scalable SM domain:

  t_kernel = max( flops / (peak_flops * f/f_nom),  bytes / dram_bw )

Weight bytes scale with the scheme's stored bit-width; HALO executes the
low-sensitivity tile groups at G3 (2.8 GHz) and the high-sensitivity ones at
G2 (2.0 GHz), with the outlier SpMV fused into the epilogue (it is <0.5% of
FLOPs).  LLM decode is DRAM-bound, so HALO's 4-bit weights also cut the
memory term -- on GPUs the win is bandwidth + clock, on the systolic array it
is clock alone; this matches the paper's observation that GPU gains are
milder than systolic gains.

Energy = P_const * t + P_sm(V, f) * t_compute + e_dram * bytes, mirroring the
AccelWattch constant/static/dynamic decomposition.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Sequence, Tuple

from .dvfs import GPU_DOMAIN, DvfsDomain


@dataclasses.dataclass(frozen=True)
class GpuSpec:
    name: str = "rtx2080ti-class"
    peak_int8_tops: float = 215e12      # tensor-core int8 at nominal clock
    peak_fp16_tflops: float = 108e12
    dram_bw_Bps: float = 616e9
    f_nominal_ghz: float = 2.0          # G2 point
    p_constant_w: float = 55.0          # fans, PCIe, idle logic
    p_sm_nominal_w: float = 160.0       # SM dynamic at (1.0 V, 2.0 GHz)
    e_dram_pj_per_byte: float = 18.0


DEFAULT_GPU = GpuSpec()


@dataclasses.dataclass(frozen=True)
class GpuScheme:
    name: str
    weight_bits: float
    act_bits: float
    # fraction of weight-tile groups executed at each DVFS point name
    point_fractions: Mapping[str, float]
    fp16: bool = False


def gpu_baseline(name: str) -> GpuScheme:
    if name == "fp16":
        return GpuScheme("fp16", 16, 16, {"G2": 1.0}, fp16=True)
    if name == "w8a8":
        return GpuScheme("w8a8", 8, 8, {"G2": 1.0})
    if name == "w4a8":
        return GpuScheme("w4a8", 4, 8, {"G2": 1.0})
    raise KeyError(name)


def gpu_halo(f3_frac: float, f2_frac: float, name: str = "halo") -> GpuScheme:
    # low-sensitivity groups ride G3 (2.8 GHz); high-sensitivity stay G2.
    return GpuScheme(name, 4.0 + 16.0 / (128 * 128), 8,
                     {"G3": f3_frac, "G2": f2_frac})


@dataclasses.dataclass
class GpuSimResult:
    time_s: float
    compute_time_s: float
    memory_time_s: float
    energy_j: float
    energy_breakdown: Dict[str, float]


def simulate_matmuls(layer_shapes: Sequence[Tuple[int, int, int]],
                     scheme: GpuScheme,
                     spec: GpuSpec = DEFAULT_GPU,
                     domain: DvfsDomain = GPU_DOMAIN) -> GpuSimResult:
    peak = spec.peak_fp16_tflops if scheme.fp16 else spec.peak_int8_tops
    t_comp = t_mem = 0.0
    e_sm = e_dram = 0.0
    for (m, k, n) in layer_shapes:
        flops = 2.0 * m * k * n
        bytes_ = (k * n * scheme.weight_bits / 8.0
                  + m * k * scheme.act_bits / 8.0 + m * n * 2.0)
        for pt_name, frac in scheme.point_fractions.items():
            if frac <= 0.0:
                continue
            pt = domain.point(pt_name)
            fscale = pt.freq_ghz / spec.f_nominal_ghz
            tc = frac * flops / (peak * fscale)
            tm = frac * bytes_ / spec.dram_bw_Bps
            t_comp += tc
            t_mem += tm
            # SM power ~ C V^2 f relative to nominal point
            p_sm = (spec.p_sm_nominal_w
                    * (pt.voltage_v / domain.point("G2").voltage_v) ** 2 * fscale)
            e_sm += p_sm * max(tc, tm * 0.35)   # SMs partially idle when DRAM-bound
        e_dram += bytes_ * spec.e_dram_pj_per_byte * 1e-12
    total_t = max(t_comp, t_mem)
    e_const = spec.p_constant_w * total_t
    return GpuSimResult(
        time_s=total_t, compute_time_s=t_comp, memory_time_s=t_mem,
        energy_j=e_sm + e_dram + e_const,
        energy_breakdown={"constant": e_const, "sm": e_sm, "dram": e_dram})
