"""Behavioral timing/energy model of an 8-bit Booth-Wallace MAC unit.

The paper characterizes a Synopsys DW02_MAC (Booth encoding, Wallace tree
reduction, final carry-propagate adder) with PrimeTime static timing analysis
and finds the worst-case critical-path delay of ``w * a + y`` depends strongly
on the *weight* operand (paper Figs. 3-5): weight values whose recoding
activates few partial-product rows admit much higher clock frequencies, and
the paper anchors three frequency classes:

  * 9 weight values   admit a 3.7 GHz clock   (class F3, low-sensitivity tiles)
  * 16 weight values  admit a 2.4 GHz clock   (class F2, high-sensitivity tiles)
  * all 256 values    admit a 1.9 GHz clock   (class F1, outliers / salient)

We cannot run PrimeTime in this container, so this module is a *behavioral*
model calibrated to those anchors.  Weights are recoded into canonical
signed-digit (CSD / non-adjacent) form -- the minimal-partial-product booth
recoding used by DesignWare multipliers -- and the critical path decomposes as

  delay(w) = t_enc + t_csa * stages(nnz(w)) + t_hi * [msb(w) >= 4]

where ``nnz`` is the number of nonzero signed digits (active partial-product
rows -> CSA tree depth ``stages = ceil(log2(nnz+1))``) and the step term
models the upper carry-lookahead block of the final adder engaging only when
the most significant active digit sits in the high nibble.  Dynamic energy
follows switching activity:  ``energy(w) = e_base + e_pp*nnz + e_msb*msb``.

The classes that fall out are exactly the paper's:

  F3 = {0, +-1, +-2, +-4, +-8}                      (nnz<=1, msb<=3; 9 values)
  F2 = F3 + {+-16, +-32, +-64, -128}                (nnz<=1;         16 values)
  F1 = all int8                                     (worst path; multi-PP)

i.e. the fast codebooks are the sign*2^k ("logarithmic") values -- single
active partial product, minimal switching -- matching the peaked shape of the
paper's Fig. 4 and the timing/power correlation of Fig. 5.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import numpy as np

INT8_MIN, INT8_MAX = -128, 127
WEIGHT_VALUES = np.arange(INT8_MIN, INT8_MAX + 1, dtype=np.int32)  # (256,)

# Paper anchors (Table I systolic-array DVFS levels).
F3_GHZ, F2_GHZ, F1_GHZ = 3.7, 2.4, 1.9


def csd_digits(w: int) -> Tuple[int, ...]:
    """Canonical signed-digit (non-adjacent form) recoding, LSB first.

    Digits in {-1, 0, +1}; minimal number of nonzeros; no two adjacent
    nonzeros.  Reconstructs w exactly: ``w = sum_i d_i * 2**i``.
    """
    w = int(w)
    if not INT8_MIN <= w <= INT8_MAX:
        raise ValueError(f"weight {w} outside int8 range")
    n, digits = w, []
    while n != 0:
        if n & 1:
            d = 2 - (n & 3)  # +-1 such that (n - d) % 4 == 0
            if d == 2:       # n % 4 == 0 unreachable here; keep math exact
                d = -2
            digits.append(d)
            n -= d
        else:
            digits.append(0)
        n >>= 1
    return tuple(digits) if digits else (0,)


def nnz_pp(w: int) -> int:
    """Number of active partial-product rows (nonzero CSD digits)."""
    return sum(1 for d in csd_digits(w) if d != 0)


def msb_pp(w: int) -> int:
    """Bit position of the most significant active partial product (0 for w=0)."""
    d = csd_digits(w)
    pos = 0
    for i, di in enumerate(d):
        if di != 0:
            pos = i
    return pos


def _stages(nnz: int) -> int:
    """CSA reduction-tree depth for `nnz` partial products."""
    return int(np.ceil(np.log2(nnz + 1))) if nnz > 0 else 0


@functools.lru_cache(maxsize=None)
def _max_stages() -> int:
    return max(_stages(nnz_pp(int(w))) for w in WEIGHT_VALUES)


@dataclasses.dataclass(frozen=True)
class MacTimingParams:
    """Coefficients (ns / pJ) of the behavioral delay & energy model.

    Defaults are solved from the paper's three frequency anchors:
      t_enc + t_csa*1                  = 1/3.7   (single PP, low nibble)
      t_enc + t_csa*1 + t_hi           = 1/2.4   (single PP, high nibble)
      t_enc + t_csa*S_max + t_hi       = 1/1.9   (worst-case value)
    """

    t_enc: float = 0.0
    t_csa: float = 0.0
    t_hi: float = 0.0
    e_base: float = 0.15   # clocking + sequencing energy per MAC (pJ)
    e_pp: float = 0.28     # per active partial-product row
    e_msb: float = 0.012   # per bit of carry-chain actually exercised

    def __post_init__(self):
        if self.t_csa == 0.0:
            d3, d2, d1 = 1.0 / F3_GHZ, 1.0 / F2_GHZ, 1.0 / F1_GHZ
            s_max = _max_stages()
            t_csa = (d1 - d2) / max(s_max - 1, 1)
            t_hi = d2 - d3
            t_enc = d3 - t_csa
            object.__setattr__(self, "t_csa", t_csa)
            object.__setattr__(self, "t_hi", t_hi)
            object.__setattr__(self, "t_enc", t_enc)

    def delay_ns(self, w: int) -> float:
        n, m = nnz_pp(w), msb_pp(w)
        return self.t_enc + self.t_csa * max(_stages(n), 1) + self.t_hi * (m >= 4)

    def energy_pj(self, w: int) -> float:
        return self.e_base + self.e_pp * nnz_pp(w) + self.e_msb * msb_pp(w)


DEFAULT_PARAMS = MacTimingParams()


@functools.lru_cache(maxsize=None)
def delay_lut(params: MacTimingParams = DEFAULT_PARAMS) -> np.ndarray:
    """(256,) float32 ns worst-case delay per weight value (index = w + 128)."""
    return np.array([params.delay_ns(int(w)) for w in WEIGHT_VALUES], np.float32)


@functools.lru_cache(maxsize=None)
def energy_lut(params: MacTimingParams = DEFAULT_PARAMS) -> np.ndarray:
    """(256,) float32 pJ dynamic energy per MAC (index = w + 128)."""
    return np.array([params.energy_pj(int(w)) for w in WEIGHT_VALUES], np.float32)


@functools.lru_cache(maxsize=None)
def achievable_freq_ghz(params: MacTimingParams = DEFAULT_PARAMS) -> np.ndarray:
    """(256,) max clock (GHz) per weight value == 1/delay.  Paper Fig. 4.

    Cached like the delay/energy LUTs (keyed on the frozen params): the
    serving autotuner prices every candidate config through these sweeps,
    so the 256-entry CSD recode must not be recomputed per candidate."""
    return (1.0 / delay_lut(params)).astype(np.float32)


def max_freq_for_values(values: np.ndarray,
                        params: MacTimingParams = DEFAULT_PARAMS) -> float:
    """Highest clock every value in `values` sustains (GHz) == min over set."""
    values = np.asarray(values, np.int32)
    if values.size == 0:
        return float(achievable_freq_ghz(params).max())
    lut = delay_lut(params)
    return float(1.0 / lut[values + 128].max())


# ---------------------------------------------------------------------------
# Frequency classes (the paper's 9 / 16 / 256 grouping)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def frequency_classes() -> Dict[str, np.ndarray]:
    """The paper's three classes as {name: sorted int32 value array}.

    F3 (9 values, 3.7 GHz):  single partial product in the low nibble.
    F2 (16 values, 2.4 GHz): single partial product anywhere (all sign*2^k).
    F1 (256 values, 1.9 GHz): the full int8 range.
    """
    single = np.array([w for w in WEIGHT_VALUES if nnz_pp(int(w)) <= 1], np.int32)
    f3 = np.array([w for w in single if msb_pp(int(w)) <= 3], np.int32)
    return {"F3": np.sort(f3), "F2": np.sort(single), "F1": WEIGHT_VALUES.copy()}


CLASS_FREQ_GHZ = {"F3": F3_GHZ, "F2": F2_GHZ, "F1": F1_GHZ}
# class id used in packed tensors: 0 -> F1 (slow), 1 -> F2, 2 -> F3 (fast)
CLASS_IDS = {"F1": 0, "F2": 1, "F3": 2}
ID_TO_CLASS = {v: k for k, v in CLASS_IDS.items()}


def validate_against_paper(params: MacTimingParams = DEFAULT_PARAMS) -> Dict[str, float]:
    """Sanity metrics tying the behavioral model to the paper's anchors."""
    classes = frequency_classes()
    lut_e = energy_lut(params)
    f = achievable_freq_ghz(params)
    return {
        "f3_ghz": max_freq_for_values(classes["F3"], params),
        "f2_ghz": max_freq_for_values(classes["F2"], params),
        "f1_ghz": max_freq_for_values(classes["F1"], params),
        "f3_size": int(classes["F3"].size),
        "f2_size": int(classes["F2"].size),
        # paper Fig. 3: weight 64 clocks ~2x faster than -127
        "w64_over_wm127": float(f[64 + 128] / f[-127 + 128]),
        # paper Fig. 5: timing & power correlate
        "delay_energy_corr": float(np.corrcoef(delay_lut(params), lut_e)[0, 1]),
    }
