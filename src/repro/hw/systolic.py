"""Cycle/energy simulator for a weight-stationary systolic array with a
global DVFS unit (the paper's custom SystemVerilog design, modeled analytically).

Model
-----
A ``t x t`` int8 MAC array (t = HALO tile size, 128 default == TPU MXU) executes
``(M, K) @ (K, N)`` by iterating weight tiles; per weight tile it pays

  cycles(tile) = t (weight preload) + M (activation streaming) + 2t (drain)

Every tile carries a frequency class; tiles of one class execute contiguously
(one DVFS transition per class, paper SIII-C3), so

  T_compute = sum_cls cycles(cls) / f(cls) + (n_cls - 1) * t_dvfs

Baselines (FP16 / W8A8 / W4A8 / W3A8) are *hardware-agnostic*: the deployment
cannot prove a shorter critical path, so the array stays at the nominal point
(F1 = 1.9 GHz; FP16 uses a slower wide-datapath clock).  That asymmetry -- not
raw bit-width -- is the paper's headline speedup mechanism.

Memory system: double-buffered weight fetch from DRAM through an SRAM buffer;
activations streamed once per (M, K) pass per tile row.  Off-chip traffic
scales with stored bits/weight (HALO: 4-bit codebook indices + per-tile scale
+ <0.5% sparse 8-bit outliers).  Energy integrates the per-value MAC LUT
(switching activity), buffer/DRAM per-byte costs, DVFS transition energy and
leakage * time.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from . import mac_model
from .dvfs import SYSTOLIC_DOMAIN, DvfsDomain, OperatingPoint


@dataclasses.dataclass(frozen=True)
class MemoryParams:
    dram_bandwidth_Bps: float = 819e9      # HBM-class
    dram_energy_pj_per_byte: float = 20.0
    sram_energy_pj_per_byte: float = 0.15  # wide banked reads, 22nm-ish
    leakage_w: float = 2.0                 # array + buffers
    act_bits: int = 8                      # activations A8 everywhere (paper)
    spmv_lanes: int = 4096                 # dedicated SpMV engine width


DEFAULT_MEM = MemoryParams()


@dataclasses.dataclass(frozen=True)
class SchemeSpec:
    """How a quantization scheme occupies the array.

    class_fractions: fraction of weight tiles per frequency-class name; the
      class also fixes which codebook the tile's weights live in.
    weight_bits: stored bits per dense weight (memory traffic).
    mac_energy_pj: mean per-MAC dynamic energy at nominal V (from the LUT over
      the scheme's actual value distribution).
    sparse_frac: fraction of weights routed to the SpMV engine (HALO: 0.0045).
    fp16: wide-datapath mode (clock capped, 4x MAC energy).
    """

    name: str
    class_fractions: Mapping[str, float]
    weight_bits: float
    mac_energy_pj: float
    sparse_frac: float = 0.0
    fp16: bool = False


# Wide fp datapath: ~2x int8 critical path plus ~30% fewer MACs/mm^2; both
# folded into an effective throughput clock for the same 128x128 grid.
FP16_CLOCK_GHZ = 0.80
FP16_MAC_ENERGY_SCALE = 4.0


def mean_mac_energy(values: np.ndarray, weights: Optional[np.ndarray] = None) -> float:
    """Mean per-MAC energy (pJ) over an int8 value distribution."""
    lut = mac_model.energy_lut()
    values = np.asarray(values, np.int32)
    e = lut[values + 128]
    if weights is None:
        return float(e.mean())
    w = np.asarray(weights, np.float64)
    return float((e * w).sum() / w.sum())


def baseline_scheme(name: str) -> SchemeSpec:
    """FP16 / W8A8 / W4A8 / W3A8 baselines (hardware-agnostic -> F1 clock)."""
    rng = np.random.default_rng(0)
    if name == "fp16":
        vals = rng.integers(-128, 128, 4096)
        return SchemeSpec("fp16", {"F1": 1.0}, 16.0,
                          mean_mac_energy(vals) * FP16_MAC_ENERGY_SCALE, fp16=True)
    if name == "w8a8":
        vals = np.clip(rng.normal(0, 42, 65536), -128, 127).astype(np.int32)
        return SchemeSpec("w8a8", {"F1": 1.0}, 8.0, mean_mac_energy(vals))
    if name == "w4a8":
        vals = np.clip(rng.normal(0, 2.7, 65536), -8, 7).astype(np.int32)
        return SchemeSpec("w4a8", {"F1": 1.0}, 4.0, mean_mac_energy(vals))
    if name == "w3a8":
        vals = np.clip(rng.normal(0, 1.4, 65536), -4, 3).astype(np.int32)
        return SchemeSpec("w3a8", {"F1": 1.0}, 3.0, mean_mac_energy(vals))
    raise KeyError(name)


def halo_scheme(f3_frac: float, f2_frac: float,
                sparse_frac: float = 0.0045,
                name: str = "halo") -> SchemeSpec:
    """HALO with the given tile-class mix (f3 + f2 must be ~1)."""
    classes = mac_model.frequency_classes()
    # codebook value usage ~ log-quantized gaussian: low exponents dominate
    e3 = mean_mac_energy(classes["F3"], weights=np.array([1, 2, 4, 6, 8, 6, 4, 2, 1]))
    w2 = np.array([1, 1, 2, 3, 5, 8, 11, 14, 16, 14, 11, 8, 5, 3, 2, 1], np.float64)
    e2 = mean_mac_energy(classes["F2"], weights=w2)
    mac_e = (f3_frac * e3 + f2_frac * e2) / max(f3_frac + f2_frac, 1e-9)
    return SchemeSpec(name, {"F3": f3_frac, "F2": f2_frac},
                      weight_bits=4.0 + 16.0 / (128 * 128),  # idx + per-tile scale
                      mac_energy_pj=mac_e, sparse_frac=sparse_frac)


def scheme_from_class_counts(counts: Mapping[str, int],
                             sparse_frac: float = 0.0045,
                             name: str = "halo-packed") -> SchemeSpec:
    """SchemeSpec from *measured* per-class tile counts.

    ``halo_scheme`` takes nominal fractions; this consumes the composition
    read back off a packed weight's own 4-bit index stream
    (core/deploy.layer_class_composition) -- the deployment ground truth the
    serving autotuner prices candidates and per-layer DVFS schedules
    against.  Handles any F1 residue (tiles that cannot prove a shorter
    critical path run at the nominal clock with full-range MAC energy).
    """
    total = float(sum(int(v) for v in counts.values()))
    if total <= 0:
        # no classed tiles at all: the hardware-agnostic deployment
        return SchemeSpec(name, {"F1": 1.0},
                          weight_bits=4.0 + 16.0 / (128 * 128),
                          mac_energy_pj=mean_mac_energy(
                              mac_model.frequency_classes()["F1"]),
                          sparse_frac=sparse_frac)
    fr = {k: int(v) / total for k, v in counts.items() if int(v) > 0}
    classes = mac_model.frequency_classes()
    # same codebook-usage priors as halo_scheme: log-quantized gaussian
    e_by = {
        "F3": mean_mac_energy(classes["F3"],
                              weights=np.array([1, 2, 4, 6, 8, 6, 4, 2, 1])),
        "F2": mean_mac_energy(classes["F2"], weights=np.array(
            [1, 1, 2, 3, 5, 8, 11, 14, 16, 14, 11, 8, 5, 3, 2, 1],
            np.float64)),
        "F1": mean_mac_energy(classes["F1"]),
    }
    mac_e = sum(f * e_by[c] for c, f in fr.items())
    return SchemeSpec(name, fr, weight_bits=4.0 + 16.0 / (128 * 128),
                      mac_energy_pj=mac_e, sparse_frac=sparse_frac)


@dataclasses.dataclass
class SimResult:
    time_s: float
    compute_time_s: float
    memory_time_s: float
    spmv_time_s: float
    dvfs_transitions: int
    energy_j: float
    energy_breakdown: Dict[str, float]

    def normalized_to(self, other: "SimResult") -> Dict[str, float]:
        return {"time": self.time_s / other.time_s,
                "energy": self.energy_j / other.energy_j}


def simulate_matmul(m: int, k: int, n: int, scheme: SchemeSpec,
                    tile: int = 128,
                    domain: DvfsDomain = SYSTOLIC_DOMAIN,
                    mem: MemoryParams = DEFAULT_MEM) -> SimResult:
    """Simulate one (m,k) @ (k,n) on the array under `scheme`."""
    classes = mac_model.frequency_classes()
    fp16 = scheme.fp16
    kt, nt = -(-k // tile), -(-n // tile)
    n_tiles = kt * nt
    cycles_per_tile = tile + m + 2 * tile

    # --- compute time: per-class contiguous groups ---
    compute_t, n_groups = 0.0, 0
    mac_count = 0.0
    for cls_name, frac in scheme.class_fractions.items():
        if frac <= 0.0:
            continue
        n_groups += 1
        if fp16:
            f_ghz = FP16_CLOCK_GHZ
        else:
            crit_ns = 1.0 / mac_model.CLASS_FREQ_GHZ[cls_name]
            f_ghz = domain.fastest_point_for_delay(crit_ns).freq_ghz
        compute_t += frac * n_tiles * cycles_per_tile / (f_ghz * 1e9)
        mac_count += frac * n_tiles * m * tile * tile
    transitions = max(n_groups - 1, 0)
    compute_t += transitions * domain.transition_time_s

    # --- SpMV engine for outliers/salient (paper: <1% of exec time) ---
    nnz = scheme.sparse_frac * k * n
    spmv_t = (nnz * m) / (mem.spmv_lanes * 1.9e9) if nnz else 0.0

    # --- memory time: DRAM sees each tensor once (weights/acts/outputs);
    # activation re-reads across weight-tile columns come from SRAM.
    w_bytes = k * n * scheme.weight_bits / 8.0
    a_bytes = m * k * mem.act_bits / 8.0
    o_bytes = m * n * 4.0                        # fp32 partials written back
    sram_restream_bytes = a_bytes * nt           # per weight-tile-column reuse
    mem_t = (w_bytes + a_bytes + o_bytes) / mem.dram_bandwidth_Bps

    # weight fetch double-buffers behind compute; activations stream.
    total_t = max(compute_t, mem_t) + spmv_t

    # --- energy ---
    e_mac = 0.0
    for cls_name, frac in scheme.class_fractions.items():
        if frac <= 0.0:
            continue
        if fp16:
            vscale = 1.0
        else:
            crit_ns = 1.0 / mac_model.CLASS_FREQ_GHZ[cls_name]
            pt = domain.fastest_point_for_delay(crit_ns)
            vscale = pt.energy_scale(domain.v_nominal)
        e_mac += (frac * n_tiles * m * tile * tile) * scheme.mac_energy_pj * vscale
    e_mac *= 1e-12
    e_sram = (w_bytes + sram_restream_bytes + o_bytes) * mem.sram_energy_pj_per_byte * 1e-12
    e_dram = (w_bytes + a_bytes + o_bytes) * mem.dram_energy_pj_per_byte * 1e-12
    e_static = mem.leakage_w * total_t
    e_dvfs = transitions * domain.transition_energy_j
    energy = e_mac + e_sram + e_dram + e_static + e_dvfs

    return SimResult(
        time_s=total_t, compute_time_s=compute_t, memory_time_s=mem_t,
        spmv_time_s=spmv_t, dvfs_transitions=transitions, energy_j=energy,
        energy_breakdown={"mac": e_mac, "sram": e_sram, "dram": e_dram,
                          "static": e_static, "dvfs": e_dvfs})


def simulate_layers(layer_shapes: Sequence[Tuple[int, int, int]],
                    scheme: SchemeSpec, tile: int = 128,
                    mem: MemoryParams = DEFAULT_MEM) -> SimResult:
    """Sum a sequence of (m, k, n) matmuls (one forward pass of a model)."""
    total = None
    for (m, k, n) in layer_shapes:
        r = simulate_matmul(m, k, n, scheme, tile=tile, mem=mem)
        if total is None:
            total = r
        else:
            total = SimResult(
                time_s=total.time_s + r.time_s,
                compute_time_s=total.compute_time_s + r.compute_time_s,
                memory_time_s=total.memory_time_s + r.memory_time_s,
                spmv_time_s=total.spmv_time_s + r.spmv_time_s,
                dvfs_transitions=total.dvfs_transitions + r.dvfs_transitions,
                energy_j=total.energy_j + r.energy_j,
                energy_breakdown={kk: total.energy_breakdown[kk] + r.energy_breakdown[kk]
                                  for kk in total.energy_breakdown})
    assert total is not None
    return total


def decoder_layer_shapes(d_model: int, d_ff: int, n_layers: int,
                         vocab: int, seq: int = 2048, batch: int = 1,
                         gated: bool = True) -> List[Tuple[int, int, int]]:
    """(m,k,n) matmul list for a decoder-only LM forward (weights only)."""
    m = seq * batch
    per_layer = [
        (m, d_model, 3 * d_model),          # qkv (approx; GQA folds into this)
        (m, d_model, d_model),              # out proj
        (m, d_model, (2 if gated else 1) * d_ff),
        (m, d_ff, d_model),
    ]
    shapes = per_layer * n_layers
    shapes.append((m, d_model, vocab))
    return shapes
