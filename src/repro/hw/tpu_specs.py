"""TPU v5e roofline constants used by the dry-run analysis (target hardware).

These are the numbers mandated by the reproduction brief:
  peak bf16 compute  : 197 TFLOP/s per chip
  HBM bandwidth      : 819 GB/s per chip
  ICI bandwidth      : ~50 GB/s per link
plus mesh/topology conventions for the production meshes.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12     # FLOP/s
    peak_int8_ops: float = 394e12       # OP/s (2x bf16)
    hbm_bandwidth: float = 819e9        # B/s
    hbm_bytes: float = 16e9             # 16 GB HBM per chip
    ici_link_bandwidth: float = 50e9    # B/s per link (brief: ~50 GB/s/link)
    vmem_bytes: float = 128e6           # ~128 MB VMEM
    mxu_shape: tuple = (128, 128)       # systolic array == HALO tile


V5E = ChipSpec()

SINGLE_POD_CHIPS = 256   # 16 x 16
MULTI_POD_CHIPS = 512    # 2 pods


def compute_time_s(hlo_flops: float, chips: int, spec: ChipSpec = V5E) -> float:
    return hlo_flops / (chips * spec.peak_bf16_flops)


def memory_time_s(hlo_bytes: float, chips: int, spec: ChipSpec = V5E) -> float:
    return hlo_bytes / (chips * spec.hbm_bandwidth)


def collective_time_s(coll_bytes: float, chips: int, spec: ChipSpec = V5E) -> float:
    return coll_bytes / (chips * spec.ici_link_bandwidth)
