"""Pallas TPU kernels for HALO deployment (validated in interpret mode on
CPU): halo_matmul (codebook dequant + class-grouped MXU matmul), spmv
(gather-free hypersparse outlier path), int8_matmul (W8A8 baseline),
paged_decode (page-table-indirect flash decode over the paged KV cache)."""

from . import (halo_matmul, int8_matmul, ops, paged_decode, ref,  # noqa: F401
               spmv)
