"""Pallas TPU flash-attention kernels (forward + backward).

The pure-JAX flash path (models/flash.py) is numerically exact but
materializes (chunk x chunk) f32 score blocks between dots at every step --
real HBM traffic on any backend.  These kernels keep the entire block
pipeline in VMEM: HBM sees q/k/v/out (+ lse) once in the forward and
q/k/v/out/dout once plus dq/dk/dv writes in the backward, which is the
traffic the roofline's "flash_vmem" accounting models.

Layout: inputs are (BH, S, D) -- batch*heads flattened by the wrapper; the
forward grid is (BH, S/bq) with an inner fori_loop over kv blocks (causal:
only j <= i); the backward runs two passes, dkv-major and dq-major, each
re-computing p from (q, k, lse).  Block sizes default to 512 x 512 with D
padded to a lane multiple.  Validated in interpret mode against
models/flash.py (itself validated against dense attention).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _causal_mask(i, j, bq, bk, window):
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = qpos >= kpos
    if window is not None:
        m &= (qpos - kpos) < window
    return m


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *,
                bq, bk, n_kv, scale, window, softcap):
    i = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                   # (bq, D)

    def body(j, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(j * bk, bk), slice(None))
                    )[0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(j * bk, bk), slice(None))
                    )[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(_causal_mask(i, j, bq, bk, window), s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    hi = jnp.minimum((i + 1) * bq // bk + ((i + 1) * bq % bk != 0), n_kv)
    lo = 0
    if window is not None:
        lo = jnp.maximum(0, (i * bq - window) // bk)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, q_ref.shape[-1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
    lse_ref[0] = m + jnp.log(jnp.maximum(l, 1e-30))


@functools.partial(
    jax.jit, static_argnames=("bq", "bk", "window", "softcap", "interpret"))
def flash_fwd(q, k, v, bq=512, bk=512, window=None, softcap=None,
              interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q,k,v: (BH, S, D) -> (out (BH,S,D), lse (BH,S))."""
    bh, s, d = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    scale = float(1.0 / np.sqrt(d))
    kernel = functools.partial(_fwd_kernel, bq=bq, bk=bk, n_kv=s // bk,
                               scale=scale, window=window, softcap=softcap)
    out, lse = pl.pallas_call(
        kernel,
        grid=(bh, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), q.dtype),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out, lse


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, *, bq, bk, n_q, scale, window, softcap):
    j = pl.program_id(1)
    k = k_ref[0].astype(jnp.float32)                    # (bk, D)
    v = v_ref[0].astype(jnp.float32)

    def body(i, carry):
        dk, dv = carry
        q = pl.load(q_ref, (pl.dslice(0, 1), pl.dslice(i * bq, bq), slice(None))
                    )[0].astype(jnp.float32)
        do = pl.load(do_ref, (pl.dslice(0, 1), pl.dslice(i * bq, bq), slice(None))
                     )[0].astype(jnp.float32)
        lse = pl.load(lse_ref, (pl.dslice(0, 1), pl.dslice(i * bq, bq)))[0]
        delta = pl.load(delta_ref, (pl.dslice(0, 1), pl.dslice(i * bq, bq)))[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pre = s
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = _causal_mask(i, j, bq, bk, window)
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dv_new = dv + jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        if softcap is not None:
            th = jnp.tanh(pre * (1.0 / softcap))
            ds = ds * (1.0 - th * th)
        ds = ds * scale
        dk_new = dk + jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return dk_new, dv_new

    lo = (j * bk) // bq
    hi = n_q
    if window is not None:
        hi = jnp.minimum(n_q, ((j + 1) * bk + window) // bq + 1)
    dk0 = jnp.zeros((bk, k_ref.shape[-1]), jnp.float32)
    dv0 = jnp.zeros((bk, v_ref.shape[-1]), jnp.float32)
    dk, dv = jax.lax.fori_loop(lo, hi, body, (dk0, dv0))
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dq_ref, *, bq, bk, n_kv, scale, window, softcap):
    i = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    delta = delta_ref[0]

    def body(j, dq):
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(j * bk, bk), slice(None))
                    )[0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(j * bk, bk), slice(None))
                    )[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pre = s
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = _causal_mask(i, j, bq, bk, window)
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        if softcap is not None:
            th = jnp.tanh(pre * (1.0 / softcap))
            ds = ds * (1.0 - th * th)
        ds = ds * scale
        return dq + jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    hi = jnp.minimum((i + 1) * bq // bk + ((i + 1) * bq % bk != 0), n_kv)
    lo = 0
    if window is not None:
        lo = jnp.maximum(0, (i * bq - window) // bk)
    dq0 = jnp.zeros((bq, q_ref.shape[-1]), jnp.float32)
    dq = jax.lax.fori_loop(lo, hi, body, dq0)
    dq_ref[0] = dq.astype(dq_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bq", "bk", "window", "softcap", "interpret"))
def flash_bwd(q, k, v, out, lse, dout, bq=512, bk=512, window=None,
              softcap=None, interpret: bool = False):
    """Backward: returns (dq, dk, dv), each (BH, S, D)."""
    bh, s, d = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    scale = float(1.0 / np.sqrt(d))
    delta = jnp.einsum("bsd,bsd->bs", out.astype(jnp.float32),
                       dout.astype(jnp.float32))

    dkv_kernel = functools.partial(
        _bwd_dkv_kernel, bq=bq, bk=bk, n_q=s // bq, scale=scale,
        window=window, softcap=softcap)
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid=(bh, s // bk),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, s, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, s), lambda b, j: (b, 0)),
            pl.BlockSpec((1, s), lambda b, j: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bh, s, d), q.dtype)] * 2,
        interpret=interpret,
    )(q, k, v, dout, lse, delta)

    dq_kernel = functools.partial(
        _bwd_dq_kernel, bq=bq, bk=bk, n_kv=s // bk, scale=scale,
        window=window, softcap=softcap)
    dq = pl.pallas_call(
        dq_kernel,
        grid=(bh, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v, dout, lse, delta)
    return dq, dk, dv
