"""Pallas TPU flash-decode kernel with fused int8-KV dequantization.

Single-token attention over a long cache is pure memory streaming; with an
int8-quantized cache (KIVI-style per-position scales) the kernel reads the
cache at 1 byte/element and dequantizes in VMEM -- halving decode's HBM
bound vs bf16 and never materializing a dequantized cache in HBM (which the
XLA fallback path does; the roofline's kvdec_vmem scope models this kernel).

Layout: q (BK, G, D) -- BK = batch*kv_heads, G = q heads per kv head;
k_q/v_q (BK, S, D) int8; k_s/v_s (BK, S) f32; length (BK, 1) int32.
Grid: one step per BK row; inner fori over S blocks with online softmax.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref, o_ref,
                   *, bs, n_blocks, scale, window, softcap):
    b = pl.program_id(0)
    q = q_ref[0].astype(jnp.float32)                # (G, D)
    length = len_ref[b]

    def body(j, carry):
        m, l, acc = carry
        kq = pl.load(kq_ref, (pl.dslice(0, 1), pl.dslice(j * bs, bs), slice(None)))[0]
        ks = pl.load(ks_ref, (pl.dslice(0, 1), pl.dslice(j * bs, bs)))[0]
        vq = pl.load(vq_ref, (pl.dslice(0, 1), pl.dslice(j * bs, bs), slice(None)))[0]
        vs = pl.load(vs_ref, (pl.dslice(0, 1), pl.dslice(j * bs, bs)))[0]
        k = kq.astype(jnp.float32) * ks[:, None]    # dequant in VMEM
        v = vq.astype(jnp.float32) * vs[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
        valid = kpos < length
        if window is not None:
            valid &= kpos >= (length - window)
        s = jnp.where(valid[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    g, d = q_ref.shape[1], q_ref.shape[2]
    m0 = jnp.full((g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g,), jnp.float32)
    a0 = jnp.zeros((g, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bs", "window", "softcap", "interpret"))
def flash_decode_int8(q: jnp.ndarray,              # (BK, G, D)
                      k_q: jnp.ndarray,            # (BK, S, D) int8
                      k_s: jnp.ndarray,            # (BK, S) f32
                      v_q: jnp.ndarray,
                      v_s: jnp.ndarray,
                      length: jnp.ndarray,         # (BK,) int32
                      bs: int = 512,
                      window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      interpret: bool = False) -> jnp.ndarray:
    bk, s, d = k_q.shape
    g = q.shape[1]
    bs = min(bs, s)
    assert s % bs == 0
    scale = float(1.0 / np.sqrt(d))
    kernel = functools.partial(_decode_kernel, bs=bs, n_blocks=s // bs,
                               scale=scale, window=window, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bk,),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda b, L: (b, 0, 0)),
            pl.BlockSpec((1, s, d), lambda b, L: (b, 0, 0)),
            pl.BlockSpec((1, s), lambda b, L: (b, 0)),
            pl.BlockSpec((1, s, d), lambda b, L: (b, 0, 0)),
            pl.BlockSpec((1, s), lambda b, L: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda b, L: (b, 0, 0)),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bk, g, d), jnp.float32),
        interpret=interpret,
    )(length.astype(jnp.int32), q, k_q, k_s, v_q, v_s)
