"""Pallas TPU kernel: HALO codebook matmul with class-grouped tile schedule.

Computes ``out (M, N) = x (M, K) @ dequant(W_halo)`` where the weight is
stored as 4-bit codebook indices (two per byte, packed along N) plus a
per-(128x128)-tile fp32 scale.  Design notes:

* **Gather-free dequant**: the shared 16-entry codebook is the sign*2^k
  table ``[-128,-64,...,-1,0,1,...,64]``, so index -> value is pure
  arithmetic (``+-exp2``), no VMEM gather -- VPU-friendly, then the MXU does
  the (bm,128)x(128,128) product per tile.
* **Class-grouped schedule** (paper SIII-C3 adapted to the MXU): the grid's
  tile axis walks a *scheduled order* delivered via scalar prefetch.  Tiles
  are ordered column-major with the K-tiles of each output column sorted by
  frequency class, so same-class tiles execute contiguously (the DVFS
  grouping) while output accumulation still sees consecutive visits.  On
  real silicon the DVFS controller keys off this order; on TPU it also
  gives the weight-DMA a regular class-banded stride.
* fp32 accumulation in VMEM scratch; out block written on each column's
  last scheduled tile.

BlockSpec tiling: x (bm, 128) VMEM; packed idx (128, 64) uint8 VMEM;
scale (1, 1) SMEM-ish block; out (bm, 128).  bm defaults to 128 (MXU-square)
and shrinks for small M (decode).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE = 128


def _decode_idx(idx: jnp.ndarray) -> jnp.ndarray:
    """4-bit codebook index -> fp32 value of the shared sign*2^k table."""
    idxf = idx.astype(jnp.float32)
    neg = -jnp.exp2(7.0 - idxf)          # idx 0..7  -> -128..-1
    pos = jnp.exp2(idxf - 9.0)           # idx 9..15 -> 1..64
    return jnp.where(idx < 8, neg, jnp.where(idx == 8, 0.0, pos))


def _halo_kernel(kt_ref, nt_ref, first_ref, last_ref,   # scalar prefetch
                 x_ref, idx_ref, scale_ref, o_ref, acc_ref):
    j = pl.program_id(1)

    @pl.when(first_ref[j] == 1)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    packed = idx_ref[...]                               # (128, 64) uint8
    lo = packed & jnp.uint8(0xF)
    hi = packed >> jnp.uint8(4)
    idx = jnp.stack([lo, hi], axis=-1).reshape(TILE, TILE)
    # per-tile-column scale row broadcasts over the tile's K rows (VPU)
    w = _decode_idx(idx) * scale_ref[0, :][None, :]
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(last_ref[j] == 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret", "out_dtype"))
def halo_matmul_packed(x: jnp.ndarray,
                       idx_packed: jnp.ndarray,      # (Kp, Np//2) uint8
                       scale: jnp.ndarray,           # (kt*nt, TILE) f32
                       order_kt: jnp.ndarray,        # (n_tiles,) int32
                       order_nt: jnp.ndarray,
                       order_first: jnp.ndarray,     # 1 on first tile of col
                       order_last: jnp.ndarray,      # 1 on last tile of col
                       bm: int = 128,
                       out_dtype=jnp.float32,
                       interpret: bool = False) -> jnp.ndarray:
    """x: (M, Kp) fp; returns (M, Np).  Caller pads/slices true shapes.

    `scale` holds one fp32 row per tile (row-major over the (kt, nt) grid):
    per-tile-column scales; a scalar-scale tensor broadcasts into rows."""
    m, kp = x.shape
    npk = idx_packed.shape[1] * 2
    kt, nt = kp // TILE, npk // TILE
    n_tiles = int(order_kt.shape[0])
    assert n_tiles == kt * nt
    assert scale.shape == (n_tiles, TILE), scale.shape

    pad_m = (-m) % bm
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    mp = m + pad_m

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(mp // bm, n_tiles),
        in_specs=[
            pl.BlockSpec((bm, TILE),
                         lambda i, j, okt, ont, of, ol: (i, okt[j])),
            pl.BlockSpec((TILE, TILE // 2),
                         lambda i, j, okt, ont, of, ol: (okt[j], ont[j])),
            pl.BlockSpec((1, TILE),
                         lambda i, j, okt, ont, of, ol:
                         (okt[j] * nt + ont[j], 0)),
        ],
        out_specs=pl.BlockSpec((bm, TILE),
                               lambda i, j, okt, ont, of, ol: (i, ont[j])),
        scratch_shapes=[pltpu.VMEM((bm, TILE), jnp.float32)],
    )
    out = pl.pallas_call(
        _halo_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mp, npk), out_dtype),
        interpret=interpret,
    )(order_kt, order_nt, order_first, order_last, x, idx_packed, scale)
    return out[:m]


def make_schedule(classes: np.ndarray, kt: int, nt: int
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Class-grouped tile order (column-major, class-sorted within column).

    classes: (kt*nt,) tile classes in row-major (kt, nt) layout.  Returns
    (order_kt, order_nt, first, last) int32 arrays of length kt*nt.
    """
    classes = np.asarray(classes).reshape(kt, nt)
    okt, ont, first, last = [], [], [], []
    for ni in range(nt):
        col_cls = classes[:, ni]
        ks = np.argsort(col_cls, kind="stable")       # slow class first
        for pos, ki in enumerate(ks):
            okt.append(ki)
            ont.append(ni)
            first.append(1 if pos == 0 else 0)
            last.append(1 if pos == kt - 1 else 0)
    return (np.asarray(okt, np.int32), np.asarray(ont, np.int32),
            np.asarray(first, np.int32), np.asarray(last, np.int32))


def natural_schedule(kt: int, nt: int):
    """Unscheduled baseline order (column-major, K ascending)."""
    return make_schedule(np.zeros(kt * nt, np.int32), kt, nt)
