"""Pallas TPU kernel: W8A8 integer matmul baseline.

``out = (x_q (M,K) int8 @ w_q (K,N) int8) * x_scale (M,1) * w_scale (1,N)``
with int32 MXU accumulation and a fused dequant epilogue on the final
K step.  This is the baseline HALO is compared against on hardware: same
memory layout discipline, no codebook, no DVFS classes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE = 128


def _int8_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref):
    k_steps = pl.num_programs(2)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(kk == k_steps - 1)
    def _():
        deq = (acc_ref[...].astype(jnp.float32)
               * xs_ref[...].astype(jnp.float32)
               * ws_ref[...].astype(jnp.float32))
        o_ref[...] = deq.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret",
                                    "out_dtype"))
def int8_matmul(x_q: jnp.ndarray, w_q: jnp.ndarray,
                x_scale: jnp.ndarray, w_scale: jnp.ndarray,
                bm: int = 128, bn: int = TILE, bk: int = TILE,
                out_dtype=jnp.float32, interpret: bool = False
                ) -> jnp.ndarray:
    """x_q (M,K) int8, w_q (K,N) int8, x_scale (M,1) f32, w_scale (1,N) f32."""
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2
    pm, pk, pn = (-m) % bm, (-k) % bk, (-n) % bn
    if pm or pk:
        x_q = jnp.pad(x_q, ((0, pm), (0, pk)))
        x_scale = jnp.pad(x_scale, ((0, pm), (0, 0)), constant_values=1.0)
    if pk or pn:
        w_q = jnp.pad(w_q, ((0, pk), (0, pn)))
        w_scale = jnp.pad(w_scale, ((0, 0), (0, pn)), constant_values=1.0)
    mp, kp, np_ = m + pm, k + pk, n + pn

    out = pl.pallas_call(
        _int8_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale)
    return out[:m, :n]
