"""Public kernel API: packing from core.HaloQuantized + jit'd dispatch.

``pack_halo`` converts a HaloQuantized tensor into the deployment layout
(packed 4-bit indices, per-tile scale matrix, class-grouped schedule, sparse
chunks); ``halo_matmul`` runs the Pallas dense kernel + SpMV kernel and adds
the two streams.  On CPU (this container) kernels run in interpret mode;
on TPU the same calls compile to Mosaic.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import tiling
from ..core.quantize import HaloQuantized
from ..utils import next_pow2
from . import halo_matmul as hk
from . import spmv as sk
from .int8_matmul import int8_matmul
from .halo_matmul import TILE


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HaloPacked:
    """Deployment layout of one quantized matrix (possibly layer-stacked).

    Arrays may carry leading stack dims (layers, experts): ``lax.scan`` over
    a stacked ``HaloPacked`` slices every array leaf per step, yielding the
    per-layer 2-D layout the Pallas kernel consumes -- no per-slice Python
    loop inside jit.  ``shape`` is always the per-slice (K, N)."""

    idx_packed: jnp.ndarray          # (..., Kp, Np//2) uint8
    scale: jnp.ndarray               # (..., kt*nt, TILE) f32 per-tile-column
    order_kt: jnp.ndarray            # schedule (class-grouped)
    order_nt: jnp.ndarray
    order_first: jnp.ndarray
    order_last: jnp.ndarray
    chunks: Optional[sk.SparseChunks]
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True),
                                               default=(0, 0))
    # autotuned Pallas block-M override (static: it steers the kernel grid,
    # never the math; None = the kernel's 128 default).  Set tree-wide via
    # ``with_block_m`` -- serving engines thread EngineKnobs.block_m here.
    block_m: Optional[int] = dataclasses.field(metadata=dict(static=True),
                                               default=None)

    @property
    def padded_shape(self) -> Tuple[int, int]:
        kp = self.idx_packed.shape[-2]
        return kp, self.idx_packed.shape[-1] * 2

    @property
    def is_stacked(self) -> bool:
        return self.idx_packed.ndim > 2

    def dequantize(self, dtype=jnp.bfloat16) -> jnp.ndarray:
        """XLA fallback: materialize the dense weight (incl. outliers).

        Serving never calls this on the hot path -- it exists for stacked
        weights consumed outside a scan (MoE einsum) and for parity tests."""
        w = _dense_decode(self.idx_packed, self.scale)
        if self.chunks is not None:
            w = w + sk.chunks_to_dense(self.chunks)
        k, n = self.shape
        return w[..., :k, :n].astype(dtype)


def pack_halo(hq: HaloQuantized, scheduled: bool = True) -> HaloPacked:
    """HaloQuantized (tile=128) -> deployment layout."""
    if hq.tile != TILE:
        raise ValueError(f"kernel requires tile=128, got {hq.tile}")
    k, n = hq.shape
    kt, nt = tiling.grid_dims(k, n, TILE)

    idx_full = tiling.from_tiles(hq.idx.astype(jnp.int32), (kt * TILE, nt * TILE),
                                 TILE).astype(jnp.uint8)
    # F1-class zero index is 8 ("0" entry); padding already encodes idx from
    # zero-padded weights which quantize to index 8 -> decode to 0.  Pack
    # pairs along N: byte j = lo(2j) | hi(2j+1) << 4.
    lo = idx_full[:, 0::2]
    hi = idx_full[:, 1::2]
    idx_packed = (lo | (hi << jnp.uint8(4))).astype(jnp.uint8)

    scale = hq.scale_per_column()                 # (kt*nt, TILE)
    classes = np.asarray(jax.device_get(hq.classes))
    if scheduled:
        okt, ont, of, ol = hk.make_schedule(classes, kt, nt)
    else:
        okt, ont, of, ol = hk.natural_schedule(kt, nt)

    sp = hq.sparse
    nnz = int(sp.row.shape[0])
    chunks = None
    if nnz:
        vals = (np.asarray(jax.device_get(sp.val), np.float32)
                * np.asarray(jax.device_get(sp.chan_scale), np.float32)[
                    np.asarray(jax.device_get(sp.col))])
        chunks = sk.bucket_sparse(np.asarray(jax.device_get(sp.row)),
                                  np.asarray(jax.device_get(sp.col)),
                                  vals, (kt * TILE, nt * TILE))
    return HaloPacked(idx_packed=idx_packed, scale=scale,
                      order_kt=jnp.asarray(okt), order_nt=jnp.asarray(ont),
                      order_first=jnp.asarray(of), order_last=jnp.asarray(ol),
                      chunks=chunks, shape=(k, n))


def stack_packed(packs: Sequence[HaloPacked],
                 lead_shape: Optional[Tuple[int, ...]] = None) -> HaloPacked:
    """Stack per-slice HaloPacked layouts into one scan-ready leaf.

    All slices must share (K, N).  Sparse chunk counts are made uniform by
    padding with inert chunks (kernels add exact zeros for them), so every
    array leaf gets a common leading stack shape and ``lax.scan`` can slice
    the packed weight per layer without Python loops in the jitted path.
    """
    packs = list(packs)
    shapes = {p.shape for p in packs}
    if len(shapes) != 1:
        raise ValueError(f"cannot stack mixed shapes: {sorted(shapes)}")
    lead = tuple(lead_shape) if lead_shape is not None else (len(packs),)
    if int(np.prod(lead)) != len(packs):
        raise ValueError(f"lead {lead} != {len(packs)} slices")
    if any(p.chunks is not None for p in packs):
        packs = [p if p.chunks is not None
                 else dataclasses.replace(
                     p, chunks=sk.empty_chunks(p.padded_shape))
                 for p in packs]
        width = max(int(p.chunks.rows.shape[0]) for p in packs)
        packs = [dataclasses.replace(p, chunks=sk.pad_chunks(p.chunks, width))
                 for p in packs]
    return jax.tree.map(
        lambda *xs: jnp.stack(xs).reshape(lead + xs[0].shape), *packs)


# back-compat alias: the shared definition lives in repro.utils
_next_pow2 = next_pow2


def _byte_pair_table() -> np.ndarray:
    """(256, 2) f32 LUT: packed byte -> (value(lo nibble), value(hi nibble)).

    Folds unpack + codebook decode into a single gather -- the cheap XLA
    rendering of what the Pallas kernel does arithmetically in VMEM."""
    from ..core import codebooks
    t16 = np.asarray(codebooks.shared_table(), np.float32)
    byte = np.arange(256, dtype=np.int32)
    return np.stack([t16[byte & 0xF], t16[byte >> 4]], axis=-1)


def _dense_decode(idx_packed: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Packed bytes (..., Kp, Np//2) + scales (..., kt*nt, TILE) -> padded
    dense f32 (..., Kp, Np).  Shared by HaloPacked.dequantize and the XLA
    matmul fallback so codebook/scale-layout changes live in one place."""
    lut = jnp.asarray(_byte_pair_table())
    val = lut[idx_packed.astype(jnp.int32)].reshape(
        idx_packed.shape[:-1] + (idx_packed.shape[-1] * 2,))
    kp, npk = val.shape[-2], val.shape[-1]
    kt, nt = kp // TILE, npk // TILE
    lead = val.shape[:-2]
    sc = scale.reshape(lead + (kt, nt, TILE))
    v = val.reshape(lead + (kt, TILE, nt, TILE)) * sc[..., :, None, :, :]
    return v.reshape(lead + (kp, npk))


def _halo_matmul_xla(x: jnp.ndarray, packed: HaloPacked,
                     out_dtype) -> jnp.ndarray:
    """CPU serving fallback: lower the packed layout through plain XLA.

    Consumes the same operands as the Pallas kernel (4-bit stream, per-tile
    scales, bucketed outlier chunks) without materializing a persistent
    bf16 weight: one byte->value-pair gather decodes the stream, and the
    outlier chunks contribute via a gather / scatter-add product over the
    <0.5% entries (never densified).  Grid-step emulation via interpret
    mode is ~100x slower on CPU and is reserved for kernel validation
    (pass interpret=True explicitly)."""
    k, n = packed.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k).astype(jnp.float32)
    with jax.named_scope("halo_packed_xla"):
        w = _dense_decode(packed.idx_packed, packed.scale)[:k, :n]
        out = jnp.matmul(x2, w)
        ch = packed.chunks
        if ch is not None:
            rows_f = (ch.chunk_kt[:, None] * TILE + ch.rows).reshape(-1)
            cols_f = (ch.chunk_nt[:, None] * TILE + ch.cols).reshape(-1)
            contrib = x2[:, rows_f] * ch.vals.reshape(-1)[None, :]
            out = out.at[:, cols_f].add(contrib)
    return out.reshape(lead + (n,)).astype(out_dtype)


def halo_matmul(x: jnp.ndarray, packed: HaloPacked,
                bm: Optional[int] = None, interpret: Optional[bool] = None,
                out_dtype=None) -> jnp.ndarray:
    """x (..., K) @ W_halo -> (..., N); dense codebook kernel + SpMV kernel.

    bm=None reads the block-M off ``packed.block_m`` (the autotuner's
    tree-wide override, see ``with_block_m``), falling back to 128; an
    explicit bm always wins.  Block size never changes the math, only the
    Pallas grid -- the XLA lowering ignores it entirely.

    interpret=None resolves per backend: Pallas/Mosaic on TPU, the XLA
    lowering of the packed layout elsewhere.  interpret=True forces the
    Pallas interpreter (validation oracle for the kernel itself).

    Under an active device mesh (dist.sharding.use_rules) the XLA
    lowering is used on every backend: a pallas_call is opaque to GSPMD
    and cannot span devices, while the XLA graph partitions along the
    sharded N/K dims like any other matmul.  Per-device Pallas tiles via
    shard_map are the TPU follow-up."""
    out_dtype = out_dtype or x.dtype
    if bm is None:
        bm = packed.block_m if packed.block_m is not None else 128
    if interpret is None:
        if default_interpret():
            return _halo_matmul_xla(x, packed, out_dtype)
        from ..dist import sharding as _sh
        if _sh.active_mesh() is not None:
            return _halo_matmul_xla(x, packed, out_dtype)
        interpret = False
    k, n = packed.shape
    kp, np_ = packed.padded_shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    if kp != k:
        x2 = jnp.pad(x2, ((0, 0), (0, kp - k)))
    # block-M sized to the actual row count (decode is M=1..batch): next
    # power of two of the rows, floored at the 8-sublane f32 tile, capped
    # at the caller's bm.  M=1 decode -> bm_eff = 8, not a full 128 block.
    bm_eff = min(bm, max(8, next_pow2(x2.shape[0])))
    out = hk.halo_matmul_packed(
        x2, packed.idx_packed, packed.scale, packed.order_kt,
        packed.order_nt, packed.order_first, packed.order_last,
        bm=bm_eff, out_dtype=jnp.float32, interpret=interpret)
    if packed.chunks is not None:
        out = out + sk.spmv_matmul(x2, packed.chunks, bm=bm_eff,
                                   out_dtype=jnp.float32,
                                   interpret=interpret)
    return out[:, :n].reshape(lead + (n,)).astype(out_dtype)


def with_block_m(params, block_m: Optional[int]):
    """Copy of a param tree with every HaloPacked leaf's static ``block_m``
    override set (None restores the kernel's 128 default).

    The override only re-tiles the Pallas grid; numerics are bit-identical
    across block sizes, so autotuned trees stay token-identical to the
    default-config oracle.  Static-field churn does force one recompile per
    distinct value -- engines apply this once at ``serve_params`` time."""
    if block_m is not None:
        block_m = int(block_m)
        if block_m < 8 or block_m % 8:
            raise ValueError(
                f"block_m must be a multiple of 8 (the f32 sublane tile), "
                f"got {block_m}")

    def is_packed(x):
        return isinstance(x, HaloPacked)

    return jax.tree.map(
        lambda leaf: (dataclasses.replace(leaf, block_m=block_m)
                      if is_packed(leaf) else leaf),
        params, is_leaf=is_packed)


def quantize_activations_int8(x: jnp.ndarray
                              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-token symmetric int8 activation quantization (for int8_matmul)."""
    absmax = jnp.abs(x).max(axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def w8a8_matmul(x: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Quantize activations per-token and run the int8 kernel."""
    interpret = default_interpret() if interpret is None else interpret
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x_q, x_scale = quantize_activations_int8(x2)
    out = int8_matmul(x_q, w_q, x_scale, w_scale.reshape(1, -1),
                      interpret=interpret)
    return out.reshape(lead + (w_q.shape[1],)).astype(x.dtype)
