"""Public kernel API: packing from core.HaloQuantized + jit'd dispatch.

``pack_halo`` converts a HaloQuantized tensor into the deployment layout
(packed 4-bit indices, per-tile scale matrix, class-grouped schedule, sparse
chunks); ``halo_matmul`` runs the Pallas dense kernel + SpMV kernel and adds
the two streams.  On CPU (this container) kernels run in interpret mode;
on TPU the same calls compile to Mosaic.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import tiling
from ..core.quantize import HaloQuantized
from . import halo_matmul as hk
from . import spmv as sk
from .int8_matmul import int8_matmul
from .halo_matmul import TILE


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HaloPacked:
    """Deployment layout of one quantized matrix."""

    idx_packed: jnp.ndarray          # (Kp, Np//2) uint8
    scale: jnp.ndarray               # (kt*nt, TILE) f32 per-tile-column
    order_kt: jnp.ndarray            # schedule (class-grouped)
    order_nt: jnp.ndarray
    order_first: jnp.ndarray
    order_last: jnp.ndarray
    chunks: Optional[sk.SparseChunks]
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True),
                                               default=(0, 0))

    @property
    def padded_shape(self) -> Tuple[int, int]:
        kp = self.idx_packed.shape[0]
        return kp, self.idx_packed.shape[1] * 2


def pack_halo(hq: HaloQuantized, scheduled: bool = True) -> HaloPacked:
    """HaloQuantized (tile=128) -> deployment layout."""
    if hq.tile != TILE:
        raise ValueError(f"kernel requires tile=128, got {hq.tile}")
    k, n = hq.shape
    kt, nt = tiling.grid_dims(k, n, TILE)

    idx_full = tiling.from_tiles(hq.idx.astype(jnp.int32), (kt * TILE, nt * TILE),
                                 TILE).astype(jnp.uint8)
    # F1-class zero index is 8 ("0" entry); padding already encodes idx from
    # zero-padded weights which quantize to index 8 -> decode to 0.  Pack
    # pairs along N: byte j = lo(2j) | hi(2j+1) << 4.
    lo = idx_full[:, 0::2]
    hi = idx_full[:, 1::2]
    idx_packed = (lo | (hi << jnp.uint8(4))).astype(jnp.uint8)

    scale = hq.scale_per_column()                 # (kt*nt, TILE)
    classes = np.asarray(jax.device_get(hq.classes))
    if scheduled:
        okt, ont, of, ol = hk.make_schedule(classes, kt, nt)
    else:
        okt, ont, of, ol = hk.natural_schedule(kt, nt)

    sp = hq.sparse
    nnz = int(sp.row.shape[0])
    chunks = None
    if nnz:
        vals = (np.asarray(jax.device_get(sp.val), np.float32)
                * np.asarray(jax.device_get(sp.chan_scale), np.float32)[
                    np.asarray(jax.device_get(sp.col))])
        chunks = sk.bucket_sparse(np.asarray(jax.device_get(sp.row)),
                                  np.asarray(jax.device_get(sp.col)),
                                  vals, (kt * TILE, nt * TILE))
    return HaloPacked(idx_packed=idx_packed, scale=scale,
                      order_kt=jnp.asarray(okt), order_nt=jnp.asarray(ont),
                      order_first=jnp.asarray(of), order_last=jnp.asarray(ol),
                      chunks=chunks, shape=(k, n))


def halo_matmul(x: jnp.ndarray, packed: HaloPacked,
                bm: int = 128, interpret: Optional[bool] = None,
                out_dtype=None) -> jnp.ndarray:
    """x (..., K) @ W_halo -> (..., N); dense codebook kernel + SpMV kernel."""
    interpret = default_interpret() if interpret is None else interpret
    out_dtype = out_dtype or x.dtype
    k, n = packed.shape
    kp, np_ = packed.padded_shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    if kp != k:
        x2 = jnp.pad(x2, ((0, 0), (0, kp - k)))
    bm_eff = min(bm, max(8, 1 << (int(np.prod(lead)) - 1).bit_length())) \
        if lead else bm
    out = hk.halo_matmul_packed(
        x2, packed.idx_packed, packed.scale, packed.order_kt,
        packed.order_nt, packed.order_first, packed.order_last,
        bm=bm_eff, out_dtype=jnp.float32, interpret=interpret)
    if packed.chunks is not None:
        out = out + sk.spmv_matmul(x2, packed.chunks, bm=bm_eff,
                                   out_dtype=jnp.float32,
                                   interpret=interpret)
    return out[:, :n].reshape(lead + (n,)).astype(out_dtype)


def quantize_activations_int8(x: jnp.ndarray
                              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-token symmetric int8 activation quantization (for int8_matmul)."""
    absmax = jnp.abs(x).max(axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def w8a8_matmul(x: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Quantize activations per-token and run the int8 kernel."""
    interpret = default_interpret() if interpret is None else interpret
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x_q, x_scale = quantize_activations_int8(x2)
    out = int8_matmul(x_q, w_q, x_scale, w_scale.reshape(1, -1),
                      interpret=interpret)
    return out.reshape(lead + (w_q.shape[1],)).astype(x.dtype)
