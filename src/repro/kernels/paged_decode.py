"""Pallas TPU paged flash-decode kernel: page-table-indirect KV reads.

Single-token attention over a block-paged KV cache: K/V live in shared
page pools ((n_pages, page_size, Hkv, D) per layer) and each slot owns a
row of the page table ((B, P) int32 physical frame ids).  The pools stay
in HBM (``memory_space=ANY``) -- per (slot, kv head) grid step the kernel
walks the slot's page table (scalar-prefetched, so frame ids are known
before the body runs) and double-buffers ONE physical frame at a time
into VMEM scratch, overlapping each frame's DMA with the previous
frame's online-softmax update.  VMEM residency is O(page_size * D) per
buffer regardless of pool size, and HBM traffic is exactly the slot's
``pages_per_slot`` frames -- never a dense (B, S, ...) gather and never
the whole pool.

``k_scale``/``v_scale`` pools ((n_pages, page_size, Hkv) f32) enable the
int8-KV configuration: quantized frames are DMA'd at 1 byte/element and
dequantized in VMEM, mirroring ``flash_decode_int8``'s contract for the
contiguous layout.

Sentinel page-table entries (>= n_pages: pages past the slot's
reservation) clamp to the LAST frame (mirroring ``gather_pages``'s clip,
the parity oracle) and are masked by the length bound; the
loop covers all ``pages_per_slot`` logical pages so the fully-masked
degenerate row (length == 0) keeps the same uniform-softmax semantics as
``attention.decode_attention``.

Same validation contract as ``flash_decode``: interpret-mode tested on
this container (tests/test_paged_cache.py verifies it against the XLA
gather lowering); compiles to Mosaic on real TPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                  page_size, pages_per_slot, n_pages, scale, window,
                  softcap, quantized):
    if quantized:
        ks_ref, vs_ref, o_ref = rest
    else:
        o_ref, = rest
    b, h = pl.program_id(0), pl.program_id(1)
    g, d = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32)                 # (G, D)
    length = len_ref[b]

    def run(*scratch):
        if quantized:
            k_buf, v_buf, ks_buf, vs_buf, sem = scratch
        else:
            k_buf, v_buf, sem = scratch

        def frame_dmas(slot, j):
            pid = jnp.minimum(pt_ref[b, j], n_pages - 1)  # sentinel clamp
            dmas = [
                pltpu.make_async_copy(k_ref.at[pid, :, h],
                                      k_buf.at[slot], sem.at[slot, 0]),
                pltpu.make_async_copy(v_ref.at[pid, :, h],
                                      v_buf.at[slot], sem.at[slot, 1]),
            ]
            if quantized:
                dmas += [
                    pltpu.make_async_copy(ks_ref.at[pid, :, h],
                                          ks_buf.at[slot],
                                          sem.at[slot, 2]),
                    pltpu.make_async_copy(vs_ref.at[pid, :, h],
                                          vs_buf.at[slot],
                                          sem.at[slot, 3]),
                ]
            return dmas

        for dma in frame_dmas(0, 0):                    # warm up buffer 0
            dma.start()

        def body(j, carry):
            m, l, acc = carry
            slot, nxt = j % 2, (j + 1) % 2

            @pl.when(j + 1 < pages_per_slot)
            def _():
                for dma in frame_dmas(nxt, j + 1):      # overlap next DMA
                    dma.start()

            for dma in frame_dmas(slot, j):
                dma.wait()
            k = k_buf[slot].astype(jnp.float32)         # (page_size, D)
            v = v_buf[slot].astype(jnp.float32)
            if quantized:                               # dequant in VMEM
                k = k * ks_buf[slot][:, None]
                v = v * vs_buf[slot][:, None]
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32
                                    ) * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            kpos = j * page_size + jax.lax.broadcasted_iota(
                jnp.int32, (1, page_size), 1)[0]
            valid = kpos < length
            if window is not None:
                valid &= kpos >= (length - window)
            s = jnp.where(valid[None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[:, None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[:, None] + jax.lax.dot_general(
                p, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            return m_new, l_new, acc_new

        m0 = jnp.full((g,), NEG_INF, jnp.float32)
        l0 = jnp.zeros((g,), jnp.float32)
        a0 = jnp.zeros((g, d), jnp.float32)
        m, l, acc = jax.lax.fori_loop(0, pages_per_slot, body,
                                      (m0, l0, a0))
        o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]
                       ).astype(o_ref.dtype)

    ps = page_size
    scratch = [pltpu.VMEM((2, ps, d), k_ref.dtype),
               pltpu.VMEM((2, ps, d), v_ref.dtype)]
    n_sems = 2
    if quantized:
        scratch += [pltpu.VMEM((2, ps), jnp.float32),
                    pltpu.VMEM((2, ps), jnp.float32)]
        n_sems = 4
    pl.run_scoped(run, *scratch, pltpu.SemaphoreType.DMA((2, n_sems)))


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "interpret"))
def paged_flash_decode(q: jnp.ndarray,            # (B, H, D)
                       k_pool: jnp.ndarray,       # (n_pages, ps, Hkv, D)
                       v_pool: jnp.ndarray,
                       page_table: jnp.ndarray,   # (B, P) int32
                       length: jnp.ndarray,       # (B,) int32
                       k_scale: Optional[jnp.ndarray] = None,
                       v_scale: Optional[jnp.ndarray] = None,
                       window: Optional[int] = None,
                       softcap: Optional[float] = None,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """Single-token attention over a paged KV cache; returns (B, H, D) f32.

    Same GQA contract as ``decode_attention``: q heads grouped over the
    pool's kv heads, the pool never repeated.  ``k_scale``/``v_scale``
    ((n_pages, ps, Hkv) f32) select the int8-KV path: frames dequantize
    in VMEM after the DMA.  ``interpret=None`` follows
    ``kernels.ops.default_interpret()`` (Mosaic on TPU, interpreter
    elsewhere)."""
    if interpret is None:
        from .ops import default_interpret
        interpret = default_interpret()
    quantized = k_scale is not None
    b, h, d = q.shape
    n_pages, ps, hkv, _ = k_pool.shape
    p = page_table.shape[1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    scale = float(1.0 / np.sqrt(d))
    kernel = functools.partial(
        _paged_kernel, page_size=ps, pages_per_slot=p, n_pages=n_pages,
        scale=scale, window=window, softcap=softcap, quantized=quantized)
    any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda bb, hh, PT, LN: (bb, hh, 0, 0)),
        any_spec,          # k pool stays in HBM; frames DMA'd on demand
        any_spec,
    ]
    args = [qg, k_pool, v_pool]
    if quantized:
        in_specs += [any_spec, any_spec]
        args += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bb, hh, PT, LN: (bb, hh, 0, 0)),
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
        interpret=interpret,
    )(page_table.astype(jnp.int32), length.astype(jnp.int32), *args)
    return out.reshape(b, h, d)


# ---------------------------------------------------------------------------
# page copy (copy-on-write fork primitive)
# ---------------------------------------------------------------------------

def _page_copy_kernel(idx_ref, pool_ref, out_ref, *, n_pages):
    """Duplicate physical frame ``idx[0]`` into frame ``idx[1]`` of a
    pool left in HBM (``memory_space=ANY``): one frame DMA'd into VMEM
    scratch and back out -- the same per-frame DMA discipline as
    ``_paged_kernel``, so VMEM residency is one frame regardless of pool
    size.  The pool aliases the output, so every other frame passes
    through untouched."""
    lyr = pl.program_id(0)
    src = jnp.minimum(idx_ref[0], n_pages - 1)
    dst = jnp.minimum(idx_ref[1], n_pages - 1)

    def run(scratch, sems):
        cp_in = pltpu.make_async_copy(pool_ref.at[lyr, src], scratch,
                                      sems.at[0])
        cp_in.start()
        cp_in.wait()
        cp_out = pltpu.make_async_copy(scratch, out_ref.at[lyr, dst],
                                       sems.at[1])
        cp_out.start()
        cp_out.wait()

    pl.run_scoped(run,
                  pltpu.VMEM(pool_ref.shape[2:], pool_ref.dtype),
                  pltpu.SemaphoreType.DMA((2,)))


@functools.partial(jax.jit, static_argnames=("stacked", "interpret"))
def page_copy(pool: jnp.ndarray, src, dst,
              stacked: bool = False,
              interpret: Optional[bool] = None) -> jnp.ndarray:
    """Copy one physical frame of a page pool: ``pool[.., dst] =
    pool[.., src]``, everything else unchanged.

    ``pool``: ``(n_pages, page_size, *rest)``, or with a leading layer
    stack when ``stacked`` (``(layers, n_pages, page_size, *rest)`` --
    the shape must disambiguate, hence the explicit flag).  Works for
    K/V pools and the int8 mode's scale pools alike (``rest`` is
    whatever the frame carries).  This is the fork-on-write primitive:
    a decode write aimed at a refcount-shared frame first duplicates the
    frame, then the single page-table entry is remapped to the copy
    (serving.batch.fork_page) -- the sharer never observes the write.

    Bitwise-identical to the XLA lowering ``pool.at[dst].set(pool[src])``
    (asserted in tests/test_paged_cache.py); ``interpret=None`` follows
    ``kernels.ops.default_interpret()``."""
    if interpret is None:
        from .ops import default_interpret
        interpret = default_interpret()
    shape = pool.shape
    lead = shape[0] if stacked else 1
    body = shape[1:] if stacked else shape
    n_pages, ps = body[0], body[1]
    rest = int(np.prod(body[2:], dtype=np.int64)) if body[2:] else 1
    flat = pool.reshape(lead, n_pages, ps, rest)
    idx = jnp.stack([jnp.asarray(src, jnp.int32),
                     jnp.asarray(dst, jnp.int32)])
    any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(lead,),
        in_specs=[any_spec],
        out_specs=any_spec,
    )
    out = pl.pallas_call(
        functools.partial(_page_copy_kernel, n_pages=n_pages),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(flat.shape, flat.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(idx, flat)
    return out.reshape(shape)
