"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core import codebooks, tiling
from .spmv import SparseChunks, TILE


def halo_matmul_ref(x: jnp.ndarray, idx: jnp.ndarray, scale: jnp.ndarray,
                    shape, tile: int) -> jnp.ndarray:
    """x (M, K) @ dequant(idx (n_tiles,t,t), scale (n_tiles,)) -> (M, N)."""
    table = jnp.asarray(codebooks.shared_table(), jnp.float32)
    tiles = table[idx] * scale[:, None, None]
    w = tiling.from_tiles(tiles, shape, tile)
    return jnp.matmul(x.astype(jnp.float32), w)


def halo_matmul_padded_ref(x: jnp.ndarray, idx_packed: jnp.ndarray,
                           scale_rows: jnp.ndarray) -> jnp.ndarray:
    """Same contract as kernels.halo_matmul.halo_matmul_packed.
    scale_rows: (kt*nt, TILE) per-tile-column scales."""
    lo = idx_packed & jnp.uint8(0xF)
    hi = idx_packed >> jnp.uint8(4)
    idx = jnp.stack([lo, hi], axis=-1).reshape(idx_packed.shape[0],
                                               idx_packed.shape[1] * 2)
    table = jnp.asarray(codebooks.shared_table(), jnp.float32)
    w = table[idx]
    kp, npk = w.shape
    kt, nt = kp // TILE, npk // TILE
    sc = scale_rows.reshape(kt, nt, TILE)
    w = (w.reshape(kt, TILE, nt, TILE)
          * sc[:, None, :, :]).reshape(kp, npk)
    return jnp.matmul(x.astype(jnp.float32), w)


def spmv_ref(x: jnp.ndarray, chunks: SparseChunks) -> jnp.ndarray:
    """Dense reconstruction of the chunked sparse weight, then matmul."""
    kpad, npad = chunks.shape
    w = jnp.zeros((kpad, npad), jnp.float32)
    rows = (chunks.chunk_kt[:, None] * TILE + chunks.rows).reshape(-1)
    cols = (chunks.chunk_nt[:, None] * TILE + chunks.cols).reshape(-1)
    vals = chunks.vals.reshape(-1)
    w = w.at[rows, cols].add(vals)
    return jnp.matmul(x.astype(jnp.float32), w)


def int8_matmul_ref(x_q: jnp.ndarray, w_q: jnp.ndarray,
                    x_scale: jnp.ndarray, w_scale: jnp.ndarray) -> jnp.ndarray:
    acc = jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    return acc.astype(jnp.float32) * x_scale * w_scale
