"""Pallas TPU kernel: hypersparse outlier/salient matmul (the SpMV engine).

The paper offloads the <0.5% outlier+salient weights to a dedicated SpMV
unit.  TPUs have no scatter/gather engine, so the TPU-native adaptation
(DESIGN.md S2) executes the hypersparse product **gather-free** on the MXU:

entries are bucketed offline by (128x128) tile and padded to 128-entry
chunks; in-kernel, each chunk builds two one-hot matrices from iota
comparisons --

  G[kk, p] = [row_p == kk]            (gather matrix,  128k x 128p)
  S[p, nn] = val_p * [col_p == nn]    (scatter matrix, 128p x 128n)

so the chunk's contribution is ``x_tile @ G @ S``: two MXU matmuls, no
dynamic indexing.  At HALO's density each tile holds ~74 entries, i.e. one
chunk, and the whole sparse path is <1% of the dense FLOPs -- matching the
paper's <1% execution-time share.

Chunks are ordered column-tile-major (scalar-prefetched), so output blocks
see consecutive visits; fp32 VMEM scratch accumulates per column tile.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE = 128
CHUNK = 128


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SparseChunks:
    """Offline-packed hypersparse weights (pytree of arrays)."""

    rows: jnp.ndarray      # (n_chunks, CHUNK) int32, tile-local row ids
    cols: jnp.ndarray      # (n_chunks, CHUNK) int32, tile-local col ids
    vals: jnp.ndarray      # (n_chunks, CHUNK) f32, val * chan_scale
    chunk_kt: jnp.ndarray  # (n_chunks,) int32 k-tile of each chunk
    chunk_nt: jnp.ndarray  # (n_chunks,) int32 n-tile
    first: jnp.ndarray     # (n_chunks,) 1 on first chunk of its n-tile
    last: jnp.ndarray      # (n_chunks,) 1 on last chunk of its n-tile
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True),
                                               default=(0, 0))


def bucket_sparse(row: np.ndarray, col: np.ndarray, val: np.ndarray,
                  shape: Tuple[int, int]) -> SparseChunks:
    """Bucket COO entries into per-tile 128-entry chunks (numpy, offline)."""
    k, n = shape
    kt, nt = -(-k // TILE), -(-n // TILE)
    row, col = np.asarray(row), np.asarray(col)
    val = np.asarray(val, np.float32)
    tile_k, tile_n = row // TILE, col // TILE
    order = np.lexsort((tile_k, tile_n))       # n-tile major
    row, col, val = row[order], col[order], val[order]
    tile_k, tile_n = tile_k[order], tile_n[order]

    rows_c, cols_c, vals_c, ckt, cnt = [], [], [], [], []
    for ni in range(nt):
        for ki in range(kt):
            m = (tile_n == ni) & (tile_k == ki)
            cnt_entries = int(m.sum())
            if cnt_entries == 0 and ki > 0:
                continue                         # coverage via ki == 0 chunk
            r = row[m] % TILE
            c = col[m] % TILE
            v = val[m]
            n_chunks = max(-(-cnt_entries // CHUNK), 1)
            pad = n_chunks * CHUNK - cnt_entries
            r = np.concatenate([r, np.zeros(pad, np.int64)])
            c = np.concatenate([c, np.zeros(pad, np.int64)])
            v = np.concatenate([v, np.zeros(pad, np.float32)])
            for j in range(n_chunks):
                sl = slice(j * CHUNK, (j + 1) * CHUNK)
                rows_c.append(r[sl])
                cols_c.append(c[sl])
                vals_c.append(v[sl])
                ckt.append(ki)
                cnt.append(ni)
    rows_c = np.asarray(rows_c, np.int32)
    cols_c = np.asarray(cols_c, np.int32)
    vals_c = np.asarray(vals_c, np.float32)
    ckt = np.asarray(ckt, np.int32)
    cnt = np.asarray(cnt, np.int32)
    first = np.zeros(len(cnt), np.int32)
    last = np.zeros(len(cnt), np.int32)
    for ni in range(nt):
        idxs = np.nonzero(cnt == ni)[0]
        first[idxs[0]] = 1
        last[idxs[-1]] = 1
    return SparseChunks(rows=jnp.asarray(rows_c), cols=jnp.asarray(cols_c),
                        vals=jnp.asarray(vals_c), chunk_kt=jnp.asarray(ckt),
                        chunk_nt=jnp.asarray(cnt), first=jnp.asarray(first),
                        last=jnp.asarray(last), shape=(kt * TILE, nt * TILE))


def empty_chunks(shape: Tuple[int, int]) -> SparseChunks:
    """Chunk set with zero entries (one zero chunk per n-tile)."""
    return bucket_sparse(np.zeros(0, np.int64), np.zeros(0, np.int64),
                         np.zeros(0, np.float32), shape)


def pad_chunks(chunks: SparseChunks, n_chunks: int) -> SparseChunks:
    """Pad to `n_chunks` with inert chunks (val 0, first/last 0), making
    chunk counts uniform across stacked layer slices for lax.scan.

    Dummy chunks target the LAST n-tile: real chunks are n-tile-major, so
    appending more visits to the final output block keeps the grid's
    output-block sequence contiguous.  On real TPU, output windows are
    flushed on block change -- revisiting an earlier block (e.g. tile 0)
    without writing would flush a stale window over its correct result.
    The dummies never reset (first=0) or write (last=0) the accumulator,
    so they contribute exact zeros.
    """
    have = int(chunks.rows.shape[0])
    if have > n_chunks:
        raise ValueError(f"cannot shrink chunks {have} -> {n_chunks}")
    if have == n_chunks:
        return chunks
    pad = n_chunks - have
    last_nt = chunks.shape[1] // TILE - 1

    def padded(x, fill=0):
        shp = (pad,) + tuple(x.shape[1:])
        return jnp.concatenate(
            [x, jnp.full(shp, fill, x.dtype)], axis=0)

    return dataclasses.replace(
        chunks, rows=padded(chunks.rows), cols=padded(chunks.cols),
        vals=padded(chunks.vals), chunk_kt=padded(chunks.chunk_kt),
        chunk_nt=padded(chunks.chunk_nt, last_nt), first=padded(chunks.first),
        last=padded(chunks.last))


def chunks_to_dense(chunks: SparseChunks) -> jnp.ndarray:
    """Scatter the chunked entries back to a dense (..., Kp, Np) f32 matrix
    (XLA fallback / parity oracle; duplicate coordinates accumulate)."""
    kpad, npad = chunks.shape

    def one(rows, cols, vals, ckt, cnt):
        k_idx = ckt[:, None] * TILE + rows
        n_idx = cnt[:, None] * TILE + cols
        return jnp.zeros((kpad, npad), jnp.float32).at[k_idx, n_idx].add(vals)

    lead = chunks.rows.shape[:-2]
    if not lead:
        return one(chunks.rows, chunks.cols, chunks.vals,
                   chunks.chunk_kt, chunks.chunk_nt)
    nl = len(lead)
    flat = [x.reshape((-1,) + x.shape[nl:])
            for x in (chunks.rows, chunks.cols, chunks.vals,
                      chunks.chunk_kt, chunks.chunk_nt)]
    out = jax.vmap(one)(*flat)
    return out.reshape(lead + (kpad, npad))


def _spmv_kernel(kt_ref, nt_ref, first_ref, last_ref,
                 x_ref, rows_ref, cols_ref, vals_ref, o_ref, acc_ref):
    j = pl.program_id(1)

    @pl.when(first_ref[j] == 1)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = rows_ref[0, :]                                  # (CHUNK,)
    cols = cols_ref[0, :]
    vals = vals_ref[0, :]
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (TILE, CHUNK), 0)
    gather = (rows[None, :] == iota_k).astype(jnp.float32)   # (K, P)
    iota_n = jax.lax.broadcasted_iota(jnp.int32, (CHUNK, TILE), 1)
    scatter = (cols[:, None] == iota_n).astype(jnp.float32) * vals[:, None]
    gx = jnp.dot(x_ref[...].astype(jnp.float32), gather,
                 preferred_element_type=jnp.float32)         # (bm, P)
    acc_ref[...] += jnp.dot(gx, scatter,
                            preferred_element_type=jnp.float32)

    @pl.when(last_ref[j] == 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret", "out_dtype"))
def spmv_matmul(x: jnp.ndarray, chunks: SparseChunks, bm: int = 128,
                out_dtype=jnp.float32, interpret: bool = False) -> jnp.ndarray:
    """x: (M, Kp) -> (M, Np): x @ W_sparse via the chunked one-hot scheme."""
    m, kp = x.shape
    kpad, npad = chunks.shape
    assert kp == kpad, (kp, kpad)
    n_chunks = int(chunks.rows.shape[0])

    pad_m = (-m) % bm
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    mp = m + pad_m

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(mp // bm, n_chunks),
        in_specs=[
            pl.BlockSpec((bm, TILE), lambda i, j, kt, nt, f, l: (i, kt[j])),
            pl.BlockSpec((1, CHUNK), lambda i, j, kt, nt, f, l: (j, 0)),
            pl.BlockSpec((1, CHUNK), lambda i, j, kt, nt, f, l: (j, 0)),
            pl.BlockSpec((1, CHUNK), lambda i, j, kt, nt, f, l: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, TILE),
                               lambda i, j, kt, nt, f, l: (i, nt[j])),
        scratch_shapes=[pltpu.VMEM((bm, TILE), jnp.float32)],
    )
    out = pl.pallas_call(
        _spmv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mp, npad), out_dtype),
        interpret=interpret,
    )(chunks.chunk_kt, chunks.chunk_nt, chunks.first, chunks.last,
      x, chunks.rows, chunks.cols, chunks.vals)
    return out[:m]
