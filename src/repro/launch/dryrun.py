import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks the device count on first
# init).  This module is the ONLY place the 512-device host platform is
# requested -- tests/benchmarks see the real single CPU device.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import functools         # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from ..analysis import roofline as RL                     # noqa: E402
from ..configs import ASSIGNED_ARCHS, get_config          # noqa: E402
from ..configs.base import ModelConfig, ShapeConfig       # noqa: E402
from ..dist import sharding as sh                         # noqa: E402
from ..models import module as M                          # noqa: E402
from ..models import transformer as T                     # noqa: E402
from ..serving.engine import serve_step                   # noqa: E402
from . import inputs as I                                 # noqa: E402
from .mesh import make_production_mesh                    # noqa: E402
from .train import TrainConfig, abstract_train_state, make_train_step  # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct stand-ins (no allocation), prove the sharding config is
coherent, and record memory/cost/collective statistics for the roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
      --shape train_4k --mesh single
"""

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _active_params(cfg: ModelConfig, specs) -> float:
    """Active (routed) parameter count for MODEL_FLOPS on MoE archs."""
    total = M.param_count(specs)
    if cfg.moe is None:
        return float(total)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: hasattr(x, "logical_axes"))[0]
    expert_params = sum(
        int(np.prod(s.shape)) for p, s in flat
        if "experts" in (s.logical_axes or ()))
    active = (total - expert_params
              + expert_params * cfg.moe.top_k / cfg.moe.n_experts)
    return float(active)


def _train_cfg_for(cfg: ModelConfig, specs) -> TrainConfig:
    from ..optim.adamw import AdamWConfig
    n = M.param_count(specs)
    big = n > 50e9
    return TrainConfig(
        grad_accum=cfg.grad_accum,
        accum_dtype=jnp.bfloat16 if big else jnp.float32,
        # 200B+ on a single 256-chip pod only fits with a factored second
        # moment (EXPERIMENTS.md SDry-run): adamw bf16 moments need 10.6
        # GiB/chip of state for nemotron-340b; adafactor needs ~6.2 GiB.
        optimizer="adafactor" if n > 200e9 else "adamw",
        adamw=AdamWConfig(
            moment_dtype=jnp.bfloat16 if big else jnp.float32))


def _sharded_bytes(tree) -> float:
    """Per-device bytes of a ShapeDtypeStruct tree (honoring shardings)."""
    total = 0.0
    for leaf in jax.tree.leaves(tree):
        shp = tuple(leaf.shape)
        if getattr(leaf, "sharding", None) is not None:
            shp = leaf.sharding.shard_shape(shp)
        total += float(np.prod(shp)) * jnp.dtype(leaf.dtype).itemsize
    return total


def _shardings_of(sds_tree):
    """Sharding pytree from a ShapeDtypeStruct tree (for out_shardings --
    without pinning outputs, GSPMD may replicate scan-carried caches)."""
    return jax.tree.map(lambda s: s.sharding, sds_tree)


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, rules,
               serve_quantized: bool = False):
    """Returns (lowered, step_kind, tokens_for_model_flops, donated_bytes)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    repl = NamedSharding(mesh, P())
    if shape.kind == "train":
        specs = T.model_specs(cfg)
        tcfg = _train_cfg_for(cfg, specs)
        state = abstract_train_state(cfg, tcfg, mesh, rules)
        batch = I.batch_specs(cfg, shape, mesh, rules)
        step = make_train_step(cfg, tcfg)
        metrics_sh = {"grad_norm": repl, "step": repl, "loss": repl,
                      "lr": repl}
        lowered = jax.jit(
            step, donate_argnums=(0,),
            out_shardings=(_shardings_of(state), metrics_sh),
        ).lower(state, batch)
        tokens = shape.global_batch * shape.seq_len
        return lowered, "train", tokens, _sharded_bytes(state)
    if shape.kind == "prefill":
        specs = T.model_specs(cfg)
        p_sds = sh.abstract_with_sharding(specs, mesh, rules)
        batch = I.batch_specs(cfg, shape, mesh, rules, with_labels=False)
        _, cache_sds, lengths_sds = I.decode_input_specs(
            cfg, shape, mesh, rules)
        logits_sh = sh.logical_to_sharding(
            ("batch", "act_vocab"),
            (shape.global_batch, cfg.padded_vocab), mesh, rules)
        fn = functools.partial(T.prefill, cfg=cfg, max_seq=shape.seq_len)
        lowered = jax.jit(
            lambda p, b: fn(p, batch=b),
            out_shardings=(logits_sh, _shardings_of(cache_sds),
                           lengths_sds.sharding),
        ).lower(p_sds, batch)
        tokens = shape.global_batch * shape.seq_len
        return lowered, "prefill", tokens, 0.0
    if shape.kind == "decode":
        specs = T.model_specs(cfg)
        if serve_quantized:
            from ..core.deploy import deploy_model_specs
            specs = deploy_model_specs(specs)
        p_sds = sh.abstract_with_sharding(specs, mesh, rules)
        inputs, cache, lengths = I.decode_input_specs(cfg, shape, mesh, rules)
        logits_sh = sh.logical_to_sharding(
            ("batch", "act_vocab"),
            (shape.global_batch, cfg.padded_vocab), mesh, rules)
        fn = functools.partial(serve_step, cfg=cfg)
        lowered = jax.jit(
            lambda p, i, c, l: fn(p, inputs=i, cache=c, lengths=l),
            donate_argnums=(2,),
            out_shardings=(logits_sh, _shardings_of(cache),
                           lengths.sharding),
        ).lower(p_sds, inputs, cache, lengths)
        tokens = shape.global_batch          # one new token per sequence
        return lowered, "decode", tokens, _sharded_bytes(cache)
    raise ValueError(shape.kind)


def analytic_peak(cfg: ModelConfig, shape: ShapeConfig, kind: str,
                  mesh, rules, state_bytes: float, cache_bytes: float,
                  accum_itemsize: int) -> float:
    """Structural per-device TPU residency estimate (documents the gap to
    XLA:CPU's no-aliasing `temp`): persistent state + gradient accumulator
    + saved layer-boundary activations + transient working set."""
    chips = int(np.prod(list(mesh.shape.values())))
    dp = chips // mesh.shape.get("model", 1)
    d = cfg.d_model
    if kind == "train":
        micro_tokens = shape.global_batch * shape.seq_len \
            / max(cfg.grad_accum, 1)
        act = cfg.n_layers * (micro_tokens / dp) * d * 2      # bf16 carries
        specs = T.model_specs(cfg)
        grads = M.param_count(specs) * accum_itemsize / chips
        logits = (micro_tokens / dp) * cfg.padded_vocab * 4 \
            / mesh.shape.get("model", 1)
        return state_bytes + grads + act * 1.5 + logits + 1e9
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        act = (tokens / dp) * d * 2 * 6       # ~6 live residual-width bufs
        params = M.param_bytes(T.model_specs(cfg)) / chips
        return params + cache_bytes + act
    # decode
    params = M.param_bytes(T.model_specs(cfg)) / chips
    return params + cache_bytes + 1e9


def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: str = OUT_DIR, verbose: bool = True,
             rules_override=None, cfg_transform=None,
             serve_quantized: bool = False,
             tag: str = "") -> Optional[dict]:
    cfg = get_config(arch)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    if not cfg.supports_shape(shape_name):
        if verbose:
            print(f"[skip] {arch} x {shape_name}: not runnable "
                  f"(see DESIGN.md SArch-applicability)")
        return None
    shape = cfg.shape(shape_name)
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = int(np.prod(list(mesh.shape.values())))
    rules = rules_override or I.arch_rules(cfg, kind=shape.kind)

    t0 = time.time()
    with sh.use_rules(mesh, rules):
        lowered, kind, tokens, donated = lower_cell(
            cfg, shape, mesh, rules, serve_quantized=serve_quantized)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    try:
        cost = compiled.cost_analysis()
    except Exception:
        cost = None
    hlo_text = compiled.as_text()

    specs = T.model_specs(cfg)
    n_active = _active_params(cfg, specs)
    mflops = RL.model_flops(M.param_count(specs), n_active, tokens, kind)
    tcfg = _train_cfg_for(cfg, specs)
    accum_isz = jnp.dtype(tcfg.accum_dtype).itemsize
    cache_bytes = donated if kind == "decode" else 0.0
    if kind == "prefill":
        _, cache_sds, _ = I.decode_input_specs(cfg, shape, mesh, rules)
        cache_bytes = _sharded_bytes(cache_sds)
    peak = analytic_peak(cfg, shape, kind, mesh, rules,
                         state_bytes=donated if kind == "train" else 0.0,
                         cache_bytes=cache_bytes, accum_itemsize=accum_isz)
    report = RL.build_report(
        arch=arch + (f"@{tag}" if tag else ""), shape=shape_name,
        mesh_name=mesh_name, chips=chips,
        step_kind=kind, hlo_text=hlo_text, memory_stats=mem,
        cost_analysis=cost, model_flops_global=mflops,
        donated_bytes=donated, analytic_peak_bytes=peak, notes=tag)
    path = RL.save_report(report, out_dir)

    if verbose:
        gb = 1 / (1 << 30)
        print(f"[ok] {arch} x {shape_name} x {mesh_name}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s | "
              f"args {report.argument_bytes*gb:.2f}GiB "
              f"temp {report.temp_bytes*gb:.2f}GiB "
              f"fits={report.fits_hbm} | dominant={report.dominant} "
              f"roofline={report.roofline_fraction*100:.1f}% -> {path}")
    return report.as_dict()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([args.shape] if args.shape
                  else [s.name for s in cfg.shapes])
        for shape in shapes:
            for mesh_name in meshes:
                try:
                    run_cell(arch, shape, mesh_name, args.out)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mesh_name, repr(e)))
                    print(f"[FAIL] {arch} x {shape} x {mesh_name}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
