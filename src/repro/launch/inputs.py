"""Abstract input specs (ShapeDtypeStruct + sharding) for every
(arch x shape x step-kind) cell -- the dry-run's allocation-free stand-ins.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..configs.base import ModelConfig, ShapeConfig
from ..dist import sharding as sh
from ..models import transformer as T


def _sds(shape, dtype, axes, mesh: Optional[Mesh], rules) -> jax.ShapeDtypeStruct:
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    s = sh.logical_to_sharding(axes, shape, mesh, rules)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=s)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                mesh: Optional[Mesh] = None, rules=None,
                with_labels: bool = True,
                microbatch: Optional[int] = None) -> Dict[str, Any]:
    """Train/prefill batch stand-ins.  `microbatch` overrides global batch
    (the train step reshapes (accum, micro, ...) internally -- specs here are
    the *global* batch; grad-accum split happens inside train_step)."""
    b = microbatch or shape.global_batch
    s = shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.embeds_input:
        out["embeds"] = _sds((b, s, cfg.d_model), cfg.dtype,
                             ("batch", "act_seq", "act_embed"), mesh, rules)
    else:
        out["tokens"] = _sds((b, s), jnp.int32, ("batch", "act_seq"),
                             mesh, rules)
    out["positions"] = _sds((b, s), jnp.int32, ("batch", "act_seq"),
                            mesh, rules)
    if with_labels:
        out["labels"] = _sds((b, s), jnp.int32, ("batch", "act_seq"),
                             mesh, rules)
    return out


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig,
                       mesh: Optional[Mesh] = None, rules=None
                       ) -> Tuple[Dict[str, Any], Any, Any]:
    """(inputs, cache, lengths) stand-ins for serve_step."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.embeds_input:
        inputs = {"embeds": _sds((b, cfg.d_model), cfg.dtype,
                                 ("batch", "act_embed"), mesh, rules)}
    else:
        inputs = {"tokens": _sds((b,), jnp.int32, ("batch",), mesh, rules)}
    cache_sds = T.cache_specs(cfg, b, s)
    cache_axes = T.cache_logical_axes(cfg)
    if mesh is not None:
        cache_sds = jax.tree.map(
            lambda sds, axes: _sds(sds.shape, sds.dtype, axes, mesh, rules),
            cache_sds, cache_axes,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    lengths = _sds((b,), jnp.int32, ("batch",), mesh, rules)
    return inputs, cache_sds, lengths


def arch_rules(cfg: ModelConfig, kind: Optional[str] = None):
    """Per-arch logical-rule overrides (small-head archs keep attention
    replicated over TP; KV caches shard by sequence instead).

    Note: naive GSPMD sequence parallelism (act_seq -> model) was measured
    *worse* for prefill here -- the blockwise attention's block gathers
    force full re-replication collectives (see EXPERIMENTS.md SPerf).
    Prefill memory is bounded by batch-microbatching instead
    (cfg.prefill_microbatch).
    """
    over = {}
    if not cfg.shard_heads:
        over.update({"heads": None, "act_heads": None})
    if kind == "train" and cfg.train_layout == "zero":
        over.update({"batch": ("data", "model"), "act_heads": None,
                     "act_mlp": None, "act_vocab": None})
    if kind == "decode":
        # weight-resident serving: params live TP-sharded (no FSDP axis), so
        # decode never all-gathers weights; the data axis forms independent
        # serving replicas.  Feasible for 100B+ archs only with 4-bit HALO
        # weights -- bf16 would need 15+ GiB/chip for params alone (SPerf).
        over.update({"embed": None})
    return sh.make_rules(**over)
