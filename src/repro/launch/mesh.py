"""Production meshes.  Functions, not module constants -- importing this
module never touches jax device state (required so smoke tests see 1 CPU
device while the dry-run sees 512 host devices)."""

from __future__ import annotations

from typing import Optional

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: `axis_types` only where it exists
    (jax < 0.5 has neither AxisType nor the kwarg; Auto is the default
    behavior there anyway)."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ("data", "model") = 256 chips.
    Multi-pod:  (2, 16, 16) ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_elastic_mesh(n_devices: Optional[int] = None, model_parallel: int = 16):
    """Largest viable (data, model) mesh for the available device count --
    the elastic-scaling path after losing hosts (dist.fault).

    Fewer devices than ``model_parallel`` fall back to a pure-TP
    ``(1, avail)`` mesh (the tiny-mesh / test regime).  A device count of
    zero, a non-positive ``model_parallel``, or a ``model_parallel`` that
    can never tile a power-of-two device count all raise instead of
    silently building a mesh of a different shape than asked for."""
    from ..dist.fault import viable_device_counts

    avail = n_devices if n_devices is not None else len(jax.devices())
    if avail < 1:
        raise ValueError(
            f"make_elastic_mesh needs at least one device, got {avail} "
            f"(after host loss, re-enumerate with jax.devices() before "
            f"rebuilding the mesh)")
    if model_parallel < 1:
        raise ValueError(
            f"model_parallel must be >= 1, got {model_parallel}")
    usable = viable_device_counts(avail, model_parallel)
    if not usable:
        if avail >= model_parallel:
            # enough devices, yet no viable count: model_parallel cannot
            # tile any power-of-two device count <= avail.  A silent
            # (1, avail) here would ignore the requested TP degree.
            raise ValueError(
                f"model_parallel={model_parallel} cannot tile any viable "
                f"device count <= {avail}; pick a power-of-two "
                f"model_parallel that divides a power of two <= {avail}")
        # tiny meshes (tests): fall back to (1, avail)
        return make_mesh_compat((1, avail), ("data", "model"))
    n = usable[0]
    return make_mesh_compat((n // model_parallel, model_parallel),
                            ("data", "model"))
