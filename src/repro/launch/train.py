"""Training driver: grad-accumulated, sharded train_step + fault-tolerant
outer loop (checkpoint/restart, straggler watchdog, elastic resume).

``make_train_step(cfg, tcfg)`` builds the jit target the dry-run lowers for
train shapes: microbatch scan (gradient accumulation), AdamW (bf16 moments
for the 100B+ archs), warmup-cosine LR, global-norm clip.  XLA overlaps each
microbatch's gradient all-reduce with the next microbatch's compute (async
collectives); the scan keeps HLO size O(1) in accumulation steps.

CLI:  python -m repro.launch.train --arch granite-8b --steps 200 ...
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..configs.base import ModelConfig
from ..data.synthetic import CorpusConfig, SyntheticCorpus
from ..dist import sharding as sh
from ..dist.fault import FailureInjector, StragglerWatchdog
from ..checkpoint.manager import CheckpointManager
from ..models import module as M
from ..models import transformer as T
from ..optim import adafactor, adamw
from ..optim.schedule import warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    optimizer: str = "adamw"           # adamw | adafactor (factored 2nd mom)
    adamw: adamw.AdamWConfig = adamw.AdamWConfig()
    adafactor: adafactor.AdafactorConfig = adafactor.AdafactorConfig()
    grad_accum: int = 1
    accum_dtype: Any = jnp.float32     # bf16 for the >=100B archs
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics).  The global
    batch is split into `grad_accum` microbatches scanned sequentially."""

    accum = max(tcfg.grad_accum, 1)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        params, opt = state

        def loss_of(p, mb):
            return T.loss_fn(p, cfg, mb)

        if accum == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)

            def mb_step(acc, mb):
                loss_acc, g_acc = acc
                loss_i, g_i = jax.value_and_grad(loss_of)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(tcfg.accum_dtype), g_acc, g_i)
                return (loss_acc + loss_i, g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, tcfg.accum_dtype), params)
            (loss, grads), _ = jax.lax.scan(mb_step,
                                            (jnp.zeros(()), g0), micro)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)

        # 1-indexed schedule step: warmup starting at 0 would make the very
        # first update a no-op (lr = 0)
        lr = warmup_cosine(opt.step + 1, tcfg.peak_lr, tcfg.warmup_steps,
                           tcfg.total_steps)
        if tcfg.optimizer == "adafactor":
            new_params, new_opt, metrics = adafactor.update(
                grads, opt, params, lr, tcfg.adafactor)
        else:
            new_params, new_opt, metrics = adamw.update(
                grads, opt, params, lr, tcfg.adamw)
        metrics = {**metrics, "loss": loss, "lr": lr}
        return TrainState(new_params, new_opt), metrics

    return train_step


def abstract_train_state(cfg: ModelConfig, tcfg: TrainConfig, mesh, rules):
    """ShapeDtypeStruct TrainState with shardings (dry-run input)."""
    specs = T.model_specs(cfg)
    p_sds = sh.abstract_with_sharding(specs, mesh, rules)
    if tcfg.optimizer == "adafactor":
        opt_specs = adafactor.state_specs(specs, tcfg.adafactor)
    else:
        opt_specs = adamw.state_specs(specs, tcfg.adamw)
    o_sds = sh.abstract_with_sharding(opt_specs, mesh, rules)
    return TrainState(params=p_sds, opt=o_sds)


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key,
                     mesh=None, rules=None) -> TrainState:
    specs = T.model_specs(cfg)
    params = M.init_params(specs, key)
    opt = (adafactor.init(params, tcfg.adafactor)
           if tcfg.optimizer == "adafactor"
           else adamw.init(params, tcfg.adamw))
    if mesh is not None:
        shard = sh.params_shardings(specs, mesh, rules)
        params = jax.tree.map(jax.device_put, params, shard)
    return TrainState(params=params, opt=opt)


# ---------------------------------------------------------------------------
# fault-tolerant outer loop
# ---------------------------------------------------------------------------

def train_loop(cfg: ModelConfig, tcfg: TrainConfig,
               corpus: SyntheticCorpus,
               mesh=None, rules=None,
               injector: Optional[FailureInjector] = None,
               log_every: int = 10,
               eval_every: int = 0,
               seed: int = 0) -> Dict[str, Any]:
    """Run to tcfg.total_steps with checkpoint/restart recovery.

    Any exception inside a step triggers restore-from-latest-checkpoint and
    continues -- the contract a preemptible fleet needs.  Returns history.
    """
    mgr = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
    watchdog = StragglerWatchdog()
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))

    ctx = sh.use_rules(mesh, rules) if mesh is not None else _nullctx()
    history = {"loss": [], "restarts": 0, "straggler_flags": []}
    with ctx:
        state = init_train_state(cfg, tcfg, jax.random.PRNGKey(seed),
                                 mesh, rules)
        start = mgr.latest_step()
        if start is not None:
            state = mgr.restore(state)
            step = int(mgr.meta()["step"])
        else:
            step = 0

        while step < tcfg.total_steps:
            try:
                if injector is not None:
                    injector.check(step)
                batch = jax.tree.map(jnp.asarray, corpus.batch_at(step))
                watchdog.step_start()
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                if watchdog.step_end(step):
                    history["straggler_flags"].append(step)
                history["loss"].append((step, loss))
                if log_every and step % log_every == 0:
                    print(f"step {step:5d} loss {loss:.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f}")
                step += 1
                if step % tcfg.ckpt_every == 0 or step == tcfg.total_steps:
                    mgr.save_async(step, state, {"arch": cfg.name})
            except Exception as e:  # noqa: BLE001 -- fleet contract
                print(f"[fault] step {step}: {type(e).__name__}: {e}; "
                      f"restoring latest checkpoint")
                mgr.wait()
                latest = mgr.latest_step()
                if latest is None:
                    state = init_train_state(cfg, tcfg,
                                             jax.random.PRNGKey(seed),
                                             mesh, rules)
                    step = 0
                else:
                    state = mgr.restore(state)
                    step = int(mgr.meta()["step"])
                history["restarts"] += 1
        mgr.wait()
    return history


class _nullctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(peak_lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 10, 1),
                       ckpt_dir=args.ckpt_dir,
                       grad_accum=1)
    corpus = SyntheticCorpus(CorpusConfig(vocab=cfg.vocab, seq_len=args.seq,
                                          batch=args.batch))
    hist = train_loop(cfg, tcfg, corpus)
    print(f"final loss: {hist['loss'][-1][1]:.4f}  "
          f"restarts: {hist['restarts']}")


if __name__ == "__main__":
    main()
