"""Model definitions: unified decoder, SSM/RG-LRU blocks, MoE, frontends."""

from . import attention, layers, module, moe, rglru, scan_ops, ssm, transformer  # noqa: F401
