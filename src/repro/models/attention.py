"""Attention: triangular blockwise (flash-style) training/prefill attention,
single-step decode attention, and the sequence-sharded decode combine.

The blockwise path never materializes the (S, S) score matrix: it scans over
the *lower-triangular list of (q-block, kv-block) pairs* carrying online
softmax statistics, so memory is O(S * chunk) and FLOPs are exactly the
causal (optionally windowed) blocks -- no masked-out waste.  This is the
TPU-idiomatic pure-JAX flash scheme; a Pallas kernel can swap in underneath
without changing callers.

GQA/MQA: q heads are grouped over kv heads.  Soft-capping (gemma-2) applies
to attention logits when configured.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import softcap

NEG_INF = -1e30


def _block_pairs(n_blocks: int, window_blocks: Optional[int]) -> np.ndarray:
    """Static (P, 2) int32 list of causal (i, j) block pairs, row-major."""
    pairs = []
    for i in range(n_blocks):
        j0 = 0 if window_blocks is None else max(0, i - window_blocks)
        for j in range(j0, i + 1):
            pairs.append((i, j))
    return np.asarray(pairs, np.int32)


@functools.partial(
    jax.jit,
    static_argnames=("chunk", "window", "attn_softcap", "scale_override"))
def causal_blockwise_attention(
    q: jnp.ndarray,             # (B, S, H, D)
    k: jnp.ndarray,             # (B, S, Hkv, D)
    v: jnp.ndarray,             # (B, S, Hkv, D)
    chunk: int = 1024,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    scale_override: Optional[float] = None,
) -> jnp.ndarray:
    """Causal (optionally sliding-window) attention, O(S*chunk) memory."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    # GQA: repeat kv to the full head count.  A (h) -> (hkv, g) reshape
    # would break 16-way TP head sharding (GSPMD cannot split one mesh axis
    # across two dims) and trigger full-replication resharding; repeat-kv
    # keeps every tensor's head axis shardable -- the Megatron-style choice
    # when TP degree > kv heads.  kv duplication is transient/compute-only.
    if hkv != h:
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    t = sp // chunk
    scale = scale_override if scale_override is not None else 1.0 / np.sqrt(d)

    # blocks-first layout: (T, B, H, chunk, D)
    qb = q.reshape(b, t, chunk, h, d).transpose(1, 0, 3, 2, 4)
    kb = k.reshape(b, t, chunk, h, d).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, t, chunk, h, d).transpose(1, 0, 3, 2, 4)

    window_blocks = None if window is None else -(-window // chunk)
    pairs = jnp.asarray(_block_pairs(t, window_blocks))

    m0 = jnp.full((t, b, h, chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((t, b, h, chunk), jnp.float32)
    a0 = jnp.zeros((t, b, h, chunk, d), jnp.float32)
    pos = jnp.arange(chunk)

    def step(carry, pair):
        m, l, acc = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qb, i, 0, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
        # bf16 MXU inputs, f32 accumulation (native TPU dot path) -- keeps
        # the block tensors half-width in HBM vs. upcasting q/k/v
        sij = jnp.einsum("bhqd,bhsd->bhqs", qi, kj,
                         preferred_element_type=jnp.float32) * scale
        if attn_softcap is not None:
            sij = softcap(sij, attn_softcap)
        qpos = i * chunk + pos[:, None]
        kpos = j * chunk + pos[None, :]
        mask = qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        mask &= kpos < s          # padded keys
        sij = jnp.where(mask, sij, NEG_INF)

        mi = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
        m_new = jnp.maximum(mi, sij.max(axis=-1))
        p = jnp.exp(sij - m_new[..., None])
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + p.sum(axis=-1)
        a_new = ai * corr[..., None] + jnp.einsum(
            "bhqs,bhsd->bhqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 0)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), pairs)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, sp, h, d)
    return out[:, :s].astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,             # (B, H, D) one new token per sequence
    k_cache: jnp.ndarray,       # (B, S, Hkv, D)
    v_cache: jnp.ndarray,       # (B, S, Hkv, D)
    length: jnp.ndarray,        # (B,) valid cache lengths
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
) -> jnp.ndarray:
    """Single-token attention over a (possibly partially filled) KV cache.

    GQA grouping is expressed as a q-side reduction instead of a kv repeat:
    the cache stays at its true kv-head count (kv_seq-sharded), scores are
    computed per kv head by summing nothing -- we fold the g query heads per
    kv head via einsum with an explicit group axis ON THE Q SIDE ONLY, so no
    (h)->(hkv,g) reshape ever touches a sharded activation axis (q heads are
    replicated in decode for the small-head archs and TP-sharded caches
    shard over kv_seq, not heads)."""
    b, h, d = q.shape
    hkv = k_cache.shape[2]
    g = h // hkv
    scale = 1.0 / np.sqrt(d)
    # keep the cache in its storage dtype: upcasting it would let XLA hoist
    # a whole-cache fp32 convert out of the layer scan (2x cache memory);
    # the MXU accumulates in fp32 via preferred_element_type regardless.
    qg = q.reshape(b, hkv, g, d).astype(k_cache.dtype)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if attn_softcap is not None:
        s = softcap(s, attn_softcap)
    kpos = jnp.arange(k_cache.shape[1])[None, :]
    mask = kpos < length[:, None]
    if window is not None:
        mask &= kpos >= (length[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, d).astype(q.dtype)


def append_attention(
    q: jnp.ndarray,             # (B, W, H, D) window of new tokens
    k_cache: jnp.ndarray,       # (B, S, Hkv, D) cache AFTER the window write
    v_cache: jnp.ndarray,       # (B, S, Hkv, D)
    q_positions: jnp.ndarray,   # (B, W) absolute position of each query
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
) -> jnp.ndarray:
    """Chunked-prefill attention: a W-token window attends a linear KV
    cache at a per-row position offset (the causal mask is offset by
    ``q_positions`` instead of assuming queries start at 0).

    The cache must already contain the window's own K/V (the caller writes
    the window at ``q_positions`` first, exactly like ``decode_attention``
    consumes the post-write cache), and cache index i must hold absolute
    position i -- ring buffers take the sequential path in
    ``transformer.block_append``.  Query w of row b attends cache entries
    ``kpos <= q_positions[b, w]`` (optionally windowed), so stale entries
    beyond a row's live length are masked for every valid query.  Rows or
    window slots past a row's chunk length produce junk outputs the caller
    discards; the mask is never empty for a valid query (it covers its own
    just-written key), and fully-masked junk rows stay finite (uniform
    softmax over NEG_INF ties), never NaN.

    Same GQA contract as ``decode_attention``: q-side grouping only, the
    cache keeps its true kv-head count."""
    b, w, h, d = q.shape
    hkv = k_cache.shape[2]
    g = h // hkv
    scale = 1.0 / np.sqrt(d)
    qg = q.reshape(b, w, hkv, g, d).astype(k_cache.dtype)
    s = jnp.einsum("bwkgd,bskd->bwkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if attn_softcap is not None:
        s = softcap(s, attn_softcap)
    kpos = jnp.arange(k_cache.shape[1])[None, None, :]
    mask = kpos <= q_positions[:, :, None]
    if window is not None:
        mask &= kpos > (q_positions[:, :, None] - window)
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bwkgs,bskd->bwkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, w, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged KV cache (block-paged pools + per-slot page tables)
# ---------------------------------------------------------------------------

def gather_pages(pool: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """Materialize per-slot KV rows from a shared page pool.

    ``pool``: (n_pages, page_size, ...) -- one physical frame per row;
    ``page_table``: (B, P) int32 -- physical frame per (slot, logical
    page); sentinel entries (>= n_pages, the unassigned marker) clip to
    the last frame, whose junk contents sit past the slot's length and
    are masked by every caller.  Returns (B, P * page_size, ...), the
    exact dense layout the contiguous cache stores -- so feeding the
    gather into ``decode_attention``/``append_attention`` is bit-identical
    to the contiguous path.  This is the XLA lowering the CPU fallback
    uses; the Pallas kernel (kernels/paged_decode.py) reads the pool
    page-table-indirect without materializing it."""
    b, p = page_table.shape
    ps = pool.shape[1]
    g = jnp.take(pool, jnp.clip(page_table, 0, pool.shape[0] - 1), axis=0)
    return g.reshape((b, p * ps) + pool.shape[2:])


def paged_decode_attention(
    q: jnp.ndarray,             # (B, H, D) one new token per sequence
    k_pool: jnp.ndarray,        # (n_pages, page_size, Hkv, D)
    v_pool: jnp.ndarray,
    page_table: jnp.ndarray,    # (B, P) int32 physical frame ids
    length: jnp.ndarray,        # (B,) valid cache lengths
    k_scale: Optional[jnp.ndarray] = None,   # (n_pages, ps, Hkv) f32
    v_scale: Optional[jnp.ndarray] = None,   # (int8 pools only)
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    use_kernel: Optional[bool] = None,
) -> jnp.ndarray:
    """``decode_attention`` over a paged cache.

    ``use_kernel=None`` routes to the Pallas paged flash-decode kernel on
    TPU (pools stay in HBM, frames DMA'd page-table-indirect; int8 pools
    dequantize in VMEM) and to the XLA gather lowering elsewhere; the
    gather lowering is bit-identical to the contiguous
    ``decode_attention`` (same dense shape, same masking, same reduction
    order), which is what makes contiguous mode the paged path's parity
    oracle."""
    if use_kernel is None:
        from ..kernels.ops import default_interpret
        use_kernel = not default_interpret()
    if use_kernel:
        from ..kernels.paged_decode import paged_flash_decode
        out = paged_flash_decode(q, k_pool, v_pool, page_table, length,
                                 k_scale=k_scale, v_scale=v_scale,
                                 window=window, softcap=attn_softcap)
        return out.astype(q.dtype)
    kd = gather_pages(k_pool, page_table)
    vd = gather_pages(v_pool, page_table)
    if k_scale is not None:
        # XLA fallback of the int8 path: dequantize the gathered frames
        # (elementwise, so gather-then-dequant == dequant-then-gather --
        # the contiguous parity contract holds bit for bit)
        with jax.named_scope("kvdec_vmem"):
            kd = (kd.astype(jnp.float32)
                  * gather_pages(k_scale, page_table)[..., None]
                  ).astype(q.dtype)
            vd = (vd.astype(jnp.float32)
                  * gather_pages(v_scale, page_table)[..., None]
                  ).astype(q.dtype)
    return decode_attention(q, kd, vd, length, window=window,
                            attn_softcap=attn_softcap)


def decode_attention_partial(
    q: jnp.ndarray, k_local: jnp.ndarray, v_local: jnp.ndarray,
    valid_mask: jnp.ndarray,
    attn_softcap: Optional[float] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Local flash-decode statistics over a KV-cache *shard*.

    Returns (m, l, pv): row max, exp-sum and weighted V of the local chunk --
    combined across shards by `combine_decode_partials` (inside shard_map
    over the KV-sequence axis).
    """
    b, h, d = q.shape
    hkv = k_local.shape[2]
    g = h // hkv
    scale = 1.0 / np.sqrt(d)
    qg = q.reshape(b, hkv, g, d).astype(k_local.dtype)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_local,
                   preferred_element_type=jnp.float32) * scale
    if attn_softcap is not None:
        s = softcap(s, attn_softcap)
    s = jnp.where(valid_mask[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    pv = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_local.dtype), v_local,
                    preferred_element_type=jnp.float32)
    return m, l, pv


def combine_decode_partials(m, l, pv, axis_name: str) -> jnp.ndarray:
    """LSE-combine flash-decode partials across `axis_name` shards."""
    m_g = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * corr, axis_name)
    pv_g = jax.lax.psum(pv * corr[..., None], axis_name)
    out = pv_g / jnp.maximum(l_g[..., None], 1e-30)
    b, hkv, g, d = out.shape
    return out.reshape(b, hkv * g, d)
