"""Flash attention with a hand-written VJP (pure JAX, TPU-fusion friendly).

The autodiff of the blockwise forward stores every block's probability
matrix (and mask) for the backward -- O(S * S) f32 traffic per layer that
dominated the training memory roofline (EXPERIMENTS.md SPerf).  This module
saves only (q, k, v, out, lse) and *recomputes* p per block in the backward,
exactly like FlashAttention's dq/dk/dv recursion:

  D_i   = rowsum(dout_i * out_i)
  p_ij  = exp(q_i k_j^T * scale - lse_i)
  dv_j += p_ij^T dout_i
  dp    = dout_i v_j^T
  ds    = p_ij * (dp - D_i) * scale        (softcap chain rule included)
  dq_i += ds k_j ;  dk_j += ds^T q_i

Inputs stay in their storage dtype (bf16) with fp32 MXU accumulation.
GQA is handled by the caller (repeat-kv), windows/softcap are static.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _block_pairs(n_blocks: int, window_blocks: Optional[int]) -> np.ndarray:
    pairs = []
    for i in range(n_blocks):
        j0 = 0 if window_blocks is None else max(0, i - window_blocks)
        for j in range(j0, i + 1):
            pairs.append((i, j))
    return np.asarray(pairs, np.int32)


@functools.lru_cache(maxsize=64)
def make_flash_attention(chunk: int, window: Optional[int],
                         attn_softcap: Optional[float],
                         scale: float):
    """Returns flash(q, k, v) for (B, S, H, D) bf16/f32 inputs, S % chunk == 0
    handled by caller padding.  k/v must already be at full head count."""

    def _mask(i, j, pos, s_valid):
        qpos = i * chunk + pos[:, None]
        kpos = j * chunk + pos[None, :]
        m = qpos >= kpos
        if window is not None:
            m &= (qpos - kpos) < window
        m &= kpos < s_valid
        return m

    def _scores(qi, kj, i, j, pos, s_valid):
        sij = jnp.einsum("bhqd,bhsd->bhqs", qi, kj,
                         preferred_element_type=jnp.float32) * scale
        pre = sij
        if attn_softcap is not None:
            sij = attn_softcap * jnp.tanh(sij / attn_softcap)
        sij = jnp.where(_mask(i, j, pos, s_valid), sij, NEG_INF)
        return sij, pre

    def forward(q, k, v, s_valid):
        b, s, h, d = q.shape
        t = s // chunk
        qb = q.reshape(b, t, chunk, h, d).transpose(1, 0, 3, 2, 4)
        kb = k.reshape(b, t, chunk, h, d).transpose(1, 0, 3, 2, 4)
        vb = v.reshape(b, t, chunk, h, d).transpose(1, 0, 3, 2, 4)
        pairs = jnp.asarray(_block_pairs(
            t, None if window is None else -(-window // chunk)))
        m0 = jnp.full((t, b, h, chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((t, b, h, chunk), jnp.float32)
        a0 = jnp.zeros((t, b, h, chunk, d), jnp.float32)
        pos = jnp.arange(chunk)

        def step(carry, pair):
            # the flash_vmem scope marks this block pipeline as Pallas-
            # kernel-resident (kernels/flash_attention.py): the roofline
            # charges only the block DMAs, not the VMEM intermediates.
            with jax.named_scope("flash_vmem"):
                m, l, acc = carry
                i, j = pair[0], pair[1]
                qi = jax.lax.dynamic_index_in_dim(qb, i, 0, keepdims=False)
                kj = jax.lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
                vj = jax.lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
                sij, _ = _scores(qi, kj, i, j, pos, s_valid)
                mi = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
                li = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
                ai = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
                m_new = jnp.maximum(mi, sij.max(axis=-1))
                p = jnp.exp(sij - m_new[..., None])
                corr = jnp.exp(mi - m_new)
                l_new = li * corr + p.sum(axis=-1)
                a_new = ai * corr[..., None] + jnp.einsum(
                    "bhqs,bhsd->bhqd", p.astype(vj.dtype), vj,
                    preferred_element_type=jnp.float32)
                m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 0)
                l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 0)
                acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 0)
                return (m, l, acc), None

        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), pairs)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))        # (t, b, h, chunk)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out_full = out.transpose(1, 0, 3, 2, 4).reshape(b, s, h, d)
        return out_full.astype(q.dtype), lse

    def fwd(q, k, v, s_valid):
        out, lse = forward(q, k, v, s_valid)
        return out, (q, k, v, out, lse, s_valid)

    def bwd(res, dout):
        q, k, v, out, lse, s_valid = res
        b, s, h, d = q.shape
        t = s // chunk
        qb = q.reshape(b, t, chunk, h, d).transpose(1, 0, 3, 2, 4)
        kb = k.reshape(b, t, chunk, h, d).transpose(1, 0, 3, 2, 4)
        vb = v.reshape(b, t, chunk, h, d).transpose(1, 0, 3, 2, 4)
        dob = dout.reshape(b, t, chunk, h, d).transpose(1, 0, 3, 2, 4)
        ob = out.reshape(b, t, chunk, h, d).transpose(1, 0, 3, 2, 4)
        # D_i = rowsum(dout * out), fp32
        D = jnp.einsum("tbhqd,tbhqd->tbhq", dob.astype(jnp.float32),
                       ob.astype(jnp.float32))
        pairs = jnp.asarray(_block_pairs(
            t, None if window is None else -(-window // chunk)))
        pos = jnp.arange(chunk)
        dq0 = jnp.zeros((t, b, h, chunk, d), jnp.float32)
        dk0 = jnp.zeros((t, b, h, chunk, d), jnp.float32)
        dv0 = jnp.zeros((t, b, h, chunk, d), jnp.float32)

        def step(carry, pair):
            with jax.named_scope("flash_vmem"):
                dq, dk, dv = carry
                i, j = pair[0], pair[1]
                qi = jax.lax.dynamic_index_in_dim(qb, i, 0, keepdims=False)
                kj = jax.lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
                vj = jax.lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
                doi = jax.lax.dynamic_index_in_dim(dob, i, 0, keepdims=False)
                lsei = jax.lax.dynamic_index_in_dim(lse, i, 0, keepdims=False)
                Di = jax.lax.dynamic_index_in_dim(D, i, 0, keepdims=False)
                sij, pre = _scores(qi, kj, i, j, pos, s_valid)
                p = jnp.exp(sij - lsei[..., None])      # (b,h,q,s) f32
                dp = jnp.einsum("bhqd,bhsd->bhqs", doi, vj,
                                preferred_element_type=jnp.float32)
                ds = p * (dp - Di[..., None])
                if attn_softcap is not None:
                    # d/dx [c*tanh(x/c)] = 1 - tanh^2(x/c)
                    th = jnp.tanh(pre * (1.0 / attn_softcap))
                    ds = ds * (1.0 - th * th)
                ds = ds * scale
                pd = p.astype(doi.dtype)
                dsd = ds.astype(qi.dtype)
                dv_j = jnp.einsum("bhqs,bhqd->bhsd", pd, doi,
                                  preferred_element_type=jnp.float32)
                dq_i = jnp.einsum("bhqs,bhsd->bhqd", dsd, kj,
                                  preferred_element_type=jnp.float32)
                dk_j = jnp.einsum("bhqs,bhqd->bhsd", dsd, qi,
                                  preferred_element_type=jnp.float32)
                dq = dq.at[i].add(dq_i)
                dk = dk.at[j].add(dk_j)
                dv = dv.at[j].add(dv_j)
                return (dq, dk, dv), None

        (dq, dk, dv), _ = jax.lax.scan(step, (dq0, dk0, dv0), pairs)

        def back(x):
            return (x.transpose(1, 0, 3, 2, 4)
                     .reshape(b, s, h, d))

        return (back(dq).astype(q.dtype), back(dk).astype(k.dtype),
                back(dv).astype(v.dtype), None)

    @jax.custom_vjp
    def flash(q, k, v, s_valid):
        return forward(q, k, v, s_valid)[0]

    flash.defvjp(fwd, bwd)
    return flash


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    chunk: int = 1024, window: Optional[int] = None,
                    attn_softcap: Optional[float] = None) -> jnp.ndarray:
    """Drop-in causal attention: (B,S,H,D) x (B,S,Hkv,D)^2 -> (B,S,H,D)."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    if hkv != h:
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, zp), jnp.pad(k, zp), jnp.pad(v, zp)
    fn = make_flash_attention(c, window, attn_softcap,
                              float(1.0 / np.sqrt(d)))
    out = fn(q, k, v, s)
    return out[:, :s]
