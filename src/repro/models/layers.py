"""Shared neural-net layers: norms, activations, rotary embeddings, dense.

All functional: ``f(params_subtree, x, ...) -> y``.  Dense weights may be
``HaloQuantized``/``StackedHalo`` (dequantized on the fly on the reference
path; the Pallas kernel path is wired in kernels/ops.py) so that a quantized
model runs through exactly the same forward code.
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.apply import StackedHalo
from ..core.quantize import HaloQuantized
from .module import ParamSpec


# ---------------------------------------------------------------------------
# weights that may be quantized
# ---------------------------------------------------------------------------

def materialize(w: Any, dtype=None) -> jnp.ndarray:
    """Dense view of a (possibly quantized) weight leaf."""
    if isinstance(w, (HaloQuantized, StackedHalo)):
        w = w.dequantize()
    else:
        from ..core.deploy import DeployQuantWeight
        from ..kernels.ops import HaloPacked
        if isinstance(w, (DeployQuantWeight, HaloPacked)):
            w = w.dequantize(dtype or jnp.bfloat16)
    return w if dtype is None else w.astype(dtype)


def dense(x: jnp.ndarray, w: Any, compute_dtype=None) -> jnp.ndarray:
    """x @ w with automatic dequantization of HALO weights.

    Honors the A8 fake-quant context (quant.common.activations_quantized)
    and the activation-statistics recorder (quant.calibrate) so baselines and
    calibration reuse the exact model forward.  DeployQuantWeight matmuls
    run under the halo_vmem scope: on TPU the Pallas halo_matmul kernel
    dequantizes in VMEM (kernels/halo_matmul.py), so the roofline charges
    only the 4-bit weight stream, not the XLA dequant intermediates.
    """
    from ..quant import common as qcommon
    from ..quant import calibrate as qcal
    from ..core.deploy import DeployQuantWeight
    from ..kernels import ops as kops
    qcal.maybe_record(w, x)
    x = qcommon.maybe_quantize_activation(x)
    cd = compute_dtype or x.dtype
    if isinstance(w, kops.HaloPacked):
        if not w.is_stacked:
            # the serving fast path: the matmul consumes the 4-bit stream +
            # bucketed outliers directly (Pallas on TPU, interpret on CPU)
            return kops.halo_matmul(x.astype(cd), w, out_dtype=cd)
        # stacked leaf reached outside a scan (MoE expert einsum feeds):
        # XLA fallback; scanned layers never hit this branch
        wd = w.dequantize(cd)
        return jnp.matmul(x.astype(cd), wd)
    if isinstance(w, DeployQuantWeight):
        with jax.named_scope("halo_vmem"):
            wd = w.dequantize(cd)
            return jnp.matmul(x.astype(cd), wd)
    wd = materialize(w)
    return jnp.matmul(x.astype(cd), wd.astype(cd))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_spec(d: int, axis: str = "embed") -> ParamSpec:
    return ParamSpec((d,), (axis,), init="ones")


def _rmsnorm_impl(scale, x, eps, plus_one):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    s = (1.0 + scale.astype(jnp.float32)) if plus_one else scale.astype(jnp.float32)
    return (y * s).astype(dt)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def rmsnorm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6,
            plus_one: bool = False) -> jnp.ndarray:
    """RMSNorm with a hand-written VJP.

    The custom backward computes in fp32 but *returns the cotangent in the
    activation dtype* -- default autodiff leaks fp32 residual-width
    cotangents into every TP gradient all-reduce (2x collective bytes and
    2x boundary HBM traffic measured on granite train; EXPERIMENTS.md
    SPerf)."""
    return _rmsnorm_impl(scale, x, eps, plus_one)


def _rmsnorm_fwd(scale, x, eps, plus_one):
    return _rmsnorm_impl(scale, x, eps, plus_one), (scale, x)


def _rmsnorm_bwd(eps, plus_one, res, dy):
    scale, x = res
    xf = x.astype(jnp.float32)
    g = dy.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)
    xhat = xf * r
    s = (1.0 + scale.astype(jnp.float32)) if plus_one \
        else scale.astype(jnp.float32)
    gs = g * s
    dx = r * (gs - xhat * jnp.mean(xhat * gs, axis=-1, keepdims=True))
    dscale = jnp.sum((g * xhat).reshape(-1, x.shape[-1]), axis=0)
    return dscale.astype(scale.dtype), dx.astype(x.dtype)


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def layernorm(scale: jnp.ndarray, bias: jnp.ndarray, x: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation(name: str, x: jnp.ndarray) -> jnp.ndarray:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    if name == "squared_relu":      # Primer / nemotron-4
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # (D/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def embed_spec(vocab: int, d: int, dtype=jnp.float32) -> ParamSpec:
    return ParamSpec((vocab, d), ("vocab", "embed"), dtype=dtype,
                     init="normal", init_scale=0.02)


def embed_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, ids, axis=0)


def unembed(x: jnp.ndarray, table_or_head: Any) -> jnp.ndarray:
    """(..., d) -> (..., vocab).  Accepts an (V, d) tied table or (d, V) head."""
    from ..kernels import ops as kops
    if isinstance(table_or_head, kops.HaloPacked) \
            and not table_or_head.is_stacked \
            and table_or_head.shape[0] == x.shape[-1]:
        return kops.halo_matmul(x, table_or_head, out_dtype=x.dtype)
    w = materialize(table_or_head)
    if w.shape[0] == x.shape[-1]:
        return jnp.matmul(x, w.astype(x.dtype))
    return jnp.matmul(x, w.T.astype(x.dtype))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  valid_vocab: Optional[int] = None,
                  label_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token NLL in fp32; padded vocab columns masked to -inf."""
    lf = logits.astype(jnp.float32)
    if valid_vocab is not None and valid_vocab < logits.shape[-1]:
        col = jnp.arange(logits.shape[-1])
        lf = jnp.where(col >= valid_vocab, -1e30, lf)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if label_mask is not None:
        return (nll * label_mask).sum() / jnp.maximum(label_mask.sum(), 1.0)
    return nll.mean()
