"""Minimal functional parameter-spec system (pure JAX, no flax/haiku).

A model definition is a nested dict of ``ParamSpec`` leaves.  From the spec
tree we derive, without ever allocating device memory:

  * ``abstract_params``  -> ShapeDtypeStruct tree (multi-pod dry-run input)
  * ``logical_axes``     -> logical sharding axes per leaf (dist.sharding
                            turns these into NamedSharding via rules)
  * ``init_params``      -> real arrays (only for small/runnable models)

Logical axis names used across the repo:
  "embed"   d_model dim            "mlp"     d_ff dim
  "heads"   q-heads*head_dim dim   "kv"      kv-heads*head_dim dim
  "vocab"   vocabulary dim         "experts" MoE expert dim
  "layers"  scan-stacked layer dim "seq"/"batch" activations only
  None      replicated
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"          # normal | zeros | ones | fan_in
    init_scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (
            f"shape {self.shape} vs axes {self.logical_axes}")

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def initialize(self, key: jax.Array) -> jnp.ndarray:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "fan_in":
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
            std = self.init_scale / math.sqrt(max(fan_in, 1))
            return (jax.random.normal(key, self.shape, jnp.float32) * std
                    ).astype(self.dtype)
        if self.init == "normal":
            return (jax.random.normal(key, self.shape, jnp.float32)
                    * self.init_scale).astype(self.dtype)
        raise ValueError(self.init)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable, specs) -> Any:
    return jax.tree.map(fn, specs, is_leaf=is_spec)


def abstract_params(specs) -> Any:
    return tree_map_specs(lambda s: s.abstract(), specs)


def logical_axes(specs) -> Any:
    return tree_map_specs(lambda s: s.logical_axes, specs)


def init_params(specs, key: jax.Array) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [s.initialize(k) for s, k in zip(leaves, keys)])


def param_count(specs) -> int:
    leaves = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)[0]
    return int(sum(np.prod(s.shape) for s in leaves))


def param_bytes(specs) -> int:
    leaves = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)[0]
    return int(sum(np.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in leaves))


def stack_specs(spec: ParamSpec, n: int, axis_name: str = "layers") -> ParamSpec:
    """Prepend a stacked (scan) dimension to a per-layer spec."""
    return dataclasses.replace(
        spec, shape=(n,) + spec.shape,
        logical_axes=(axis_name,) + spec.logical_axes)


def stack_tree(specs, n: int, axis_name: str = "layers"):
    return tree_map_specs(lambda s: stack_specs(s, n, axis_name), specs)
