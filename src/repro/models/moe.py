"""Mixture-of-Experts FFN with token-sort dispatch (dbrx / llama4-scout).

Dispatch is the fixed-shape "sort tokens by expert" scheme:
  router -> top-k (expert_id, weight) per token -> flatten -> stable-sort by
  expert -> position-within-expert via running counts -> scatter into an
  (E, C, d) buffer (capacity C, overflow dropped) -> per-expert batched
  matmuls -> gather back and combine with routing weights.

All shapes are static (jit/pjit friendly).  The (E, C, d) buffer carries the
"experts" logical axis, so under expert parallelism GSPMD materializes the
dispatch/return as all-to-all-style collectives over the "model" mesh axis.
A load-balancing auxiliary loss (Switch-style) is returned for training.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import MoeConfig
from .layers import activation, dense, materialize
from .module import ParamSpec


def moe_ffn_specs(d_model: int, d_ff: int, cfg: MoeConfig,
                  dtype=jnp.float32) -> Dict[str, ParamSpec]:
    e = cfg.n_experts
    wi_cols = (2 if cfg.gated else 1) * d_ff
    return {
        "router": ParamSpec((d_model, e), ("embed", None), dtype, "fan_in"),
        "wi": ParamSpec((e, d_model, wi_cols), ("experts", "embed", "mlp"),
                        dtype, "fan_in"),
        "wo": ParamSpec((e, d_ff, d_model), ("experts", "mlp", "embed"),
                        dtype, "fan_in"),
    }


def moe_ffn(p, x: jnp.ndarray, cfg: MoeConfig,
            shard_fn=lambda a, axes: a,
            token_chunks: int = 1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    token_chunks > 1 runs the dispatch/expert/combine pipeline over token
    chunks sequentially (lax.map), dividing the (E, C, ff) capacity buffers
    by the chunk count -- required to fit 32k-token prefills in HBM."""
    b, s, d = x.shape
    if token_chunks > 1 and (b * s) % token_chunks == 0:
        xc = x.reshape(token_chunks, (b * s) // token_chunks, d)

        def one(xi):                       # (chunk_t, d)
            o, a = moe_ffn(p, xi[None], cfg, shard_fn, token_chunks=1)
            return o[0], a

        outs, auxes = jax.lax.map(one, xc)
        return outs.reshape(b, s, d), auxes.mean()
    t = b * s
    xt = x.reshape(t, d)
    e, k = cfg.n_experts, cfg.top_k

    logits = dense(xt, p["router"], compute_dtype=jnp.float32)     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                          # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux: E * sum_e (frac_tokens_e * mean_prob_e)
    token_frac = jnp.mean(
        jax.nn.one_hot(top_e, e, dtype=jnp.float32).sum(1), axis=0)
    prob_frac = probs.mean(axis=0)
    aux = cfg.router_aux_weight * e * jnp.sum(token_frac * prob_frac)

    # ---- flatten, sort by expert ----
    flat_e = top_e.reshape(-1)                                      # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_tok[order], flat_w[order]

    # position within expert group = rank - first_rank_of_expert
    counts = jnp.bincount(se, length=e)                             # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k) - starts[se]

    cap = int(t * k * cfg.capacity_factor / e + 0.999)
    cap = max(cap, 1)
    keep = pos_in_e < cap
    safe_pos = jnp.where(keep, pos_in_e, cap - 1)

    # ---- dispatch into (E, C, d) ----
    buf = jnp.zeros((e, cap, d), x.dtype)
    gathered = jnp.where(keep[:, None], xt[st], 0)
    buf = buf.at[se, safe_pos].add(gathered)     # add: dropped slots collide
    buf = shard_fn(buf, ("experts", None, "embed"))

    # ---- expert computation (batched over E) ----
    wi, wo = materialize(p["wi"]), materialize(p["wo"])
    hid = jnp.einsum("ecd,edf->ecf", buf.astype(x.dtype), wi.astype(x.dtype))
    if cfg.gated:
        h1, h2 = jnp.split(hid, 2, axis=-1)
        hid = activation(cfg.act, h1) * h2
    else:
        hid = activation(cfg.act, hid)
    out_e = jnp.einsum("ecf,efd->ecd", hid, wo.astype(x.dtype))
    out_e = shard_fn(out_e, ("experts", None, "embed"))

    # ---- combine back ----
    expert_out = out_e[se, safe_pos]                                # (T*k, d)
    expert_out = jnp.where(keep[:, None], expert_out, 0)
    contrib = expert_out * sw[:, None].astype(x.dtype)
    out = jax.ops.segment_sum(contrib, st, num_segments=t)
    return out.reshape(b, s, d), aux
