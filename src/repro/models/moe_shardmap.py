"""Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

GSPMD partitions the sort-based dispatch (models/moe.py) through scatter /
gather ops and falls back to replicate+all-reduce -- measured 155 s of
collective time per dbrx train step (EXPERIMENTS.md SPerf cell B).  This
module is the production path: experts are sharded one-per-rank over the
"model" axis and tokens move with two ``lax.all_to_all``s:

  per rank: route local tokens -> bucket by destination expert rank
  (capacity C per (src, dst) pair) -> all_to_all -> local expert FFN over
  the 16 received buckets -> all_to_all back -> weighted combine.

Collective volume per layer is exactly 2 x T_local * top_k * cf * d bytes
(plus the transposed pair in the backward), vs. GSPMD's full-buffer
all-reduces.  Shapes are static; dropping is per (src, dst) bucket.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                      # jax >= 0.6
    from jax import shard_map as _shard_map
except ImportError:                       # jax 0.4/0.5
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map_unchecked(body, mesh, in_specs, out_specs):
    """shard_map with replication checking off, across jax versions
    (the kwarg was renamed check_rep -> check_vma)."""
    try:
        return _shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:
        return _shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)

from ..configs.base import MoeConfig
from .layers import activation


def _bucket_by_dest(xt, top_e, top_w, n_dest: int, cap: int):
    """Bucket (token, k) assignments by destination rank.

    Returns (buckets (n_dest, cap, d), meta (n_dest, cap, 2) int32 holding
    (flat assignment index + 1, expert_local_slot placeholder)).  Slot 0 in
    meta means 'padding'."""
    t, d = xt.shape
    k = top_e.shape[1]
    flat_e = top_e.reshape(-1)                        # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e, stable=True)
    se, st = flat_e[order], flat_tok[order]
    counts = jnp.bincount(se, length=n_dest)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[se]
    keep = pos < cap
    safe_pos = jnp.where(keep, pos, cap - 1)
    buckets = jnp.zeros((n_dest, cap, d), xt.dtype)
    buckets = buckets.at[se, safe_pos].add(
        jnp.where(keep[:, None], xt[st], 0))
    meta = jnp.zeros((n_dest, cap), jnp.int32)
    meta = meta.at[se, safe_pos].max(
        jnp.where(keep, order + 1, 0))                # assignment id + 1
    return buckets, meta


def moe_ffn_a2a(p, x: jnp.ndarray, cfg: MoeConfig, mesh,
                axis: str = "model") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Drop-in replacement for moe_ffn using expert-parallel all-to-all.

    Requires n_experts % mesh.shape[axis] == 0.  x: (B, S, d)."""
    n_ranks = mesh.shape[axis]
    assert cfg.n_experts % n_ranks == 0, (cfg.n_experts, n_ranks)
    e_loc = cfg.n_experts // n_ranks
    b, s, d = x.shape

    batch_axes = tuple(a for a in ("data", "pod") if a in mesh.shape
                       and b % mesh.shape[a] == 0)
    bspec = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    p_specs = {"router": P(), "wi": P(axis, None, None),
               "wo": P(axis, None, None)}
    # tokens split over the EP axis too (sequence dim) so each rank routes
    # 1/n_ranks of the tokens -- without this every model-rank would
    # redundantly process the whole data-shard (16x wasted FLOPs, measured)
    seq_axis = axis if s % n_ranks == 0 else None
    in_specs = (p_specs, P(bspec, seq_axis, None))
    out_specs = (P(bspec, seq_axis, None), P())

    def body(pp, xx):
        bl, sl, _ = xx.shape
        t = bl * sl
        xt = xx.reshape(t, d)
        logits = (xt.astype(jnp.float32)
                  @ pp["router"].astype(jnp.float32))         # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, cfg.top_k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
        one_hot = jax.nn.one_hot(top_e, cfg.n_experts, dtype=jnp.float32)
        aux = cfg.router_aux_weight * cfg.n_experts * jnp.sum(
            one_hot.sum(1).mean(0) * probs.mean(0))
        for a in batch_axes:
            aux = jax.lax.pmean(aux, a)
        aux = jax.lax.pmean(aux, axis)

        # destination RANK of each assignment (expert // e_loc)
        dest = top_e // e_loc
        cap = max(int(t * cfg.top_k * cfg.capacity_factor / n_ranks
                      + 0.999), 1)
        buckets, meta = _bucket_by_dest(xt, dest, top_w, n_ranks, cap)
        # remember which local expert each kept assignment wanted
        flat_e_of_meta = jnp.where(
            meta > 0, top_e.reshape(-1)[jnp.clip(meta - 1, 0)] % e_loc, 0)

        # ---- exchange: (n_ranks, cap, d) -> (n_ranks, cap, d) ----
        recv = jax.lax.all_to_all(buckets, axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        recv_e = jax.lax.all_to_all(flat_e_of_meta, axis, split_axis=0,
                                    concat_axis=0, tiled=False)
        recv_live = jax.lax.all_to_all((meta > 0).astype(jnp.int32), axis,
                                       split_axis=0, concat_axis=0,
                                       tiled=False)

        # ---- local expert FFN over all received tokens ----
        tok = recv.reshape(n_ranks * cap, d)
        sel = jax.nn.one_hot(recv_e.reshape(-1), e_loc, dtype=tok.dtype) \
            * recv_live.reshape(-1, 1)
        # e_loc is small (1 for dbrx/llama4 on 16 ranks): compute per local
        # expert and select
        outs = jnp.zeros_like(tok)
        for j in range(e_loc):
            hid = tok @ pp["wi"][j].astype(tok.dtype)
            if cfg.gated:
                h1, h2 = jnp.split(hid, 2, axis=-1)
                hid = activation(cfg.act, h1) * h2
            else:
                hid = activation(cfg.act, hid)
            outs = outs + (hid @ pp["wo"][j].astype(tok.dtype)) \
                * sel[:, j:j + 1]

        # ---- return path ----
        back = jax.lax.all_to_all(outs.reshape(n_ranks, cap, d), axis,
                                  split_axis=0, concat_axis=0, tiled=False)

        # combine: scatter outputs back to tokens with routing weights
        flat_tok = jnp.repeat(jnp.arange(t), cfg.top_k)
        flat_w = top_w.reshape(-1)
        out_flat = jnp.zeros((t, d), xx.dtype)
        contrib = back.reshape(n_ranks * cap, d)
        # meta holds assignment-id+1 at (dest_rank, slot)
        aid = jnp.clip(meta.reshape(-1) - 1, 0)
        live = (meta.reshape(-1) > 0)
        tok_of = flat_tok[aid]
        w_of = jnp.where(live, flat_w[aid], 0.0)
        out_flat = out_flat.at[tok_of].add(
            (contrib * w_of[:, None]).astype(xx.dtype))
        return out_flat.reshape(bl, sl, d), aux

    fn = shard_map_unchecked(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    return fn(p, x)
