"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: x -> {branch1: linear_x -> causal conv1d -> RG-LRU;
             branch2: linear_y -> GeLU} -> elementwise product -> linear_out.

RG-LRU cell (diagonal, gated; gates are *block-diagonal* per head, as in the
reference implementation -- which also makes them shard cleanly over TP):
  r_t = sigmoid(W_a h_in + b_a)            recurrence gate
  i_t = sigmoid(W_x h_in + b_x)            input gate
  log_a_t = -c * softplus(Lambda) * r_t    (c = 8)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Diagonal recurrence -> shared chunked scan.  Projections/gates are MAC
matmuls (HALO-quantizable); Lambda and the scan are not.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .layers import dense, rmsnorm
from .module import ParamSpec
from .scan_ops import chunked_diag_scan, diag_scan_step

RG_LRU_C = 8.0
GATE_BLOCKS = 16   # block-diagonal gate heads (divides every d_rnn we use)


def rglru_block_specs(d_model: int, d_rnn: int, conv_k: int = 4,
                      dtype=jnp.float32) -> Dict[str, ParamSpec]:
    db = d_rnn // GATE_BLOCKS
    assert db * GATE_BLOCKS == d_rnn, (d_rnn, GATE_BLOCKS)
    return {
        "ln": ParamSpec((d_model,), ("embed",), dtype, init="ones"),
        "wx": ParamSpec((d_model, d_rnn), ("embed", "mlp"), dtype, "fan_in"),
        "wy": ParamSpec((d_model, d_rnn), ("embed", "mlp"), dtype, "fan_in"),
        "conv_w": ParamSpec((conv_k, d_rnn), (None, "mlp"), dtype, "normal", 0.1),
        "conv_b": ParamSpec((d_rnn,), ("mlp",), dtype, "zeros"),
        "gate_a_w": ParamSpec((GATE_BLOCKS, db, db), ("mlp", None, None),
                              dtype, "fan_in"),
        "gate_a_b": ParamSpec((d_rnn,), ("mlp",), dtype, "zeros"),
        "gate_x_w": ParamSpec((GATE_BLOCKS, db, db), ("mlp", None, None),
                              dtype, "fan_in"),
        "gate_x_b": ParamSpec((d_rnn,), ("mlp",), dtype, "zeros"),
        "lam": ParamSpec((d_rnn,), ("mlp",), dtype, "normal", 0.8),
        "out": ParamSpec((d_rnn, d_model), ("mlp", "embed"), dtype, "fan_in"),
    }


class RglruState(NamedTuple):
    conv: jnp.ndarray    # (B, conv_k - 1, d_rnn)
    h: jnp.ndarray       # (B, d_rnn) fp32


def init_rglru_state(batch: int, d_rnn: int, conv_k: int = 4,
                     dtype=jnp.float32) -> RglruState:
    return RglruState(conv=jnp.zeros((batch, conv_k - 1, d_rnn), dtype),
                      h=jnp.zeros((batch, d_rnn), jnp.float32))


def _block_diag_matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x (..., d_rnn) times block-diagonal w (nb, db, db) -> (..., d_rnn)."""
    from .layers import materialize   # quantized stacked gate support
    w = materialize(w)
    nb, db, _ = w.shape
    xb = x.reshape(x.shape[:-1] + (nb, db))
    yb = jnp.einsum("...nd,nde->...ne", xb, w.astype(x.dtype))
    return yb.reshape(x.shape)


def _cell_coeffs(p, xc: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(a_t, b_t) of the diagonal recurrence for conv output xc (..., d_rnn)."""
    r = jax.nn.sigmoid(_block_diag_matmul(xc, p["gate_a_w"]) + p["gate_a_b"])
    i = jax.nn.sigmoid(_block_diag_matmul(xc, p["gate_x_w"]) + p["gate_x_b"])
    log_a = (-RG_LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32))
             * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xc).astype(jnp.float32)
    return a, b


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k)) + b


def rglru_block(p, x: jnp.ndarray, scan_chunk: int = 256,
                return_state: bool = False):
    """Full-sequence forward. x: (B,S,d) -> (B,S,d) with residual."""
    hin = rmsnorm(p["ln"], x)
    xb = dense(hin, p["wx"])
    yb = jax.nn.gelu(dense(hin, p["wy"]))
    xc = _causal_conv(xb, p["conv_w"], p["conv_b"])
    a, b = _cell_coeffs(p, xc)
    h0 = jnp.zeros((x.shape[0], xc.shape[-1]), jnp.float32)
    hs, h_last = chunked_diag_scan(a, b, h0, chunk=scan_chunk)
    out = (hs.astype(x.dtype) * yb)
    out = x + dense(out, p["out"]).astype(x.dtype)
    if not return_state:
        return out
    km1 = p["conv_w"].shape[0] - 1
    conv_tail = xb[:, -km1:, :]
    pad = km1 - conv_tail.shape[1]
    if pad > 0:
        conv_tail = jnp.pad(conv_tail, ((0, 0), (pad, 0), (0, 0)))
    return out, RglruState(conv=conv_tail, h=h_last)


def rglru_decode_step(p, x: jnp.ndarray, state: RglruState
                      ) -> Tuple[jnp.ndarray, RglruState]:
    """One-token step. x: (B,d)."""
    hin = rmsnorm(p["ln"], x)
    xb = dense(hin, p["wx"])
    yb = jax.nn.gelu(dense(hin, p["wy"]))
    win = jnp.concatenate([state.conv, xb[:, None, :]], axis=1)
    xc = jnp.einsum("bkd,kd->bd", win, p["conv_w"]) + p["conv_b"]
    a, b = _cell_coeffs(p, xc)
    h_new = diag_scan_step(a, b, state.h)
    out = (h_new.astype(x.dtype) * yb)
    out = x + dense(out, p["out"]).astype(x.dtype)
    return out, RglruState(conv=win[:, 1:], h=h_new)
