"""Chunked diagonal linear recurrences: h_t = a_t * h_{t-1} + b_t.

Shared by Mamba-1's selective scan and RecurrentGemma's RG-LRU.  A pure
``associative_scan`` over the full sequence materializes O(S log S)
intermediates -- ruinous at 4k-500k tokens -- so we scan sequentially over
fixed-size chunks and run the associative scan only within a chunk:
memory O(B * chunk * d * log chunk), exact same result.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def _combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, a2 * b1 + b2


@functools.partial(jax.jit, static_argnames=("chunk",))
def chunked_diag_scan(a: jnp.ndarray, b: jnp.ndarray,
                      h0: jnp.ndarray, chunk: int = 256
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run h_t = a_t * h_{t-1} + b_t along axis 1 (sequence).

    a, b: (B, S, ...) with identical trailing dims; h0: (B, ...).
    Returns (h_all (B, S, ...), h_final (B, ...)).  S padded internally to a
    chunk multiple (a=1, b=0 padding keeps the state unchanged).
    """
    bsz, s = a.shape[0], a.shape[1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        a = jnp.concatenate(
            [a, jnp.ones((bsz, pad) + a.shape[2:], a.dtype)], axis=1)
        b = jnp.concatenate(
            [b, jnp.zeros((bsz, pad) + b.shape[2:], b.dtype)], axis=1)
    n_chunks = a.shape[1] // chunk
    a_c = a.reshape((bsz, n_chunks, chunk) + a.shape[2:]).swapaxes(0, 1)
    b_c = b.reshape((bsz, n_chunks, chunk) + b.shape[2:]).swapaxes(0, 1)

    def chunk_step(h, ab):
        a_i, b_i = ab                                   # (B, chunk, ...)
        # fold carry-in into the first step's b
        b_i = b_i.at[:, 0].add(a_i[:, 0] * h)
        aa, bb = jax.lax.associative_scan(_combine, (a_i, b_i), axis=1)
        return bb[:, -1], bb

    h_last, h_chunks = jax.lax.scan(chunk_step, h0, (a_c, b_c))
    h_all = h_chunks.swapaxes(0, 1).reshape((bsz, n_chunks * chunk) + a.shape[2:])
    return h_all[:, :s], h_last


def diag_scan_step(a: jnp.ndarray, b: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Single decode step of the same recurrence."""
    return a * h + b
