"""Mamba-1 selective state-space block (falcon-mamba-7b's layer).

Structure (arXiv:2312.00752): in_proj -> (x, z); x through causal depthwise
conv1d + SiLU; input-dependent (dt, B, C) from x; discretized diagonal SSM
scan; gated by SiLU(z); out_proj.  The recurrence is diagonal per (channel,
state) pair -> runs on the shared chunked scan.

HALO applicability note (DESIGN.md S3.2): the in/x/dt/out projections are
ordinary MAC matmuls and are quantized; A_log/D/conv/dt biases and the scan
itself stay dense.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense, rmsnorm
from .module import ParamSpec
from .scan_ops import chunked_diag_scan, diag_scan_step


class SsmDims(NamedTuple):
    d_model: int
    d_inner: int       # expand * d_model (falcon-mamba: 2 * 4096 = 8192)
    d_state: int       # 16
    dt_rank: int       # ceil(d_model / 16)
    conv_k: int = 4


def ssm_dims(d_model: int, d_state: int = 16, expand: int = 2,
             conv_k: int = 4) -> SsmDims:
    return SsmDims(d_model, expand * d_model, d_state,
                   -(-d_model // 16), conv_k)


def mamba_block_specs(dims: SsmDims, dtype=jnp.float32) -> Dict[str, ParamSpec]:
    d, di, ds, dr, ck = dims
    return {
        "ln": ParamSpec((d,), ("embed",), dtype, init="ones"),
        "in_proj": ParamSpec((d, 2 * di), ("embed", "mlp"), dtype, "fan_in"),
        "conv_w": ParamSpec((ck, di), (None, "mlp"), dtype, "normal", 0.1),
        "conv_b": ParamSpec((di,), ("mlp",), dtype, "zeros"),
        "x_proj": ParamSpec((di, dr + 2 * ds), ("mlp", None), dtype, "fan_in"),
        "dt_w": ParamSpec((dr, di), (None, "mlp"), dtype, "fan_in"),
        "dt_b": ParamSpec((di,), ("mlp",), dtype, "normal", 0.1),
        "A_log": ParamSpec((di, ds), ("mlp", None), dtype, "normal", 0.5),
        "D": ParamSpec((di,), ("mlp",), dtype, "ones"),
        "out_proj": ParamSpec((di, d), ("mlp", "embed"), dtype, "fan_in"),
    }


class MambaState(NamedTuple):
    """Decode-time recurrent state of one layer."""
    conv: jnp.ndarray   # (B, conv_k - 1, d_inner)
    ssm: jnp.ndarray    # (B, d_inner, d_state)


def init_mamba_state(batch: int, dims: SsmDims, dtype=jnp.float32) -> MambaState:
    return MambaState(
        conv=jnp.zeros((batch, dims.conv_k - 1, dims.d_inner), dtype),
        ssm=jnp.zeros((batch, dims.d_inner, dims.d_state), jnp.float32))


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along seq. x: (B,S,di); w: (k,di)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out + b


def _ssm_inner(p, x: jnp.ndarray, dims: SsmDims
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Input-dependent discretization. x: (B,S,di) post-conv activations.
    Returns (a_bar, b_bar_x, C) for the diagonal recurrence."""
    d, di, ds, dr, _ = dims
    dbc = dense(x, p["x_proj"])
    dt_low, bc = dbc[..., :dr], dbc[..., dr:]
    b_in, c_in = bc[..., :ds], bc[..., ds:]
    dt = jax.nn.softplus(dense(dt_low, p["dt_w"]) + p["dt_b"])      # (B,S,di)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                    # (di,ds)
    a_bar = jnp.exp(dt[..., None].astype(jnp.float32) * a)          # (B,S,di,ds)
    bx = (dt * x)[..., None] * b_in[..., None, :].astype(jnp.float32)
    return a_bar, bx.astype(jnp.float32), c_in.astype(jnp.float32)


def mamba_block(p, x: jnp.ndarray, dims: SsmDims,
                scan_chunk: int = 256,
                return_state: bool = False):
    """Full-sequence forward (train / prefill). x: (B,S,d) -> (B,S,d).

    With return_state=True also returns the MambaState a decoder would
    continue from (final ssm state + last conv_k-1 pre-conv activations).
    """
    h = rmsnorm(p["ln"], x)
    xz = dense(h, p["in_proj"])
    x1_pre, z = jnp.split(xz, 2, axis=-1)
    x1 = jax.nn.silu(_causal_conv(x1_pre, p["conv_w"], p["conv_b"]))
    a_bar, bx, c_in = _ssm_inner(p, x1, dims)
    h0 = jnp.zeros((x.shape[0], dims.d_inner, dims.d_state), jnp.float32)
    hs, h_last = chunked_diag_scan(a_bar, bx, h0, chunk=scan_chunk)
    y = jnp.einsum("bsdn,bsn->bsd", hs, c_in)
    y = y.astype(x1.dtype) + p["D"] * x1
    y = y * jax.nn.silu(z)
    out = x + dense(y, p["out_proj"]).astype(x.dtype)
    if not return_state:
        return out
    km1 = dims.conv_k - 1
    conv_tail = x1_pre[:, -km1:, :]
    pad = km1 - conv_tail.shape[1]
    if pad > 0:
        conv_tail = jnp.pad(conv_tail, ((0, 0), (pad, 0), (0, 0)))
    return out, MambaState(conv=conv_tail, ssm=h_last)


def mamba_decode_step(p, x: jnp.ndarray, state: MambaState, dims: SsmDims
                      ) -> Tuple[jnp.ndarray, MambaState]:
    """One-token step. x: (B,d) -> (B,d), updated state."""
    h = rmsnorm(p["ln"], x)
    xz = dense(h, p["in_proj"])
    x1, z = jnp.split(xz, 2, axis=-1)                               # (B,di)
    win = jnp.concatenate([state.conv, x1[:, None, :]], axis=1)     # (B,k,di)
    x1 = jax.nn.silu(jnp.einsum("bkd,kd->bd", win, p["conv_w"]) + p["conv_b"])
    a_bar, bx, c_in = _ssm_inner(p, x1[:, None, :], dims)
    h_new = diag_scan_step(a_bar[:, 0], bx[:, 0], state.ssm)        # (B,di,ds)
    y = jnp.einsum("bdn,bn->bd", h_new, c_in[:, 0]).astype(x1.dtype)
    y = y + p["D"] * x1
    y = y * jax.nn.silu(z)
    out = x + dense(y, p["out_proj"]).astype(x.dtype)
    return out, MambaState(conv=win[:, 1:], ssm=h_new)
