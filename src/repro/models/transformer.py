"""Unified decoder-only model covering all assigned architectures.

A model is a periodic program of blocks (``cfg.block_pattern``):
  "attn"        global causal attention + MLP/MoE
  "attn_local"  sliding-window attention + MLP/MoE
  "rec"         RG-LRU recurrent mixer + MLP
  "mamba"       Mamba-1 block (no separate MLP)

Layers are stacked per period position and scanned over periods (remat'd);
non-divisible remainders are unrolled with their own parameters.  The same
block functions serve full-sequence forward/prefill and single-token decode,
with caches (KV / SSM / RG-LRU states) stacked alongside the parameter
stacks.  Weights may be HaloQuantized -- `layers.dense` dequantizes
transparently, so PTQ'd models run through this exact code.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..dist.sharding import shard_activation
from . import rglru, ssm
from .attention import (append_attention, causal_blockwise_attention,
                        decode_attention, gather_pages,
                        paged_decode_attention)
from .layers import (activation, apply_rope, cross_entropy, dense,
                     embed_lookup, layernorm, materialize, rmsnorm, softcap)
from .module import ParamSpec, stack_tree
from .moe import moe_ffn, moe_ffn_specs


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _norm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    s = {"scale": ParamSpec((d,), ("embed",), cfg.dtype,
                            init="zeros" if cfg.norm_plus_one else "ones")}
    if cfg.norm_type == "layernorm":
        s["bias"] = ParamSpec((d,), ("embed",), cfg.dtype, init="zeros")
    return s


def _apply_norm(p, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm_type == "layernorm":
        return layernorm(p["scale"], p["bias"], x, cfg.norm_eps)
    return rmsnorm(p["scale"], x, cfg.norm_eps, plus_one=cfg.norm_plus_one)


def _mlp_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.moe is not None:
        s: Dict[str, Any] = {"ln": _norm_specs(cfg)}
        s.update(moe_ffn_specs(d, ff, cfg.moe, cfg.dtype))
        return s
    cols = (2 if cfg.gated_mlp else 1) * ff
    s = {
        "ln": _norm_specs(cfg),
        "wi": ParamSpec((d, cols), ("embed", "mlp"), cfg.dtype, "fan_in"),
        "wo": ParamSpec((ff, d), ("mlp", "embed"), cfg.dtype, "fan_in"),
    }
    if cfg.use_bias:
        s["bi"] = ParamSpec((cols,), ("mlp",), cfg.dtype, "zeros")
        s["bo"] = ParamSpec((d,), ("embed",), cfg.dtype, "zeros")
    return s


def _attn_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    s: Dict[str, Any] = {
        "ln": _norm_specs(cfg),
        "wq": ParamSpec((d, h * dh), ("embed", "heads"), cfg.dtype, "fan_in"),
        "wk": ParamSpec((d, hkv * dh), ("embed", "kv"), cfg.dtype, "fan_in"),
        "wv": ParamSpec((d, hkv * dh), ("embed", "kv"), cfg.dtype, "fan_in"),
        "wo": ParamSpec((h * dh, d), ("heads", "embed"), cfg.dtype, "fan_in"),
    }
    if cfg.use_bias:
        for nm, dim in (("bq", h * dh), ("bk", hkv * dh), ("bv", hkv * dh)):
            s[nm] = ParamSpec((dim,), ("heads" if nm == "bq" else "kv",),
                              cfg.dtype, "zeros")
        s["bo"] = ParamSpec((d,), ("embed",), cfg.dtype, "zeros")
    return s


def block_specs(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    if kind == "mamba":
        return {"mamba": ssm.mamba_block_specs(
            ssm.ssm_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_expand,
                         cfg.conv_k), cfg.dtype)}
    if kind == "rec":
        d_rnn = cfg.d_rnn or cfg.d_model
        return {"rec": rglru.rglru_block_specs(cfg.d_model, d_rnn, cfg.conv_k,
                                               cfg.dtype),
                "mlp": _mlp_specs(cfg)}
    if kind in ("attn", "attn_local"):
        return {"attn": _attn_specs(cfg), "mlp": _mlp_specs(cfg)}
    raise ValueError(kind)


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = {}
    if not cfg.embeds_input:
        specs["embed"] = ParamSpec((cfg.padded_vocab, cfg.d_model),
                                   ("vocab", "embed"), cfg.dtype,
                                   "normal", 0.02)
    if cfg.pos_emb == "learned":
        specs["pos_embed"] = ParamSpec((cfg.max_position, cfg.d_model),
                                       (None, "embed"), cfg.dtype,
                                       "normal", 0.02)
    specs["final_norm"] = _norm_specs(cfg)
    if not cfg.tied_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.padded_vocab),
                                     ("embed", "vocab"), cfg.dtype, "fan_in")
    specs["period"] = tuple(
        stack_tree(block_specs(cfg, kind), cfg.n_periods)
        for kind in cfg.block_pattern)
    specs["remainder"] = tuple(
        block_specs(cfg, kind) for kind in cfg.remainder_pattern)
    return specs


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

class AttnCache(NamedTuple):
    """KV cache; int8 mode stores per-(position, head) scales alongside
    (KIVI-style post-RoPE quantization) -- halves decode cache residency
    and HBM read traffic (SPerf cell C)."""

    k: jnp.ndarray   # (B, S_max, Hkv, Dh) storage dtype (bf16 or int8)
    v: jnp.ndarray
    k_scale: Optional[jnp.ndarray] = None   # (B, S_max, Hkv) f32, int8 only
    v_scale: Optional[jnp.ndarray] = None


def _quantize_kv(x: jnp.ndarray):
    """(..., Hkv, Dh) -> (int8 values, per-(...,Hkv) f32 scales)."""
    absmax = jnp.abs(x.astype(jnp.float32)).max(axis=-1)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q: jnp.ndarray, scale: Optional[jnp.ndarray],
                   dtype) -> jnp.ndarray:
    if scale is None:
        return q
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# Paged mode: unassigned page-table entries carry this sentinel.  It is
# far above any real frame id, so reads clip to the last frame (junk that
# sits past the slot's length and is masked) and writes scatter out of
# bounds and are dropped -- an evicted slot can never corrupt frame 0.
PAGE_SENTINEL = 2 ** 30


def paged_kind(cfg: ModelConfig, kind: str) -> bool:
    """True for block kinds whose KV cache is block-paged in paged mode:
    global attention (and windowless "attn_local", which behaves
    identically).  Ring local-KV caches are already bounded by
    ``local_window`` and stay batch-major; SSM/RG-LRU states are O(1) per
    slot and have no sequence axis to page."""
    if kind == "attn":
        return True
    return kind == "attn_local" and cfg.local_window is None


def _block_cache_spec(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                      paged: bool = False, page_size: int = 0,
                      n_pages: int = 0):
    if paged and paged_kind(cfg, kind):
        shp = (n_pages, page_size, cfg.n_kv_heads, cfg.head_dim_)
        if cfg.kv_cache_dtype == "int8":
            sshp = (n_pages, page_size, cfg.n_kv_heads)
            return AttnCache(
                k=jax.ShapeDtypeStruct(shp, jnp.int8),
                v=jax.ShapeDtypeStruct(shp, jnp.int8),
                k_scale=jax.ShapeDtypeStruct(sshp, jnp.float32),
                v_scale=jax.ShapeDtypeStruct(sshp, jnp.float32))
        return AttnCache(k=jax.ShapeDtypeStruct(shp, cfg.dtype),
                         v=jax.ShapeDtypeStruct(shp, cfg.dtype))
    if kind == "mamba":
        dims = ssm.ssm_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_expand,
                            cfg.conv_k)
        return ssm.MambaState(
            conv=jax.ShapeDtypeStruct((batch, cfg.conv_k - 1, dims.d_inner),
                                      cfg.dtype),
            ssm=jax.ShapeDtypeStruct((batch, dims.d_inner, dims.d_state),
                                     jnp.float32))
    if kind == "rec":
        d_rnn = cfg.d_rnn or cfg.d_model
        return rglru.RglruState(
            conv=jax.ShapeDtypeStruct((batch, cfg.conv_k - 1, d_rnn),
                                      cfg.dtype),
            h=jax.ShapeDtypeStruct((batch, d_rnn), jnp.float32))
    if kind in ("attn", "attn_local"):
        seq = max_seq
        if kind == "attn_local" and cfg.local_window is not None:
            seq = min(max_seq, cfg.local_window)
        shp = (batch, seq, cfg.n_kv_heads, cfg.head_dim_)
        if cfg.kv_cache_dtype == "int8":
            sshp = (batch, seq, cfg.n_kv_heads)
            return AttnCache(
                k=jax.ShapeDtypeStruct(shp, jnp.int8),
                v=jax.ShapeDtypeStruct(shp, jnp.int8),
                k_scale=jax.ShapeDtypeStruct(sshp, jnp.float32),
                v_scale=jax.ShapeDtypeStruct(sshp, jnp.float32))
        return AttnCache(k=jax.ShapeDtypeStruct(shp, cfg.dtype),
                         v=jax.ShapeDtypeStruct(shp, cfg.dtype))
    raise ValueError(kind)


def _stack_sds(tree, n: int):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + tuple(s.shape), s.dtype), tree)


def _check_paged_dims(max_seq: int, page_size: int) -> int:
    if page_size < 1 or max_seq % page_size:
        raise ValueError(
            f"page_size {page_size} must divide max_seq {max_seq} "
            f"(pick a page_size dividing the bucket-rounded slot length)")
    return max_seq // page_size


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int,
                paged: bool = False, page_size: int = 16,
                n_pages: Optional[int] = None):
    """Abstract cache pytree (ShapeDtypeStructs).

    ``paged=True`` replaces each pageable KV leaf's per-slot contiguous
    rows (batch, max_seq, ...) with a SHARED page pool (n_pages,
    page_size, ...) and adds one top-level ``"page_table"`` leaf --
    (batch, max_seq // page_size) int32 physical frame ids shared by every
    layer (each layer's pool uses the same frame numbering, vLLM-style).
    ``n_pages`` defaults to ``batch * max_seq // page_size``: the same
    memory as the contiguous layout, but slots now borrow frames from one
    pool, so the serving scheduler can run more slots than
    ``n_pages // pages_per_slot`` whenever resident requests don't all
    need ``max_seq`` (serving/scheduler.PageAllocator)."""
    if not paged:
        page_size = n_total = 0
    else:
        pps = _check_paged_dims(max_seq, page_size)
        n_total = batch * pps if n_pages is None else int(n_pages)
    period = tuple(
        _stack_sds(_block_cache_spec(cfg, kind, batch, max_seq, paged,
                                     page_size, n_total), cfg.n_periods)
        for kind in cfg.block_pattern)
    rem = tuple(_block_cache_spec(cfg, kind, batch, max_seq, paged,
                                  page_size, n_total)
                for kind in cfg.remainder_pattern)
    out = {"period": period, "remainder": rem}
    if paged:
        out["page_table"] = jax.ShapeDtypeStruct(
            (batch, max_seq // page_size), jnp.int32)
    return out


def cache_logical_axes(cfg: ModelConfig, paged: bool = False):
    """Logical axes per cache leaf, mirroring cache_specs structure.

    Paged pool leaves carry a leading ``"pages"`` axis instead of
    ``"batch"`` -- the deploy row helpers key off that to pass pools
    through slot-row gathers untouched (the page table, not the pool, is
    what a slot owns)."""

    def block_axes(kind: str, stacked: bool):
        lead = ("layers",) if stacked else ()
        if kind == "mamba":
            return ssm.MambaState(conv=lead + ("batch", None, "act_mlp"),
                                  ssm=lead + ("batch", "act_mlp", None))
        if kind == "rec":
            return rglru.RglruState(conv=lead + ("batch", None, "act_mlp"),
                                    h=lead + ("batch", "act_mlp"))
        lead_kv = "pages" if paged and paged_kind(cfg, kind) else "batch"
        kv_axes = lead + (lead_kv, "kv_seq", "kv", None)
        sc_axes = lead + (lead_kv, "kv_seq", "kv")
        if cfg.kv_cache_dtype == "int8":
            return AttnCache(k=kv_axes, v=kv_axes,
                             k_scale=sc_axes, v_scale=sc_axes)
        return AttnCache(k=kv_axes, v=kv_axes)

    out = {"period": tuple(block_axes(k, True) for k in cfg.block_pattern),
           "remainder": tuple(block_axes(k, False)
                              for k in cfg.remainder_pattern)}
    if paged:
        out["page_table"] = ("batch", None)
    return out


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               paged: bool = False, page_size: int = 16,
               n_pages: Optional[int] = None):
    specs = cache_specs(cfg, batch, max_seq, paged=paged,
                        page_size=page_size, n_pages=n_pages)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)
    if paged:
        # all-zeros would alias every slot onto physical frame 0
        cache["page_table"] = jnp.full(specs["page_table"].shape,
                                       PAGE_SENTINEL, jnp.int32)
    return cache


# ---------------------------------------------------------------------------
# block forward (full sequence)
# ---------------------------------------------------------------------------

def _attn_forward(p, cfg: ModelConfig, x: jnp.ndarray, kind: str,
                  positions: jnp.ndarray,
                  return_kv: bool = False):
    b, s, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    hin = _apply_norm(p["ln"], cfg, x)
    q = dense(hin, p["wq"]) + (p.get("bq", 0) if cfg.use_bias else 0)
    k = dense(hin, p["wk"]) + (p.get("bk", 0) if cfg.use_bias else 0)
    v = dense(hin, p["wv"]) + (p.get("bv", 0) if cfg.use_bias else 0)
    q = shard_activation(q.reshape(b, s, h, dh),
                         ("batch", "act_seq", "act_heads", None))
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    window = cfg.local_window if kind == "attn_local" else None
    if cfg.flash_vjp:
        from .flash import flash_attention
        out = flash_attention(q, k, v, chunk=cfg.attn_chunk, window=window,
                              attn_softcap=cfg.attn_softcap)
    else:
        out = causal_blockwise_attention(
            q, k, v, chunk=cfg.attn_chunk, window=window,
            attn_softcap=cfg.attn_softcap)
    out = dense(out.reshape(b, s, h * dh), p["wo"]) \
        + (p.get("bo", 0) if cfg.use_bias else 0)
    y = x + out.astype(x.dtype)
    kv = (k, v) if return_kv else None
    return y, kv


def _mlp_forward(p, cfg: ModelConfig, x: jnp.ndarray):
    hin = _apply_norm(p["ln"], cfg, x)
    if cfg.moe is not None:
        pp = {k: v for k, v in p.items() if k != "ln"}
        from ..dist.sharding import active_mesh
        mesh = active_mesh()
        if (cfg.moe_impl == "a2a" and mesh is not None
                and "model" in mesh.shape
                and cfg.moe.n_experts % mesh.shape["model"] == 0):
            from .moe_shardmap import moe_ffn_a2a
            out, aux = moe_ffn_a2a(pp, hin, cfg.moe, mesh)
        else:
            out, aux = moe_ffn(pp, hin, cfg.moe, shard_fn=shard_activation,
                               token_chunks=cfg.moe_token_chunks)
        return x + out.astype(x.dtype), aux
    hmid = dense(hin, p["wi"]) + (p.get("bi", 0) if cfg.use_bias else 0)
    if cfg.gated_mlp:
        h1, h2 = jnp.split(hmid, 2, axis=-1)
        hmid = activation(cfg.activation, h1) * h2
    else:
        hmid = activation(cfg.activation, hmid)
    hmid = shard_activation(hmid, ("batch", "act_seq", "act_mlp"))
    out = dense(hmid, p["wo"]) + (p.get("bo", 0) if cfg.use_bias else 0)
    return x + out.astype(x.dtype), jnp.zeros((), jnp.float32)


def block_forward(p, cfg: ModelConfig, kind: str, x: jnp.ndarray,
                  positions: jnp.ndarray, return_cache: bool = False,
                  max_seq: int = 0):
    """One block, full sequence.  Returns (x, aux, cache_entry | None)."""
    aux = jnp.zeros((), jnp.float32)
    cache_entry = None
    if kind == "mamba":
        dims = ssm.ssm_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_expand,
                            cfg.conv_k)
        if return_cache:
            x, cache_entry = ssm.mamba_block(p["mamba"], x, dims,
                                             cfg.scan_chunk, return_state=True)
        else:
            x = ssm.mamba_block(p["mamba"], x, dims, cfg.scan_chunk)
        return x, aux, cache_entry
    if kind == "rec":
        if return_cache:
            x, cache_entry = rglru.rglru_block(p["rec"], x, cfg.scan_chunk,
                                               return_state=True)
        else:
            x = rglru.rglru_block(p["rec"], x, cfg.scan_chunk)
        x, aux = _mlp_forward(p["mlp"], cfg, x)
        return x, aux, cache_entry
    x, kv = _attn_forward(p["attn"], cfg, x, kind, positions,
                          return_kv=return_cache)
    if return_cache and kv is not None:
        k, v = kv
        s = k.shape[1]
        seq_cap = max_seq
        if kind == "attn_local" and cfg.local_window is not None:
            seq_cap = min(max_seq, cfg.local_window)
            k, v = k[:, -seq_cap:], v[:, -seq_cap:]
            if s >= seq_cap:
                # ring alignment: buffer[i] <- abs position p, p % cap == i
                shift = s % seq_cap
                k = jnp.roll(k, shift, axis=1)
                v = jnp.roll(v, shift, axis=1)
        pad = seq_cap - k.shape[1]
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if cfg.kv_cache_dtype == "int8":
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            cache_entry = AttnCache(k=kq, v=vq, k_scale=ks, v_scale=vs)
        else:
            cache_entry = AttnCache(k=k, v=v)
    x, aux = _mlp_forward(p["mlp"], cfg, x)
    return x, aux, cache_entry


# ---------------------------------------------------------------------------
# block decode (single token)
# ---------------------------------------------------------------------------

def _mask_rows(active: Optional[jnp.ndarray], new: jnp.ndarray,
               old: jnp.ndarray) -> jnp.ndarray:
    """Row-gated state update: keep ``old`` rows where ``active`` is False.

    ``active`` is the continuous-batching slot-liveness mask (serving/batch);
    None (the single-request / one-shot paths) means every row advances."""
    if active is None:
        return new
    m = active.reshape(active.shape + (1,) * (new.ndim - active.ndim))
    return jnp.where(m, new, old)


def block_decode(p, cfg: ModelConfig, kind: str, x: jnp.ndarray,
                 cache, lengths: jnp.ndarray,
                 active: Optional[jnp.ndarray] = None,
                 page_table: Optional[jnp.ndarray] = None,
                 write_floor: Optional[jnp.ndarray] = None):
    """One block, one token.  x: (B, d).  Returns (x, new_cache).

    ``active`` (optional (B,) bool) freezes the cache rows of dead slots:
    a padded continuous-batching step still computes every row (static
    shapes), but an inactive row's KV/conv/SSM state must not drift while
    the slot waits to be recycled.

    ``page_table`` ((B, P) int32, paged mode only): pageable KV leaves are
    shared pools -- the new token's K/V scatters to the slot's physical
    frame (inactive or unreserved rows route to the sentinel and drop)
    and attention reads page-table-indirect (Pallas kernel on TPU, XLA
    gather lowering elsewhere).

    ``write_floor`` (optional (B,) int32, paged mode only): the
    shared-prefix write guard -- positions below a row's floor live in
    refcount-shared frames other page tables map (copy-on-write prefix
    sharing, see docs/serving.md), so writes aimed there route to the
    sentinel and drop.  The READ path is unchanged: shared frames are
    ordinary page-table indirection."""
    if kind == "mamba":
        dims = ssm.ssm_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_expand,
                            cfg.conv_k)
        x, new_state = ssm.mamba_decode_step(p["mamba"], x, cache, dims)
        if active is not None:
            new_state = jax.tree.map(
                lambda n, o: _mask_rows(active, n, o), new_state, cache)
        return x, new_state
    if kind == "rec":
        x, new_state = rglru.rglru_decode_step(p["rec"], x, cache)
        if active is not None:
            new_state = jax.tree.map(
                lambda n, o: _mask_rows(active, n, o), new_state, cache)
        x, _ = _mlp_forward(p["mlp"], cfg, x[:, None, :])
        return x[:, 0], new_state

    # attention decode
    b, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ap = p["attn"]
    hin = _apply_norm(ap["ln"], cfg, x)
    q = dense(hin, ap["wq"]) + (ap.get("bq", 0) if cfg.use_bias else 0)
    k = dense(hin, ap["wk"]) + (ap.get("bk", 0) if cfg.use_bias else 0)
    v = dense(hin, ap["wv"]) + (ap.get("bv", 0) if cfg.use_bias else 0)
    q = q.reshape(b, h, dh)
    k = k.reshape(b, hkv, dh)
    v = v.reshape(b, hkv, dh)
    if cfg.pos_emb == "rope":
        q = apply_rope(q.reshape(b, 1, h, dh), lengths[:, None],
                       cfg.rope_theta).reshape(b, h, dh)
        k = apply_rope(k.reshape(b, 1, hkv, dh), lengths[:, None],
                       cfg.rope_theta).reshape(b, hkv, dh)
    # head-parallel decode: q follows the q-head shards, k/v follow the
    # KV pool's "kv" placement so the cache scatter stays local
    q = shard_activation(q, ("batch", "act_heads", None))
    k = shard_activation(k, ("batch", "kv", None))
    v = shard_activation(v, ("batch", "kv", None))

    if page_table is not None and paged_kind(cfg, kind):
        # paged KV: scatter the token into the slot's physical frame,
        # attend through the page table (same masking as contiguous)
        npg, ps = cache.k.shape[0], cache.k.shape[1]
        p_max = page_table.shape[1]
        logical = jnp.clip(lengths // ps, 0, p_max - 1)
        phys = jnp.take_along_axis(page_table, logical[:, None],
                                   axis=1)[:, 0]
        ok = lengths < p_max * ps
        if active is not None:
            ok &= active
        if write_floor is not None:
            ok &= lengths >= write_floor       # shared frames: read-only
        phys = jnp.where(ok, phys, jnp.int32(PAGE_SENTINEL))  # OOB -> drop
        off = lengths % ps
        window = cfg.local_window if kind == "attn_local" else None
        if cfg.kv_cache_dtype == "int8":
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            new_cache = AttnCache(
                k=cache.k.at[phys, off].set(kq),
                v=cache.v.at[phys, off].set(vq),
                k_scale=cache.k_scale.at[phys, off].set(ks),
                v_scale=cache.v_scale.at[phys, off].set(vs))
            out = paged_decode_attention(
                q.astype(cfg.dtype), new_cache.k, new_cache.v, page_table,
                lengths + 1, k_scale=new_cache.k_scale,
                v_scale=new_cache.v_scale, window=window,
                attn_softcap=cfg.attn_softcap)
        else:
            kc = cache.k.at[phys, off].set(k.astype(cache.k.dtype))
            vc = cache.v.at[phys, off].set(v.astype(cache.v.dtype))
            new_cache = AttnCache(k=kc, v=vc)
            out = paged_decode_attention(q, kc, vc, page_table,
                                         lengths + 1, window=window,
                                         attn_softcap=cfg.attn_softcap)
        out = shard_activation(out, ("batch", "act_heads", None))
        out = dense(out.reshape(b, h * dh), ap["wo"]) \
            + (ap.get("bo", 0) if cfg.use_bias else 0)
        x = x + out.astype(x.dtype)
        x, _ = _mlp_forward(p["mlp"], cfg, x[:, None, :])
        return x[:, 0], new_cache

    s_max = cache.k.shape[1]
    if kind == "attn_local" and cfg.local_window is not None \
            and s_max <= cfg.local_window:
        slot = lengths % s_max                       # ring buffer
    else:
        slot = jnp.minimum(lengths, s_max - 1)
    row = jnp.arange(b)

    def write(buf, new):
        """Write ``new`` at (row, slot), frozen for inactive rows.

        The gate gathers the old entry instead of where-ing the whole
        buffer, so the masked write touches one position per row."""
        return buf.at[row, slot].set(_mask_rows(active, new, buf[row, slot]))

    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        kc = write(cache.k, kq)
        vc = write(cache.v, vq)
        ksc = write(cache.k_scale, ks)
        vsc = write(cache.v_scale, vs)
        new_cache = AttnCache(k=kc, v=vc, k_scale=ksc, v_scale=vsc)
        # kvdec_vmem: on TPU the fused int8-KV flash-decode kernel
        # (kernels/flash_decode.py) streams the int8 cache and dequantizes
        # in VMEM; the XLA fallback below materializes the dequant, which
        # the roofline's scope rule discounts accordingly.
        with jax.named_scope("kvdec_vmem"):
            kd = _dequantize_kv(kc, ksc, cfg.dtype)   # per-layer transient
            vd = _dequantize_kv(vc, vsc, cfg.dtype)
    else:
        kc = write(cache.k, k.astype(cache.k.dtype))
        vc = write(cache.v, v.astype(cache.v.dtype))
        new_cache = AttnCache(k=kc, v=vc)
        kd, vd = kc, vc
    new_len = lengths + 1

    window = cfg.local_window if kind == "attn_local" else None
    if kind == "attn_local" and s_max <= (cfg.local_window or s_max):
        # ring buffer holds exactly the window; all valid entries attend
        valid = jnp.minimum(new_len, s_max)
        out = decode_attention(q, kd, vd, valid, window=None,
                               attn_softcap=cfg.attn_softcap)
    else:
        out = decode_attention(q, kd, vd, new_len, window=window,
                               attn_softcap=cfg.attn_softcap)
    out = shard_activation(out, ("batch", "act_heads", None))
    out = dense(out.reshape(b, h * dh), ap["wo"]) \
        + (ap.get("bo", 0) if cfg.use_bias else 0)
    x = x + out.astype(x.dtype)
    x, _ = _mlp_forward(p["mlp"], cfg, x[:, None, :])
    return x[:, 0], new_cache


# ---------------------------------------------------------------------------
# block append (chunked prefill: a W-token window into an existing cache)
# ---------------------------------------------------------------------------

def _append_attn(p, cfg: ModelConfig, kind: str, x: jnp.ndarray,
                 cache, lengths: jnp.ndarray, positions: jnp.ndarray,
                 valid: jnp.ndarray,
                 page_table: Optional[jnp.ndarray] = None,
                 write_floor: Optional[jnp.ndarray] = None):
    """Attention block over a (B, W) window appended at ``positions``.

    Global attention writes the whole window into the cache in one masked
    scatter (invalid window slots are routed out of bounds, so the scatter
    drops them -- no read-modify-write race with a valid write at the same
    index) and attends with the offset causal mask.  Sliding-window layers
    keep a ring cache (cache len == min(max_seq, local_window), see
    ``_block_cache_spec``) where later window tokens overwrite ring slots
    earlier queries still need, so they take a per-token ``lax.scan`` of
    exactly the ``block_decode`` write/attend step -- q/k/v are still
    computed window-parallel; only write+attend serializes."""
    b, w, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ap = p["attn"]
    hin = _apply_norm(ap["ln"], cfg, x)
    q = dense(hin, ap["wq"]) + (ap.get("bq", 0) if cfg.use_bias else 0)
    k = dense(hin, ap["wk"]) + (ap.get("bk", 0) if cfg.use_bias else 0)
    v = dense(hin, ap["wv"]) + (ap.get("bv", 0) if cfg.use_bias else 0)
    q = q.reshape(b, w, h, dh)
    k = k.reshape(b, w, hkv, dh)
    v = v.reshape(b, w, hkv, dh)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_activation(q, ("batch", "act_seq", "act_heads", None))
    k = shard_activation(k, ("batch", "act_seq", "kv", None))
    v = shard_activation(v, ("batch", "act_seq", "kv", None))

    if page_table is not None and paged_kind(cfg, kind):
        # paged KV: scatter the whole window into the seats' physical
        # frames (invalid slots route to the sentinel and drop), then
        # attend the page-table gather with the same offset-causal mask
        ps = cache.k.shape[1]
        p_max = page_table.shape[1]
        logical = jnp.clip(positions // ps, 0, p_max - 1)       # (B, W)
        phys = jnp.take_along_axis(page_table, logical, axis=1)
        ok = valid & (positions < p_max * ps)
        if write_floor is not None:
            ok &= positions >= write_floor[:, None]  # shared: read-only
        phys = jnp.where(ok, phys, jnp.int32(PAGE_SENTINEL))
        off = positions % ps

        def pwrite(buf, new):
            return buf.at[phys, off].set(new.astype(buf.dtype))

        if cfg.kv_cache_dtype == "int8":
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            new_cache = AttnCache(k=pwrite(cache.k, kq),
                                  v=pwrite(cache.v, vq),
                                  k_scale=pwrite(cache.k_scale, ks),
                                  v_scale=pwrite(cache.v_scale, vs))
            with jax.named_scope("kvdec_vmem"):
                kd = _dequantize_kv(
                    gather_pages(new_cache.k, page_table),
                    gather_pages(new_cache.k_scale, page_table), cfg.dtype)
                vd = _dequantize_kv(
                    gather_pages(new_cache.v, page_table),
                    gather_pages(new_cache.v_scale, page_table), cfg.dtype)
        else:
            new_cache = AttnCache(k=pwrite(cache.k, k),
                                  v=pwrite(cache.v, v))
            kd = gather_pages(new_cache.k, page_table)
            vd = gather_pages(new_cache.v, page_table)
        window = cfg.local_window if kind == "attn_local" else None
        out = append_attention(q, kd, vd, positions, window=window,
                               attn_softcap=cfg.attn_softcap)
        out = shard_activation(out, ("batch", "act_seq", "act_heads", None))
        out = dense(out.reshape(b, w, h * dh), ap["wo"]) \
            + (ap.get("bo", 0) if cfg.use_bias else 0)
        return x + out.astype(x.dtype), new_cache

    s_max = cache.k.shape[1]
    ring = (kind == "attn_local" and cfg.local_window is not None
            and s_max <= cfg.local_window)
    if not ring:
        # linear cache: one scatter for the whole window.  Invalid slots
        # scatter out of bounds (index s_max) and are dropped wholesale,
        # which also keeps them from colliding with a valid write clipped
        # to the same index.
        row = jnp.arange(b)[:, None]
        pos_w = jnp.where(valid, jnp.minimum(positions, s_max - 1), s_max)

        def write(buf, new):
            return buf.at[row, pos_w].set(new.astype(buf.dtype))

        if cfg.kv_cache_dtype == "int8":
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            new_cache = AttnCache(k=write(cache.k, kq), v=write(cache.v, vq),
                                  k_scale=write(cache.k_scale, ks),
                                  v_scale=write(cache.v_scale, vs))
            with jax.named_scope("kvdec_vmem"):
                kd = _dequantize_kv(new_cache.k, new_cache.k_scale, cfg.dtype)
                vd = _dequantize_kv(new_cache.v, new_cache.v_scale, cfg.dtype)
        else:
            new_cache = AttnCache(k=write(cache.k, k), v=write(cache.v, v))
            kd, vd = new_cache.k, new_cache.v
        window = cfg.local_window if kind == "attn_local" else None
        out = append_attention(q, kd, vd, positions, window=window,
                               attn_softcap=cfg.attn_softcap)
    else:
        # ring cache: per-token scan, one write + one decode-attend a step
        # (identical formulas to block_decode's ring branch)
        rowi = jnp.arange(b)

        def step(carry, xs):
            kv, cur_len = carry
            qi, ki, vi, vm = xs

            slot = cur_len % s_max

            def wr(buf, new):
                return buf.at[rowi, slot].set(
                    _mask_rows(vm, new.astype(buf.dtype), buf[rowi, slot]))

            if cfg.kv_cache_dtype == "int8":
                kq, ks = _quantize_kv(ki)
                vq, vs = _quantize_kv(vi)
                kv = AttnCache(k=wr(kv.k, kq), v=wr(kv.v, vq),
                               k_scale=wr(kv.k_scale, ks),
                               v_scale=wr(kv.v_scale, vs))
                with jax.named_scope("kvdec_vmem"):
                    kd = _dequantize_kv(kv.k, kv.k_scale, cfg.dtype)
                    vd = _dequantize_kv(kv.v, kv.v_scale, cfg.dtype)
            else:
                kv = AttnCache(k=wr(kv.k, ki), v=wr(kv.v, vi))
                kd, vd = kv.k, kv.v
            new_len = cur_len + vm.astype(cur_len.dtype)
            out_i = decode_attention(qi, kd, vd,
                                     jnp.minimum(new_len, s_max),
                                     window=None,
                                     attn_softcap=cfg.attn_softcap)
            return (kv, new_len), out_i

        (new_cache, _), outs = jax.lax.scan(
            step, (cache, lengths),
            (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1), valid.T))
        out = outs.swapaxes(0, 1)

    out = shard_activation(out, ("batch", "act_seq", "act_heads", None))
    out = dense(out.reshape(b, w, h * dh), ap["wo"]) \
        + (ap.get("bo", 0) if cfg.use_bias else 0)
    return x + out.astype(x.dtype), new_cache


def _append_recurrent(decode_fn, x: jnp.ndarray, state,
                      valid: jnp.ndarray):
    """Run a per-token decode step over the (B, W) window, advancing the
    recurrent state only for valid tokens (SSM / RG-LRU window append)."""

    def step(carry, xs):
        x_i, v_i = xs
        y_i, new_state = decode_fn(x_i, carry)
        new_state = jax.tree.map(lambda nn, oo: _mask_rows(v_i, nn, oo),
                                 new_state, carry)
        return new_state, y_i

    state, ys = jax.lax.scan(step, state, (x.swapaxes(0, 1), valid.T))
    return ys.swapaxes(0, 1), state


def block_append(p, cfg: ModelConfig, kind: str, x: jnp.ndarray,
                 cache, lengths: jnp.ndarray, positions: jnp.ndarray,
                 valid: jnp.ndarray,
                 page_table: Optional[jnp.ndarray] = None,
                 write_floor: Optional[jnp.ndarray] = None):
    """One block over a W-token window appended to an existing cache.

    x: (B, W, d); ``lengths``: (B,) tokens already in the cache (the
    window's position offset); ``positions``: (B, W) absolute positions;
    ``valid``: (B, W) bool -- False slots (padding past a row's chunk
    length, or rows whose slot is not being appended) compute junk but
    never touch cache/state, mirroring the ``active`` gate of
    ``block_decode``; ``write_floor`` is the paged shared-prefix write
    guard (see ``block_decode``).  Returns (x, new_cache_entry)."""
    if kind == "mamba":
        dims = ssm.ssm_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_expand,
                            cfg.conv_k)
        return _append_recurrent(
            lambda xi, st: ssm.mamba_decode_step(p["mamba"], xi, st, dims),
            x, cache, valid)
    if kind == "rec":
        x, new_state = _append_recurrent(
            lambda xi, st: rglru.rglru_decode_step(p["rec"], xi, st),
            x, cache, valid)
        x, _ = _mlp_forward(p["mlp"], cfg, x)
        return x, new_state
    x, new_cache = _append_attn(p, cfg, kind, x, cache, lengths, positions,
                                valid, page_table=page_table,
                                write_floor=write_floor)
    x, _ = _mlp_forward(p["mlp"], cfg, x)
    return x, new_cache


# ---------------------------------------------------------------------------
# whole-model forward / prefill / decode
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    if cfg.embeds_input:
        x = batch["embeds"].astype(cfg.dtype)
    else:
        x = embed_lookup(materialize(params["embed"]), batch["tokens"])
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if cfg.pos_emb == "learned":
        pos = batch["positions"]
        x = x + jnp.take(materialize(params["pos_embed"]), pos, axis=0)
    return shard_activation(x.astype(cfg.dtype),
                            ("batch", "act_seq", "act_embed"))


def _logits(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = _apply_norm(params["final_norm"], cfg, x)
    if cfg.tied_embeddings:
        w = materialize(params["embed"])
        logits = jnp.matmul(x, w.T.astype(x.dtype))
    else:
        logits = dense(x, params["lm_head"])
    logits = softcap(logits, cfg.logit_softcap)
    axes = ("batch", "act_seq", "act_vocab") if logits.ndim == 3 \
        else ("batch", "act_vocab")
    return shard_activation(logits, axes)


def _maybe_remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def forward(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence logits.  batch: tokens (B,S) or embeds (B,S,d),
    positions (B,S).  Returns (logits, aux_loss)."""
    x = _embed_inputs(params, cfg, batch)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                     x.shape[:2])

    def period_fn(carry, period_params):
        x, aux = carry
        for pos_i, kind in enumerate(cfg.block_pattern):
            x, a, _ = block_forward(period_params[pos_i], cfg, kind, x,
                                    positions)
            aux = aux + a
        x = shard_activation(x, ("batch", "act_seq", "act_embed"))
        return (x, aux), None

    step = _maybe_remat(period_fn, cfg)
    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                               params["period"])
    for rp, kind in zip(params["remainder"], cfg.remainder_pattern):
        x, a, _ = block_forward(rp, cfg, kind, x, positions)
        aux = aux + a
    return _logits(params, cfg, x), aux


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]
            ) -> jnp.ndarray:
    logits, aux = forward(params, cfg, batch)
    nll = cross_entropy(logits, batch["labels"], valid_vocab=cfg.vocab,
                        label_mask=batch.get("label_mask"))
    return nll + aux


def prefill(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            max_seq: int):
    """Process a full prompt, building the cache.  Returns
    (last-position logits (B, V), cache, lengths (B,)).

    ``batch["prompt_lengths"]`` (optional, (B,) int32) marks the true
    prompt length when the sequence axis is right-padded to a bucket (the
    engine pads to bound recompiles): logits are gathered at the true last
    position and the returned lengths are the true ones.  Padded positions
    beyond the prompt leave junk KV entries; decode overwrites slot
    ``lengths`` onward and attention masks by length, so they are inert.

    cfg.prefill_microbatch > 1 scans over batch slices so long-prompt
    activation transients scale with B/m while the returned cache is the
    full batch (microbatch caches are restitched along the batch axis)."""
    mb = cfg.prefill_microbatch
    b_total = (batch["embeds"] if cfg.embeds_input
               else batch["tokens"]).shape[0]
    if mb > 1 and b_total % mb == 0:
        split = jax.tree.map(
            lambda x: x.reshape((mb, b_total // mb) + x.shape[1:]), batch)
        logits, caches, lengths = jax.lax.map(
            lambda mbb: _prefill_once(params, _cfg_no_mb(cfg), mbb, max_seq),
            split)

        # restitch the microbatch axis into each cache leaf's batch axis
        def stitch(leaf, axes):
            bpos = axes.index("batch")
            moved = jnp.moveaxis(leaf, 0, bpos)           # (..., mb, B/mb, ..)
            return moved.reshape(moved.shape[:bpos] + (b_total,)
                                 + moved.shape[bpos + 2:])

        cache = jax.tree.map(stitch, caches, cache_logical_axes(cfg))
        return (logits.reshape(b_total, -1), cache,
                lengths.reshape(b_total))
    return _prefill_once(params, cfg, batch, max_seq)


def _cfg_no_mb(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, prefill_microbatch=1)


def _prefill_once(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
                  max_seq: int):
    x = _embed_inputs(params, cfg, batch)
    b, s = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def period_fn(carry, period_params):
        x = carry
        entries = []
        for pos_i, kind in enumerate(cfg.block_pattern):
            x, _, ce = block_forward(period_params[pos_i], cfg, kind, x,
                                     positions, return_cache=True,
                                     max_seq=max_seq)
            entries.append(ce)
        x = shard_activation(x, ("batch", "act_seq", "act_embed"))
        return x, tuple(entries)

    step = _maybe_remat(period_fn, cfg)
    x, period_cache = jax.lax.scan(step, x, params["period"])
    rem_cache = []
    for rp, kind in zip(params["remainder"], cfg.remainder_pattern):
        x, _, ce = block_forward(rp, cfg, kind, x, positions,
                                 return_cache=True, max_seq=max_seq)
        rem_cache.append(ce)
    plen = batch.get("prompt_lengths")
    if plen is None:
        logits = _logits(params, cfg, x[:, -1:, :])[:, 0]
        lengths = jnp.full((b,), s, jnp.int32)
    else:
        lengths = plen.astype(jnp.int32)
        idx = jnp.clip(lengths - 1, 0, s - 1)[:, None, None]
        x_last = jnp.take_along_axis(x, idx, axis=1)      # (B, 1, d)
        logits = _logits(params, cfg, x_last)[:, 0]
    cache = {"period": period_cache, "remainder": tuple(rem_cache)}
    return logits, cache, lengths


def prefill_chunk(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
                  cache, lengths: jnp.ndarray,
                  active: Optional[jnp.ndarray] = None,
                  write_floor: Optional[jnp.ndarray] = None,
                  all_logits: bool = False):
    """Incremental prefill: append a W-token prompt window into an
    EXISTING cache at each row's current length (the cache-append
    primitive under chunked prefill and k-way admission -- see
    docs/serving.md).

    ``batch``: tokens (B, W) or embeds (B, W, d); optional
    ``chunk_lengths`` (B,) int32 = valid tokens this window (0..W, default
    W -- rows may consume different amounts of one fused call); optional
    ``positions`` (B, W) absolute positions (default ``lengths + arange``,
    matching ``decode_step``'s use of ``lengths`` as the next position).

    ``active`` (optional (B,) bool) is the slot-liveness gate: inactive
    rows compute junk (shapes are static) but their cache rows, states and
    lengths are untouched, exactly like ``decode_step`` -- so one fused
    call can append windows to any subset of a resident slot batch.
    ``write_floor`` (optional (B,) int32, paged only) guards
    refcount-shared prefix frames against writes (see ``block_decode``).

    Returns (logits (B, V) at each row's last valid window position,
    new_cache, new_lengths).  With ``all_logits=True`` the logits are
    returned at every window position instead, shaped (B, W, V) --
    positions at or beyond ``chunk_lengths`` carry junk values the caller
    must mask (the speculative verify path consumes this).  Splitting a prompt into windows and feeding
    them through ``prefill_chunk`` yields the same cache/logits as one
    ``prefill`` call over the whole prompt (modulo fp summation order:
    window attention is an offset-masked softmax over the cache rather
    than the blockwise-online-softmax prefill uses)."""
    lengths = lengths.astype(jnp.int32)
    if cfg.embeds_input:
        x = batch["embeds"].astype(cfg.dtype)
    else:
        x = embed_lookup(materialize(params["embed"]), batch["tokens"])
    b, w = x.shape[:2]
    cl = batch.get("chunk_lengths")
    cl = (jnp.full((b,), w, jnp.int32) if cl is None
          else cl.astype(jnp.int32))
    if active is not None:
        cl = jnp.where(active, cl, 0)
    positions = batch.get("positions")
    if positions is None:
        positions = lengths[:, None] + jnp.arange(w, dtype=jnp.int32)[None]
    valid = jnp.arange(w)[None, :] < cl[:, None]

    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if cfg.pos_emb == "learned":
        x = x + jnp.take(materialize(params["pos_embed"]),
                         jnp.minimum(positions, cfg.max_position - 1),
                         axis=0)
    x = shard_activation(x.astype(cfg.dtype),
                         ("batch", "act_seq", "act_embed"))

    page_table = cache.get("page_table")

    def period_fn(x, xs):
        period_params, cache_slice = xs
        new_entries = []
        for pos_i, kind in enumerate(cfg.block_pattern):
            x, nc = block_append(period_params[pos_i], cfg, kind, x,
                                 cache_slice[pos_i], lengths, positions,
                                 valid, page_table=page_table,
                                 write_floor=write_floor)
            new_entries.append(nc)
        x = shard_activation(x, ("batch", "act_seq", "act_embed"))
        return x, tuple(new_entries)

    x, new_period = jax.lax.scan(period_fn, x,
                                 (params["period"], cache["period"]))
    new_rem = []
    for rp, kind, ce in zip(params["remainder"], cfg.remainder_pattern,
                            cache["remainder"]):
        x, nc = block_append(rp, cfg, kind, x, ce, lengths, positions,
                             valid, page_table=page_table,
                             write_floor=write_floor)
        new_rem.append(nc)
    if all_logits:
        logits = _logits(params, cfg, x)                  # (B, W, V)
    else:
        idx = jnp.clip(cl - 1, 0, w - 1)[:, None, None]
        x_last = jnp.take_along_axis(x, idx, axis=1)      # (B, 1, d)
        logits = _logits(params, cfg, x_last)[:, 0]
    new_cache = {"period": new_period, "remainder": tuple(new_rem)}
    if page_table is not None:
        new_cache["page_table"] = page_table
    return logits, new_cache, lengths + cl


def decode_step(params, cfg: ModelConfig, inputs: Dict[str, jnp.ndarray],
                cache, lengths: jnp.ndarray,
                active: Optional[jnp.ndarray] = None,
                write_floor: Optional[jnp.ndarray] = None):
    """One decode step.  inputs: token (B,) or embeds (B, d).
    Returns (logits (B, V), new_cache, new_lengths).

    ``active`` (optional (B,) bool) is the continuous-batching liveness
    mask: inactive rows still compute (shapes are static) but their cache
    rows and lengths are frozen, so a parked slot can be recycled later
    with no state drift.  ``active=None`` (default) advances every row --
    the one-shot/batch paths are unchanged.  ``write_floor`` (optional
    (B,) int32, paged only) guards refcount-shared prefix frames against
    writes (see ``block_decode``)."""
    if cfg.embeds_input:
        x = inputs["embeds"].astype(cfg.dtype)
    else:
        x = embed_lookup(materialize(params["embed"]), inputs["tokens"])
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if cfg.pos_emb == "learned":
        x = x + jnp.take(materialize(params["pos_embed"]),
                         jnp.minimum(lengths, cfg.max_position - 1), axis=0)
    x = shard_activation(x, ("batch", "act_embed"))

    page_table = cache.get("page_table")

    def period_fn(x, xs):
        period_params, cache_slice = xs
        new_entries = []
        for pos_i, kind in enumerate(cfg.block_pattern):
            x, nc = block_decode(period_params[pos_i], cfg, kind, x,
                                 cache_slice[pos_i], lengths, active=active,
                                 page_table=page_table,
                                 write_floor=write_floor)
            new_entries.append(nc)
        return x, tuple(new_entries)

    x, new_period = jax.lax.scan(period_fn, x,
                                 (params["period"], cache["period"]))
    new_rem = []
    for rp, kind, ce in zip(params["remainder"], cfg.remainder_pattern,
                            cache["remainder"]):
        x, nc = block_decode(rp, cfg, kind, x, ce, lengths, active=active,
                             page_table=page_table,
                             write_floor=write_floor)
        new_rem.append(nc)
    logits = _logits(params, cfg, x)
    new_cache = {"period": new_period, "remainder": tuple(new_rem)}
    if page_table is not None:
        new_cache["page_table"] = page_table
    if active is None:
        new_lengths = lengths + 1
    else:
        new_lengths = lengths + active.astype(lengths.dtype)
    return logits, new_cache, new_lengths
