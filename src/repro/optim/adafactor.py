"""Adafactor-style factored second moment (Shazeer & Stern, 1804.04235).

For a (.., K, N) weight the second moment is stored as row/col factors
(K + N numbers instead of K*N): with first moment in bf16 this cuts
optimizer state from 2x to ~1x of the parameter bytes -- the difference
between nemotron-4-340b fitting a single 256-chip v5e pod or not
(EXPERIMENTS.md SDry-run).  Vectors keep a full second moment.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .adamw import global_norm


class FactoredState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # first moment (bf16 by default)
    vr: Any          # row factor  (.., K) or full moment for vectors
    vc: Any          # col factor  (.., N) or zeros(0) for vectors


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    b1: float = 0.9
    decay: float = 0.99          # second-moment decay (paper uses schedule)
    eps: float = 1e-30
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: Any = jnp.bfloat16


def _factored(p) -> bool:
    return p.ndim >= 2


def init(params, cfg: AdafactorConfig = AdafactorConfig()) -> FactoredState:
    def vr_of(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def vc_of(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((0,), jnp.float32)

    return FactoredState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.moment_dtype),
                        params),
        vr=jax.tree.map(vr_of, params),
        vc=jax.tree.map(vc_of, params))


def update(grads, state: FactoredState, params, lr,
           cfg: AdafactorConfig = AdafactorConfig()
           ) -> Tuple[Any, FactoredState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        s = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * s.astype(g.dtype), grads)
    d = cfg.decay

    def upd(g, m, vr, vc, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + cfg.eps
        if _factored(p):
            vr_new = d * vr + (1 - d) * g2.mean(axis=-1)
            vc_new = d * vc + (1 - d) * g2.mean(axis=-2)
            denom = (vr_new[..., None] * vc_new[..., None, :]
                     / jnp.maximum(vr_new.mean(axis=-1)[..., None, None],
                                   cfg.eps))
            ghat = gf * jax.lax.rsqrt(denom + cfg.eps)
        else:
            vr_new = d * vr + (1 - d) * g2
            vc_new = vc
            ghat = gf * jax.lax.rsqrt(vr_new + cfg.eps)
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * ghat
        delta = m_new
        if cfg.weight_decay and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new.astype(cfg.moment_dtype), vr_new, vc_new

    def upd_leaf(i):
        return jax.tree.map(lambda g, m, vr, vc, p: upd(g, m, vr, vc, p)[i],
                            grads, state.mu, state.vr, state.vc, params)

    new_params = upd_leaf(0)
    new_mu = upd_leaf(1)
    new_vr = upd_leaf(2)
    new_vc = upd_leaf(3)
    return (new_params,
            FactoredState(step=step, mu=new_mu, vr=new_vr, vc=new_vc),
            {"grad_norm": gnorm, "step": step})


def state_specs(param_specs, cfg: AdafactorConfig = AdafactorConfig()):
    from ..models.module import ParamSpec, tree_map_specs

    def mu_of(s: ParamSpec):
        return ParamSpec(s.shape, s.logical_axes, cfg.moment_dtype, "zeros")

    def vr_of(s: ParamSpec):
        if len(s.shape) >= 2:
            return ParamSpec(s.shape[:-1], s.logical_axes[:-1],
                             jnp.float32, "zeros")
        return ParamSpec(s.shape, s.logical_axes, jnp.float32, "zeros")

    def vc_of(s: ParamSpec):
        if len(s.shape) >= 2:
            return ParamSpec(s.shape[:-2] + s.shape[-1:],
                             s.logical_axes[:-2] + s.logical_axes[-1:],
                             jnp.float32, "zeros")
        return ParamSpec((0,), (None,), jnp.float32, "zeros")

    return FactoredState(
        step=ParamSpec((), (), jnp.int32, "zeros"),
        mu=tree_map_specs(mu_of, param_specs),
        vr=tree_map_specs(vr_of, param_specs),
        vc=tree_map_specs(vc_of, param_specs))
