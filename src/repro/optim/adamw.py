"""Sharded AdamW with optional bf16 moments (no optax in this container).

Moments inherit each parameter's sharding (the update is elementwise, so
GSPMD keeps optimizer state ZeRO-sharded wherever params are FSDP-sharded).
bf16 moments halve optimizer memory -- required to fit nemotron-340B on
256 x 16 GB (see EXPERIMENTS.md SDry-run).  Skips HaloQuantized leaves --
PTQ'd params are frozen by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32     # bf16 for the >=100B archs
    clip_norm: Optional[float] = 1.0


def init(params, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def update(grads, state: AdamWState, params, lr,
           cfg: AdamWConfig = AdamWConfig()) -> Tuple[Any, AdamWState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:      # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new.astype(cfg.moment_dtype), v_new.astype(cfg.moment_dtype)

    def upd_leaf(g, m, v, p):
        # layer-stacked tensors update via lax.map over the stack so the
        # fp32 scratch is one layer-slice, not the whole stack (the ZeRO-
        # style chunked-optimizer trick; matters for the 100B+ archs).
        if p.ndim >= 3 and p.shape[0] >= 8:
            return jax.lax.map(lambda a: upd(*a), (g, m, v, p))
        return upd(g, m, v, p)

    # three passes (XLA CSE merges the shared math under jit); a tuple-typed
    # transpose would confuse pytrees that already contain tuples.
    new_params = jax.tree.map(lambda g, m, v, p: upd_leaf(g, m, v, p)[0],
                              grads, state.mu, state.nu, params)
    new_mu = jax.tree.map(lambda g, m, v, p: upd_leaf(g, m, v, p)[1],
                          grads, state.mu, state.nu, params)
    new_nu = jax.tree.map(lambda g, m, v, p: upd_leaf(g, m, v, p)[2],
                          grads, state.mu, state.nu, params)
    metrics = {"grad_norm": gnorm, "step": step}
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), metrics


def state_specs(param_specs, cfg: AdamWConfig = AdamWConfig()):
    """ParamSpec tree for the optimizer state (for dry-run abstract inputs)."""
    from ..models.module import ParamSpec, tree_map_specs

    def mom(s: ParamSpec):
        return ParamSpec(s.shape, s.logical_axes, cfg.moment_dtype, "zeros")

    return AdamWState(
        step=ParamSpec((), (), jnp.int32, "zeros"),
        mu=tree_map_specs(mom, param_specs),
        nu=tree_map_specs(mom, param_specs))
