"""PowerSGD gradient compression with error feedback (arXiv:1905.13727).

Cuts data-parallel all-reduce bytes by factor ~(K*N)/(r*(K+N)) per matrix:
instead of reducing G (K, N), workers reduce P = G Q (K, r) and
Q' = G^T P (N, r) -- two rank-r factors -- and reconstruct G_hat = P Q'^T.
The residual G - G_hat feeds back into the next step's gradient (error
feedback), preserving convergence.

Usage is shard_map-style data parallelism (see examples/compressed_dp.py):
the main GSPMD train path lets XLA place the all-reduces, and this module
provides the drop-in compressed reducer for DP axes where interconnect is
the bottleneck (e.g. the cross-pod "pod" axis over DCN).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class PowerSGDState(NamedTuple):
    q: Any        # per-matrix (N, r) iterate, warm-started across steps
    error: Any    # per-matrix error-feedback buffer (K, N)


@dataclasses.dataclass(frozen=True)
class PowerSGDConfig:
    rank: int = 4
    min_size: int = 16_384        # smaller tensors reduce uncompressed
    warm_start: bool = True


def _orthonormalize(m: jnp.ndarray) -> jnp.ndarray:
    """Gram-Schmidt columns (r is small; QR would also do)."""
    q, _ = jnp.linalg.qr(m.astype(jnp.float32))
    return q


def _compressible(g: jnp.ndarray, cfg: PowerSGDConfig) -> bool:
    return g.ndim >= 2 and g.size >= cfg.min_size


def init_state(grads, cfg: PowerSGDConfig = PowerSGDConfig(),
               key: Optional[jax.Array] = None) -> PowerSGDState:
    key = key if key is not None else jax.random.PRNGKey(17)
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))

    def one(g, k):
        if not _compressible(g, cfg):
            return jnp.zeros((0,), jnp.float32)
        n = g.reshape(g.shape[0], -1).shape[1] if g.ndim == 2 else \
            int(jnp.prod(jnp.asarray(g.shape[1:])))
        return jax.random.normal(k, (n, cfg.rank), jnp.float32)

    qs = [one(g, k) for g, k in zip(leaves, keys)]
    errs = [jnp.zeros(g.shape, jnp.float32) if _compressible(g, cfg)
            else jnp.zeros((0,), jnp.float32) for g in leaves]
    return PowerSGDState(q=jax.tree_util.tree_unflatten(treedef, qs),
                         error=jax.tree_util.tree_unflatten(treedef, errs))


def compressed_mean(grads, state: PowerSGDState, axis_name: str,
                    cfg: PowerSGDConfig = PowerSGDConfig()
                    ) -> Tuple[Any, PowerSGDState]:
    """Inside shard_map over `axis_name`: mean-reduce grads with PowerSGD.

    Returns (reduced grads identical on all members, new state).
    """
    nmem = jax.lax.psum(1, axis_name)

    def one(g, q, e):
        if not _compressible(g, cfg):
            return jax.lax.pmean(g, axis_name), q, e
        shape = g.shape
        g2 = g.reshape(shape[0], -1).astype(jnp.float32) + e.reshape(
            shape[0], -1)
        p = g2 @ q                                   # (K, r)
        p = jax.lax.psum(p, axis_name) / nmem
        p = _orthonormalize(p)
        q_new = g2.T @ p                             # (N, r)
        q_new = jax.lax.psum(q_new, axis_name) / nmem
        g_hat = p @ q_new.T
        err = (g2 - g_hat)                           # local error feedback
        return (g_hat.reshape(shape).astype(g.dtype),
                q_new if cfg.warm_start else q,
                err.reshape(shape))

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_q = jax.tree_util.tree_flatten(state.q)[0]
    flat_e = jax.tree_util.tree_flatten(state.error)[0]
    outs = [one(g, q, e) for g, q, e in zip(flat_g, flat_q, flat_e)]
    g_out = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    q_out = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    e_out = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
    return g_out, PowerSGDState(q=q_out, error=e_out)


def compression_ratio(grads, cfg: PowerSGDConfig = PowerSGDConfig()) -> float:
    """Bytes(un-compressed) / bytes(compressed) for reporting."""
    full = compressed = 0
    for g in jax.tree.leaves(grads):
        full += g.size
        if _compressible(g, cfg):
            k = g.shape[0]
            n = g.size // k
            compressed += cfg.rank * (k + n)
        else:
            compressed += g.size
    return full / max(compressed, 1)
