"""Baseline PTQ methods the paper compares against (RTN, SmoothQuant, GPTQ,
ZeroQuant) plus shared quantization primitives and activation calibration."""

from . import calibrate, common, gptq, rtn, smoothquant, zeroquant  # noqa: F401
