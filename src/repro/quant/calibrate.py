"""Activation calibration: record per-input-channel statistics at each dense.

SmoothQuant needs per-channel activation absmax; GPTQ needs the input Gram
matrix H = E[x x^T].  The recorder keys statistics by the identity of the
weight leaf (stable in eager mode); run the model *unjitted* on a few
calibration batches inside `recording(params)`, then translate to param
paths with `stats_by_path`.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


class Recorder:
    """Keys statistics by (stacked-param path, layer index)."""

    def __init__(self, collect_gram: bool = False):
        self.absmax: Dict[tuple, np.ndarray] = {}
        self.gram: Dict[tuple, np.ndarray] = {}
        self.count: Dict[tuple, int] = {}
        self.collect_gram = collect_gram
        self._id_to_key: Dict[int, tuple] = {}

    def register(self, tree, path_prefix: str, layer: Optional[int]) -> None:
        """Map concrete leaf ids -> (path, layer) before a block executes."""
        from ..core.apply import _path_str
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            full = (f"{path_prefix}/{_path_str(path)}"
                    if path_prefix else _path_str(path))
            self._id_to_key[id(leaf)] = (full, layer)

    def record(self, wid: int, x: jnp.ndarray) -> None:
        key = self._id_to_key.get(wid)
        if key is None:
            return
        xf = np.asarray(jax.device_get(x), np.float32).reshape(-1, x.shape[-1])
        am = np.abs(xf).max(axis=0)
        if key in self.absmax:
            self.absmax[key] = np.maximum(self.absmax[key], am)
            self.count[key] += xf.shape[0]
        else:
            self.absmax[key] = am
            self.count[key] = xf.shape[0]
        if self.collect_gram:
            g = xf.T @ xf
            self.gram[key] = self.gram.get(key, 0.0) + g


class _Ctx(threading.local):
    def __init__(self):
        self.rec: Optional[Recorder] = None


_CTX = _Ctx()


@contextlib.contextmanager
def recording(collect_gram: bool = False):
    rec = Recorder(collect_gram)
    prev = _CTX.rec
    _CTX.rec = rec
    try:
        yield rec
    finally:
        _CTX.rec = prev


def maybe_record(w: Any, x: jnp.ndarray) -> None:
    rec = _CTX.rec
    if rec is None or isinstance(x, jax.core.Tracer):
        return
    try:
        wid = id(w)
    except Exception:
        return
    if hasattr(x, "shape") and x.ndim >= 2:
        rec.record(wid, x)


def calibrated_forward(params, cfg, batch):
    """Forward pass with layer scans unrolled in Python so the recorder sees
    concrete per-layer weights (inside lax.scan everything is a tracer and
    nothing records).  Numerically identical to transformer.forward."""
    from ..models import transformer as T
    rec = _CTX.rec
    assert rec is not None, "use inside calibrate.recording()"

    x = T._embed_inputs(params, cfg, batch)
    rec.register({k: v for k, v in params.items()
                  if k not in ("period", "remainder")}, "", None)
    b, s = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        import jax.numpy as jnp
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    layer = 0
    for i in range(cfg.n_periods):
        for p_i, kind in enumerate(cfg.block_pattern):
            block = jax.tree.map(lambda l: l[i], params["period"][p_i])
            rec.register(block, f"period/{p_i}", i)
            x, _, _ = T.block_forward(block, cfg, kind, x, positions)
            layer += 1
    for rp, kind in zip(params["remainder"], cfg.remainder_pattern):
        rec.register(rp, "remainder", None)
        x, _, _ = T.block_forward(rp, cfg, kind, x, positions)
    return T._logits(params, cfg, x)


def stats_by_path(rec: Recorder, params) -> Dict[str, Dict[str, Any]]:
    """Aggregate recorded stats: per stacked-param path, a merged view
    (absmax: max over layers; gram: count-weighted mean) plus per-layer
    entries under "layers" for slice-wise quantizers."""
    out: Dict[str, Dict[str, Any]] = {}
    for (path, layer), am in rec.absmax.items():
        entry = out.setdefault(path, {"layers": {}})
        entry["absmax"] = (np.maximum(entry["absmax"], am)
                          if "absmax" in entry else am)
        sub = {"absmax": am, "count": rec.count[(path, layer)]}
        if (path, layer) in rec.gram:
            g = rec.gram[(path, layer)] / max(rec.count[(path, layer)], 1)
            sub["gram"] = g
            if "gram" in entry:
                entry["gram"] = entry["gram"] + g
                entry["_gram_n"] = entry["_gram_n"] + 1
            else:
                entry["gram"] = g.copy()
                entry["_gram_n"] = 1
        if layer is not None:
            entry["layers"][layer] = sub
    for entry in out.values():
        if "_gram_n" in entry:
            entry["gram"] = entry["gram"] / entry.pop("_gram_n")
    return out
