"""Shared uniform quantization primitives for the baseline methods.

All baselines here are *fake-quant* for accuracy evaluation (quantize ->
dequantize in fp32), matching how the paper compares perplexities; deployment
kernels live in kernels/.  Activation A8 is per-token dynamic symmetric,
toggled through a context so every `layers.dense` call picks it up.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def symmetric_scale(w: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    qmax = 2.0 ** (bits - 1) - 1
    absmax = jnp.abs(w).max() if axis is None else jnp.abs(w).max(
        axis=axis, keepdims=True)
    return jnp.maximum(absmax, 1e-12) / qmax


def quantize_symmetric(w: jnp.ndarray, bits: int, axis=None
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (int levels, scale). axis: reduction axes for per-channel scales."""
    scale = symmetric_scale(w, bits, axis)
    qmin, qmax = -(2.0 ** (bits - 1)), 2.0 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(w / scale), qmin, qmax)
    return q, scale


def fake_quant_symmetric(w: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    q, scale = quantize_symmetric(w, bits, axis)
    return q * scale


def quantize_asymmetric(w: jnp.ndarray, bits: int, axis=None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """-> (uint levels, scale, zero_point)."""
    if axis is None:
        lo, hi = w.min(), w.max()
    else:
        lo = w.min(axis=axis, keepdims=True)
        hi = w.max(axis=axis, keepdims=True)
    qmax = 2.0 ** bits - 1
    scale = jnp.maximum(hi - lo, 1e-12) / qmax
    zp = jnp.round(-lo / scale)
    q = jnp.clip(jnp.round(w / scale) + zp, 0, qmax)
    return q, scale, zp


def fake_quant_asymmetric(w: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    q, scale, zp = quantize_asymmetric(w, bits, axis)
    return (q - zp) * scale


def fake_quant_act_per_token(x: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """Per-token (last-dim grouped) dynamic symmetric activation quant."""
    qmax = 2.0 ** (bits - 1) - 1
    absmax = jnp.abs(x).max(axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    return (q * scale).astype(x.dtype)


# --- activation-quant context (read by layers.dense at trace time) ---------

class _ActQuantCtx(threading.local):
    def __init__(self):
        self.bits: Optional[int] = None


_ACT_CTX = _ActQuantCtx()


@contextlib.contextmanager
def activations_quantized(bits: Optional[int] = 8):
    prev = _ACT_CTX.bits
    _ACT_CTX.bits = bits
    try:
        yield
    finally:
        _ACT_CTX.bits = prev


def maybe_quantize_activation(x: jnp.ndarray) -> jnp.ndarray:
    if _ACT_CTX.bits is None:
        return x
    return fake_quant_act_per_token(x, _ACT_CTX.bits)
