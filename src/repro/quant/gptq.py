"""GPTQ (arXiv:2210.17323) baseline: Hessian-guided error-compensating
weight quantization.

For each weight matrix W (K, N) with layer-input Gram H = E[x x^T] (K, K),
quantize input-rows one at a time in blocks; after quantizing row k, the
remaining rows absorb the scaled quantization error via the Cholesky factor
of the (damped) inverse Hessian -- the standard GPTQ recursion, offline in
numpy (quantization is a one-time cost).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.apply import _path_str, default_should_quantize


def gptq_quantize_matrix(w: np.ndarray, gram: Optional[np.ndarray],
                         bits: int, block: int = 128,
                         percdamp: float = 0.01) -> np.ndarray:
    """w: (K, N) fp32; gram: (K, K) E[x x^T] or None (falls back to identity,
    which degenerates to RTN with error feedback along rows)."""
    k, n = w.shape
    wq = w.copy().astype(np.float64)
    h = (gram.astype(np.float64).copy() if gram is not None
         else np.eye(k))
    # dead input channels
    dead = np.diag(h) <= 0
    h[dead, dead] = 1.0
    wq[dead, :] = 0.0
    damp = percdamp * float(np.mean(np.diag(h)))
    h[np.diag_indices(k)] += max(damp, 1e-8)

    # per-output-channel symmetric scale from the original weights
    qmax = 2.0 ** (bits - 1) - 1
    scale = np.maximum(np.abs(w).max(axis=0), 1e-12) / qmax   # (N,)

    # inverse Hessian Cholesky (upper)
    hinv = np.linalg.inv(h)
    # enforce symmetry for numerical stability
    hinv = (hinv + hinv.T) / 2.0
    try:
        u = np.linalg.cholesky(hinv).T        # upper triangular
    except np.linalg.LinAlgError:
        hinv += np.eye(k) * (1e-6 * np.trace(hinv) / k)
        u = np.linalg.cholesky(hinv).T

    for b0 in range(0, k, block):
        b1 = min(b0 + block, k)
        w_blk = wq[b0:b1].copy()
        err_blk = np.zeros_like(w_blk)
        for i in range(b1 - b0):
            kk = b0 + i
            d = u[kk, kk]
            q = np.clip(np.round(w_blk[i] / scale), -qmax - 1, qmax)
            dq = q * scale
            err = (w_blk[i] - dq) / d
            # compensate remaining rows inside the block
            if i + 1 < b1 - b0:
                w_blk[i + 1:] -= np.outer(u[kk, b0 + i + 1:b1], err)
            err_blk[i] = err
            w_blk[i] = dq
        wq[b0:b1] = w_blk
        # propagate block error to all later rows
        if b1 < k:
            wq[b1:] -= u[b0:b1, b1:].T @ err_blk
    # final clamp to the grid (rows were compensated after being quantized
    # only within later blocks; re-round everything once for safety)
    wq = np.clip(np.round(wq / scale), -qmax - 1, qmax) * scale
    return wq.astype(np.float32)


def gptq_params(params: Any, act_stats: Dict[str, Dict], bits: int,
                should_quantize=None) -> Any:
    sq = should_quantize or default_should_quantize
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        pstr = _path_str(path)
        if not sq(pstr, leaf):
            out.append(leaf)
            continue
        stats = act_stats.get(pstr, {})
        gram = stats.get("gram")
        w = np.asarray(jax.device_get(leaf), np.float32)
        if w.ndim == 2:
            wq = gptq_quantize_matrix(w, gram, bits)
        else:
            layers = stats.get("layers", {})
            w2 = w.reshape((-1,) + w.shape[-2:])
            wq = np.stack([
                gptq_quantize_matrix(
                    w2[j], layers.get(j, {}).get("gram", gram), bits)
                for j in range(w2.shape[0])]).reshape(w.shape)
        out.append(jnp.asarray(wq, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
