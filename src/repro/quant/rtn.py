"""Round-To-Nearest (RTN) WxA8 baseline: per-output-channel symmetric weight
quantization at x in {8, 4, 3} bits; activations A8 via the shared context."""

from __future__ import annotations

from typing import Any, Optional

import jax.numpy as jnp

from ..core.apply import default_should_quantize, _path_str
from .common import fake_quant_symmetric
import jax


def rtn_quantize_tensor(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Per-output-channel (last dim) symmetric RTN."""
    reduce_axes = tuple(range(w.ndim - 1))
    return fake_quant_symmetric(w.astype(jnp.float32), bits,
                                axis=reduce_axes).astype(w.dtype)


def rtn_quantize_params(params: Any, bits: int,
                        should_quantize=None) -> Any:
    sq = should_quantize or default_should_quantize
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        if sq(_path_str(path), leaf):
            out.append(rtn_quantize_tensor(leaf, bits))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)
