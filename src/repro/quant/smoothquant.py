"""SmoothQuant (arXiv:2211.10438) baseline.

Per-input-channel smoothing factor s_j = absmax_act_j^alpha /
absmax_weight_j^(1-alpha) migrates activation outliers into the weights
(W' = diag(s) W, X' = X diag(s)^-1); weights then quantize per-channel at
x bits, activations at 8.  We fold the smoothing into the weights and apply
RTN -- the equivalent fake-quant formulation for accuracy studies (the
activation-side 1/s fold merges into the previous layer at deployment; for
evaluation the error model is identical because the pair is mathematically
a no-op before quantization).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.apply import _path_str, default_should_quantize
from .common import fake_quant_symmetric


def smooth_and_quantize_tensor(w: jnp.ndarray, act_absmax: np.ndarray,
                               bits: int, alpha: float = 0.5) -> jnp.ndarray:
    """w: (..., K, N) with input channels on axis -2."""
    wf = w.astype(jnp.float32)
    w_absmax = jnp.abs(wf).max(axis=-1, keepdims=True)        # (..., K, 1)
    a = jnp.asarray(act_absmax, jnp.float32).reshape(
        (1,) * (w.ndim - 2) + (-1, 1))
    s = jnp.clip(a ** alpha / jnp.maximum(w_absmax, 1e-6) ** (1 - alpha),
                 1e-4, 1e4)
    w_s = wf * s
    q = fake_quant_symmetric(w_s, bits, axis=tuple(range(w.ndim - 1)))
    # evaluation-side: smoothing is folded back (X' = X/s at deployment)
    return (q / s).astype(w.dtype)


def smoothquant_params(params: Any, act_stats: Dict[str, Dict],
                       bits: int, alpha: float = 0.5,
                       should_quantize=None) -> Any:
    sq = should_quantize or default_should_quantize
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        pstr = _path_str(path)
        if not sq(pstr, leaf):
            out.append(leaf)
            continue
        stats = act_stats.get(pstr)
        if stats is None:
            # no activation stats recorded (e.g. never executed): plain RTN
            out.append(fake_quant_symmetric(
                leaf.astype(jnp.float32), bits,
                axis=tuple(range(leaf.ndim - 1))).astype(leaf.dtype))
            continue
        if leaf.ndim == 2:
            out.append(smooth_and_quantize_tensor(leaf, stats["absmax"],
                                                  bits, alpha))
            continue
        # layer-stacked: per-slice smoothing with per-layer stats when
        # available (calibrate.calibrated_forward records them)
        lead = leaf.shape[:-2]
        w2 = leaf.reshape((-1,) + leaf.shape[-2:])
        layers = stats.get("layers", {})
        slices = []
        for j in range(w2.shape[0]):
            am = layers.get(j, stats)["absmax"]
            slices.append(smooth_and_quantize_tensor(w2[j], am, bits, alpha))
        out.append(jnp.stack(slices).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)
