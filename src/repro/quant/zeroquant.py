"""ZeroQuant baselines (arXiv:2206.01861, AAAI'24 LoRC study).

ZQ-Local: fine-grained quantization on t x t tiles (128x128 in the paper)
with per-tile scale and zero-point, compensation ratio 1.0.
ZQ-Global: fuses groups of 64 input channels and applies a global
compensation factor 0.8 per tile's scale to reduce calibration complexity.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..core import tiling
from ..core.apply import _path_str, default_should_quantize
from .common import quantize_asymmetric


def zq_local_tensor(w: jnp.ndarray, bits: int, tile: int = 128,
                    compensation: float = 1.0) -> jnp.ndarray:
    """Per-tile asymmetric quantization with per-tile (scale, zp)."""
    wf = w.astype(jnp.float32)
    tiles = tiling.to_tiles(wf, tile)                 # (n, t, t)
    q, scale, zp = quantize_asymmetric(tiles, bits, axis=(1, 2))
    deq = (q - zp) * (scale * compensation)
    return tiling.from_tiles(deq, wf.shape, tile).astype(w.dtype)


def zq_global_tensor(w: jnp.ndarray, bits: int, group: int = 64,
                     compensation: float = 0.8) -> jnp.ndarray:
    """Channel-group quantization: fuse `group` input rows per scale.

    The global compensation factor rescales each group's reconstruction by
    a least-squares-optimal scalar, damped by `compensation` toward 1 --
    a deployable per-group constant (folds into the stored scale):
      c* = <w, deq> / <deq, deq>;  w_hat = (1 + comp*(c*-1)) * deq
    """
    wf = w.astype(jnp.float32)
    k, n = wf.shape
    pad = (-k) % group
    wp = jnp.pad(wf, ((0, pad), (0, 0)))
    g = wp.reshape(-1, group, n)
    q, scale, zp = quantize_asymmetric(g, bits, axis=(1,))
    deq = (q - zp) * scale
    num = (g * deq).sum(axis=1, keepdims=True)
    den = (deq * deq).sum(axis=1, keepdims=True)
    c_ls = jnp.clip(num / jnp.maximum(den, 1e-12), 0.5, 1.5)
    deq = deq * (1.0 + compensation * (c_ls - 1.0))
    return deq.reshape(k + pad, n)[:k].astype(w.dtype)


def _map_tensor(fn, params, should_quantize=None):
    sq = should_quantize or default_should_quantize
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        if not sq(_path_str(path), leaf):
            out.append(leaf)
            continue
        if leaf.ndim == 2:
            out.append(fn(leaf))
        else:
            w2 = leaf.reshape((-1,) + leaf.shape[-2:])
            out.append(jnp.stack([fn(w2[j]) for j in range(w2.shape[0])]
                                 ).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def zq_local_params(params: Any, bits: int, tile: int = 128,
                    should_quantize=None) -> Any:
    return _map_tensor(lambda w: zq_local_tensor(w, bits, tile), params,
                       should_quantize)


def zq_global_params(params: Any, bits: int, group: int = 64,
                     should_quantize=None) -> Any:
    return _map_tensor(lambda w: zq_global_tensor(w, bits, group), params,
                       should_quantize)
