"""Serving: bucketed-prefill engine, packed HALO fast path, the
continuous-batching scheduler, and the hardware-in-the-loop autotuner
(see docs/serving.md)."""

from .engine import Engine, SamplerConfig, serve_step
from .scheduler import Request, Scheduler
from .tuning import EngineKnobs, TunedConfig

__all__ = ["Engine", "SamplerConfig", "serve_step", "Request", "Scheduler",
           "EngineKnobs", "TunedConfig", "autotune"]


def __getattr__(name):
    # the autotuner imports benchmarking-ish deps (time, itertools) and the
    # engine; keep it lazy so `import repro.serving` stays light
    if name == "autotune":
        from . import autotune as _autotune
        return _autotune
    raise AttributeError(name)
