"""Serving: bucketed-prefill engine, packed HALO fast path, and the
continuous-batching scheduler (see docs/serving.md)."""

from .engine import Engine, SamplerConfig, serve_step
from .scheduler import Request, Scheduler

__all__ = ["Engine", "SamplerConfig", "serve_step", "Request", "Scheduler"]
