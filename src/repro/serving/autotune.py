"""Hardware-in-the-loop autotuner + per-layer DVFS planner for the engine.

Closing the loop the paper leaves open: the `hw/` models (Booth-Wallace MAC
timing LUTs, DVFS operating points, the systolic-array roofline) price
serving configurations, and the serving stack *measures* them.

Search.  The engine/kernel knob space (``EngineKnobs``: decode ``chunk``,
``admit_k``, paged ``page_size``, ``prefill_chunk_width``, speculative
``spec_k``, Pallas ``block_m``) is enumerated from a ``SearchSpace`` grid,
strict-validated against the engine geometry, and pruned by an analytic
cost model built on the hw/ stack: ``systolic.simulate_layers`` over the
packed tree's *measured* weight-class composition
(``deploy.layer_class_composition`` reads classes back off the 4-bit index
streams), plus host-side terms for the engine's one-sync-per-tick contract,
fused-admission dispatches and paged-gather indirection.  Only the
model-plausible top-N candidates are timed: each probe replays a short
seeded trace through the real ``Engine.submit``/``drain`` path (warm-up
replay, then best-of-repeats wall clock).  The default knobs are always
probed too and win ties, so the tuned config never regresses on the probe;
every candidate's emitted tokens must match the first candidate's exactly
(knobs schedule work, they must never change tokens) or the tuner raises.

DVFS.  Per layer, the packed index stream gives each matmul's true tile
class mix; ``dvfs.plan_for_classes`` turns that into the executed
class-grouped schedule (transitions = distinct classes - 1 per matmul),
the fastest safe operating points, and the frequency headroom over the
hardware-agnostic F1 clock, while ``systolic.simulate_matmul`` prices a
decode token's modeled time/energy per layer -- reported next to measured
tokens/s in the ``TunedConfig`` artifact and BENCH_serving.json.
"""

from __future__ import annotations

import dataclasses
import itertools
import os
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..configs.base import ModelConfig
from ..core import deploy
from ..hw import dvfs as hw_dvfs
from ..hw import systolic
from ..utils import next_pow2, round_up
from .engine import Engine, SamplerConfig
from .tuning import EngineKnobs, TunedConfig


class AutotuneError(RuntimeError):
    """A candidate config changed emitted tokens (scheduling knobs must be
    semantics-free) or the probe protocol was violated."""


def host_info() -> Dict[str, Any]:
    """Host/context fingerprint stored in artifacts and bench reports."""
    import platform

    devs = jax.devices()
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": len(devs),
        "devices": sorted({d.device_kind for d in devs}),
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python_version": platform.python_version(),
        "cpu_count": os.cpu_count(),
    }


# ---------------------------------------------------------------------------
# search space
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Grid of knob values the tuner may combine.

    Empty axes pin the base value.  ``page_size`` only varies for paged
    candidates; ``spec_k`` values each add a speculative candidate arm on
    top of the non-speculative grid.  ``block_m`` is Pallas-only: the
    CPU/XLA lowering carries it inert, so the default space leaves it
    unset off-TPU."""

    chunk: Tuple[int, ...] = (4, 8, 16)
    admit_k: Tuple[int, ...] = (2, 4)
    paged: Tuple[bool, ...] = (False, True)
    page_size: Tuple[int, ...] = (8, 16)
    prefill_chunk_width: Tuple[Optional[int], ...] = (None, 32)
    block_m: Tuple[Optional[int], ...] = (None,)
    spec_k: Tuple[int, ...] = ()

    @classmethod
    def smoke(cls) -> "SearchSpace":
        """Tiny CI-budget space: a handful of candidates, still crossing
        the paged/contiguous and tick-length axes."""
        return cls(chunk=(4, 8), admit_k=(2,), paged=(False, True),
                   page_size=(8,), prefill_chunk_width=(None,),
                   block_m=(None,), spec_k=())

    def candidates(self, base: EngineKnobs) -> List[EngineKnobs]:
        """Expand the grid around ``base`` (always included)."""
        def axis(vals, fallback):
            return tuple(vals) if vals else (fallback,)

        out = {base}
        spec_arms = [(False, base.spec_k)] + [
            (True, int(k)) for k in self.spec_k]
        for chunk, admit_k, paged, width, bm, (spec, sk) in itertools.product(
                axis(self.chunk, base.chunk),
                axis(self.admit_k, base.admit_k),
                axis(self.paged, base.paged),
                axis(self.prefill_chunk_width, base.prefill_chunk_width),
                axis(self.block_m, base.block_m),
                spec_arms):
            for page_size in (axis(self.page_size, base.page_size)
                              if paged else (base.page_size,)):
                out.add(dataclasses.replace(
                    base, chunk=chunk, admit_k=admit_k, paged=paged,
                    page_size=page_size, prefill_chunk_width=width,
                    block_m=bm, speculative=spec, spec_k=sk))
        return sorted(out, key=_knob_key)


def _knob_key(kn: EngineKnobs) -> Tuple:
    return (kn.chunk, kn.admit_k, kn.paged, kn.page_size,
            kn.prefill_chunk_width or 0, kn.speculative, kn.spec_k,
            kn.block_m or 0)


def knob_label(kn: EngineKnobs) -> str:
    parts = [f"chunk={kn.chunk}", f"admit_k={kn.admit_k}"]
    parts.append(f"paged(ps={kn.page_size})" if kn.paged else "contig")
    if kn.prefill_chunk_width is not None:
        parts.append(f"width={kn.prefill_chunk_width}")
    if kn.speculative:
        parts.append(f"spec_k={kn.spec_k}")
    if kn.block_m is not None:
        parts.append(f"bm={kn.block_m}")
    return ",".join(parts)


# ---------------------------------------------------------------------------
# probe traces
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ProbeSpec:
    """Probe-trace protocol: short seeded requests replayed through the
    real Engine.submit/step/drain path, all arriving at t=0 (the tuner
    measures steady-state engine throughput, not arrival shaping)."""

    n_requests: int = 6
    prompt_len: Tuple[int, int] = (4, 20)
    max_new: Tuple[int, int] = (4, 16)
    seed: int = 0
    repeats: int = 2

    @classmethod
    def smoke(cls) -> "ProbeSpec":
        return cls(n_requests=4, prompt_len=(4, 12), max_new=(4, 8),
                   repeats=1)


def make_probe_trace(spec: ProbeSpec, vocab: int
                     ) -> List[Tuple[np.ndarray, int]]:
    """Seeded [(prompt tokens, max_new)] -- deterministic per spec.seed."""
    rng = np.random.default_rng(spec.seed)
    trace = []
    for _ in range(spec.n_requests):
        s = int(rng.integers(spec.prompt_len[0], spec.prompt_len[1] + 1))
        mn = int(rng.integers(spec.max_new[0], spec.max_new[1] + 1))
        toks = rng.integers(0, vocab, size=s, dtype=np.int64)
        trace.append((toks, mn))
    return trace


def _trace_stats(trace: Sequence[Tuple[np.ndarray, int]]) -> Dict[str, int]:
    return {
        "n_requests": len(trace),
        "total_prompt": int(sum(len(t) for t, _ in trace)),
        "total_new": int(sum(mn for _, mn in trace)),
        "longest": int(max(len(t) + mn for t, mn in trace)),
    }


# ---------------------------------------------------------------------------
# analytic cost model (the pruning stage)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HostModel:
    """Host-side serving costs coupling the systolic roofline to the
    engine's tick structure.  Coarse by design: the model only has to rank
    candidates well enough that the measured probe sees the right top-N.

    sync_s: scheduler tick + device->host token readback (one per tick).
    admit_s: per fused admission / prefill-append dispatch.
    page_gather_tokens: paged-decode indirection (frame-DMA setup), in
      token-equivalents per page -- smaller pages pay it more often.
    spec_accept: assumed draft acceptance rate for speculative arms.
    """

    sync_s: float = 3e-4
    admit_s: float = 2e-4
    page_gather_tokens: float = 2.0
    spec_accept: float = 0.5


def modeled_tokens_per_s(knobs: EngineKnobs, *, cfg: ModelConfig,
                         capacity: int, prefill_bucket: int,
                         comp_counts: Dict[str, int],
                         stats: Dict[str, int],
                         host: HostModel = HostModel(),
                         domain: hw_dvfs.DvfsDomain = hw_dvfs.SYSTOLIC_DOMAIN,
                         ) -> Dict[str, float]:
    """Roofline + MAC-timing estimate of probe-trace tokens/s for a knob
    setting; used to prune the grid before anything is measured."""
    scheme = systolic.scheme_from_class_counts(comp_counts)
    live = max(min(capacity, stats["n_requests"]), 1)

    # one decode step over the live batch, priced by the systolic sim over
    # the model's real layer shapes and measured class mix
    shapes = systolic.decoder_layer_shapes(
        cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.padded_vocab,
        seq=1, batch=live, gated=cfg.gated_mlp)
    step = systolic.simulate_layers(shapes, scheme)
    bm_eff = min(knobs.block_m or 128, max(8, next_pow2(live)))
    pad_rows = -(-live // bm_eff) * bm_eff
    t_compute = step.compute_time_s * (pad_rows / live)
    t_step = max(t_compute, step.memory_time_s) + step.spmv_time_s
    if knobs.paged:
        t_step *= 1.0 + host.page_gather_tokens / knobs.page_size

    tok_per_step = 1.0
    if knobs.speculative and knobs.spec_k > 0:
        # half-stack self-draft per drafted token + full-model verify of
        # the k+1 window; acceptance folds expected commits per step
        t_step *= 1.0 + 0.5 * knobs.spec_k
        tok_per_step = 1.0 + host.spec_accept * knobs.spec_k

    steps = stats["total_new"] / (live * tok_per_step)
    ticks = max(steps / knobs.chunk, 1.0)
    decode_s = steps * t_step + ticks * host.sync_s

    # prefill: fused k-way admission then chunk_width-token windows
    width = knobs.prefill_chunk_width
    if width is None:
        width = max(4 * prefill_bucket, 64)
    width = round_up(max(int(width), 1), max(prefill_bucket, 1))
    pre_shapes = systolic.decoder_layer_shapes(
        cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.padded_vocab,
        seq=width, batch=1, gated=cfg.gated_mlp)
    t_window = systolic.simulate_layers(pre_shapes, scheme).time_s
    admits = -(-stats["n_requests"] // max(min(knobs.admit_k, capacity), 1))
    # every prompt pays ceil(len/width) windows; the first rides admission
    extra_windows = max(stats["total_prompt"] / width - stats["n_requests"],
                        0.0)
    prefill_s = (admits + extra_windows) * (t_window + host.admit_s)

    total_s = decode_s + prefill_s
    return {
        "tokens_per_s": stats["total_new"] / total_s,
        "decode_s": decode_s,
        "prefill_s": prefill_s,
        "t_step_s": t_step,
    }


# ---------------------------------------------------------------------------
# hardware-in-the-loop measurement
# ---------------------------------------------------------------------------


def measure_knobs(params, cfg: ModelConfig, knobs: EngineKnobs, *,
                  capacity: int, max_seq: int, prefill_bucket: int,
                  trace: Sequence[Tuple[np.ndarray, int]],
                  repeats: int = 2,
                  sampler: SamplerConfig = SamplerConfig()) -> Dict[str, Any]:
    """Measured tokens/s for one knob setting on the probe trace.

    Builds a real engine and replays the trace through submit/drain: one
    warm-up replay compiles every shape, then ``repeats`` timed replays
    keep the best wall clock.  Returns the emitted tokens too so the tuner
    can assert token-identity across candidates."""
    eng = Engine(params, cfg, sampler=sampler, capacity=capacity,
                 max_seq=max_seq, prefill_bucket=prefill_bucket,
                 decode_bucket=16,
                 tuned=TunedConfig(knobs=knobs))

    def replay():
        t0 = time.perf_counter()
        rids = [eng.submit({"tokens": toks}, max_new=mn)
                for toks, mn in trace]
        done = eng.drain()
        dt = time.perf_counter() - t0
        out = [np.asarray(done[r]).tolist() for r in rids]
        eng.pop_finished()              # drop bookkeeping between replays
        return dt, out

    replay()                                  # warm: compile once
    best, tokens = float("inf"), None
    for _ in range(max(int(repeats), 1)):
        dt, toks = replay()
        if dt < best:
            best = dt
        tokens = toks
    total_new = sum(len(t) for t in tokens)
    return {"wall_s": best, "tokens_per_s": total_new / best,
            "total_new": total_new, "tokens": tokens}


# ---------------------------------------------------------------------------
# per-layer DVFS schedule
# ---------------------------------------------------------------------------


def dvfs_layer_report(params, cfg: ModelConfig,
                      domain: hw_dvfs.DvfsDomain = hw_dvfs.SYSTOLIC_DOMAIN,
                      tile: int = 128) -> Dict[str, Any]:
    """Per-layer DVFS schedule from the packed weight-class composition.

    For every layer (and the packed unembed head, ``layer=null``): the
    executed class-grouped schedule's transition count (summed over the
    layer's matmuls -- each matmul pays distinct-classes-1), the fastest
    safe operating points and tile-weighted achievable frequency/headroom
    (``dvfs.plan_for_classes``), and the modeled decode-token time/energy
    (``systolic.simulate_matmul`` at m=1 over the measured mix).  Totals
    compare against an F1 deployment of the same shapes -- the clock a
    hardware-agnostic 4-bit deployment would be stuck at."""
    comp = deploy.layer_class_composition(params, cfg)
    layers = []
    tot_e = tot_t = tot_e_f1 = tot_t_f1 = 0.0
    tot_trans = 0
    f_weighted = tiles_total = 0
    f1_scheme = systolic.scheme_from_class_counts({"F1": 1})
    for rec in comp:
        if not rec["leaves"]:
            layers.append({"layer": rec["layer"], "pattern": rec["pattern"],
                           "n_tiles": 0, "counts": {}, "dvfs_transitions": 0})
            continue
        all_cls = np.concatenate([l["classes"] for l in rec["leaves"]])
        plan = hw_dvfs.plan_for_classes(all_cls, domain=domain)
        transitions = sum(
            max(int(np.unique(l["classes"]).size) - 1, 0)
            for l in rec["leaves"])
        e = t = e_f1 = t_f1 = 0.0
        for l in rec["leaves"]:
            k, n = l["shape"]
            ids, cnt = np.unique(l["classes"], return_counts=True)
            counts = {hw_dvfs_name(i): int(c)
                      for i, c in zip(ids.tolist(), cnt.tolist())}
            scheme = systolic.scheme_from_class_counts(counts)
            r = systolic.simulate_matmul(1, k, n, scheme, tile=tile,
                                         domain=domain)
            rf1 = systolic.simulate_matmul(1, k, n, f1_scheme, tile=tile,
                                           domain=domain)
            e, t = e + r.energy_j, t + r.time_s
            e_f1, t_f1 = e_f1 + rf1.energy_j, t_f1 + rf1.time_s
        layers.append({
            "layer": rec["layer"], "pattern": rec["pattern"],
            "n_tiles": rec["n_tiles"], "counts": rec["counts"],
            "dvfs_transitions": transitions,
            "points": {nm: {"voltage_v": p.voltage_v, "freq_ghz": p.freq_ghz}
                       for nm, p in plan["points"].items()},
            "achievable_freq_ghz": round(plan["achievable_freq_ghz"], 4),
            "freq_headroom": round(plan["freq_headroom"], 4),
            "modeled_time_s_per_token": t,
            "modeled_energy_j_per_token": e,
        })
        tot_e, tot_t = tot_e + e, tot_t + t
        tot_e_f1, tot_t_f1 = tot_e_f1 + e_f1, tot_t_f1 + t_f1
        tot_trans += transitions
        f_weighted += plan["achievable_freq_ghz"] * rec["n_tiles"]
        tiles_total += rec["n_tiles"]
    nominal = min(domain.points, key=lambda p: p.freq_ghz).freq_ghz
    mean_f = (f_weighted / tiles_total) if tiles_total else nominal
    return {
        "domain": domain.name,
        "nominal_freq_ghz": nominal,
        "layers": layers,
        "totals": {
            "n_tiles": int(tiles_total),
            "dvfs_transitions": int(tot_trans),
            "mean_achievable_freq_ghz": round(mean_f, 4),
            "mean_freq_headroom": round(mean_f / nominal, 4),
            "modeled_energy_j_per_token": tot_e,
            "modeled_time_s_per_token": tot_t,
            "modeled_speedup_vs_f1": (tot_t_f1 / tot_t) if tot_t else 1.0,
            "modeled_energy_ratio_vs_f1": (tot_e / tot_e_f1) if tot_e_f1
            else 1.0,
        },
    }


def hw_dvfs_name(cls_id: int) -> str:
    from ..hw import mac_model
    return mac_model.ID_TO_CLASS[int(cls_id)]


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------


def autotune(params, cfg: ModelConfig, *,
             capacity: int = 4,
             max_seq: Optional[int] = None,
             prefill_bucket: int = 8,
             space: Optional[SearchSpace] = None,
             probe: Optional[ProbeSpec] = None,
             n_probe: int = 4,
             base: Optional[EngineKnobs] = None,
             sampler: SamplerConfig = SamplerConfig(),
             host: HostModel = HostModel(),
             domain: hw_dvfs.DvfsDomain = hw_dvfs.SYSTOLIC_DOMAIN,
             verbose: bool = False) -> TunedConfig:
    """Tune the serving knobs against measured tokens/s; emit TunedConfig.

    ``params`` is the packed serving tree (``deploy.pack_params`` output).
    Model-implausible candidates are pruned before measurement; the default
    knobs are always measured and win ties, so the result never regresses
    on the probe trace.  Raises ``AutotuneError`` if any candidate changes
    emitted tokens."""
    space = space or SearchSpace()
    probe = probe or ProbeSpec()
    trace = make_probe_trace(probe, cfg.vocab)
    stats = _trace_stats(trace)
    if max_seq is None:
        max_seq = round_up(stats["longest"], max(prefill_bucket, 1))
    # clamp the defaults to this engine geometry (e.g. admit_k > a small
    # capacity) so "never regress vs defaults" compares against the knobs
    # the engine would actually run with
    base = (base or EngineKnobs()).validated(
        capacity=capacity, max_seq=round_up(max_seq, max(prefill_bucket, 1)),
        prefill_bucket=prefill_bucket, strict=False)

    comp = deploy.layer_class_composition(params, cfg)
    comp_counts: Dict[str, int] = {}
    for rec in comp:
        for nm, c in rec["counts"].items():
            comp_counts[nm] = comp_counts.get(nm, 0) + c

    # --- enumerate + strict-validate + model-prune --------------------
    rounded_seq = round_up(max_seq, max(prefill_bucket, 1))
    table = []
    for kn in space.candidates(base):
        try:
            kn.validated(capacity=capacity, max_seq=rounded_seq,
                         prefill_bucket=prefill_bucket, strict=True)
        except ValueError as e:
            table.append({"knobs": kn.to_dict(), "label": knob_label(kn),
                          "invalid": str(e)})
            continue
        m = modeled_tokens_per_s(
            kn, cfg=cfg, capacity=capacity, prefill_bucket=prefill_bucket,
            comp_counts=comp_counts, stats=stats, host=host, domain=domain)
        table.append({"knobs": kn.to_dict(), "label": knob_label(kn),
                      "modeled_tokens_per_s": m["tokens_per_s"],
                      "modeled": m, "candidate": kn})
    valid = [r for r in table if "candidate" in r]
    valid.sort(key=lambda r: -r["modeled_tokens_per_s"])
    keep = valid[:max(int(n_probe), 1)]
    if not any(r["candidate"] == base for r in keep):
        base_row = next((r for r in valid if r["candidate"] == base), None)
        if base_row is None:
            raise AutotuneError(
                "base knobs failed strict validation for this engine "
                "geometry; pass a compatible base= to autotune()")
        keep.append(base_row)

    # --- measure the survivors through the real engine ----------------
    oracle_tokens = None
    for row in keep:
        meas = measure_knobs(
            params, cfg, row["candidate"], capacity=capacity,
            max_seq=max_seq, prefill_bucket=prefill_bucket, trace=trace,
            repeats=probe.repeats, sampler=sampler)
        if oracle_tokens is None:
            oracle_tokens = meas["tokens"]
        elif meas["tokens"] != oracle_tokens:
            raise AutotuneError(
                f"candidate {row['label']} changed emitted tokens -- "
                f"tuning knobs must be semantics-free")
        row["measured_tokens_per_s"] = meas["tokens_per_s"]
        row["measured_wall_s"] = meas["wall_s"]
        if verbose:
            print(f"[autotune] {row['label']:48s} "
                  f"modeled {row['modeled_tokens_per_s']:8.1f} "
                  f"measured {meas['tokens_per_s']:8.1f} tok/s")

    base_row = next(r for r in keep if r["candidate"] == base)
    best_row = max(keep, key=lambda r: r["measured_tokens_per_s"])
    if best_row["measured_tokens_per_s"] <= base_row["measured_tokens_per_s"]:
        best_row = base_row                   # never regress vs defaults

    for row in table:                         # JSON-safe telemetry
        row.pop("candidate", None)

    return TunedConfig(
        knobs=EngineKnobs.from_dict(best_row["knobs"]),
        model=cfg.name,
        backend=jax.default_backend(),
        capacity=int(capacity),
        max_seq=int(max_seq),
        prefill_bucket=int(prefill_bucket),
        seed=probe.seed,
        probe={
            "protocol": dataclasses.asdict(probe),
            "trace": stats,
            "n_candidates": len(table),
            "n_measured": len(keep),
            "winner": best_row["label"],
            "default": {
                "label": base_row["label"],
                "measured_tokens_per_s": base_row["measured_tokens_per_s"],
            },
            "measured_tokens_per_s": best_row["measured_tokens_per_s"],
            "speedup_vs_default": (best_row["measured_tokens_per_s"]
                                   / base_row["measured_tokens_per_s"]),
            "candidates": table,
            "class_counts": comp_counts,
        },
        dvfs=dvfs_layer_report(params, cfg, domain=domain),
        meta=host_info(),
    )
