"""Device-side slot state for continuous-batching serving.

The scheduler (serving/scheduler.py) owns request bookkeeping on the host;
this module owns everything that lives on device: the slot-major decode
state (last token, per-slot length, per-slot PRNG stream, the KV/SSM/conv
caches batched over slots) and the jitted updates the scheduler drives it
with --

  ``prefill_append``  one fused call that appends a W-token prompt window
                      into up to K slots' cache rows at their current
                      lengths (chunked prefill + k-way admission in one
                      jit target; seats that complete their prompt sample
                      their first token on device)
  ``evict_slot``      zero a finished row so recycling never sees stale
                      state
  ``decode_chunk``    a ``lax.scan`` of ``n_steps`` decode steps with
                      per-slot liveness gating (remaining-token budget and
                      EOS stop evaluated on device, mid-chunk)

All decode shapes are fixed by (capacity, max_seq, chunk); prefill shapes
by (K, W) where K is the admission seat count and W ranges over the
bounded window-width bucket set -- requests coming and going never trigger
a recompile.  Inactive rows still compute each step (static shapes) but
their cache rows, lengths, keys and last token are frozen by the
``active`` gate threaded through ``T.decode_step`` / ``T.prefill_chunk``.

Paged mode (``init_slots(..., paged=True)``) swaps the per-slot
contiguous KV rows for shared page pools plus a per-slot page table; the
jitted updates are unchanged except that admission installs the slot's
allocator-assigned frames via ``set_page_row`` and the fresh prefill
path re-pages its dense rows (``deploy.cache_rows_scatter_dense``).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core import deploy
from ..models import transformer as T


class SlotState(NamedTuple):
    """Everything the decode loop carries, batched over capacity slots."""

    tok: jnp.ndarray       # (B,) int32  last emitted token per slot
    lengths: jnp.ndarray   # (B,) int32  tokens currently in the cache
    keys: jnp.ndarray      # (B, 2) uint32  per-slot PRNG streams
    cache: Any             # model cache pytree, batch axis = capacity


def init_slots(cfg: ModelConfig, capacity: int, max_seq: int,
               paged: bool = False, page_size: int = 16,
               n_pages: Optional[int] = None) -> SlotState:
    return SlotState(
        tok=jnp.zeros((capacity,), jnp.int32),
        lengths=jnp.zeros((capacity,), jnp.int32),
        keys=jnp.zeros((capacity, 2), jnp.uint32),
        cache=T.init_cache(cfg, capacity, max_seq, paged=paged,
                           page_size=page_size, n_pages=n_pages))


def slots_logical_axes(cfg: ModelConfig, paged: bool = False) -> SlotState:
    """Logical axes per SlotState leaf (mirrors ``init_slots`` structure).

    Host-scheduler-owned per-slot vectors (last token, lengths, PRNG
    streams) and the paged page table carry the ``"batch"`` axis; cache
    leaves follow ``cache_logical_axes`` -- paged pools lead with
    ``"pages"`` (no rule: replicated frame axis) and shard their KV-head
    dim on ``"kv"``, so a TP mesh splits every pool by heads while the
    page-table indirection stays whole on each device."""
    return SlotState(tok=("batch",), lengths=("batch",),
                     keys=("batch", None),
                     cache=T.cache_logical_axes(cfg, paged=paged))


def shard_slots(state: SlotState, cfg: ModelConfig, mesh, rules=None,
                paged: bool = False) -> SlotState:
    """Lay the slot state out on ``mesh`` by its logical axes.

    Done once at executor construction; the jitted append/decode updates
    then keep every leaf on its placement (their outputs inherit the
    constrained shardings), so no per-tick resharding happens."""
    from ..dist import sharding as sh
    axes = slots_logical_axes(cfg, paged=paged)
    return jax.tree.map(
        lambda x, ax: sh.shard_array(x, ax, mesh, rules), state, axes)


def set_page_row(state: SlotState, slot, row: jnp.ndarray,
                 length=0) -> SlotState:
    """Install a slot's page-table row ((P,) int32 physical frame ids,
    sentinel-padded past the reservation) -- the device half of paged
    admission: the host allocator picks the frames, this writes them.

    ``length`` seeds the slot's resident token count; admission with a
    shared prefix passes the skip (the prefix tokens are already IN the
    mapped frames, so the first append window must offset past them).
    Plain admissions pass 0 (the eviction default, re-asserted)."""
    pt = state.cache["page_table"].at[slot].set(row.astype(jnp.int32))
    return state._replace(
        lengths=state.lengths.at[slot].set(jnp.asarray(length, jnp.int32)),
        cache={**state.cache, "page_table": pt})


def copy_frame(state: SlotState, src, dst, *, cfg: ModelConfig) -> SlotState:
    """Duplicate physical frame ``src`` into ``dst`` across every paged
    pool leaf (no page-table change) -- the data half of fork-on-write.
    Admission uses it when a shared prefix must be re-entered (the
    re-run window writes into the last shared page, so that page is
    forked into a private frame before the row is installed)."""
    return state._replace(
        cache=deploy.cache_page_copy(cfg, state.cache, src, dst))


def fork_page(state: SlotState, slot, logical, src, dst, *,
              cfg: ModelConfig) -> SlotState:
    """Full copy-on-write fork: duplicate frame ``src`` into ``dst`` and
    remap the SINGLE page-table entry ``(slot, logical)`` to the copy.
    The sharer's page table still maps ``src`` -- its subsequent reads
    and tokens are untouched (bystander isolation, asserted in
    tests/test_serving_fuzz.py)."""
    cache = deploy.cache_page_copy(cfg, state.cache, src, dst)
    pt = cache["page_table"].at[slot, logical].set(
        jnp.asarray(dst, jnp.int32))
    return state._replace(cache={**cache, "page_table": pt})


# ---------------------------------------------------------------------------
# weight resolution + decode inputs (shared with the one-shot engine loop)
# ---------------------------------------------------------------------------

def predecode(params, cfg: ModelConfig):
    """Backend-resolve packed weights at jit entry.

    TPU: identity -- every matmul streams the 4-bit HaloPacked layout
    through the Pallas kernel (weight HBM reads /4 vs bf16, per token).

    CPU (no Mosaic): decode each packed stream ONCE, so the token loop
    multiplies dense weights instead of re-decoding 4-bit codes every
    token.  Weights at rest stay 4-bit; the dense copies are transients of
    the call (the continuous executor resolves once per engine and keeps
    the result resident for the scheduler's lifetime -- see
    docs/serving.md)."""
    from ..kernels import ops as kops
    if not kops.default_interpret():
        return params

    def dec(w):
        if isinstance(w, kops.HaloPacked):
            return w.dequantize(cfg.dtype)
        return w

    return jax.tree.map(dec, params,
                        is_leaf=lambda x: isinstance(x, kops.HaloPacked))


def decode_inputs(tok: jnp.ndarray, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    if cfg.embeds_input:
        # stub frontends: feed the token back through a fixed
        # pseudo-embedding (hash of the token id)
        return {"embeds": pseudo_embed(tok, cfg)}
    return {"tokens": tok}


def pseudo_embed(tok: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Deterministic stand-in embedding for stub-frontend decode loops."""
    d = cfg.d_model
    phase = (tok[:, None].astype(jnp.float32) + 1.0) \
        * jnp.arange(1, d + 1, dtype=jnp.float32)[None, :]
    return jnp.sin(phase * 0.01).astype(cfg.dtype)


# ---------------------------------------------------------------------------
# sampling (per-slot PRNG streams)
# ---------------------------------------------------------------------------

def mask_vocab(logits: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """fp32 logits with padded vocab columns masked out (shared by every
    sampling path -- one-shot batch, legacy, and per-slot streams)."""
    lf = logits.astype(jnp.float32)
    col = jnp.arange(lf.shape[-1])
    return jnp.where(col >= cfg.vocab, -1e30, lf)


def sample_rows(logits: jnp.ndarray, cfg: ModelConfig, sampler,
                keys: jnp.ndarray) -> jnp.ndarray:
    """(B, V) logits + (B, 2) per-row keys -> (B,) token ids.

    Unlike the one-shot batch loop (one key per step shared by the whole
    batch), every slot samples from its own stream, keyed by request id at
    admission -- a request's temperature sequence is reproducible no
    matter which slot it lands in or what its neighbors do."""
    lf = mask_vocab(logits, cfg)
    if sampler.temperature <= 0.0:
        return jnp.argmax(lf, axis=-1).astype(jnp.int32)
    draw = jax.vmap(
        lambda k, l: jax.random.categorical(k, l / sampler.temperature))
    return draw(keys, lf).astype(jnp.int32)


def request_key(seed: int, rid: int) -> jax.Array:
    """Per-request PRNG stream root (slot-placement independent)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), rid)


# ---------------------------------------------------------------------------
# jitted slot updates
# ---------------------------------------------------------------------------

def prefill_append(params, state: SlotState, slots, window, chunk_lens,
                   total_lens, seat, rids, first,
                   write_floor: Optional[jnp.ndarray] = None, *,
                   cfg: ModelConfig, sampler, fresh: bool = False,
                   max_seq: int = 0, all_logits: bool = False
                   ) -> Tuple[SlotState, jnp.ndarray, jnp.ndarray]:
    """Fused k-way chunked-prefill admission: append one W-token prompt
    window to up to K slots in a single jit call.

    ``slots``: (K,) int32 slot row per seat -- padded seats carry an
    out-of-range id (>= capacity) and ``seat`` False, so every write
    scatters to nowhere (order-safe no-op; see deploy.cache_rows_scatter).
    ``window``: {"tokens": (K, W)} (or "embeds"/"positions") -- the next
    window of each seat's prompt, right-padded to W;
    ``chunk_lens``: (K,) int32 valid tokens this window;
    ``total_lens``: (K,) int32 full prompt length;
    ``rids``: (K,) int32 request ids -- each seat's PRNG stream root
    (``request_key(sampler.seed, rid)``) is derived ON DEVICE and
    installed on its ``first`` chunk (admission), then carried in slot
    state across chunks (no per-admission host key sync).
    ``write_floor`` (optional (K,) int32): per-seat first writable
    position -- the shared-prefix scatter guard (paged mode): positions
    below a seat's floor live in refcount-shared frames another page
    table maps, so their writes are routed out of bounds and dropped.
    Correct flows never aim a write below the floor (appends start at
    the seat's length >= floor); the guard makes a bug corrupt the
    buggy request instead of its sharers.

    Two internal strategies behind one contract:

    ``fresh=True`` (static; the caller promises every seat is a FIRST
    window covering its WHOLE prompt -- the dominant short-prompt case):
    the window runs through the one-shot ``T.prefill`` -- blockwise
    O(W*chunk) attention over a fresh ``max_seq``-sized cache, no row
    gather (an admitted slot's rows are always zeroed by eviction) -- and
    the K rows scatter in.  Token-for-token identical to the historical
    batch-1 prefill+insert admission, just k seats per call.

    ``fresh=False``: gathers the K seats' cache rows
    (deploy.cache_rows_gather), appends the window via ``T.prefill_chunk``
    at each row's current length -- compute scales with K seats, not
    capacity -- and scatters the rows back.

    Seats whose append reaches ``total_lens`` are ``done``: they sample
    their first token from the final window logits with their own PRNG
    stream (one split, exactly like the old one-shot admission, so a
    request's sample sequence is unchanged).

    Returns (new_state, tok0 (K,) int32, done (K,) bool); ``tok0`` is
    meaningful only where ``done``.  With ``all_logits=True`` (static;
    the engine's scoring path) the return grows a fourth element: the
    full-window logits (K, W, V) from ``T.prefill_chunk(all_logits=
    True)`` -- positions at or beyond ``chunk_lens`` carry junk the
    caller must mask.  Scoring always appends (``fresh=True`` with
    ``all_logits`` raises: the one-shot prefill only materializes final
    logits)."""
    if all_logits and fresh:
        raise ValueError("prefill_append(all_logits=True) requires the "
                         "append path (fresh=False): T.prefill only "
                         "returns final-position logits")
    cap = state.tok.shape[0]
    slots = jnp.asarray(slots, jnp.int32)
    slots_c = jnp.clip(slots, 0, cap - 1)               # in-range gathers
    req_keys = jax.vmap(lambda r: request_key(sampler.seed, r))(
        jnp.asarray(rids, jnp.int32))
    keys_in = jnp.where((first & seat)[:, None], req_keys,
                        state.keys[slots_c])

    batch = dict(window)
    if fresh:
        batch["prompt_lengths"] = jnp.asarray(chunk_lens, jnp.int32)
        logits, new_sub, new_len = T.prefill(params, cfg, batch, max_seq)
        new_len = jnp.where(seat, new_len, 0)
    else:
        sub_cache = deploy.cache_rows_gather(cfg, state.cache, slots_c)
        sub_len = jnp.where(seat, state.lengths[slots_c], 0)
        batch["chunk_lengths"] = jnp.asarray(chunk_lens, jnp.int32)
        logits, new_sub, new_len = T.prefill_chunk(params, cfg, batch,
                                                   sub_cache, sub_len,
                                                   active=seat,
                                                   write_floor=write_floor,
                                                   all_logits=all_logits)
    window_logits = None
    if all_logits:
        # keep the full (K, W, V) window for the caller; sampling below
        # gathers each seat's last valid position out of it (the same
        # rows prefill_chunk's all_logits=False path would compute)
        window_logits = logits
        w = logits.shape[1]
        idx = jnp.clip(jnp.asarray(chunk_lens, jnp.int32) - 1,
                       0, w - 1)[:, None, None]
        logits = jnp.take_along_axis(
            logits, jnp.broadcast_to(idx, (logits.shape[0], 1,
                                           logits.shape[2])), axis=1)[:, 0]
    done = seat & (new_len >= total_lens)
    split = jax.vmap(jax.random.split)(keys_in)          # (K, 2, 2)
    keys_out = jnp.where(done[:, None], split[:, 0], keys_in)
    t0 = sample_rows(logits, cfg, sampler, split[:, 1])
    tok0 = jnp.where(done, t0, state.tok[slots_c])

    sl = jnp.where(seat, slots, cap)                     # OOB -> dropped
    # fresh windows come back in T.prefill's contiguous layout; in paged
    # mode the dense rows are re-paged through the seats' page tables
    # (cache_rows_scatter_dense), keeping the fresh fast path numerically
    # identical across layouts.  Non-fresh subs already carry the pools.
    scatter = (deploy.cache_rows_scatter_dense if fresh
               else deploy.cache_rows_scatter)
    new = SlotState(
        tok=state.tok.at[sl].set(tok0),
        lengths=state.lengths.at[sl].set(new_len),
        keys=state.keys.at[sl].set(keys_out),
        cache=scatter(cfg, state.cache, new_sub, slots, mask=seat))
    if all_logits:
        return new, tok0, done, window_logits
    return new, tok0, done


def evict_slot(state: SlotState, slot, *, cfg: ModelConfig) -> SlotState:
    return SlotState(
        tok=state.tok.at[slot].set(0),
        lengths=state.lengths.at[slot].set(0),
        keys=state.keys.at[slot].set(jnp.zeros((2,), jnp.uint32)),
        cache=deploy.cache_slot_evict(cfg, state.cache, slot))


# ---------------------------------------------------------------------------
# preemption: page-level device<->host swap
# ---------------------------------------------------------------------------

def swap_out_slot(state: SlotState, slot, frames: jnp.ndarray, *,
                  cfg: ModelConfig) -> Tuple[list, list]:
    """Jit target for preemption swap-OUT: gather the victim's private
    physical frames ((N,) int32, padded ids clamp) out of every page
    pool into compact (N, page, ...) buffers, plus the slot's batch-major
    cache rows (SSM/RG-LRU/ring state in mixed architectures; empty for
    fully pageable ones).  The host pulls both lists into its swap pool
    (``np.asarray`` -- the only transfer preemption costs, O(pages));
    refcount-shared frames are NOT in ``frames`` -- they stay resident
    and the victim keeps its refcount on them."""
    return (deploy.cache_frames_gather(cfg, state.cache, frames),
            deploy.cache_hostrow_gather(cfg, state.cache, slot))


def swap_in_slot(state: SlotState, slot, frames: jnp.ndarray, page_data: list,
                 row_data: list, row: jnp.ndarray, tok, length, key, *,
                 cfg: ModelConfig) -> SlotState:
    """Jit target for preemption swap-IN (the PREFILLING-free resume):
    scatter the host pool's frame buffers into freshly allocated frames
    (padded ids drop), restore the slot's batch-major rows, install the
    rebuilt page-table row (kept shared frames at their original logical
    positions, fresh frames where data was swapped) and re-seed the
    slot's token/length/PRNG-key registers exactly as saved -- the
    resumed request continues mid-decode, token-identical to a run that
    was never preempted."""
    cache = deploy.cache_frames_scatter(cfg, state.cache, page_data, frames)
    cache = deploy.cache_hostrow_scatter(cfg, cache, row_data, slot)
    pt = cache["page_table"].at[slot].set(row.astype(jnp.int32))
    return SlotState(
        tok=state.tok.at[slot].set(jnp.asarray(tok, jnp.int32)),
        lengths=state.lengths.at[slot].set(jnp.asarray(length, jnp.int32)),
        keys=state.keys.at[slot].set(jnp.asarray(key, jnp.uint32)),
        cache={**cache, "page_table": pt})


# ---------------------------------------------------------------------------
# chunked decode
# ---------------------------------------------------------------------------

def decode_chunk(params, state: SlotState, active: jnp.ndarray,
                 remaining: jnp.ndarray, eos_ids: jnp.ndarray,
                 write_floor: Optional[jnp.ndarray] = None, *,
                 cfg: ModelConfig, sampler, n_steps: int
                 ) -> Tuple[SlotState, jnp.ndarray, jnp.ndarray]:
    """Run ``n_steps`` decode steps over all slots.

    ``active``: (B,) bool rows holding a live request at chunk entry;
    ``remaining``: (B,) int32 tokens each row may still emit;
    ``eos_ids``: (B,) int32 per-slot stop token (-1: never stops);
    ``write_floor`` (optional (B,) int32): per-slot shared-prefix scatter
    guard (see ``prefill_append``) -- decode positions below a slot's
    floor would land in refcount-shared frames, so those writes drop.

    Returns (new_state, toks (n_steps, B) int32, emitted (n_steps, B)
    bool).  A row alive at the start of a step emits exactly one token
    that step; it dies after emitting its last budgeted token or an EOS
    match (the EOS itself is emitted).  Dead rows keep computing junk the
    scheduler discards -- their state is frozen by the ``active`` gate, so
    chunk size only trades host syncs against bounded idle slot-steps.
    ``params`` must already be backend-resolved (see ``predecode``)."""

    def body(carry, _):
        st, alive, rem = carry
        logits, cache, lengths = T.decode_step(
            params, cfg, decode_inputs(st.tok, cfg), st.cache, st.lengths,
            active=alive, write_floor=write_floor)
        split = jax.vmap(jax.random.split)(st.keys)          # (B, 2, 2)
        keys = jnp.where(alive[:, None], split[:, 0], st.keys)
        new_tok = sample_rows(logits, cfg, sampler, split[:, 1])
        tok = jnp.where(alive, new_tok, st.tok)
        rem = rem - alive.astype(jnp.int32)
        hit_eos = alive & (eos_ids >= 0) & (new_tok == eos_ids)
        next_alive = alive & (rem > 0) & ~hit_eos
        nxt = SlotState(tok=tok, lengths=lengths, keys=keys, cache=cache)
        return (nxt, next_alive, rem), (tok, alive)

    (st, _, _), (toks, emitted) = jax.lax.scan(
        body, (state, active, remaining), xs=None, length=n_steps)
    return st, toks, emitted


# ---------------------------------------------------------------------------
# self-speculative decode (draft -> verify -> commit, one jit)
# ---------------------------------------------------------------------------

def spec_chunk(params, draft_params, state: SlotState,
               draft_state: SlotState, active: jnp.ndarray,
               remaining: jnp.ndarray, eos_ids: jnp.ndarray,
               write_floor: Optional[jnp.ndarray] = None, *,
               cfg: ModelConfig, draft_cfg: ModelConfig, sampler, k: int
               ) -> Tuple[SlotState, SlotState, jnp.ndarray, jnp.ndarray]:
    """One speculative tick: every live slot drafts ``k`` tokens, the full
    model verifies all k+1 positions in ONE ``T.prefill_chunk`` call, and
    each slot commits its accepted run -- between 1 and k+1 tokens.

    Emitted tokens are ALWAYS the verifier's own choices.  The draft's
    greedy proposals d_1..d_k only decide how many verifier positions are
    usable this tick: position i+1's logits are conditioned on d_1..d_i,
    so they equal the sequential model's logits exactly while the
    proposals match the verifier tokens (``d_i == v_{i-1}``), and become
    counterfactual at the first mismatch.  Each v_i is sampled with the
    SAME per-slot PRNG subkey the sequential ``decode_chunk`` would use
    at that step (the split chain is precomputed for all k+1 steps and
    the slot key advanced by exactly the number of tokens committed), so
    the emitted stream is token-identical to the non-speculative path --
    greedy or sampled -- and draft quality moves ONLY throughput.

    The verifier cache keeps all ``min(k+1, remaining)`` appended window
    positions; entries past the committed length are dead weight the
    length mask hides until the next tick overwrites them.  That is only
    sound for length-masked layouts (global attention / windowless
    local), which is why the engine gates speculation on the same
    ``T.paged_kind`` predicate as prefix sharing -- ring buffers and
    SSM/RG-LRU states mutate destructively and cannot roll back.  The
    draft cache (always contiguous) rolls back the same way: its scan
    wrote k entries, its length advances by the committed count.

    Shapes mirror ``decode_chunk`` with ``n_steps = k + 1``: returns
    (new_state, new_draft_state, toks (k+1, B), emitted (k+1, B))."""
    assert k >= 1, "spec_chunk requires k >= 1 (k=0 is plain decode)"
    b = state.tok.shape[0]
    rows = jnp.arange(b)
    active = active.astype(bool)

    # --- draft: k greedy steps on the truncated model ---------------------
    def draft_body(carry, _):
        dcache, dlen, tok = carry
        logits, dcache, dlen = T.decode_step(
            draft_params, draft_cfg, decode_inputs(tok, draft_cfg),
            dcache, dlen, active=active)
        nt = jnp.argmax(mask_vocab(logits, draft_cfg), -1).astype(jnp.int32)
        return (dcache, dlen, jnp.where(active, nt, tok)), nt

    (dcache, _, _), props = jax.lax.scan(
        draft_body, (draft_state.cache, draft_state.lengths, state.tok),
        xs=None, length=k)                                  # props (k, B)

    # --- verify: all k+1 positions in one fused append --------------------
    win_tok = jnp.concatenate([state.tok[:, None], props.T], axis=1)
    window = ({"embeds": jax.vmap(lambda t: pseudo_embed(t, cfg),
                                  in_axes=1, out_axes=1)(win_tok)}
              if cfg.embeds_input else {"tokens": win_tok})
    cl = jnp.clip(remaining, 1, k + 1)
    window["chunk_lengths"] = cl
    logits, cache, _ = T.prefill_chunk(params, cfg, window, state.cache,
                                       state.lengths, active=active,
                                       write_floor=write_floor,
                                       all_logits=True)     # (B, k+1, V)

    # --- sample every position with the sequential path's key chain -------
    def key_body(keys, _):
        split = jax.vmap(jax.random.split)(keys)            # (B, 2, 2)
        return split[:, 0], (split[:, 0], split[:, 1])

    _, (key_after, subkeys) = jax.lax.scan(
        key_body, state.keys, xs=None, length=k + 1)
    v = jax.vmap(lambda l, sk: sample_rows(l, cfg, sampler, sk),
                 in_axes=(1, 0))(logits, subkeys)           # (k+1, B)

    # --- acceptance: longest prefix of proposals matching verifier --------
    match = (props == v[:k]).astype(jnp.int32)              # (k, B)
    n_acc = jnp.cumprod(match, axis=0).sum(0) if k else jnp.zeros(
        (b,), jnp.int32)
    m = jnp.minimum(n_acc + 1, cl)
    is_eos = (eos_ids[None, :] >= 0) & (v == eos_ids[None, :])
    m = jnp.where(is_eos.any(0),
                  jnp.minimum(m, jnp.argmax(is_eos, axis=0) + 1), m)
    m = jnp.where(active, jnp.maximum(m, 1), 0)

    # --- commit m tokens per slot; roll both caches back to length+m ------
    mi = jnp.clip(m - 1, 0, k)
    last = v[mi, rows]
    new_state = SlotState(
        tok=jnp.where(active, last, state.tok),
        lengths=state.lengths + m,
        keys=jnp.where(active[:, None], key_after[mi, rows], state.keys),
        cache=cache)
    new_draft = SlotState(
        tok=jnp.where(active, last, draft_state.tok),
        lengths=draft_state.lengths + m,
        keys=draft_state.keys,
        cache=dcache)
    emitted = jnp.arange(k + 1, dtype=jnp.int32)[:, None] < m[None, :]
    toks = jnp.where(emitted, v, state.tok[None, :])
    return new_state, new_draft, toks, emitted
