"""Batched serving engine: bucketed prefill + device-resident decode loop.

The decode loop is a single jitted ``lax.scan`` over new tokens: sampling
(greedy or temperature) runs on device with a scan-carried PRNG key, the KV
cache is donated into the loop, and the only device->host transfer per
``generate`` call is the final (B, max_new) token block.  Prompt lengths are
right-padded to a bucket multiple so the number of prefill compilations is
bounded by the bucket count, not by distinct prompt lengths.

Weight formats are transparent: dense, HALO-quantized, ``DeployQuantWeight``
(per-call XLA dequant), or ``HaloPacked`` (the pack-at-load Pallas kernel
path -- see core.deploy.pack_params and docs/serving.md).  ``serve_step`` is
the jit target the dry-run lowers for decode shapes.

``generate(..., legacy_loop=True)`` keeps the original per-token Python loop
(one host sync per token); it exists as the parity oracle and as the
benchmark baseline for the scan path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import transformer as T


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0          # 0 -> greedy
    seed: int = 0


def sample_logits(logits: jnp.ndarray, cfg: ModelConfig,
                  sampler: SamplerConfig, key: jax.Array) -> jnp.ndarray:
    lf = logits.astype(jnp.float32)
    col = jnp.arange(lf.shape[-1])
    lf = jnp.where(col >= cfg.vocab, -1e30, lf)     # mask padded vocab
    if sampler.temperature <= 0.0:
        return jnp.argmax(lf, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, lf / sampler.temperature,
                                  axis=-1).astype(jnp.int32)


def serve_step(params, cfg: ModelConfig, inputs: Dict[str, jnp.ndarray],
               cache, lengths: jnp.ndarray):
    """One decode step (the dry-run target for decode_*/long_* shapes)."""
    return T.decode_step(params, cfg, inputs, cache, lengths)


def _decode_inputs(tok: jnp.ndarray, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    if cfg.embeds_input:
        # stub frontends: feed the token back through a fixed
        # pseudo-embedding (hash of the token id)
        return {"embeds": _pseudo_embed(tok, cfg)}
    return {"tokens": tok}


def _predecode(params, cfg: ModelConfig):
    """Backend-resolve packed weights at jit entry.

    TPU: identity -- every matmul streams the 4-bit HaloPacked layout
    through the Pallas kernel (weight HBM reads /4 vs bf16, per token).

    CPU (no Mosaic): decode each packed stream ONCE per engine call,
    before the token scan, so the per-token loop multiplies dense weights
    instead of re-decoding 4-bit codes every token.  Weights at rest stay
    4-bit; the dense copies are transients of the call.  Per-matmul decode
    on CPU was measured ~3x slower per token than this hoist with zero
    memory-traffic benefit (no VMEM to win back)."""
    from ..kernels import ops as kops
    if not kops.default_interpret():
        return params

    def dec(w):
        if isinstance(w, kops.HaloPacked):
            return w.dequantize(cfg.dtype)
        return w

    return jax.tree.map(dec, params,
                        is_leaf=lambda x: isinstance(x, kops.HaloPacked))


def _decode_loop(params, tok0: jnp.ndarray, cache, lengths: jnp.ndarray,
                 key: jax.Array, max_new: int, *, cfg: ModelConfig,
                 sampler: SamplerConfig) -> jnp.ndarray:
    """(B,) first token + cache -> (B, max_new) tokens, all on device.

    The per-step PRNG split mirrors the legacy Python loop exactly
    (``key, k1 = split(key)`` then sample with k1), so temperature sampling
    emits the same sequence either way."""

    params = _predecode(params, cfg)

    def body(carry, _):
        tok, cache, lengths, key = carry
        logits, cache, lengths = T.decode_step(
            params, cfg, _decode_inputs(tok, cfg), cache, lengths)
        key, k1 = jax.random.split(key)
        tok = sample_logits(logits, cfg, sampler, k1)
        return (tok, cache, lengths, key), tok

    if max_new <= 1:
        return tok0[:, None]
    _, toks = jax.lax.scan(body, (tok0, cache, lengths, key), xs=None,
                           length=max_new - 1)
    return jnp.concatenate([tok0[:, None], toks.swapaxes(0, 1)], axis=1)


class Engine:
    def __init__(self, params, cfg: ModelConfig,
                 sampler: SamplerConfig = SamplerConfig(),
                 prefill_bucket: int = 64, decode_bucket: int = 16):
        self.params = params
        self.cfg = cfg
        self.sampler = sampler
        self.prefill_bucket = max(int(prefill_bucket), 1)
        self.decode_bucket = max(int(decode_bucket), 1)
        self._prefill = jax.jit(
            lambda params, batch, max_seq: T.prefill(
                _predecode(params, cfg), cfg, batch, max_seq),
            static_argnames=("max_seq",))
        self._decode = jax.jit(functools.partial(T.decode_step, cfg=cfg))
        # KV cache donated into the loop (in-place on TPU; CPU has no
        # donation support and would warn on every call)
        donate = () if jax.default_backend() == "cpu" else (2,)
        self._decode_loop = jax.jit(
            functools.partial(_decode_loop, cfg=cfg, sampler=sampler),
            static_argnames=("max_new",), donate_argnums=donate)
        self._sample = jax.jit(
            functools.partial(sample_logits, cfg=cfg, sampler=sampler))

    # ------------------------------------------------------------------
    # prefill (bucketed)
    # ------------------------------------------------------------------

    def _bucket(self, n: int) -> int:
        b = self.prefill_bucket
        return -(-n // b) * b

    def _pad_prompts(self, prompts: Dict[str, jnp.ndarray], s: int,
                     s_pad: int) -> Dict[str, jnp.ndarray]:
        if s_pad == s:
            return dict(prompts)
        pad = s_pad - s
        out = dict(prompts)
        if "tokens" in out:
            out["tokens"] = jnp.pad(out["tokens"], ((0, 0), (0, pad)))
        if "embeds" in out:
            out["embeds"] = jnp.pad(out["embeds"],
                                    ((0, 0), (0, pad), (0, 0)))
        if "positions" in out:
            pos = out["positions"]
            ext = pos[:, -1:] + jnp.arange(1, pad + 1, dtype=pos.dtype)
            out["positions"] = jnp.concatenate([pos, ext], axis=1)
        return out

    def run_prefill(self, prompts: Dict[str, jnp.ndarray], max_new: int,
                    max_seq: Optional[int] = None
                    ) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
        """Bucket-padded prefill.  Returns (last logits, cache, lengths)."""
        cfg = self.cfg
        b, s = (prompts["embeds"].shape[:2] if cfg.embeds_input
                else prompts["tokens"].shape)
        s_pad = self._bucket(s)
        want = max_seq or (s + max_new)
        max_seq = max(self._bucket(want), s_pad)
        batch = self._pad_prompts(prompts, s, s_pad)
        batch["prompt_lengths"] = jnp.full((b,), s, jnp.int32)
        return self._prefill(self.params, batch=batch, max_seq=max_seq)

    # ------------------------------------------------------------------
    # generate
    # ------------------------------------------------------------------

    def generate(self, prompts: Dict[str, jnp.ndarray], max_new: int,
                 max_seq: Optional[int] = None,
                 legacy_loop: bool = False) -> np.ndarray:
        if legacy_loop:
            return self._generate_legacy(prompts, max_new, max_seq)
        # scan length bucketed so distinct max_new values share a compiled
        # loop (scan steps are sequential, so the first max_new tokens are
        # identical regardless of trailing discarded steps); short requests
        # use power-of-two buckets to cap discarded work at <2x.  The cache
        # is sized for ALL n_steps writes so no KV slot ever clamps.
        db = self.decode_bucket
        if max_new >= db:
            n_steps = -(-max_new // db) * db
        else:
            n_steps = 1 if max_new <= 1 else 1 << (max_new - 1).bit_length()
        logits, cache, lengths = self.run_prefill(prompts, n_steps, max_seq)
        key = jax.random.PRNGKey(self.sampler.seed)
        key, k0 = jax.random.split(key)
        tok0 = self._sample(logits, key=k0)
        toks = self._decode_loop(self.params, tok0, cache, lengths, key,
                                 max_new=n_steps)
        return np.asarray(toks)[:, :max_new]   # the ONE host sync per call

    def _generate_legacy(self, prompts: Dict[str, jnp.ndarray], max_new: int,
                         max_seq: Optional[int] = None) -> np.ndarray:
        """Original per-token loop: one device->host sync per token."""
        cfg = self.cfg
        b, s = (prompts["embeds"].shape[:2] if cfg.embeds_input
                else prompts["tokens"].shape)
        max_seq = max_seq or (s + max_new)
        logits, cache, lengths = self._prefill(self.params, batch=prompts,
                                               max_seq=max_seq)
        key = jax.random.PRNGKey(self.sampler.seed)
        outs = []
        key, k0 = jax.random.split(key)
        tok = sample_logits(logits, cfg, self.sampler, k0)
        outs.append(np.asarray(tok))
        for _ in range(max_new - 1):
            logits, cache, lengths = self._decode(
                self.params, inputs=_decode_inputs(tok, cfg), cache=cache,
                lengths=lengths)
            key, k1 = jax.random.split(key)
            tok = sample_logits(logits, cfg, self.sampler, k1)
            outs.append(np.asarray(tok))
        return np.stack(outs, axis=1)     # (B, max_new)


def _pseudo_embed(tok: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Deterministic stand-in embedding for stub-frontend decode loops."""
    d = cfg.d_model
    phase = (tok[:, None].astype(jnp.float32) + 1.0) \
        * jnp.arange(1, d + 1, dtype=jnp.float32)[None, :]
    return jnp.sin(phase * 0.01).astype(cfg.dtype)
