"""Batched serving engine: prefill + decode loop over the unified model.

Greedy or temperature sampling; per-sequence lengths; works with dense,
HALO-quantized, or baseline-quantized parameter trees (the model's `dense`
dequantizes transparently).  `serve_step` is the jit target the dry-run
lowers for decode shapes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import transformer as T


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0          # 0 -> greedy
    seed: int = 0


def sample_logits(logits: jnp.ndarray, cfg: ModelConfig,
                  sampler: SamplerConfig, key: jax.Array) -> jnp.ndarray:
    lf = logits.astype(jnp.float32)
    col = jnp.arange(lf.shape[-1])
    lf = jnp.where(col >= cfg.vocab, -1e30, lf)     # mask padded vocab
    if sampler.temperature <= 0.0:
        return jnp.argmax(lf, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, lf / sampler.temperature,
                                  axis=-1).astype(jnp.int32)


def serve_step(params, cfg: ModelConfig, inputs: Dict[str, jnp.ndarray],
               cache, lengths: jnp.ndarray):
    """One decode step (the dry-run target for decode_*/long_* shapes)."""
    return T.decode_step(params, cfg, inputs, cache, lengths)


class Engine:
    def __init__(self, params, cfg: ModelConfig,
                 sampler: SamplerConfig = SamplerConfig()):
        self.params = params
        self.cfg = cfg
        self.sampler = sampler
        self._prefill = jax.jit(
            functools.partial(T.prefill, cfg=cfg),
            static_argnames=("max_seq",))
        self._decode = jax.jit(functools.partial(T.decode_step, cfg=cfg))

    def generate(self, prompts: Dict[str, jnp.ndarray], max_new: int,
                 max_seq: Optional[int] = None) -> np.ndarray:
        cfg = self.cfg
        b, s = (prompts["embeds"].shape[:2] if cfg.embeds_input
                else prompts["tokens"].shape)
        max_seq = max_seq or (s + max_new)
        logits, cache, lengths = self._prefill(self.params, batch=prompts,
                                               max_seq=max_seq)
        key = jax.random.PRNGKey(self.sampler.seed)
        outs = []
        key, k0 = jax.random.split(key)
        tok = sample_logits(logits, cfg, self.sampler, k0)
        outs.append(np.asarray(tok))
        for _ in range(max_new - 1):
            if cfg.embeds_input:
                # stub frontends: feed the token back through a fixed
                # pseudo-embedding (hash of the token id)
                emb = _pseudo_embed(tok, cfg)
                inputs = {"embeds": emb}
            else:
                inputs = {"tokens": tok}
            logits, cache, lengths = self._decode(
                self.params, inputs=inputs, cache=cache, lengths=lengths)
            key, k1 = jax.random.split(key)
            tok = sample_logits(logits, cfg, self.sampler, k1)
            outs.append(np.asarray(tok))
        return np.stack(outs, axis=1)     # (B, max_new)


def _pseudo_embed(tok: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Deterministic stand-in embedding for stub-frontend decode loops."""
    d = cfg.d_model
    phase = (tok[:, None].astype(jnp.float32) + 1.0) \
        * jnp.arange(1, d + 1, dtype=jnp.float32)[None, :]
    return jnp.sin(phase * 0.01).astype(cfg.dtype)
