"""Serving engine: bucketed prefill, scan decode, continuous batching.

Three serving modes share one weight/kernel stack (dense, HALO-quantized,
``DeployQuantWeight`` per-call XLA dequant, or ``HaloPacked`` -- the
pack-at-load Pallas kernel path, see core.deploy.pack_params and
docs/serving.md):

``generate(..., mode="continuous")`` (default) routes through the
continuous-batching scheduler (serving/scheduler.py + serving/batch.py):
each row becomes a request, admitted into a fixed-capacity slot batch by
bucketed prompt length, decoded in jitted chunks with per-slot stop/EOS
state, slots recycled mid-decode.  ``Engine.submit`` / ``Engine.step`` /
``Engine.drain`` expose the same machinery for streaming multi-request
serving (arrival times, per-request ``max_new``/EOS).

``generate(..., mode="batch")`` is the one-shot padded-batch loop: a
single jitted ``lax.scan`` over new tokens, on-device sampling with a
scan-carried PRNG key, donated KV cache, one device->host transfer per
call.  It is the continuous scheduler's throughput baseline
(benchmarks/serving_latency.py) and its greedy parity oracle.

``generate(..., legacy_loop=True)`` keeps the original per-token Python
loop (one host sync per token) as the ground-truth oracle.

``Engine(..., paged=True, page_size=16, cache_pages=None)`` switches the
continuous path's KV cache to the block-paged layout: shared page pools
plus per-slot page tables, admission reserving pages from a host
``PageAllocator`` -- so ``capacity`` may exceed what contiguous rows of
the same memory could seat (see docs/serving.md).  Contiguous
(``paged=False``, default) remains the parity oracle; the one-shot
batch/legacy paths are contiguous-only.

``Engine(..., paged=True, share_prefix=True)`` additionally shares
full-page-aligned prompt prefixes ACROSS requests, copy-on-write: a
host ``PrefixIndex`` maps page-aligned token blocks to the physical
frames that already hold their KV; admission of a matching request maps
those frames into its page table at refcount + 1 and skips their
prefill windows entirely (PREFILLING starts at the first unshared
page).  Requires an architecture whose cache is fully pageable (pure
global attention); engines mixing recurrent / ring-local state serve
normally with sharing inert.  Token outputs are unchanged -- the
differential fuzzer (tests/test_serving_fuzz.py) holds all modes to the
contiguous oracle.

``Engine(..., speculative=True, draft=..., k=...)`` turns on
self-speculative decoding on the continuous path: a cheap draft model --
by default the first ``draft_layers`` blocks sliced out of the SAME
weight tree (zero extra weight memory), or an explicit low-bit re-pack --
proposes ``k`` tokens per live slot per tick and the full model verifies
all k+1 positions in one fused call, committing 1..k+1 tokens per slot
per tick (serving/batch.spec_chunk).  Emitted tokens are token-identical
to the non-speculative path, greedy or sampled; draft quality only moves
throughput.  Architectures with ring/recurrent cache state serve
normally with speculation inert (same gate as share_prefix), as does
``k=0``.  See docs/serving.md.

Prompt lengths are right-padded to ``prefill_bucket`` multiples so prefill
compilations are bounded by the bucket count.  The continuous path admits
prompts of ANY length that fits the slot cache: prompts are appended to a
slot's cache in fixed-width windows (``prefill_chunk_width``), up to
``admit_k`` same-width seats fused into one jitted ``prefill_append``
call, and long prompts stream window-by-window interleaved with decode
ticks (the ``PREFILLING`` phase -- see docs/serving.md).  ``serve_step``
is the jit target the dry-run lowers for decode shapes.
"""

from __future__ import annotations

import dataclasses
import functools
import os
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import deploy
from ..dist import sharding as sh
from ..models import transformer as T
from ..utils import next_pow2, round_up
from . import batch as B
from .scheduler import (PageAllocator, PrefixIndex, PriorityAdmission,
                        Request, Scheduler, TenantQuota, pages_needed,
                        prefix_keys)
from .tuning import EngineKnobs, TunedConfig


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One emitted token, as yielded by ``Engine.stream()``.

    ``index`` is the token's position in the request's output stream
    (0 = the prefill-sampled first token); ``ttft`` is populated on that
    first event only -- wall seconds from ``submit`` to the token's
    emission, the stream's first-class TTFT observable."""

    rid: int
    token: int
    index: int
    tenant: str
    done: bool                    # this was the request's last token
    ttft: Optional[float] = None  # first event of the request only


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0          # 0 -> greedy
    seed: int = 0


def sample_logits(logits: jnp.ndarray, cfg: ModelConfig,
                  sampler: SamplerConfig, key: jax.Array) -> jnp.ndarray:
    """Batch-shared-key sampling (the one-shot loops' semantics)."""
    lf = B.mask_vocab(logits, cfg)
    if sampler.temperature <= 0.0:
        return jnp.argmax(lf, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, lf / sampler.temperature,
                                  axis=-1).astype(jnp.int32)


def serve_step(params, cfg: ModelConfig, inputs: Dict[str, jnp.ndarray],
               cache, lengths: jnp.ndarray,
               active: Optional[jnp.ndarray] = None):
    """One decode step (the dry-run target for decode_*/long_* shapes)."""
    return T.decode_step(params, cfg, inputs, cache, lengths, active=active)


def _decode_loop(params, tok0: jnp.ndarray, cache, lengths: jnp.ndarray,
                 key: jax.Array, max_new: int, *, cfg: ModelConfig,
                 sampler: SamplerConfig) -> jnp.ndarray:
    """(B,) first token + cache -> (B, max_new) tokens, all on device.

    The per-step PRNG split mirrors the legacy Python loop exactly
    (``key, k1 = split(key)`` then sample with k1), so temperature sampling
    emits the same sequence either way."""

    params = B.predecode(params, cfg)

    def body(carry, _):
        tok, cache, lengths, key = carry
        logits, cache, lengths = T.decode_step(
            params, cfg, B.decode_inputs(tok, cfg), cache, lengths)
        key, k1 = jax.random.split(key)
        tok = sample_logits(logits, cfg, sampler, k1)
        return (tok, cache, lengths, key), tok

    if max_new <= 1:
        return tok0[:, None]
    _, toks = jax.lax.scan(body, (tok0, cache, lengths, key), xs=None,
                           length=max_new - 1)
    return jnp.concatenate([tok0[:, None], toks.swapaxes(0, 1)], axis=1)


def _with_rules(fn, mesh, rules):
    """Wrap a jitted callable so it traces under ``use_rules(mesh,
    rules)`` -- the ambient context is read at TRACE time, which is when
    the model's ``shard_activation`` constraints decide whether to fire.
    Identity when no mesh is given (zero overhead on the 1-device path)."""
    if mesh is None:
        return fn

    @functools.wraps(fn)
    def call(*args, **kwargs):
        with sh.use_rules(mesh, rules):
            return fn(*args, **kwargs)

    return call


class _DeviceExecutor:
    """Engine-backed scheduler executor (the device half of the contract
    in serving/scheduler.py).

    Owns the slot-batched decode state for one (capacity, max_seq) cache
    and the three jitted entry points: ``prefill_append`` (fused k-way
    chunked-prefill admission -- one call appends a W-token prompt window
    to up to ``admit_k`` slots and samples first tokens for seats that
    complete), the chunked decode scan, and eviction.  Weights are
    resolved once via ``Engine.serve_params`` -- on CPU the 4-bit streams
    decode to dense copies held for the executor's lifetime instead of
    once per token/call; on TPU the packed layout streams through the
    Pallas kernels untouched."""

    def __init__(self, eng: "Engine", capacity: int, max_seq: int,
                 chunk: int):
        cfg = eng.cfg
        self.eng = eng
        # every jitted entry point traces under the engine's (mesh,
        # rules) context so activation constraints fire; _with_rules is
        # the identity when the engine has no mesh
        wrap = functools.partial(_with_rules, mesh=eng.mesh,
                                 rules=eng.rules)
        self.capacity = int(capacity)
        self.chunk = max(int(chunk), 1)
        self.max_seq = eng._round_bucket(int(max_seq))
        self.admit_k = max(1, min(int(eng.admit_k), self.capacity))
        self.chunk_width = eng._chunk_width()
        self.params = eng.serve_params()
        # paged KV: shared page pool + per-slot page tables; admission
        # reserves ceil((prompt_len + max_new) / page_size) frames from
        # the host allocator, so capacity may exceed what a contiguous
        # layout of the same memory could seat (see docs/serving.md)
        self.paged = bool(eng.paged)
        self.page_size = int(eng.page_size)
        # prefix sharing needs every sequence-axis cache leaf paged: a
        # recurrent (SSM/RG-LRU) or ring local-KV block would need its
        # prefix STATE rebuilt, which is exactly the prefill work sharing
        # skips -- such engines serve normally with sharing inert
        self.share = (bool(eng.share_prefix) and self.paged and all(
            T.paged_kind(cfg, k)
            for k in tuple(cfg.block_pattern) + tuple(cfg.remainder_pattern)))
        if self.paged:
            if self.max_seq % self.page_size:
                raise ValueError(
                    f"page_size {self.page_size} must divide the "
                    f"bucket-rounded slot cache length {self.max_seq}")
            self.pages_per_slot = self.max_seq // self.page_size
            self.n_pages = (int(eng.cache_pages)
                            if eng.cache_pages is not None
                            else self.capacity * self.pages_per_slot)
            self.allocator = PageAllocator(self.n_pages)
            self._slot_frames: Dict[int, List[int]] = {}
            # shared-prefix write guard: first position each slot may
            # write (positions below live in refcount-shared frames)
            self._floors = np.zeros((self.capacity,), np.int32)
            if self.share:
                self.prefix = PrefixIndex(self.allocator)
                # (chain keys, frames) per slot, registered into the
                # index when the slot's prefill completes
                self._slot_reg: Dict[int, Tuple[list, List[int]]] = {}
                # sharing diagnostics (asserted on in tests; reported
                # by the --share-prefix bench section)
                self.shared_pages = 0      # frames mapped from the index
                self.forks = 0             # copy-on-write page forks
                self.skipped_tokens = 0    # prefill tokens never appended
            # donate the slot state: without it every admission's row
            # update would copy the whole state -- pools included
            donate = () if jax.default_backend() == "cpu" else (0,)
            self._set_pages = wrap(jax.jit(B.set_page_row,
                                           donate_argnums=donate))
            self._copy_frame = wrap(jax.jit(
                functools.partial(B.copy_frame, cfg=cfg),
                donate_argnums=donate))
            # preemption: page-level device<->host swap.  The gather is
            # read-only (no donation -- the state survives); the scatter
            # donates like every other slot update.
            self._swap_gather = wrap(jax.jit(
                functools.partial(B.swap_out_slot, cfg=cfg)))
            self._swap_scatter = wrap(jax.jit(
                functools.partial(B.swap_in_slot, cfg=cfg),
                donate_argnums=donate))
            # host-memory swap pool: rid -> the victim's saved private
            # state (frame data, batch rows, tok/length/PRNG key, floor)
            self._swap: Dict[int, Dict[str, Any]] = {}
            self.swap_outs = 0
            self.swap_ins = 0
        self.state = B.init_slots(cfg, self.capacity, self.max_seq,
                                  paged=self.paged,
                                  page_size=self.page_size,
                                  n_pages=getattr(self, "n_pages", None))
        if eng.mesh is not None:
            # lay the slot state out once: page pools shard on their KV
            # head dim ("kv"), page tables and per-slot vectors
            # replicate; the jitted updates then keep every leaf on its
            # placement (the shard_activation constraints pin them)
            self.state = B.shard_slots(self.state, cfg, eng.mesh,
                                       eng.rules, paged=self.paged)
        # (width, n_seats) per fused append call -- k-way admission and
        # chunk-streaming diagnostics (asserted on in tests); bounded so
        # a long-running server's host memory tracks in-flight work.
        # ``append_calls`` is the monotonic companion: delta arithmetic
        # over it stays correct after the deque saturates.
        self.append_log: "deque[Tuple[int, int]]" = deque(maxlen=65536)
        self.append_calls = 0
        # slot state donated into append/chunk (in-place on TPU; CPU has
        # no donation support and would warn on every call)
        donate = () if jax.default_backend() == "cpu" else (1,)
        self._append = wrap(jax.jit(
            functools.partial(B.prefill_append, cfg=cfg, sampler=eng.sampler),
            static_argnames=("fresh", "max_seq", "all_logits"),
            donate_argnums=donate))
        # scoring capture (Engine.score): {rid: [(window_start, (take, V)
        # fp32 host logits), ...]}.  While armed, every prefill window
        # routes through the append path with all_logits=True and its
        # valid positions are copied to the host -- the eval harness'
        # teacher-forced log-likelihoods come from the exact windows the
        # serving path computed.  None = normal serving (zero overhead).
        self.capture: Optional[Dict[int, List[Tuple[int, np.ndarray]]]] \
            = None
        self._evict = wrap(jax.jit(functools.partial(B.evict_slot, cfg=cfg)))
        # keep the raw jit handle: decode_hlo() lowers it for the
        # bench's per-tick collective count (the wrapper hides .lower)
        self._chunk_jit = jax.jit(
            functools.partial(B.decode_chunk, cfg=cfg, sampler=eng.sampler,
                              n_steps=self.chunk),
            donate_argnums=donate)
        self._chunk = wrap(self._chunk_jit)
        # self-speculative decode: gated on the SAME predicate as prefix
        # sharing -- rejected verify-window entries (and the draft's own
        # over-eager appends) roll back by LENGTH accounting only, which
        # is sound for length-masked cache layouts but not for ring
        # local-KV or SSM/RG-LRU state, whose writes are destructive.
        # Gated engines serve normally with speculation inert.
        self.spec = bool(eng.speculative) and eng.spec_k >= 1 and all(
            T.paged_kind(cfg, kind)
            for kind in tuple(cfg.block_pattern)
            + tuple(cfg.remainder_pattern))
        if self.spec:
            self.draft_params, self.draft_cfg = eng.draft_serve_params()
            dcfg = self.draft_cfg
            self.spec = all(
                T.paged_kind(dcfg, kind)
                for kind in tuple(dcfg.block_pattern)
                + tuple(dcfg.remainder_pattern))
        if self.spec:
            # the draft's KV cache is ALWAYS contiguous (it is private to
            # this executor: nothing shares it, so paging buys nothing)
            self.draft_state = B.init_slots(dcfg, self.capacity,
                                            self.max_seq)
            if eng.mesh is not None:
                self.draft_state = B.shard_slots(self.draft_state, dcfg,
                                                 eng.mesh, eng.rules)
            spec_donate = () if jax.default_backend() == "cpu" else (2, 3)
            self._spec_chunk = wrap(jax.jit(
                functools.partial(B.spec_chunk, cfg=cfg, draft_cfg=dcfg,
                                  sampler=eng.sampler, k=eng.spec_k),
                donate_argnums=spec_donate))
            self._draft_append = wrap(jax.jit(
                functools.partial(B.prefill_append, cfg=dcfg,
                                  sampler=eng.sampler),
                static_argnames=("fresh", "max_seq"),
                donate_argnums=donate))
            self._draft_evict = wrap(jax.jit(
                functools.partial(B.evict_slot, cfg=dcfg)))
            # acceptance diagnostics (host-side, from the already-synced
            # ``emitted``): committed tokens per slot-tick =
            # spec_tokens / spec_slots in [1, k+1]; draft acceptance rate
            # = (spec_tokens - spec_slots) / (spec_slots * k)
            self.spec_ticks = 0
            self.spec_slots = 0
            self.spec_tokens = 0

    def prefill_width(self, remaining: int) -> int:
        """Window width for a seat with ``remaining`` prompt tokens left:
        bucket-rounded, capped at ``prefill_chunk_width``.  The width set
        {bucket, 2*bucket, ..., chunk_width} bounds append compilations."""
        return min(self.chunk_width,
                   self.eng._round_bucket(max(int(remaining), 1)))

    def prefill_step(self, seats: List[Tuple[int, Request, int]]
                     ) -> Dict[int, Tuple[int, Optional[int]]]:
        """Advance every prefilling seat by one window.

        ``seats``: (slot, request, tokens_already_appended).  Seats are
        grouped by (window width, freshness) -- same-bucket requests land
        in one fused ``prefill_append`` of up to ``admit_k`` seats -- and
        each group call appends its window to all its slots' cache rows
        at their current lengths.  Freshness (whole-prompt first window)
        is part of the group key so a request's numeric path -- and
        therefore its sampled tokens -- never depends on which neighbors
        happen to share its admission call.  Returns
        {slot: (tokens_consumed, tok0)} where tok0 is the request's first
        sampled token when its prompt completed this step (None while
        chunks remain)."""
        out: Dict[int, Tuple[int, Optional[int]]] = {}
        groups: Dict[Tuple[int, bool],
                     List[Tuple[int, Request, int]]] = {}
        for slot, req, start in seats:
            if (start == req.prefill_skip
                    and req.prompt_len + req.max_new > self.max_seq):
                # guard for callers driving the Scheduler directly
                # (Engine.submit checks this before enqueueing); without
                # it the append would silently clamp overflow writes onto
                # the last cache row and decode garbage
                raise ValueError(
                    f"rid {req.rid}: prompt_len {req.prompt_len} + "
                    f"max_new {req.max_new} exceeds the slot cache "
                    f"length {self.max_seq}")
            wdt = self.prefill_width(req.prompt_len - start)
            # fresh = whole prompt in one first window into ZEROED rows;
            # a shared-prefix seat (prefill_skip > 0) starts mid-cache,
            # so it always takes the gather/append path.  Scoring capture
            # also forces the append path: T.prefill only materializes
            # final-position logits, the capture needs every position.
            fresh = start == 0 and req.prefill_skip == 0 \
                and req.prompt_len <= wdt and self.capture is None
            groups.setdefault((wdt, fresh), []).append((slot, req, start))
        for (wdt, fresh), group in groups.items():
            for i in range(0, len(group), self.admit_k):
                out.update(self._append_group(wdt, fresh,
                                              group[i:i + self.admit_k]))
        if self.paged and self.share:
            # completed prompts: publish their full-page prefix frames
            # (the KV is finished now, never before) into the index
            for slot, (_, tok0) in out.items():
                if tok0 is not None:
                    keys, frames = self._slot_reg.pop(slot, ((), ()))
                    if keys:
                        self.prefix.register(keys, frames)
        return out

    def _append_group(self, width: int, fresh: bool,
                      group: List[Tuple[int, Request, int]]
                      ) -> Dict[int, Tuple[int, Optional[int]]]:
        """One fused append of up to ``admit_k`` same-(width, fresh)
        seats.  ``fresh`` seats (whole-prompt first windows) take the
        fast path: blockwise one-shot prefill into zeroed rows (no
        gather, cheaper attention).

        The call is shaped (len(group), width): a lone admission computes
        a batch-1 window rather than padding to ``admit_k`` seats (4x the
        prefill FLOPs for nothing under trickle arrivals).  Compilations
        stay bounded by admit_k x |width set| x 2."""
        eng, cfg, k = self.eng, self.eng.cfg, len(group)
        lead = "embeds" if cfg.embeds_input else "tokens"
        slots = np.full((k,), self.capacity, np.int32)
        seat = np.zeros((k,), bool)
        chunk_lens = np.zeros((k,), np.int32)
        total = np.zeros((k,), np.int32)
        first = np.zeros((k,), bool)
        rids = np.zeros((k,), np.int32)
        win = (np.zeros((k, width, cfg.d_model), np.float32)
               if cfg.embeds_input else np.zeros((k, width), np.int32))
        floors = np.zeros((k,), np.int32)
        for j, (slot, req, start) in enumerate(group):
            take = min(width, req.prompt_len - start)
            win[j, :take] = np.asarray(req.prompt[lead])[0, start:start + take]
            slots[j], seat[j] = slot, True
            chunk_lens[j], total[j] = take, req.prompt_len
            # a shared-prefix seat's FIRST window starts at its skip
            # offset (its PRNG root installs there, like start == 0)
            first[j] = start == req.prefill_skip
            rids[j] = req.rid
            if self.paged:
                floors[j] = self._floors[slot]
        window = {lead: jnp.asarray(win)}
        if any("positions" in req.prompt for _, req, _ in group):
            pos = np.zeros((k, width), np.int32)
            for j, (slot, req, start) in enumerate(group):
                take = min(width, req.prompt_len - start)
                if "positions" in req.prompt:
                    p = np.asarray(req.prompt["positions"])[0]
                    pos[j, :take] = p[start:start + take]
                    last = int(p[start + take - 1]) if take else start
                else:
                    pos[j, :take] = start + np.arange(take)
                    last = start + max(take, 1) - 1
                pos[j, take:] = last + 1 + np.arange(width - take)
            window["positions"] = jnp.asarray(pos)
        if self.capture is None:
            self.state, tok0, done = self._append(
                self.params, self.state, jnp.asarray(slots), window,
                jnp.asarray(chunk_lens), jnp.asarray(total),
                jnp.asarray(seat), jnp.asarray(rids), jnp.asarray(first),
                jnp.asarray(floors), fresh=fresh, max_seq=self.max_seq)
        else:
            # scoring capture: same fused append, but the full-window
            # logits come back too and each captured request's valid
            # positions are copied to the host keyed by window start
            self.state, tok0, done, wlog = self._append(
                self.params, self.state, jnp.asarray(slots), window,
                jnp.asarray(chunk_lens), jnp.asarray(total),
                jnp.asarray(seat), jnp.asarray(rids), jnp.asarray(first),
                jnp.asarray(floors), fresh=False, max_seq=self.max_seq,
                all_logits=True)
            wl = np.asarray(wlog, np.float32)
            for j, (slot, req, start) in enumerate(group):
                if req.rid in self.capture:
                    take = int(chunk_lens[j])
                    self.capture[req.rid].append(
                        (start, wl[j, :take].copy()))
        if self.spec:
            # mirror the window into the draft cache (its drafts must
            # condition on the prompt too).  Same call shape, draft
            # weights, contiguous rows, no floors; the sampled tok0 /
            # key updates land in draft slot state nobody reads
            # (spec_chunk drafts from the VERIFIER's token and PRNG).
            # No host sync: the result stays on device.
            self.draft_state, _, _ = self._draft_append(
                self.draft_params, self.draft_state, jnp.asarray(slots),
                window, jnp.asarray(chunk_lens), jnp.asarray(total),
                jnp.asarray(seat), jnp.asarray(rids), jnp.asarray(first),
                None, fresh=fresh, max_seq=self.max_seq)
        tok0, done = np.asarray(tok0), np.asarray(done)   # host sync
        self.append_log.append((width, len(group)))
        self.append_calls += 1
        return {int(slots[j]): (int(chunk_lens[j]),
                                int(tok0[j]) if done[j] else None)
                for j in range(len(group))}

    def run_chunk(self, active: np.ndarray, remaining: np.ndarray,
                  eos_ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        floor = jnp.asarray(self._floors) if self.paged else None
        if self.spec:
            # draft scan + fused verify + acceptance + commit + rollback,
            # all inside ONE jit call -- the draft->verify round-trip
            # never bounces through the host, preserving the
            # one-host-sync-per-tick contract below
            (self.state, self.draft_state, toks, emitted) = \
                self._spec_chunk(
                    self.params, self.draft_params, self.state,
                    self.draft_state, jnp.asarray(active),
                    jnp.asarray(remaining, dtype=jnp.int32),
                    jnp.asarray(eos_ids, dtype=jnp.int32), floor)
            toks = np.asarray(toks)          # the one host sync per chunk
            emitted = np.asarray(emitted)
            alive = int(np.asarray(active).sum())
            if alive:
                self.spec_ticks += 1
                self.spec_slots += alive
                self.spec_tokens += int(emitted.sum())
            return toks, emitted
        self.state, toks, emitted = self._chunk(
            self.params, self.state, jnp.asarray(active),
            jnp.asarray(remaining, dtype=jnp.int32),
            jnp.asarray(eos_ids, dtype=jnp.int32), floor)
        # the one host sync per chunk
        return np.asarray(toks), np.asarray(emitted)

    def decode_hlo(self) -> str:
        """Compiled HLO of one decode chunk (the per-tick jit target),
        lowered against this executor's live state.  The sharded bench
        counts the collectives GSPMD placed inside the scan from this
        text (analysis/hlo.collective_stats) -- they all sit in the jit
        body, so the per-tick host-sync count is unchanged by the mesh."""
        floor = jnp.asarray(self._floors) if self.paged else None
        args = (self.params, self.state,
                jnp.zeros((self.capacity,), bool),
                jnp.zeros((self.capacity,), jnp.int32),
                jnp.full((self.capacity,), -1, jnp.int32), floor)
        with sh.use_rules(self.eng.mesh, self.eng.rules):
            return self._chunk_jit.lower(*args).compile().as_text()

    def reserve(self, slot: int, req: Request) -> bool:
        """Paged admission: reserve the request's whole page budget --
        ceil((prompt_len + max_new) / page_size) frames -- and install
        them in the slot's page-table row.  Reserving up front is what
        makes mid-flight allocation failure impossible: prefill windows
        and decode chunks only ever touch reserved frames.  Returns False
        (admission blocks, head-of-line) while the pool is too full.
        Contiguous executors always admit on a free seat.

        With prefix sharing, the request's full-page-aligned prompt
        prefix is first looked up in the ``PrefixIndex``: hit frames map
        into the new page table at refcount + 1 instead of consuming
        fresh pages, and ``req.prefill_skip`` tells the scheduler to
        start PREFILLING past them.  A prompt shared in its ENTIRETY
        still re-enters its last token (the logits that seed tok0 must
        come from a real forward pass), so its last shared page is
        forked copy-on-write -- frame duplicated, one page-table entry
        remapped -- before the window writes into it.  When the free
        list alone can't cover the unshared remainder, LRU index entries
        are reclaimed first (cached-but-unmapped frames are reclaimable
        capacity, not leaks)."""
        if not self.paged:
            return True
        if req.prompt_len + req.max_new > self.max_seq:
            raise ValueError(
                f"rid {req.rid}: prompt_len {req.prompt_len} + max_new "
                f"{req.max_new} exceeds the slot cache length "
                f"{self.max_seq}")
        need = pages_needed(req.prompt_len, req.max_new, self.page_size)
        if need > self.n_pages:
            raise ValueError(
                f"rid {req.rid}: needs {need} pages but the pool holds "
                f"{self.n_pages}; raise cache_pages or lower max_new")
        ps = self.page_size
        keys: list = []
        kept: List[int] = []
        fork_src: Optional[int] = None
        skip = 0
        # sharing keys on prompt TOKENS; an explicit "positions" row
        # changes the RoPE rotation baked into cached K, so such prompts
        # neither share nor register (identical tokens at different
        # positions are different KV)
        if (self.share and req.prompt is not None
                and "tokens" in req.prompt
                and "positions" not in req.prompt
                and req.prompt_len >= ps):
            keys = req.prefix_key_chain
            if keys is None:
                toks = np.asarray(req.prompt["tokens"]).reshape(-1)
                keys = prefix_keys(toks[:req.prompt_len], ps)
                req.prefix_key_chain = keys
            kept = self.prefix.lookup(keys)
            skip = len(kept) * ps
            if skip == req.prompt_len:
                # whole prompt resident: re-enter the last token for its
                # logits; its window writes into the last shared page,
                # which therefore forks copy-on-write
                skip -= 1
                fork_src = kept.pop()
        n_fresh = need - len(kept)
        # pin the hits BEFORE allocating: reclaim below can then never
        # free them (their refcount is >= 2 until we undo)
        self.allocator.share(kept)
        frames = self.allocator.alloc(n_fresh)
        if frames is None and self.share:
            self.prefix.reclaim(n_fresh - self.allocator.n_free
                                - self.allocator.n_swapped)
            frames = self.allocator.alloc(n_fresh)
        if frames is None:
            self.allocator.free(kept)          # undo: admission blocks
            return False
        row_frames = kept + frames             # page order: shared, fresh
        row = np.full((self.pages_per_slot,), T.PAGE_SENTINEL, np.int32)
        row[:need] = row_frames
        if fork_src is not None:
            # duplicate the donor's frame into our private one (the
            # page-copy primitive), THEN install the row mapping it
            self.state = self._copy_frame(self.state, np.int32(fork_src),
                                          np.int32(frames[0]))
            self.forks += 1
        self.state = self._set_pages(self.state, np.int32(slot),
                                     jnp.asarray(row), np.int32(skip))
        self._slot_frames[slot] = row_frames
        self._floors[slot] = len(kept) * ps
        req.prefill_skip = skip
        if self.spec and skip:
            # the shared prefix's KV was never computed for the DRAFT
            # cache (sharing skips exactly that prefill); seed the draft
            # row's length so its appends stay position-aligned with the
            # verifier.  The draft attends zeros over the skipped span --
            # that can only cost acceptance rate, never correctness
            # (emitted tokens are always the verifier's).
            self.draft_state = self.draft_state._replace(
                lengths=self.draft_state.lengths.at[slot].set(
                    np.int32(skip)))
        if self.share:
            n_full = req.prompt_len // ps
            self._slot_reg[slot] = (keys[:n_full], row_frames[:n_full])
            self.shared_pages += len(kept)
            self.skipped_tokens += skip
        return True

    def release(self, slot: int) -> None:
        if self.paged:
            frames = self._slot_frames.pop(slot, None)
            if frames:
                # refcount decrement: frames another table or the prefix
                # index still holds stay resident (and index-cached
                # frames stay warm for the next shared admission)
                self.allocator.free(frames)
            self._floors[slot] = 0
            if self.share:
                self._slot_reg.pop(slot, None)
        self.state = self._evict(self.state, np.int32(slot))
        if self.spec:
            self.draft_state = self._draft_evict(self.draft_state,
                                                 np.int32(slot))

    def _pad_frames(self, frames: List[int]) -> np.ndarray:
        """Pad a frame-id list to a power-of-two width so the swap
        gather/scatter compile for a bounded width set (log2(pages per
        slot) shapes), not one shape per preemption.  Pad lanes carry
        ``n_pages``: the gather clamps them onto a real frame (whose
        rows are never consumed) and the scatter drops them."""
        n = max(1, next_pow2(max(len(frames), 1)))
        out = np.full((n,), self.n_pages, np.int32)
        out[:len(frames)] = frames
        return out

    def preempt(self, slot: int, req: Request) -> None:
        """Swap a RUNNING request's private state out to host memory.

        Only the frames this request alone owns (refcount 1) move:
        their pool rows are gathered into compact buffers and pulled to
        the host swap pool, then the allocator vacates them
        (live -> swapped, reusable capacity).  Refcount-shared frames --
        prefix-index pins and cross-request shared prefixes -- stay
        resident, and the victim KEEPS its refcount on them, so no
        sharer (or index reclaim) can free data it still needs.  The
        slot's batch-major rows (recurrent state in mixed archs) and
        its token/length/PRNG registers are saved too, the seat is
        evicted, and the whole bundle parks under ``req.rid`` until
        ``resume``.  Cost: O(pages owned), one transfer."""
        frames = self._slot_frames.pop(slot)
        priv_idx = [i for i, f in enumerate(frames)
                    if self.allocator.refcount(f) == 1]
        priv = [frames[i] for i in priv_idx]
        padded = jnp.asarray(self._pad_frames(priv))
        page_data, row_data = self._swap_gather(self.state, np.int32(slot),
                                                padded)
        page_data = [np.asarray(x) for x in page_data]   # host pull
        row_data = [np.asarray(x) for x in row_data]
        tok = int(np.asarray(self.state.tok[slot]))
        length = int(np.asarray(self.state.lengths[slot]))
        key = np.asarray(self.state.keys[slot])
        self.allocator.swap_out(priv)
        self._swap[req.rid] = dict(
            frames=list(frames), priv_idx=priv_idx, page_data=page_data,
            row_data=row_data, tok=tok, length=length, key=key,
            floor=int(self._floors[slot]))
        self._floors[slot] = 0
        self.state = self._evict(self.state, np.int32(slot))
        if self.spec:
            self.draft_state = self._draft_evict(self.draft_state,
                                                 np.int32(slot))
        self.swap_outs += 1

    def resume(self, slot: int, req: Request) -> bool:
        """Restore a preempted request into ``slot`` -- the
        PREFILLING-free re-entry.  Fresh frames are allocated for the
        swapped data (reclaiming LRU prefix-index entries under
        pressure, like ``reserve``), the host buffers scatter in, and
        the page-table row is rebuilt with the kept shared frames at
        their original logical positions.  Token/length/PRNG registers
        restore exactly, so the resumed decode is token-identical to a
        run that was never preempted.  False: pool still too full (the
        request stays PREEMPTED and retries)."""
        h = self._swap[req.rid]
        n_priv = len(h["priv_idx"])
        fresh = self.allocator.alloc(n_priv)
        if fresh is None and self.share:
            self.prefix.reclaim(n_priv - self.allocator.n_free
                                - self.allocator.n_swapped)
            fresh = self.allocator.alloc(n_priv)
        if fresh is None:
            return False
        frames = list(h["frames"])
        for i, f in zip(h["priv_idx"], fresh):
            frames[i] = f
        row = np.full((self.pages_per_slot,), T.PAGE_SENTINEL, np.int32)
        row[:len(frames)] = frames
        self.state = self._swap_scatter(
            self.state, np.int32(slot), jnp.asarray(self._pad_frames(fresh)),
            [jnp.asarray(d) for d in h["page_data"]],
            [jnp.asarray(d) for d in h["row_data"]],
            jnp.asarray(row), np.int32(h["tok"]), np.int32(h["length"]),
            jnp.asarray(h["key"]))
        self._slot_frames[slot] = frames
        self._floors[slot] = h["floor"]
        if self.spec:
            # the draft cache was dropped at preemption; re-seed the
            # row's length so draft appends stay position-aligned with
            # the verifier.  The draft attends zeros over the restored
            # span -- that costs acceptance rate on the first ticks
            # after resume, never correctness (emitted tokens are
            # always the verifier's; same argument as prefill_skip).
            self.draft_state = self.draft_state._replace(
                tok=self.draft_state.tok.at[slot].set(
                    np.int32(h["tok"])),
                lengths=self.draft_state.lengths.at[slot].set(
                    np.int32(h["length"])))
        del self._swap[req.rid]
        self.swap_ins += 1
        return True


class Engine:
    def __init__(self, params, cfg: ModelConfig,
                 sampler: SamplerConfig = SamplerConfig(),
                 prefill_bucket: int = 64, decode_bucket: int = 16,
                 capacity: int = 8, chunk: Optional[int] = None,
                 max_seq: Optional[int] = None,
                 prefill_chunk_width: Optional[int] = None,
                 admit_k: Optional[int] = None,
                 paged: bool = False, page_size: Optional[int] = None,
                 cache_pages: Optional[int] = None,
                 share_prefix: bool = False,
                 speculative: bool = False,
                 draft: Any = None,
                 draft_layers: Optional[int] = None,
                 k: Optional[int] = None,
                 priority_levels: Optional[int] = None,
                 preempt: bool = False,
                 tenant_slots: Optional[int] = None,
                 tenant_pages: Optional[int] = None,
                 tenants: Optional[Dict[str, Dict[str, Any]]] = None,
                 mesh: Any = None,
                 rules: Optional[Dict[str, Any]] = None,
                 tuned: Any = None):
        self.params = params
        self.cfg = cfg
        self.sampler = sampler
        self.prefill_bucket = max(int(prefill_bucket), 1)
        self.decode_bucket = max(int(decode_bucket), 1)
        # continuous-batching knobs live in one validated dataclass
        # (serving/tuning.EngineKnobs): slot count, decode steps per host
        # sync, slot cache length (None: sized from the first submit),
        # widest prompt window per fused prefill-append call (None: 4
        # buckets, floored at 64), seats per fused admission call, paged
        # page size, speculative draft depth, Pallas block-M.  The kwargs
        # above are a thin compatibility layer: ``tuned`` (a TunedConfig
        # artifact from serving/autotune.py, or a path to its JSON) seeds
        # the knobs, and any explicitly-passed kwarg overrides it.  A
        # False ``paged``/``speculative`` kwarg is the unset default and
        # defers to the artifact; build from a default TunedConfig to
        # force either off.
        if isinstance(tuned, (str, os.PathLike)):
            tuned = TunedConfig.load(tuned)
        self.tuned: Optional[TunedConfig] = tuned
        self.knobs = EngineKnobs.resolve(
            tuned,
            chunk=chunk, admit_k=admit_k,
            paged=True if paged else None,
            page_size=page_size,
            prefill_chunk_width=prefill_chunk_width,
            speculative=True if speculative else None,
            spec_k=k,
            priority_levels=priority_levels,
            preempt=True if preempt else None,
            tenant_slots=tenant_slots, tenant_pages=tenant_pages)
        self.capacity = max(int(capacity), 1)
        self.chunk = self.knobs.chunk
        self.max_seq = max_seq
        self.prefill_chunk_width = self.knobs.prefill_chunk_width
        self.admit_k = self.knobs.admit_k
        # paged KV cache (continuous path only): slots share one page
        # pool of ``cache_pages`` frames (default capacity * max_seq /
        # page_size, i.e. the contiguous layout's memory) and admission
        # reserves pages for prompt_len + max_new -- so capacity slots
        # can exceed what contiguous rows of equal memory could hold
        self.paged = self.knobs.paged
        self.page_size = self.knobs.page_size
        self.cache_pages = cache_pages
        # copy-on-write prefix sharing across requests (paged only):
        # page-aligned prompt prefixes already resident in the pool are
        # mapped at refcount + 1 and their prefill windows skipped
        self.share_prefix = bool(share_prefix)
        if self.share_prefix and not self.paged:
            raise ValueError(
                "share_prefix=True requires paged=True (prefix sharing "
                "maps page-table entries; contiguous rows have none)")
        # self-speculative decoding (continuous path only): a cheap draft
        # model -- by default the FIRST draft_layers blocks of the same
        # weight tree (core/deploy.truncate_params; zero extra weight
        # HBM), or any caller-supplied tree such as an aggressive low-bit
        # HALO re-pack -- proposes ``k`` tokens per live slot per tick and
        # the full model verifies all k+1 positions in one fused call.
        # Emitted tokens are token-identical to the non-speculative path
        # (see serving/batch.spec_chunk); draft quality only moves
        # throughput.  k=0 disables speculation bit-identically, and
        # architectures with ring/recurrent cache state (which cannot
        # roll back rejected entries) serve normally with speculation
        # inert -- the same gate as share_prefix.
        self.speculative = self.knobs.speculative
        self.spec_k = self.knobs.spec_k
        # multi-tenant control plane (continuous path only).  FIFO stays
        # the default: the scheduler only switches to priority +
        # weighted-fair-share admission when the knobs actually ask for
        # it (priority_levels >= 2, preempt=True, or per-tenant weights),
        # so a default-constructed engine is behaviorally identical to
        # the pre-policy scheduler.  ``tenants`` maps tenant name ->
        # {"weight": fair-share weight, "slots"/"pages"/"queue":
        # per-tenant quota overrides}; ``tenant_slots``/``tenant_pages``
        # set the default quota every tenant inherits.
        self.priority_levels = self.knobs.priority_levels
        self.preempt = self.knobs.preempt
        self.tenants: Dict[str, Dict[str, Any]] = {
            str(t): dict(spec or {})
            for t, spec in dict(tenants or {}).items()}
        for t, spec in self.tenants.items():
            bad = set(spec) - {"weight", "slots", "pages", "queue"}
            if bad:
                raise ValueError(
                    f"tenant {t!r}: unknown spec key(s) {sorted(bad)} "
                    f"(allowed: weight, slots, pages, queue)")
        if draft is not None and draft_layers is not None:
            raise ValueError(
                "pass either draft (an explicit param tree / (params, "
                "cfg) pair) or draft_layers (truncated self-draft), "
                "not both")
        if draft_layers is not None and not (
                1 <= int(draft_layers) < cfg.n_layers):
            raise ValueError(
                f"draft_layers must be in [1, {cfg.n_layers - 1}], "
                f"got {draft_layers}")
        self.draft = draft
        self.draft_layers = (int(draft_layers)
                             if draft_layers is not None else None)
        self._draft_resolved: Optional[Tuple[Any, ModelConfig]] = None
        # tensor-parallel sharded serving: weight leaves and KV page
        # pools are laid out on a (data, model) device mesh by the
        # logical-axis rules (dist/sharding.py), while the host
        # scheduler, PageAllocator and PrefixIndex stay global -- page
        # tables and per-slot vectors replicate, pools shard on their
        # head ("kv") dim, and GSPMD places the collectives inside the
        # jitted decode scan, so the one-host-sync-per-tick contract
        # survives unchanged.  Default rules are the weight-resident
        # decode set (launch/inputs.arch_rules(cfg, kind="decode")) with
        # the slot batch replicated: the continuous slot batch is ONE
        # global batch owned by the host scheduler; data-parallel
        # serving is a separate engine replica, not a mesh axis here.
        self.mesh = mesh
        if mesh is not None and rules is None:
            from ..launch.inputs import arch_rules
            rules = dict(arch_rules(cfg, kind="decode"))
            rules["batch"] = None
        self.rules = rules
        if mesh is not None and draft is not None:
            # loud refusal: an explicit draft tree has no ParamSpec tree
            # of its own to resolve logical axes against (its config may
            # differ arbitrarily from the verifier's); the truncated
            # self-draft (draft_layers=) shares the verifier's sharded
            # leaves and composes fine.
            raise ValueError(
                "Engine(mesh=...) cannot place an explicit draft tree; "
                "use draft_layers= (the truncated self-draft slices the "
                "already-sharded verifier leaves) or drop the mesh")
        self._prefill = jax.jit(
            lambda params, batch, max_seq: T.prefill(
                B.predecode(params, cfg), cfg, batch, max_seq),
            static_argnames=("max_seq",))
        self._decode = jax.jit(functools.partial(T.decode_step, cfg=cfg))
        # KV cache donated into the loop (in-place on TPU; CPU has no
        # donation support and would warn on every call)
        donate = () if jax.default_backend() == "cpu" else (2,)
        self._decode_loop = jax.jit(
            functools.partial(_decode_loop, cfg=cfg, sampler=sampler),
            static_argnames=("max_new",), donate_argnums=donate)
        self._sample = jax.jit(
            functools.partial(sample_logits, cfg=cfg, sampler=sampler))
        self._resolved_params = None
        self._sched: Optional[Scheduler] = None
        self._executors: Dict[Tuple[int, int], _DeviceExecutor] = {}

    @classmethod
    def from_tuned(cls, params, cfg: ModelConfig, tuned, **kw) -> "Engine":
        """Engine from an autotuner artifact (TunedConfig or JSON path).

        The artifact's engine geometry (capacity / max_seq /
        prefill_bucket, recorded at tune time) seeds the corresponding
        kwargs; anything passed explicitly still wins, and the knobs
        themselves resolve exactly as ``Engine(tuned=...)``."""
        if isinstance(tuned, (str, os.PathLike)):
            tuned = TunedConfig.load(tuned)
        if tuned.capacity is not None:
            kw.setdefault("capacity", tuned.capacity)
        if tuned.max_seq is not None:
            kw.setdefault("max_seq", tuned.max_seq)
        if tuned.prefill_bucket is not None:
            kw.setdefault("prefill_bucket", tuned.prefill_bucket)
        return cls(params, cfg, tuned=tuned, **kw)

    # ------------------------------------------------------------------
    # prefill (bucketed)
    # ------------------------------------------------------------------

    def _round_bucket(self, n: int) -> int:
        return round_up(n, self.prefill_bucket)

    def _chunk_width(self) -> int:
        """Widest prompt window a fused ``prefill_append`` call carries,
        rounded to a bucket multiple.  Prompts longer than this stream in
        ``chunk_width``-token windows interleaved with decode ticks; the
        continuous path never compiles a prefill wider than this."""
        w = self.prefill_chunk_width
        if w is None:
            w = max(4 * self.prefill_bucket, 64)
        return self._round_bucket(max(int(w), 1))

    def _pad_prompts(self, prompts: Dict[str, jnp.ndarray], s: int,
                     s_pad: int) -> Dict[str, jnp.ndarray]:
        """Right-pad a prompt batch from true length ``s`` to the bucketed
        ``s_pad`` (a shape guard, not an admission policy: callers bucket
        first, so ``s > s_pad`` means a bug, never a long prompt)."""
        if s > s_pad:
            raise ValueError(
                f"prompt length {s} exceeds the padded width {s_pad}; "
                f"refusing to silently truncate")
        if s_pad == s:
            return dict(prompts)
        pad = s_pad - s
        out = dict(prompts)
        if "tokens" in out:
            out["tokens"] = jnp.pad(out["tokens"], ((0, 0), (0, pad)))
        if "embeds" in out:
            out["embeds"] = jnp.pad(out["embeds"],
                                    ((0, 0), (0, pad), (0, 0)))
        if "positions" in out:
            pos = out["positions"]
            ext = pos[:, -1:] + jnp.arange(1, pad + 1, dtype=pos.dtype)
            out["positions"] = jnp.concatenate([pos, ext], axis=1)
        return out

    def run_prefill(self, prompts: Dict[str, jnp.ndarray], max_new: int,
                    max_seq: Optional[int] = None
                    ) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
        """Bucket-padded prefill.  Returns (last logits, cache, lengths)."""
        cfg = self.cfg
        b, s = (prompts["embeds"].shape[:2] if cfg.embeds_input
                else prompts["tokens"].shape)
        s_pad = self._round_bucket(s)
        want = max_seq or (s + max_new)
        max_seq = max(self._round_bucket(want), s_pad)
        batch = self._pad_prompts(prompts, s, s_pad)
        batch["prompt_lengths"] = jnp.full((b,), s, jnp.int32)
        return self._prefill(self.params, batch=batch, max_seq=max_seq)

    # ------------------------------------------------------------------
    # continuous batching: submit / step / drain
    # ------------------------------------------------------------------

    def serve_params(self):
        """Backend-resolved weights for the continuous executors, computed
        once per engine.  CPU: each packed 4-bit stream is decoded to a
        dense copy held for the engine's lifetime (re-decoding per chunk
        buys nothing without VMEM to win back).  TPU / already-dense
        trees: the weights pass through untouched."""
        if self._resolved_params is None:
            from ..kernels import ops as kops
            is_packed = lambda x: isinstance(x, kops.HaloPacked)  # noqa: E731
            has_packed = any(
                is_packed(l)
                for l in jax.tree.leaves(self.params, is_leaf=is_packed))
            if has_packed and kops.default_interpret():
                self._resolved_params = jax.jit(functools.partial(
                    B.predecode, cfg=self.cfg))(self.params)
            else:
                self._resolved_params = self.params
                if has_packed and self.knobs.block_m is not None:
                    # autotuned Pallas block-M, threaded once tree-wide
                    # (bit-identical math; predecoded CPU trees have no
                    # packed leaves left to tag)
                    self._resolved_params = kops.with_block_m(
                        self._resolved_params, self.knobs.block_m)
            if self.mesh is not None:
                # lay the resolved tree out on the mesh once, by each
                # leaf's logical axes (packed leaves shard idx_packed;
                # HaloPacked's fused (kt*nt, TILE) scale replicates)
                self._resolved_params = deploy.shard_params(
                    self._resolved_params, T.model_specs(self.cfg),
                    self.mesh, self.rules)
        return self._resolved_params

    def draft_serve_params(self) -> Tuple[Any, ModelConfig]:
        """Backend-resolved draft weights + config, computed once per
        engine (the speculative executors' second resident param set).

        Default (no ``draft``): the first ``draft_layers`` blocks (half
        the stack if unset) are SLICED out of the verifier's resolved
        tree -- the slices are views, so the self-draft costs no extra
        weight memory.  An explicit ``draft`` (a param tree sharing the
        engine's config, e.g. an aggressive low-bit ``pack_params``
        re-pack, or a ``(params, cfg)`` pair) is resolved exactly like
        ``serve_params`` resolves the verifier."""
        if self._draft_resolved is None:
            cfg = self.cfg
            if self.draft is None:
                m = (self.draft_layers if self.draft_layers is not None
                     else max(1, cfg.n_layers // 2))
                self._draft_resolved = deploy.truncate_params(
                    self.serve_params(), cfg, m)
                if self.mesh is not None:
                    # slicing a sharded stack yields a derived layout;
                    # re-place explicitly so the draft matches what its
                    # own spec tree would prescribe
                    dparams, dcfg = self._draft_resolved
                    dparams = deploy.shard_params(
                        dparams, T.model_specs(dcfg), self.mesh,
                        self.rules)
                    self._draft_resolved = (dparams, dcfg)
            else:
                dparams, dcfg = (self.draft if isinstance(self.draft, tuple)
                                 else (self.draft, cfg))
                from ..kernels import ops as kops
                is_packed = lambda x: isinstance(x, kops.HaloPacked)  # noqa: E731
                has_packed = any(
                    is_packed(l)
                    for l in jax.tree.leaves(dparams, is_leaf=is_packed))
                if has_packed and kops.default_interpret():
                    dparams = jax.jit(functools.partial(
                        B.predecode, cfg=dcfg))(dparams)
                self._draft_resolved = (dparams, dcfg)
        return self._draft_resolved

    # each cached executor holds a full capacity x max_seq slot cache on
    # device; keep only the most recent few (capped LRU) so generate()
    # calls with heterogeneous shapes can't accumulate caches until OOM
    _MAX_EXECUTORS = 4

    def _executor(self, capacity: int, max_seq: int) -> _DeviceExecutor:
        key = (int(capacity), self._round_bucket(int(max_seq)))
        ex = self._executors.pop(key, None)
        if ex is None:
            ex = _DeviceExecutor(self, key[0], key[1], self.chunk)
        self._executors[key] = ex          # re-insert = mark most recent
        while len(self._executors) > self._MAX_EXECUTORS:
            self._executors.pop(next(iter(self._executors)))
        return ex

    def _normalize_request(self, prompts) -> Tuple[Dict[str, np.ndarray],
                                                   int]:
        """-> (dict with leading batch dim 1, true prompt length).

        Prompts are normalized to HOST arrays: the chunked-prefill path
        slices windows host-side and ships only the active window to the
        device, so a queued long prompt never occupies device memory."""
        out = {k: np.asarray(v) for k, v in dict(prompts).items()}
        lead = "embeds" if self.cfg.embeds_input else "tokens"
        want_ndim = 3 if lead == "embeds" else 2
        if out[lead].ndim == want_ndim - 1:
            out[lead] = out[lead][None]
        if "positions" in out and out["positions"].ndim == 1:
            out["positions"] = out["positions"][None]
        if out[lead].shape[0] != 1:
            raise ValueError(
                f"submit takes one request at a time; got batch "
                f"{out[lead].shape[0]} (call submit per row, or use "
                f"generate for a fixed batch)")
        return out, int(out[lead].shape[1])

    def submit(self, prompts, max_new: int, eos_id: Optional[int] = None,
               arrival: float = 0.0, tenant: str = "default",
               priority: int = 0) -> int:
        """Enqueue one request; returns its request id.

        ``prompts``: {"tokens": (s,) or (1, s)} (or "embeds"/"positions"
        rows).  The request is admitted by the scheduler when a slot frees
        up and ``arrival`` has passed (as judged by the ``now`` handed to
        ``step``/``drain``).  There is no prompt-length bucket cap: a
        prompt of any length completes via chunked prefill
        (``prefill_chunk_width``-token windows interleaved with decode);
        the only hard limit is the slot cache -- ``prompt_len + max_new``
        must fit ``max_seq``.

        ``tenant``/``priority`` feed the multi-tenant control plane:
        priority must sit in [0, priority_levels), and a tenant at its
        ``queue`` quota gets ``QuotaExceeded`` backpressure here instead
        of silent unbounded queuing.  Defaults reproduce single-tenant
        FIFO exactly."""
        req, s = self._normalize_request(prompts)
        sched = self._scheduler(prompt_len=s, max_new=max_new)
        ex = sched.ex
        if s + max_new > ex.max_seq:
            raise ValueError(
                f"prompt_len {s} + max_new {max_new} exceeds the slot "
                f"cache length {ex.max_seq}; construct the Engine with "
                f"max_seq>={s + max_new}")
        if ex.paged:
            # reject a request that could NEVER be admitted here, not at
            # its queue-head turn -- a late raise from reserve() would
            # strand every request behind it
            need = pages_needed(s, max_new, ex.page_size)
            if need > ex.n_pages:
                raise ValueError(
                    f"prompt_len {s} + max_new {max_new} needs {need} "
                    f"pages but the pool holds {ex.n_pages}; raise "
                    f"cache_pages or lower max_new")
        return sched.submit(req, s, max_new, eos_id=eos_id,
                            arrival=arrival, tenant=tenant,
                            priority=priority)

    def _make_policy(self):
        """Admission policy from the knobs: None (the scheduler's FIFO
        default) unless priorities, preemption, or fair-share weights
        were asked for -- so a default engine stays bit-compatible."""
        weights = {t: spec["weight"] for t, spec in self.tenants.items()
                   if "weight" in spec}
        if self.priority_levels <= 1 and not self.preempt and not weights:
            return None
        return PriorityAdmission(levels=self.priority_levels,
                                 weights=weights or None,
                                 preempt=self.preempt)

    def _make_quotas(self) -> Tuple[Dict[str, TenantQuota],
                                    Optional[TenantQuota]]:
        """(per-tenant quota overrides, default quota) from the knobs +
        ``tenants`` specs.  A tenant spec carrying any quota axis builds
        its own TenantQuota, inheriting unset axes from the defaults."""
        ts, tp = self.knobs.tenant_slots, self.knobs.tenant_pages
        default = (TenantQuota(slots=ts, pages=tp)
                   if ts is not None or tp is not None else None)
        quotas = {}
        for t, spec in self.tenants.items():
            if {"slots", "pages", "queue"} & set(spec):
                quotas[t] = TenantQuota(slots=spec.get("slots", ts),
                                        pages=spec.get("pages", tp),
                                        queue=spec.get("queue"))
        return quotas, default

    def _scheduler(self, prompt_len: int = 0, max_new: int = 0) -> Scheduler:
        if self._sched is None:
            ms = self.max_seq or (prompt_len + max_new)
            ex = _DeviceExecutor(self, self.capacity, ms, self.chunk)
            quotas, default = self._make_quotas()
            self._sched = Scheduler(ex, policy=self._make_policy(),
                                    quotas=quotas, default_quota=default)
        return self._sched

    def step(self, now: float = float("inf")) -> List[int]:
        """One scheduler tick: admit due requests into free slots, run one
        decode chunk over active slots.  Returns rids finished this tick."""
        if self._sched is None:
            return []
        return self._sched.tick(now)

    def drain(self, now: float = float("inf"),
              fresh_only: bool = False) -> Dict[int, np.ndarray]:
        """Run the scheduler until every admissible request completes;
        returns {rid: (n_tokens,) int32} for finished requests.

        CONTRACT: by default the result is CUMULATIVE -- every request
        that ever finished on this engine and was not popped, not just
        the ones this call ran.  A repeat-measurement loop that submits,
        drains, and forgets ``pop_finished()`` therefore double-counts
        earlier replays' tokens in later results.  Either pop between
        replays, or pass ``fresh_only=True`` to get only the requests
        that finished DURING this call (bookkeeping is untouched: the
        fresh results remain collectible via ``result``/``results``/
        ``pop_finished`` afterwards)."""
        if self._sched is None:
            return {}
        fin = self._sched.drain(now)
        if fresh_only:
            reqs = self._sched.requests
            return {rid: np.asarray(reqs[rid].tokens, np.int32)
                    for rid in fin if rid in reqs}
        return self._sched.results()

    def result(self, rid: int) -> Optional[np.ndarray]:
        if self._sched is None or rid not in self._sched.requests:
            return None
        req = self._sched.requests[rid]
        return np.asarray(req.tokens, np.int32) if req.done else None

    def pop_finished(self) -> Dict[int, np.ndarray]:
        """Collect finished requests AND drop their bookkeeping -- what a
        long-running submit/step server should call each cycle so host
        memory tracks in-flight work, not everything ever served.  This
        is also what resets ``drain()``'s cumulative results between
        repeat measurements (or use ``drain(fresh_only=True)``)."""
        if self._sched is None:
            return {}
        return self._sched.pop_finished()

    def score(self, sequences) -> List[np.ndarray]:
        """Teacher-forced token log-likelihoods THROUGH the serving path.

        Each sequence ((s,) int token ids, s >= 2) is submitted as a real
        request (``max_new=1``) and driven through the scheduler's fused
        prefill-append windows on THIS engine's executor -- packed
        kernels, paged cache and all -- with logits captured at every
        window position (``prefill_chunk(all_logits=True)``).  Returns
        one (s-1,) float32 array per sequence: ``out[i] = log P(seq[i+1]
        | seq[:i+1])``, the quantity PPL and per-option continuation
        scoring are built from (src/repro/eval/).

        Scoring requests pin explicit default positions, which (a)
        leaves RoPE rotations identical to a plain submit and (b) keeps
        them out of the prefix-sharing index -- a shared prefix SKIPS
        its prefill windows, and a scored sequence needs logits at every
        position.  The engine must be idle (no queued/running requests):
        capture forces every concurrent prefill through the append path,
        which would perturb a generation request's numeric grouping.
        Scoring bookkeeping is dropped on exit, so ``drain``/
        ``pop_finished`` results never mix scoring rids into serving
        traffic.  The first sampled token of each request is discarded.
        """
        cfg = self.cfg
        if cfg.embeds_input:
            raise ValueError("score() requires a token-input model "
                             "(embeds-frontend configs have no token "
                             "likelihoods to score)")
        seqs = [np.asarray(s).reshape(-1).astype(np.int32)
                for s in sequences]
        if not seqs:
            return []
        if min(len(s) for s in seqs) < 2:
            raise ValueError("score() needs sequences of >= 2 tokens "
                             "(one context token, one to score)")
        sched = self._scheduler(prompt_len=max(len(s) for s in seqs),
                                max_new=1)
        ex = sched.ex
        if ex.capture is not None:
            raise RuntimeError("score() is not reentrant")
        if sched.pending:
            raise RuntimeError(
                "score() requires an idle engine: drain() or "
                "pop_finished() in-flight requests first (logit capture "
                "changes how concurrent prefills group)")
        ex.capture = {}
        rids: List[int] = []
        try:
            for s in seqs:
                rid = self.submit(
                    {"tokens": s[None, :],
                     "positions": np.arange(len(s), dtype=np.int32)[None]},
                    max_new=1)
                ex.capture[rid] = []
                rids.append(rid)
            sched.drain()
            out: List[np.ndarray] = []
            for rid, s in zip(rids, seqs):
                wins = sorted(ex.capture[rid], key=lambda t: t[0])
                pos = 0
                contiguous = bool(wins)
                for st, w in wins:
                    contiguous = contiguous and st == pos
                    pos += w.shape[0]
                if not contiguous or pos != len(s):
                    raise RuntimeError(
                        f"rid {rid}: captured windows cover {pos} of "
                        f"{len(s)} positions (starts "
                        f"{[st for st, _ in wins]}) -- scoring capture "
                        f"lost prefill windows")
                logits = np.concatenate([w for _, w in wins], axis=0)
                # stable log-softmax over the REAL vocab columns (padded
                # columns are junk the sampler masks; mask here too)
                lf = logits[:, :cfg.vocab].astype(np.float64)
                m = lf.max(axis=-1, keepdims=True)
                lsm = lf - (m + np.log(
                    np.exp(lf - m).sum(axis=-1, keepdims=True)))
                out.append(lsm[np.arange(len(s) - 1),
                               s[1:]].astype(np.float32))
            return out
        finally:
            ex.capture = None
            for rid in rids:
                sched.requests.pop(rid, None)

    def stream(self, now: float = float("inf")):
        """Tick the scheduler and yield a ``TokenEvent`` per emitted
        token, in emission order -- the streaming face of the continuous
        path, making time-to-first-token observable per request (each
        request's first event carries its ``ttft``).

        Runs until every request with ``arrival <= now`` completes (the
        same stop condition as ``drain``), but hands tokens back as each
        tick lands instead of at the end.  Purely additive bookkeeping:
        ``drain()``/``pop_finished()`` semantics are untouched, and
        finished requests stay collectible afterwards.  More requests
        may be submitted between events; the generator picks them up on
        its next tick."""
        if self._sched is None:
            return
        sched = self._sched
        cursors: Dict[int, int] = {}
        while sched.pending:
            if not sched.n_active and not sched.preempted:
                nxt = sched.next_arrival()
                if nxt is not None and nxt > now:
                    break                       # future arrivals only
            sched.tick(now)
            events: List[TokenEvent] = []
            for rid, req in sched.requests.items():
                seen = cursors.get(rid, 0)
                if len(req.tokens) > seen:
                    events.extend(
                        TokenEvent(rid=rid, token=int(req.tokens[i]),
                                   index=i, tenant=req.tenant,
                                   done=(req.done
                                         and i == len(req.tokens) - 1),
                                   ttft=req.ttft if i == 0 else None)
                        for i in range(seen, len(req.tokens)))
                    cursors[rid] = len(req.tokens)
            # buffered per tick: yielding mid-dict-walk would break if
            # the consumer submits or pops between events
            yield from events

    def stats(self) -> Dict[str, Any]:
        """Control-plane telemetry snapshot: scheduler counters
        (preemptions, per-tenant resident usage) plus, for paged
        engines, the allocator's frame-state counters
        (``PageAllocator.stats()``) and executor swap counts.  The bench
        and the fuzzer invariants read this instead of poking
        internals."""
        out: Dict[str, Any] = {"preemptions": 0, "tenants": {}}
        if self._sched is None:
            return out
        sched = self._sched
        out["preemptions"] = sched.preemptions
        out["tenants"] = {t: {"slots": u[0], "pages": u[1]}
                          for t, u in sched.tenant_usage.items()}
        ex = sched.ex
        if getattr(ex, "paged", False):
            out["pages"] = ex.allocator.stats()
            out["swap_outs"] = ex.swap_outs
            out["swap_ins"] = ex.swap_ins
        return out

    # ------------------------------------------------------------------
    # generate
    # ------------------------------------------------------------------

    def _decode_steps(self, max_new: int) -> int:
        # scan length bucketed so distinct max_new values share a compiled
        # loop (scan steps are sequential, so the first max_new tokens are
        # identical regardless of trailing discarded steps); short requests
        # use power-of-two buckets to cap discarded work at <2x.
        db = self.decode_bucket
        if max_new >= db:
            return round_up(max_new, db)
        return next_pow2(max_new)

    def generate(self, prompts: Dict[str, jnp.ndarray], max_new: int,
                 max_seq: Optional[int] = None,
                 legacy_loop: bool = False,
                 mode: str = "continuous") -> np.ndarray:
        """(B, max_new) tokens.  ``mode``: "continuous" (scheduler path,
        default), "batch" (one-shot padded scan loop), "legacy" (per-token
        Python loop).  ``legacy_loop=True`` is the historical alias for
        mode="legacy".

        Greedy output is identical across all three modes.  For a fixed
        batch where minimum host syncs matter more than slot recycling,
        prefer mode="batch" (one batched prefill, one sync per call);
        the continuous path prefills per row and syncs per chunk.  Under
        temperature>0 the continuous path samples per-slot PRNG streams,
        not the batch-shared stream (see docs/serving.md)."""
        if legacy_loop:
            mode = "legacy"
        if mode == "legacy":
            return self._generate_legacy(prompts, max_new, max_seq)
        if mode == "batch":
            return self._generate_batch(prompts, max_new, max_seq)
        if mode != "continuous":
            raise ValueError(f"unknown generate mode: {mode!r}")
        return self._generate_continuous(prompts, max_new, max_seq)

    def _generate_continuous(self, prompts: Dict[str, jnp.ndarray],
                             max_new: int,
                             max_seq: Optional[int] = None) -> np.ndarray:
        """Compatibility wrapper: each row becomes a scheduler request
        (capacity = batch, so admission is immediate); greedy output is
        token-for-token identical to mode="batch"."""
        cfg = self.cfg
        # host copies once: the executor slices a window per prefill call,
        # which must not re-fetch device-resident prompts every window
        prompts = {k: np.asarray(v) for k, v in dict(prompts).items()}
        b, s = (prompts["embeds"].shape[:2] if cfg.embeds_input
                else prompts["tokens"].shape)
        # mirror the batch path's cache sizing exactly (decode-bucketed
        # steps) so both modes compile and mask identical shapes
        n_steps = self._decode_steps(max_new)
        want = max_seq or (s + n_steps)
        ms = max(self._round_bucket(want), self._round_bucket(s))
        ex = self._executor(capacity=b, max_seq=ms)
        sched = Scheduler(ex)
        rids = []
        for i in range(b):
            row = {k: v[i:i + 1] for k, v in prompts.items()}
            rids.append(sched.submit(row, s, max_new))
        sched.drain()
        res = sched.results()
        return np.stack([res[r][:max_new] for r in rids], axis=0)

    def _generate_batch(self, prompts: Dict[str, jnp.ndarray], max_new: int,
                        max_seq: Optional[int] = None) -> np.ndarray:
        """One-shot padded batch: bucketed prefill + a single jitted scan
        decode with one host sync per call."""
        n_steps = self._decode_steps(max_new)
        # the cache is sized for ALL n_steps writes so no KV slot clamps
        logits, cache, lengths = self.run_prefill(prompts, n_steps, max_seq)
        key = jax.random.PRNGKey(self.sampler.seed)
        key, k0 = jax.random.split(key)
        tok0 = self._sample(logits, key=k0)
        toks = self._decode_loop(self.params, tok0, cache, lengths, key,
                                 max_new=n_steps)
        return np.asarray(toks)[:, :max_new]   # the ONE host sync per call

    def _generate_legacy(self, prompts: Dict[str, jnp.ndarray], max_new: int,
                         max_seq: Optional[int] = None) -> np.ndarray:
        """Original per-token loop: one device->host sync per token."""
        cfg = self.cfg
        b, s = (prompts["embeds"].shape[:2] if cfg.embeds_input
                else prompts["tokens"].shape)
        max_seq = max_seq or (s + max_new)
        logits, cache, lengths = self._prefill(self.params, batch=prompts,
                                               max_seq=max_seq)
        key = jax.random.PRNGKey(self.sampler.seed)
        outs = []
        key, k0 = jax.random.split(key)
        tok = sample_logits(logits, cfg, self.sampler, k0)
        outs.append(np.asarray(tok))
        for _ in range(max_new - 1):
            logits, cache, lengths = self._decode(
                self.params, inputs=B.decode_inputs(tok, cfg), cache=cache,
                lengths=lengths)
            key, k1 = jax.random.split(key)
            tok = sample_logits(logits, cfg, self.sampler, k1)
            outs.append(np.asarray(tok))
        return np.stack(outs, axis=1)     # (B, max_new)
