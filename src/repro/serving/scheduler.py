"""Continuous-batching request scheduler (host side).

Request lifecycle::

    submit -> queue (FIFO) -> admission into a free slot (arrival due) ->
    PREFILLING (prompt appended to the slot's cache window-by-window;
    same-width seats fused k-way per tick; first token sampled when the
    prompt completes) -> RUNNING (interleaved chunked decode) -> done ->
    slot recycled for the next queued request, mid-decode

The scheduler is deliberately model-free: it drives an ``Executor`` --
either the engine-backed device executor (serving.engine) or a scripted
fake (tests/test_scheduler.py) -- through three operations::

    prefill_step(seats)                    -> {slot: (consumed, tok0|None)}
    run_chunk(active, remaining, eos_ids)  -> (tokens, emitted) [steps x B]
    release(slot)                          -> evict a finished row

``prefill_step`` takes every seat currently prefilling, as (slot,
request, tokens_already_appended) triples, advances each by one window
(the engine executor fuses up to ``admit_k`` same-width seats per jitted
call), and reports per-slot progress -- ``tok0`` is the request's first
sampled token once its whole prompt is in the cache.  Prefill windows and
decode chunks interleave tick-by-tick, so a long prompt streams in while
resident slots keep decoding.

This keeps the invariant surface (no dropped / duplicated / reordered
tokens, occupancy <= capacity, FIFO admission, prefill progress every
tick, every slot freed at drain) property-testable without JAX in the
loop.

Paged executors additionally expose ``reserve(slot, req)``: admission
claims KV pages (``PageAllocator``) before a request takes its seat, and
blocks head-of-line while the pool is too full -- free SEATS are no
longer sufficient, the backing pages must exist too.  With prefix
sharing (``PrefixIndex``), reserve may map already-resident prefix
frames into the new page table (refcount + 1) and set
``req.prefill_skip``: the scheduler then skips those tokens' prefill
windows entirely and streams only the unshared suffix.

Multi-tenant control plane: admission order is owned by a pluggable
``AdmissionPolicy``.  The default (``FifoAdmission``) reproduces the
historical head-of-line FIFO pop bit-compatibly; ``PriorityAdmission``
adds priority classes, weighted fair-share across tenants (min virtual
service time wins within the top effective-priority band), anti-
starvation aging (``skipped // aging`` effective-priority bumps), and
optional preemption: a RUNNING victim of lower effective priority is
swapped out (``Executor.preempt`` -- its private KV pages move to a
host pool, refcount-shared frames stay resident), parks in the
PREEMPTED phase, and later re-enters RUNNING directly through
``Executor.resume`` -- no re-prefill, lengths/positions preserved,
O(pages) cost.  Each preemption grants the victim ``aging`` skip
credits, so repeated victims climb out of eligibility and progress is
guaranteed.  Per-tenant ``TenantQuota``s bound resident seats and
reserved pages at admission and outstanding requests at submit
(``QuotaExceeded`` backpressure).

Token accounting matches the one-shot engine paths exactly: the first
token of a request is sampled from its prefill logits (it counts toward
``max_new``), the remaining ``max_new - 1`` come from decode steps, and an
EOS match (``eos_id >= 0``) stops the request *after* emitting the EOS.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional, Protocol, Tuple

import numpy as np

QUEUED, PREFILLING, RUNNING, DONE = ("queued", "prefilling", "running",
                                    "done")
PREEMPTED = "preempted"


class QuotaExceeded(RuntimeError):
    """Submit-time backpressure: the tenant's outstanding-request quota
    is full.  Callers should retry after draining results (or shed
    load); the request was NOT enqueued."""


def pages_needed(prompt_len: int, max_new: int, page_size: int) -> int:
    """Frames a request's admission must reserve: whole prompt + decode
    budget, rounded up to whole pages, never zero (the empty prompt still
    owns the frame its first decode token lands in).  Single definition
    shared by ``Engine.submit``'s early reject and the executor's
    ``reserve`` backstop -- a disagreement between the two would let a
    request pass submit and then strand the queue at its head turn."""
    return max(1, -(-(int(prompt_len) + int(max_new)) // int(page_size)))


class PageAllocator:
    """Host-side refcounted free list over a shared KV page pool.

    A slot's admission RESERVES ``ceil((prompt_len + max_new) /
    page_size)`` physical frames up front (``alloc``), so device-side
    prefill windows and decode chunks can never run out of frames
    mid-flight -- the deadlock-free discipline behind letting capacity
    exceed ``n_pages // pages_per_slot`` seats.

    Prefix sharing adds per-frame REFCOUNTS: ``alloc`` hands out frames
    at refcount 1, ``share`` pins an already-live frame for one more
    owner (a second page table mapping it, or the prefix index caching
    it), and ``free`` releases one owner -- a frame returns to the free
    list only when its last owner lets go, so evicting a sharer can
    never free frames a live sequence still maps.

    Preemption adds a third frame state: ``swap_out`` VACATES a
    refcount-1 frame whose data just moved to a host-memory pool
    (live -> swapped).  Swapped frames are reusable capacity -- ``alloc``
    draws from the free list first, then from the swapped pool (the
    device copy is dead; the owner's data lives on host until its
    resume scatters it into freshly allocated frames).  Refcount-shared
    frames are never swapped: ``swap_out`` refuses them, and the
    preempted owner keeps its refcount so the sharers' release can
    never free data the victim still needs.  Conservation invariant
    (property-tested in tests/test_serving_fuzz.py)::

        free + live + swapped == n_pages     (every frame in one state)

    Pure host bookkeeping, no JAX."""

    def __init__(self, n_pages: int):
        if n_pages < 1:
            raise ValueError(f"n_pages must be >= 1, got {n_pages}")
        self.n_pages = int(n_pages)
        # LIFO free list: recently freed (still-warm) frames reused first
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._ref: Dict[int, int] = {}
        # frames vacated by preemption (their data moved to host); drawn
        # by alloc after the free list runs dry
        self._swapped: List[int] = []

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        """Frames with refcount >= 1 (mapped by a table or index-cached)."""
        return len(self._ref)

    @property
    def n_swapped(self) -> int:
        """Frames vacated by preemption, not yet reallocated."""
        return len(self._swapped)

    @property
    def n_pinned(self) -> int:
        """Frames with refcount >= 2 (shared across tables / the index)."""
        return sum(1 for r in self._ref.values() if r >= 2)

    def refcount(self, frame: int) -> int:
        return self._ref.get(frame, 0)

    def stats(self) -> Dict[str, int]:
        """Snapshot of the pool's frame-state counters -- the single
        observable tests and bench reporting should read instead of
        poking internals.  ``free + live + swapped == n_pages`` always;
        ``pinned`` counts the subset of ``live`` at refcount >= 2."""
        return {"n_pages": self.n_pages, "free": self.n_free,
                "live": self.n_live, "pinned": self.n_pinned,
                "swapped": self.n_swapped}

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` frames at refcount 1 -- free list first, then
        preemption-vacated frames -- or None (and no change) if
        unavailable."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free) + len(self._swapped):
            return None
        frames = [(self._free.pop() if self._free else self._swapped.pop())
                  for _ in range(n)]
        for f in frames:
            self._ref[f] = 1
        return frames

    def swap_out(self, frames: List[int]) -> None:
        """Vacate refcount-1 frames whose data just moved to host
        (live -> swapped).  Shared frames (refcount >= 2) must stay
        resident -- the preempting caller splits them out and keeps its
        refcount on them; passing one here is a bug and raises."""
        for f in frames:
            if self._ref.get(f, 0) != 1:
                raise ValueError(
                    f"swap_out of page {f} at refcount "
                    f"{self._ref.get(f, 0)} (only private refcount-1 "
                    f"frames may be swapped)")
        for f in frames:
            del self._ref[f]
            self._swapped.append(f)

    def share(self, frames: List[int]) -> None:
        """Add one owner to each (live) frame -- the copy-on-write map:
        a prefix hit installs the donor's frames in a second page table
        at refcount + 1 instead of copying them."""
        for f in frames:
            if self._ref.get(f, 0) < 1:
                raise ValueError(f"share of free page {f}")
            self._ref[f] += 1

    def free(self, frames: List[int]) -> None:
        """Release one owner per frame; frames whose last owner lets go
        return to the free list."""
        for f in frames:
            r = self._ref.get(f, 0)
            if r < 1:
                raise ValueError(f"double free of page {f}")
            if r == 1:
                del self._ref[f]
                self._free.append(f)
            else:
                self._ref[f] = r - 1


def prefix_keys(tokens, page_size: int) -> List[Any]:
    """Chain keys for every FULL page of a prompt: ``key_i =
    sha256(key_{i-1} || page_i tokens)`` covers tokens ``[0, (i+1) *
    page_size)``, so two prompts share key_i iff their first ``(i+1) *
    page_size`` tokens are identical (collisions cryptographically
    negligible).  Chained digests keep every key constant-size -- dict
    hashing and equality are O(1) per page regardless of prefix length
    (nested token tuples would re-hash the whole ancestry on every
    lookup, quadratic in prompt length).  The tail partial page never
    gets a key: only pages whose every position holds a prompt token are
    shareable."""
    import hashlib
    toks = np.ascontiguousarray(np.asarray(tokens).astype(np.int64))
    keys: List[Any] = []
    digest = b"halo-prefix-v1"
    for i in range(toks.shape[0] // page_size):
        page = toks[i * page_size:(i + 1) * page_size].tobytes()
        digest = hashlib.sha256(digest + page).digest()
        keys.append(digest)
    return keys


class PrefixIndex:
    """Host-side prefix cache: chain key (``prefix_keys``) -> physical
    frame holding that page's KV.

    Each entry pins its frame with one ``share`` ref, so a donor's pages
    survive the donor's release ("recently freed but cached") until pool
    pressure reclaims them LRU-first (``reclaim`` -- an evicted entry
    drops the index ref; the frame is actually freed only if no live
    page table still maps it).  ``lookup`` walks the chain from page 0
    and returns the longest indexed prefix; the caller shares the hit
    frames into the new page table.  Entries are only ever registered
    AFTER the owning request's prefill completed, so an indexed frame
    always holds finished prompt KV.

    Note the chain discipline: reclaiming a parent entry makes any
    surviving extension unreachable (``lookup`` stops at the gap); such
    orphans age out LRU like everything else."""

    def __init__(self, allocator: PageAllocator):
        self.alloc = allocator
        self._entries: "OrderedDict[Any, int]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, keys: List[Any]) -> List[int]:
        """Longest indexed prefix of ``keys`` -> its frames (LRU-touched).
        Frames are NOT shared here; the caller pins the ones it keeps."""
        hits: List[int] = []
        for k in keys:
            f = self._entries.get(k)
            if f is None:
                break
            self._entries.move_to_end(k)
            hits.append(f)
        return hits

    def register(self, keys: List[Any], frames: List[int]) -> None:
        """Index ``frames[i]`` under ``keys[i]`` (one index ref each).
        Keys already present keep their existing frame (two requests that
        prefilled the same prefix concurrently: first writer wins, the
        duplicate frames stay owned by their seat alone)."""
        for k, f in zip(keys, frames):
            if k in self._entries:
                self._entries.move_to_end(k)
                continue
            self.alloc.share([f])
            self._entries[k] = f

    def reclaim(self, n: int) -> int:
        """Drop LRU entries until ``n`` frames actually returned to the
        free list (entries whose frame a live table still maps free
        nothing) or the index is empty.  Returns the frames freed."""
        freed = 0
        while self._entries and freed < n:
            _, f = self._entries.popitem(last=False)
            before = self.alloc.n_free
            self.alloc.free([f])
            freed += self.alloc.n_free - before
        return freed

    def flush(self) -> int:
        """Drop every entry (shutdown / tests).  Returns frames freed."""
        return self.reclaim(self.alloc.n_pages)


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Per-tenant resource bounds.  ``None`` axes are unlimited.

    ``slots``/``pages`` bound RESIDENT usage (seats held and KV pages
    reserved by PREFILLING/RUNNING requests) -- enforced at admission,
    so an at-quota tenant's requests simply wait while other tenants'
    admit past them.  ``queue`` bounds OUTSTANDING requests (queued +
    resident + preempted) -- enforced at submit, where overflow raises
    ``QuotaExceeded`` (backpressure, not silent queuing)."""

    slots: Optional[int] = None
    pages: Optional[int] = None
    queue: Optional[int] = None

    def __post_init__(self):
        for name in ("slots", "pages", "queue"):
            v = getattr(self, name)
            if v is not None and int(v) < 1:
                raise ValueError(
                    f"TenantQuota.{name} must be >= 1 or None, got {v}")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Any                # dict of per-request arrays, leading dim 1
    prompt_len: int
    max_new: int
    eos_id: int = -1           # -1: never stops on a token
    arrival: float = 0.0
    status: str = QUEUED
    slot: Optional[int] = None
    prefilled: int = 0         # prompt tokens already appended to the cache
    tenant: str = "default"
    priority: int = 0          # higher = more urgent (policy-interpreted)
    skipped: int = 0           # admissions that passed this request over
    preempt_count: int = 0     # times this request was swapped out
    pages_reserved: int = 0    # quota accounting while resident (paged)
    # wall-clock stamps (time.perf_counter) for TTFT reporting: submit
    # time, first emitted token, completion.  TTFT = first_token_wall -
    # submit_wall; realtime benches subtract their own arrival offsets.
    submit_wall: float = 0.0
    first_token_wall: Optional[float] = None
    done_wall: Optional[float] = None
    # prompt tokens already RESIDENT at admission (shared-prefix pages the
    # executor's reserve() mapped from the prefix index): prefill starts
    # at this offset instead of 0, skipping the shared windows entirely
    prefill_skip: int = 0
    # memoized ``prefix_keys(...)`` result (reserve() retries every tick
    # while the head of line is blocked on pages; the chain is computed
    # once)
    prefix_key_chain: Optional[List[Any]] = dataclasses.field(
        default=None, repr=False)
    tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.status == DONE

    @property
    def remaining(self) -> int:
        return self.max_new - len(self.tokens)

    @property
    def ttft(self) -> Optional[float]:
        """Seconds from submit to first emitted token (None until then)."""
        if self.first_token_wall is None:
            return None
        return self.first_token_wall - self.submit_wall

    def _should_finish(self) -> bool:
        if len(self.tokens) >= self.max_new:
            return True
        return (self.eos_id >= 0 and bool(self.tokens)
                and self.tokens[-1] == self.eos_id)


class AdmissionPolicy:
    """Pluggable admission order (the object replacing the scheduler's
    historical hardcoded FIFO pop).  This base class IS the default
    FIFO policy: strictly head-of-line -- the oldest queued request
    admits only when it has arrived, a seat is free, its quota allows,
    and its page reservation succeeds; otherwise admission stops for
    the tick (later arrivals never jump the queue).  Bit-compatible
    with the pre-policy scheduler, which existing property tests and
    the differential fuzzer assert.

    Subclass hooks:

    ``select(sched, now, excluded)``  next request to try seating (None
        ends the admission loop for head-of-line policies, or just
        skips the excluded set otherwise);
    ``victim(sched, cand)``           RUNNING request to preempt so that
        ``cand`` can seat (None: never preempt);
    ``effective(req)``                the request's effective priority
        (aging-adjusted) -- used for victim eligibility;
    ``on_admit(sched, req)`` / ``on_preempt(req)``  bookkeeping taps.
    """

    name = "fifo"
    levels = 1                 # valid priorities: [0, levels)
    head_of_line = True        # a blocked candidate stops admission
    preempt = False

    def select(self, sched: "Scheduler", now: float,
               excluded: set) -> Optional[Request]:
        if not sched.queue:
            return None
        req = sched.requests[sched.queue[0]]
        if req.arrival > now or req.rid in excluded:
            return None
        if not sched._quota_ok(req):
            return None        # head-of-line: quota backpressure waits
        return req

    def effective(self, req: Request) -> int:
        return req.priority

    def victim(self, sched: "Scheduler",
               cand: Request) -> Optional[Request]:
        return None

    def on_admit(self, sched: "Scheduler", req: Request) -> None:
        pass

    def on_preempt(self, req: Request) -> None:
        pass


FifoAdmission = AdmissionPolicy


class PriorityAdmission(AdmissionPolicy):
    """Priority classes + weighted fair share + aging + preemption.

    Selection: among all waiting requests (queued AND preempted) that
    have arrived and fit their tenant's quota, take the highest
    EFFECTIVE priority band (``priority + skipped // aging``, capped at
    ``levels - 1``); within the band, the tenant with the least virtual
    service time wins (weighted fair share: ``vtime[tenant] +=
    (prompt_len + max_new) / weight`` on admit, with an idle-tenant
    catch-up floor so a returning tenant can't burst on stale credit);
    ties break on rid (submit order).  Not head-of-line: a blocked
    candidate is skipped and the next one tried, so one tenant's page
    pressure never stalls everyone.

    Aging is the no-starvation mechanism: every admission that passes a
    waiting request over bumps its ``skipped`` counter, and each
    ``aging`` skips raise its effective priority one level -- any
    request reaches the top band after a bounded wait, no matter how
    hot the high-priority arrival stream is (fuzzer-enforced).

    Preemption (``preempt=True``, executors exposing
    ``preempt``/``resume``): when a candidate finds no free seat (or
    not enough pages), a RUNNING victim with effective priority
    STRICTLY below the candidate's base priority is swapped out --
    lowest effective band first, newest rid within it.  A preempted
    victim is granted ``aging`` skip credits, so each round-trip
    raises its effective priority until it is no longer preemptable:
    livelock-free by construction."""

    name = "priority"
    head_of_line = False

    def __init__(self, levels: int = 2,
                 weights: Optional[Dict[str, float]] = None,
                 aging: int = 16, preempt: bool = False):
        if int(levels) < 1:
            raise ValueError(f"levels must be >= 1, got {levels}")
        if int(aging) < 0:
            raise ValueError(f"aging must be >= 0 (0 disables), "
                             f"got {aging}")
        self.levels = int(levels)
        self.weights = {t: float(w) for t, w in dict(weights or {}).items()}
        for t, w in self.weights.items():
            if w <= 0:
                raise ValueError(f"weight for tenant {t!r} must be > 0, "
                                 f"got {w}")
        self.aging = int(aging)
        self.preempt = bool(preempt)
        self.vtime: Dict[str, float] = {}

    def weight(self, tenant: str) -> float:
        return self.weights.get(tenant, 1.0)

    def effective(self, req: Request) -> int:
        eff = req.priority
        if self.aging > 0:
            eff += req.skipped // self.aging
        return min(self.levels - 1, eff)

    def select(self, sched: "Scheduler", now: float,
               excluded: set) -> Optional[Request]:
        cands = [r for r in sched._waiting(now)
                 if r.rid not in excluded and sched._quota_ok(r)]
        if not cands:
            return None
        top = max(self.effective(r) for r in cands)
        band = [r for r in cands if self.effective(r) == top]
        return min(band, key=lambda r: (self.vtime.get(r.tenant, 0.0),
                                        r.rid))

    def victim(self, sched: "Scheduler",
               cand: Request) -> Optional[Request]:
        if not self.preempt:
            return None
        elig = [sched.requests[rid] for rid in sched.slots
                if rid is not None
                and sched.requests[rid].status == RUNNING
                and self.effective(sched.requests[rid]) < cand.priority]
        if not elig:
            return None
        # lowest effective band loses first; newest admission within it
        return min(elig, key=lambda r: (self.effective(r), -r.rid))

    def on_admit(self, sched: "Scheduler", req: Request) -> None:
        t = req.tenant
        floor = min((self.vtime.get(r.tenant, 0.0)
                     for r in sched._waiting(float("inf"))), default=0.0)
        cost = float(req.prompt_len + req.max_new)
        self.vtime[t] = max(self.vtime.get(t, 0.0), floor) \
            + cost / self.weight(t)

    def on_preempt(self, req: Request) -> None:
        if self.aging > 0:
            req.skipped += self.aging    # one effective level per trip


class Executor(Protocol):
    """Device-facing half of the scheduler (see module docstring)."""

    capacity: int
    chunk: int

    def prefill_step(self, seats: List[Tuple[int, Request, int]]
                     ) -> Dict[int, Tuple[int, Optional[int]]]: ...

    def run_chunk(self, active: np.ndarray, remaining: np.ndarray,
                  eos_ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]: ...

    def release(self, slot: int) -> None: ...

    # Optional (paged executors): claim backing resources (KV pages) for a
    # request before it takes ``slot``; False blocks admission at the
    # queue head until a release frees enough.  Executors without the
    # method admit on free seats alone.  A successful reserve may set
    # ``req.prefill_skip`` > 0 (shared-prefix pages already resident):
    # the scheduler then starts PREFILLING at that offset and the
    # executor treats the first window as ``start == prefill_skip``.
    # def reserve(self, slot: int, req: Request) -> bool: ...

    # Optional (preemption-capable executors): swap a RUNNING request's
    # private state out of ``slot`` to host memory (keyed by req.rid) and
    # later restore it into a possibly different slot.  ``resume``
    # returns False while backing pages are unavailable (the request
    # stays PREEMPTED and retries).  A resumed request re-enters RUNNING
    # directly -- no PREFILLING pass; lengths, positions, PRNG streams
    # and emitted tokens are all preserved exactly.
    # def preempt(self, slot: int, req: Request) -> None: ...
    # def resume(self, slot: int, req: Request) -> bool: ...


class Scheduler:
    def __init__(self, executor: Executor,
                 policy: Optional[AdmissionPolicy] = None,
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 default_quota: Optional[TenantQuota] = None):
        self.ex = executor
        # admission order is policy-owned; the default reproduces the
        # historical head-of-line FIFO pop exactly
        self.policy = policy if policy is not None else FifoAdmission()
        self.quotas: Dict[str, TenantQuota] = dict(quotas or {})
        self.default_quota = default_quota
        self.queue: deque[int] = deque()          # rids, submit order
        self.preempted: List[int] = []            # rids awaiting resume
        self.requests: Dict[int, Request] = {}
        self.slots: List[Optional[int]] = [None] * executor.capacity
        self.preemptions = 0                      # lifetime swap-outs
        # resident usage per tenant: tenant -> [seats, reserved pages]
        self.tenant_usage: Dict[str, List[int]] = {}
        # outstanding (not DONE) requests per tenant, for submit-time
        # queue-quota backpressure
        self.tenant_outstanding: Dict[str, int] = {}
        self._ids = itertools.count()
        # busy-slot count per executor step, for occupancy reporting
        # (bounded so a long-running server doesn't grow host memory
        # per decode step).  Entries count decoding slots that emitted
        # PLUS slots that spent the tick appending prompt windows -- a
        # PREFILLING slot is doing real work (see ``occupancy``).
        self.occupancy_trace: deque[int] = deque(maxlen=65536)
        # prefill-busy seats per tick (diagnostics / the prefill-heavy
        # bench section); parallel to ticks, not decode steps
        self.prefill_trace: deque[int] = deque(maxlen=65536)

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------

    def submit(self, prompt: Any, prompt_len: int, max_new: int,
               eos_id: Optional[int] = None, arrival: float = 0.0,
               tenant: str = "default", priority: int = 0) -> int:
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if not 0 <= int(priority) < self.policy.levels:
            raise ValueError(
                f"priority {priority} outside [0, {self.policy.levels}) "
                f"(the {self.policy.name!r} policy's level count)")
        q = self._quota(tenant)
        if q is not None and q.queue is not None:
            outstanding = self.tenant_outstanding.get(tenant, 0)
            if outstanding >= q.queue:
                raise QuotaExceeded(
                    f"tenant {tenant!r} has {outstanding} outstanding "
                    f"requests (queue quota {q.queue}); drain results "
                    f"before submitting more")
        rid = next(self._ids)
        self.requests[rid] = Request(
            rid=rid, prompt=prompt, prompt_len=int(prompt_len),
            max_new=int(max_new),
            eos_id=-1 if eos_id is None else int(eos_id),
            arrival=float(arrival), tenant=str(tenant),
            priority=int(priority), submit_wall=time.perf_counter())
        self.queue.append(rid)
        self.tenant_outstanding[tenant] = \
            self.tenant_outstanding.get(tenant, 0) + 1
        return rid

    # ------------------------------------------------------------------
    # progress
    # ------------------------------------------------------------------

    @property
    def pending(self) -> bool:
        return (bool(self.queue) or bool(self.preempted)
                or any(s is not None for s in self.slots))

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def n_running(self) -> int:
        return sum(1 for rid in self.slots if rid is not None
                   and self.requests[rid].status == RUNNING)

    def next_arrival(self) -> Optional[float]:
        return (self.requests[self.queue[0]].arrival if self.queue
                else None)

    def tick(self, now: float = float("inf")) -> List[int]:
        """One scheduler step: admit due requests into free slots, advance
        every prefilling slot by one prompt window, then run one decode
        chunk over the running slots.  Returns rids finished this tick.
        Slots freed by the chunk are refilled on the *next* tick
        (mid-decode recycling); a request whose prompt completes in the
        admission/prefill phase decodes in the SAME tick's chunk."""
        finished: List[int] = []
        self._admit(now)
        pf_busy = self._prefill_tick(finished)
        if self.n_running:
            self._decode_chunk(finished, pf_busy)
        elif pf_busy:
            # prefill-only tick: decode ran zero steps but pf_busy slots
            # did prompt-append work -- record one occupancy entry so
            # utilization doesn't read as idle (the old accounting bug:
            # PREFILLING slots were invisible to occupancy())
            self.occupancy_trace.append(pf_busy)
        return finished

    def drain(self, now: float = float("inf")) -> List[int]:
        """Tick until nothing is queued or running (admitting every
        request with arrival <= ``now``; default: everything)."""
        finished: List[int] = []
        while self.pending:
            if not self.n_active and not self.preempted:
                nxt = self.next_arrival()
                if nxt is not None and nxt > now:
                    break                      # future arrivals only
            finished.extend(self.tick(now))
        return finished

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------

    def _finish(self, req: Request, finished: List[int]) -> None:
        req.status = DONE
        req.prompt = None      # the prompt arrays are dead weight now
        req.done_wall = time.perf_counter()
        if req.slot is not None:
            self.ex.release(req.slot)
            self.slots[req.slot] = None
            req.slot = None
            self._usage_sub(req)
        t = req.tenant
        self.tenant_outstanding[t] = max(
            0, self.tenant_outstanding.get(t, 0) - 1)
        finished.append(req.rid)

    # -- tenant quota bookkeeping --------------------------------------

    def _quota(self, tenant: str) -> Optional[TenantQuota]:
        q = self.quotas.get(tenant)
        return self.default_quota if q is None else q

    def _pages_for(self, req: Request) -> int:
        if not getattr(self.ex, "paged", False):
            return 0
        return pages_needed(req.prompt_len, req.max_new, self.ex.page_size)

    def _quota_ok(self, req: Request) -> bool:
        """Would seating ``req`` keep its tenant inside quota?"""
        q = self._quota(req.tenant)
        if q is None:
            return True
        seats, pages = self.tenant_usage.get(req.tenant, (0, 0))
        if q.slots is not None and seats + 1 > q.slots:
            return False
        if q.pages is not None and pages + self._pages_for(req) > q.pages:
            return False
        return True

    def _usage_add(self, req: Request) -> None:
        req.pages_reserved = self._pages_for(req)
        u = self.tenant_usage.setdefault(req.tenant, [0, 0])
        u[0] += 1
        u[1] += req.pages_reserved

    def _usage_sub(self, req: Request) -> None:
        u = self.tenant_usage.setdefault(req.tenant, [0, 0])
        u[0] -= 1
        u[1] -= req.pages_reserved
        req.pages_reserved = 0

    # -- admission -----------------------------------------------------

    def _waiting(self, now: float) -> List[Request]:
        """Arrived requests not currently seated: preempted (awaiting
        resume) first, then queued, both in submit order."""
        out = [self.requests[rid] for rid in self.preempted]
        out += [self.requests[rid] for rid in self.queue]
        return [r for r in out if r.arrival <= now]

    def _pick_victim(self, cand: Request) -> Optional[Request]:
        if not hasattr(self.ex, "preempt"):
            return None
        return self.policy.victim(self, cand)

    def _preempt(self, victim: Request) -> None:
        """Swap a RUNNING victim out of its slot: the executor moves its
        private state to host memory (keyed by rid); the scheduler parks
        it PREEMPTED.  Its emitted tokens, lengths and PRNG position all
        survive -- resume continues mid-decode, no re-prefill."""
        slot = victim.slot
        self.ex.preempt(slot, victim)
        self.slots[slot] = None
        victim.slot = None
        victim.status = PREEMPTED
        self.preempted.append(victim.rid)
        self._usage_sub(victim)
        self.preemptions += 1
        victim.preempt_count += 1
        self.policy.on_preempt(victim)

    def _seat(self, slot: int, req: Request) -> bool:
        """Try to place ``req`` in ``slot``: resume for PREEMPTED
        requests (executor restores swapped state -> RUNNING directly),
        reserve + PREFILLING for queued ones.  False: backing pages
        unavailable, nothing changed."""
        if req.status == PREEMPTED:
            if not self.ex.resume(slot, req):
                return False
            self.preempted.remove(req.rid)
            req.slot, req.status = slot, RUNNING
        else:
            reserve = getattr(self.ex, "reserve", None)
            if reserve is not None and not reserve(slot, req):
                return False
            self.queue.remove(req.rid)
            # reserve() may have mapped shared-prefix pages: those prompt
            # tokens are already resident, so prefill starts past them
            req.slot, req.status = slot, PREFILLING
            req.prefilled = req.prefill_skip
        self.slots[slot] = req.rid
        self._usage_add(req)
        return True

    def _admit(self, now: float) -> None:
        """Policy-driven admission loop.  Under the default FIFO policy
        this is bit-compatible with the historical head-of-line pop: the
        oldest queued request admits only when it has arrived, a seat is
        free and its reserve succeeds; any block stops admission for the
        tick.  Non-head-of-line policies (PriorityAdmission) instead
        skip a blocked candidate and try the next, and may create the
        free seat by preempting a lower-priority RUNNING victim --
        either when no seat is free, or when the seat exists but the
        page pool can't cover the candidate (each preemption frees the
        victim's private frames, so the reserve is retried after every
        swap-out).  Admission only assigns the seat (PREFILLING /
        resumed RUNNING); prompts stream in via ``_prefill_tick`` --
        same-width seats admitted together land in one fused append."""
        excluded: set = set()
        while True:
            cand = self.policy.select(self, now, excluded)
            if cand is None:
                return
            slot = next((i for i, r in enumerate(self.slots) if r is None),
                        None)
            if slot is None:
                victim = self._pick_victim(cand)
                if victim is None:
                    if self.policy.head_of_line:
                        return
                    excluded.add(cand.rid)
                    continue
                slot = victim.slot
                self._preempt(victim)
            if not self._seat(slot, cand):
                seated = False
                while True:          # free pages by evicting more victims
                    victim = self._pick_victim(cand)
                    if victim is None:
                        break
                    self._preempt(victim)
                    if self._seat(slot, cand):
                        seated = True
                        break
                if not seated:
                    if self.policy.head_of_line:
                        return
                    excluded.add(cand.rid)
                    continue
            for r in self._waiting(now):     # aging: passed-over waiters
                r.skipped += 1
            self.policy.on_admit(self, cand)

    def _prefill_tick(self, finished: List[int]) -> int:
        """Advance every PREFILLING slot by one prompt window.  A request
        whose prompt completes samples its first token (it counts toward
        ``max_new``, exactly like the one-shot paths) and turns RUNNING --
        or finishes outright on max_new == 1 / instant EOS.

        Returns the number of seats whose prompt-append work this tick is
        NOT otherwise visible to occupancy: seats still prefilling after
        the tick, plus seats that finished outright here (max_new == 1 /
        instant EOS -- they never reach a decode chunk).  Seats that
        turned RUNNING are excluded: they decode in the same tick's chunk
        and would double-count."""
        seats = [(req.slot, req, req.prefilled)
                 for rid in self.slots if rid is not None
                 for req in (self.requests[rid],)
                 if req.status == PREFILLING]
        if not seats:
            self.prefill_trace.append(0)
            return 0
        pf_busy = 0
        progress = self.ex.prefill_step(seats)
        for slot, (consumed, tok0) in progress.items():
            rid = self.slots[slot]
            if rid is None:
                raise RuntimeError(
                    f"executor prefilled empty slot {int(slot)}")
            req = self.requests[rid]
            if consumed <= 0 and tok0 is None:
                # consumed == 0 is legitimate only for the empty-prompt
                # degenerate case, which must complete (tok0) immediately
                raise RuntimeError(
                    f"prefill_step made no progress on slot {int(slot)} "
                    f"(rid {rid})")
            req.prefilled += int(consumed)
            if tok0 is None:
                pf_busy += 1                   # still appending next tick
                continue
            if req.prefilled < req.prompt_len:
                raise RuntimeError(
                    f"rid {rid} sampled tok0 with only {req.prefilled}/"
                    f"{req.prompt_len} prompt tokens appended")
            req.status = RUNNING
            req.tokens.append(int(tok0))
            if req.first_token_wall is None:   # TTFT: first emitted token
                req.first_token_wall = time.perf_counter()
            if req._should_finish():           # max_new == 1 or instant EOS
                self._finish(req, finished)
                pf_busy += 1                   # worked here, never decodes
        self.prefill_trace.append(pf_busy)
        return pf_busy

    def _decode_chunk(self, finished: List[int], pf_busy: int = 0) -> None:
        """One executor decode tick over the RUNNING slots.

        Tokens-per-tick-per-slot is VARIABLE: the executor returns
        ``(toks, emitted)`` shaped (n_steps, capacity) where n_steps is
        whatever the tick ran -- ``chunk`` sequential decode steps on the
        plain path, ``k + 1`` verify positions on the speculative path --
        and ``emitted[t, s]`` marks the steps that really produced a
        token (a speculative slot commits anywhere from 1 to k+1 per
        tick, and EOS/budget death mid-run truncates the tail on
        device).  The host accounting below only trusts ``emitted``; it
        never assumes a fixed per-slot rate."""
        cap = self.ex.capacity
        active = np.zeros((cap,), bool)
        remaining = np.zeros((cap,), np.int32)
        eos_ids = np.full((cap,), -1, np.int32)
        for s, rid in enumerate(self.slots):
            if rid is None:
                continue
            req = self.requests[rid]
            if req.status != RUNNING:          # PREFILLING slots stay parked
                continue
            active[s] = True
            remaining[s] = req.remaining
            eos_ids[s] = req.eos_id
        toks, emitted = self.ex.run_chunk(active, remaining, eos_ids)
        # each decode step's busy count includes the seats concurrently
        # streaming prompt windows this tick (disjoint from RUNNING
        # slots, so the sum stays <= capacity)
        self.occupancy_trace.extend(int(n) + pf_busy
                                    for n in emitted.sum(axis=1))
        # over-emission guard: the device clamps every slot's run to its
        # remaining budget (and truncates at EOS), so a tick emitting
        # MORE than ``remaining`` for any slot is an executor bug -- fail
        # loudly here rather than silently over-appending tokens a page
        # reservation never covered
        counts = emitted.sum(axis=0)
        over = active & (counts > remaining)
        if over.any():
            s = int(np.nonzero(over)[0][0])
            raise RuntimeError(
                f"executor emitted {int(counts[s])} tokens for slot {s} "
                f"(rid {self.slots[s]}) with only {int(remaining[s])} "
                f"remaining")
        if bool(emitted[:, ~active].any()):
            s = int(np.nonzero(emitted.any(axis=0) & ~active)[0][0])
            raise RuntimeError(
                f"executor emitted tokens for inactive slot {s}")
        for t in range(toks.shape[0]):
            for s in np.nonzero(emitted[t])[0]:
                rid = self.slots[s]
                if rid is None:
                    raise RuntimeError(
                        f"executor emitted a token for empty slot {int(s)}")
                self.requests[rid].tokens.append(int(toks[t, s]))
        for rid in list(self.slots):
            if rid is not None and self.requests[rid]._should_finish():
                self._finish(self.requests[rid], finished)

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------

    def occupancy(self) -> float:
        """Mean fraction of slots doing useful work per executor step.

        "Useful work" counts decode emissions AND prompt-window appends:
        a slot mid-chunked-prefill is busy, not idle (the prefill-heavy
        bench section previously misreported utilization because only
        decode ``emitted`` steps were counted).  Prefill-only ticks
        contribute one entry each; ticks with a decode chunk contribute
        one entry per decode step, each including the seats that spent
        the tick prefilling."""
        if not self.occupancy_trace:
            return 0.0
        return float(np.mean(self.occupancy_trace)) / self.ex.capacity

    def results(self) -> Dict[int, np.ndarray]:
        return {rid: np.asarray(r.tokens, np.int32)
                for rid, r in self.requests.items() if r.done}

    def pop_finished(self) -> Dict[int, np.ndarray]:
        """``results()`` that also forgets the finished requests -- the
        bookkeeping a long-running submit/step server should use so host
        memory tracks in-flight work, not total work ever served."""
        out = self.results()
        for rid in out:
            del self.requests[rid]
        return out
