"""Engine tuning knobs + the versioned TunedConfig artifact.

``EngineKnobs`` consolidates every continuous-serving tuning parameter that
used to live as scattered ``Engine.__init__`` kwargs (``chunk``, ``admit_k``,
``page_size``, ``prefill_chunk_width``, speculative ``k``) plus the Pallas
kernel block-M override, behind one frozen, validated dataclass.  The engine
kwargs survive as a thin compatibility layer: an explicit kwarg always wins
over a knob coming from a ``TunedConfig``.

``TunedConfig`` is the artifact the hardware-in-the-loop autotuner
(serving/autotune.py) emits: the winning knobs plus the probe telemetry, the
per-layer DVFS schedule derived from the packed weight-class composition,
and enough host/context metadata to keep bench trajectories comparable.  It
round-trips through JSON (``save``/``load``) and is versioned so stale
artifacts fail loudly instead of mis-tuning a future engine.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Optional, Tuple

from ..utils import round_up

TUNED_CONFIG_VERSION = 1


@dataclasses.dataclass(frozen=True)
class EngineKnobs:
    """Every continuous-serving tuning knob in one place.

    chunk: decode steps fused per host sync (tick length).
    admit_k: seats per fused admission/prefill-append call (the executor
      still clamps to its own capacity, preserving the historical kwarg
      behavior -- ``validated(strict=True)`` raises instead).
    paged / page_size: paged KV cache and its frame length in tokens.
    prefill_chunk_width: widest prompt window per fused prefill-append call
      (None: the engine's auto default, 4 buckets floored at 64).
    speculative / spec_k: self-speculative decoding and its draft depth.
    block_m: Pallas ``halo_matmul`` block-M override threaded to every
      packed weight leaf (None: the kernel's 128 default).  Numerics are
      bit-identical across block sizes; on the CPU/XLA lowering the value
      is carried but inert.
    priority_levels: scheduler priority classes (1: the FIFO default --
      the engine keeps the plain FIFO admission policy; >= 2 switches the
      scheduler to priority + weighted-fair-share admission).
    preempt: allow the scheduler to swap low-priority RUNNING requests'
      KV pages out to host memory when a higher-priority request is
      blocked (requires the paged cache; FIFO engines never preempt).
    tenant_slots / tenant_pages: default per-tenant resident quotas
      (slots seated / pages reserved); None = unlimited.  Per-tenant
      overrides ride ``Engine(tenants=...)``.
    """

    chunk: int = 8
    admit_k: int = 4
    paged: bool = False
    page_size: int = 16
    prefill_chunk_width: Optional[int] = None
    speculative: bool = False
    spec_k: int = 4
    block_m: Optional[int] = None
    priority_levels: int = 1
    preempt: bool = False
    tenant_slots: Optional[int] = None
    tenant_pages: Optional[int] = None

    def __post_init__(self):
        if int(self.chunk) < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        if int(self.admit_k) < 1:
            raise ValueError(f"admit_k must be >= 1, got {self.admit_k}")
        if int(self.page_size) < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.prefill_chunk_width is not None and int(
                self.prefill_chunk_width) < 1:
            raise ValueError(
                f"prefill_chunk_width must be >= 1 or None, got "
                f"{self.prefill_chunk_width}")
        if int(self.spec_k) < 0:
            raise ValueError(f"k must be >= 0, got {self.spec_k}")
        if self.block_m is not None and (
                int(self.block_m) < 8 or int(self.block_m) % 8):
            raise ValueError(
                f"block_m must be a multiple of 8 (the f32 sublane tile), "
                f"got {self.block_m}")
        if int(self.priority_levels) < 1:
            raise ValueError(
                f"priority_levels must be >= 1, got {self.priority_levels}")
        if self.preempt and not self.paged:
            raise ValueError(
                "preempt=True requires paged=True (preemption swaps "
                "page-table frames; contiguous rows have none)")
        if self.tenant_slots is not None and int(self.tenant_slots) < 1:
            raise ValueError(
                f"tenant_slots must be >= 1 or None, got "
                f"{self.tenant_slots}")
        if self.tenant_pages is not None and int(self.tenant_pages) < 1:
            raise ValueError(
                f"tenant_pages must be >= 1 or None, got "
                f"{self.tenant_pages}")

    @classmethod
    def resolve(cls, tuned: Optional["TunedConfig"] = None,
                **overrides: Any) -> "EngineKnobs":
        """Knob resolution for the engine kwargs compatibility layer.

        Starts from ``tuned.knobs`` (or the defaults) and applies every
        override that is not None -- so an explicit ``Engine(...)`` kwarg
        always beats the artifact, and omitted kwargs defer to it."""
        base = tuned.knobs if tuned is not None else cls()
        kw = {k: v for k, v in overrides.items() if v is not None}
        bad = set(kw) - {f.name for f in dataclasses.fields(cls)}
        if bad:
            raise TypeError(f"unknown knob override(s): {sorted(bad)}")
        return dataclasses.replace(base, **kw) if kw else base

    def validated(self, capacity: Optional[int] = None,
                  max_seq: Optional[int] = None,
                  prefill_bucket: int = 1,
                  strict: bool = True) -> "EngineKnobs":
        """Context validation against the engine geometry.

        strict=True (TunedConfig artifacts, autotuner candidates): raise on
        ``admit_k > capacity``, a ``page_size`` that does not divide the
        bucket-rounded ``max_seq``, a ``tenant_slots`` quota no engine seat
        count could satisfy, or a ``tenant_pages`` quota exceeding the
        default page pool.  strict=False mirrors the historical kwarg
        behavior -- ``admit_k`` clamps to capacity, quotas clamp to the
        geometry, and the page check is left to the paged executor."""
        out = self
        if capacity is not None and out.admit_k > int(capacity):
            if strict:
                raise ValueError(
                    f"admit_k={out.admit_k} exceeds capacity={capacity}")
            out = dataclasses.replace(out, admit_k=int(capacity))
        if (capacity is not None and out.tenant_slots is not None
                and out.tenant_slots > int(capacity)):
            if strict:
                raise ValueError(
                    f"tenant_slots={out.tenant_slots} exceeds "
                    f"capacity={capacity}")
            out = dataclasses.replace(out, tenant_slots=int(capacity))
        if out.paged and max_seq is not None:
            rounded = round_up(int(max_seq), max(int(prefill_bucket), 1))
            if strict and rounded % out.page_size:
                raise ValueError(
                    f"page_size={out.page_size} does not divide the "
                    f"bucket-rounded max_seq={rounded}")
            if (out.tenant_pages is not None and capacity is not None
                    and rounded % out.page_size == 0):
                # the default pool (Engine(cache_pages=None)): capacity
                # contiguous rows' worth of frames
                pool = int(capacity) * (rounded // out.page_size)
                if out.tenant_pages > pool:
                    if strict:
                        raise ValueError(
                            f"tenant_pages={out.tenant_pages} exceeds the "
                            f"default page pool ({pool} frames)")
                    out = dataclasses.replace(out, tenant_pages=pool)
        return out

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "EngineKnobs":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in dict(d).items() if k in known})


@dataclasses.dataclass
class TunedConfig:
    """Versioned autotuner artifact: winning knobs + how they were found.

    probe: search telemetry -- candidate table with modeled and measured
      tokens/s, the probe-trace protocol, pruning stats.
    dvfs: per-layer DVFS schedule derived from the packed weight-class
      composition (transitions, achievable-frequency headroom, modeled
      time/energy per token) -- see serving/autotune.dvfs_layer_report.
    meta: host/context info (jax version, backend, devices) so artifacts
      and bench trajectories stay comparable across machines.
    """

    knobs: EngineKnobs = dataclasses.field(default_factory=EngineKnobs)
    version: int = TUNED_CONFIG_VERSION
    model: str = ""
    backend: str = ""
    capacity: Optional[int] = None
    max_seq: Optional[int] = None
    prefill_bucket: Optional[int] = None
    seed: Optional[int] = None
    probe: Dict[str, Any] = dataclasses.field(default_factory=dict)
    dvfs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["knobs"] = self.knobs.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TunedConfig":
        d = dict(d)
        version = int(d.get("version", -1))
        if not 1 <= version <= TUNED_CONFIG_VERSION:
            raise ValueError(
                f"unsupported TunedConfig version {version} (this build "
                f"reads <= {TUNED_CONFIG_VERSION}); re-run the autotuner")
        d["knobs"] = EngineKnobs.from_dict(d.get("knobs", {}))
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    def save(self, path) -> str:
        path = os.fspath(path)
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")
        return path

    @classmethod
    def load(cls, path) -> "TunedConfig":
        with open(os.fspath(path)) as f:
            return cls.from_dict(json.load(f))
