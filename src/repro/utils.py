"""Small shared numeric helpers.

Bucket/tile rounding shows up in every serving and kernel layer (prompt
length buckets, decode-scan steps, Pallas block sizing).  One definition
here so the shapes every jit target compiles against come from the same
arithmetic -- a bucket disagreement between the engine and a kernel is a
silent recompile storm, not an error.
"""

from __future__ import annotations


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n <= 1 -> 1)."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def round_up(n: int, multiple: int) -> int:
    """Smallest positive multiple of ``multiple`` >= n (never 0: n <= 0
    rounds to one full multiple, matching bucket semantics where the empty
    prompt still occupies the smallest bucket)."""
    if multiple < 1:
        raise ValueError(f"multiple must be >= 1, got {multiple}")
    return max(-(-n // multiple) * multiple, multiple)
