"""Dependency-free stand-in for the `hypothesis` API surface these tests
use (given / settings / strategies.{integers,floats,sampled_from}).

The container has no hypothesis wheel and installs are disallowed, so when
the real package is missing `conftest.py` registers this module under the
``hypothesis`` name.  Semantics: ``@given`` expands into a deterministic
seeded sweep of ``max_examples`` drawn inputs -- same spirit (randomized
shape/dtype sweeps), fully reproducible, no shrinking.
"""

from __future__ import annotations

import inspect
import random
import sys
import types

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rnd: random.Random):
        return self._draw(rnd)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(
        lambda rnd: min_value + (max_value - min_value) * rnd.random())


def sampled_from(seq) -> _Strategy:
    items = list(seq)
    return _Strategy(lambda rnd: items[rnd.randrange(len(items))])


def booleans() -> _Strategy:
    return _Strategy(lambda rnd: bool(rnd.getrandbits(1)))


def just(value) -> _Strategy:
    return _Strategy(lambda rnd: value)


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    return _Strategy(lambda rnd: [
        elements.draw(rnd)
        for _ in range(rnd.randint(min_size, max_size))])


def given(*strategies_args, **strategies_kwargs):
    """Expand the test into a seeded loop over drawn examples.

    Positional strategies bind to the *last* positional parameters of
    the test function; keyword strategies bind by name.  Remaining
    leading parameters (self, pytest fixtures) keep flowing from pytest,
    which sees a trimmed ``__signature__``.
    """

    def decorate(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        n = len(strategies_args)
        lead = params[:-n] if n else params
        lead = [p for p in lead if p.name not in strategies_kwargs]

        def wrapper(*args, **kwargs):
            examples = getattr(wrapper, "_max_examples",
                               DEFAULT_MAX_EXAMPLES)
            rnd = random.Random(0x5EED)
            for _ in range(examples):
                drawn = [s.draw(rnd) for s in strategies_args]
                drawn_kw = {name: s.draw(rnd)
                            for name, s in strategies_kwargs.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__signature__ = sig.replace(parameters=lead)
        # honor @settings applied below @given (it stamps the raw fn)
        wrapper._max_examples = getattr(fn, "_max_examples",
                                        DEFAULT_MAX_EXAMPLES)
        return wrapper

    return decorate


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def decorate(fn):
        fn._max_examples = max_examples
        return fn

    return decorate


def install() -> None:
    """Register this module as `hypothesis` (call only when missing)."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "booleans", "just",
                 "lists"):
        setattr(strategies, name, globals()[name])
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
