import os
import sys

# tests run on the single real CPU device -- the 512-device host platform is
# requested ONLY by repro.launch.dryrun (per the brief).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:  # offline container: register the deterministic stub
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub
    _hypothesis_stub.install()

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
