import os
import sys

# tests run on the single real CPU device -- the 512-device host platform is
# requested ONLY by repro.launch.dryrun (per the brief).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:  # offline container: register the deterministic stub
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub
    _hypothesis_stub.install()

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# --max-test-seconds: fail the session if any single test runs too long
# (CI runs the serving tier with --max-test-seconds=120 -- see ci.yml)
# ---------------------------------------------------------------------------

def pytest_addoption(parser):
    parser.addoption(
        "--max-test-seconds", type=float, default=None,
        help="fail the session if any test's call phase exceeds this "
             "many seconds (tests still run to completion)")


class _DurationGate:
    def __init__(self, limit):
        self.limit = limit
        self.over = []

    def pytest_runtest_logreport(self, report):
        if report.when == "call" and report.duration > self.limit:
            self.over.append((report.nodeid, report.duration))

    def pytest_terminal_summary(self, terminalreporter):
        if self.over:
            terminalreporter.section("duration gate")
            for nodeid, dur in self.over:
                terminalreporter.write_line(
                    f"FAILED duration gate ({dur:.1f}s > "
                    f"{self.limit:.0f}s): {nodeid}")

    def pytest_sessionfinish(self, session, exitstatus):
        if self.over and session.exitstatus == 0:
            session.exitstatus = 1


def pytest_configure(config):
    limit = config.getoption("--max-test-seconds")
    if limit:
        config.pluginmanager.register(_DurationGate(limit),
                                      "duration-gate")
