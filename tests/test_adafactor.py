"""Adafactor (factored second moment) optimizer."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adafactor as AF


def test_factored_state_shapes():
    params = {"w": jnp.zeros((16, 8)), "b": jnp.zeros((8,)),
              "stack": jnp.zeros((4, 6, 10))}
    st = AF.init(params)
    assert st.vr["w"].shape == (16,)
    assert st.vc["w"].shape == (8,)
    assert st.vr["b"].shape == (8,)       # vectors keep full moment
    assert st.vc["b"].shape == (0,)
    assert st.vr["stack"].shape == (4, 6)
    assert st.vc["stack"].shape == (4, 10)


def test_quadratic_convergence():
    target = jnp.asarray(np.random.default_rng(0)
                         .normal(size=(16, 8)).astype(np.float32))
    params = {"w": jnp.zeros((16, 8))}
    cfg = AF.AdafactorConfig(weight_decay=0.0, clip_norm=None)
    state = AF.init(params, cfg)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = AF.update(g, state, params, 0.05, cfg)
    assert float(loss(params)) < 1e-2


def test_state_smaller_than_adamw():
    from repro.optim import adamw
    from repro.models import module as M
    params = {"w": jnp.zeros((256, 512), jnp.bfloat16)}
    af = AF.init(params)
    aw = adamw.init(params)

    def nbytes(tree):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))

    assert nbytes((af.mu, af.vr, af.vc)) < 0.6 * nbytes((aw.mu, aw.nu))


def test_train_step_integration():
    import repro.configs as configs
    from repro.launch.train import TrainConfig, TrainState, make_train_step
    from repro.models import module as M
    from repro.models import transformer as T
    cfg = configs.get_smoke_config("granite-8b")
    tcfg = TrainConfig(optimizer="adafactor", grad_accum=1, total_steps=10,
                       warmup_steps=1)
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(0))
    state = TrainState(params, AF.init(params, tcfg.adafactor))
    step = jax.jit(make_train_step(cfg, tcfg))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab),
             "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab),
             "positions": jnp.broadcast_to(jnp.arange(32), (2, 32))}
    losses = []
    for _ in range(4):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
