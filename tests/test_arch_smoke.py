"""Required per-arch smoke tests: reduced same-family configs run one
forward + one train step on CPU, asserting output shapes and no NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.launch.train import TrainConfig, TrainState, make_train_step
from repro.models import module as M
from repro.models import transformer as T
from repro.optim import adamw

ALL_ARCHS = list(configs.ARCH_MODULES)


def make_batch(cfg, key, b=2, s=64):
    batch = {}
    if cfg.embeds_input:
        batch["embeds"] = jax.random.normal(
            key, (b, s, cfg.d_model), jnp.float32).astype(cfg.dtype)
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch["positions"] = jnp.broadcast_to(jnp.arange(s), (b, s))
    batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(T.model_specs(cfg), key)
    batch = make_batch(cfg, key)
    logits, aux = T.forward(params, cfg, batch)
    assert logits.shape == (2, 64, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_updates_and_finite(arch):
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = M.init_params(T.model_specs(cfg), key)
    tcfg = TrainConfig(grad_accum=2, total_steps=10, warmup_steps=1)
    state = TrainState(params, adamw.init(params, tcfg.adamw))
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = make_batch(cfg, key, b=4, s=32)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # at least one parameter moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        state.params, new_state.params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["granite-8b", "gemma2-2b",
                                  "recurrentgemma-2b", "falcon-mamba-7b",
                                  "dbrx-132b"])
def test_loss_decreases_briefly(arch):
    """5 steps on a repeated batch must reduce the loss (learnability)."""
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(2)
    params = M.init_params(T.model_specs(cfg), key)
    tcfg = TrainConfig(grad_accum=1, total_steps=20, warmup_steps=1,
                       peak_lr=5e-3)
    state = TrainState(params, adamw.init(params, tcfg.adamw))
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = make_batch(cfg, key, b=4, s=32)
    losses = []
    for _ in range(5):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (the brief's table)."""
    expect = {
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = configs.get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == h, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab == v, arch
    assert configs.get_config("falcon-mamba-7b").ssm_state == 16
    assert configs.get_config("dbrx-132b").moe.n_experts == 16
    assert configs.get_config("dbrx-132b").moe.top_k == 4
    assert configs.get_config("llama4-scout-17b-a16e").moe.top_k == 1


def test_param_counts_near_nameplate():
    """Full-size spec trees should land near the nameplate parameter count
    (verifies configs produce the right-size models without allocating)."""
    expect_b = {"granite-8b": (7, 9.5), "mistral-large-123b": (115, 130),
                "nemotron-4-340b": (320, 360), "falcon-mamba-7b": (6.5, 8.5),
                "gemma2-2b": (2.2, 3.3), "recurrentgemma-2b": (2.2, 3.6),
                "dbrx-132b": (125, 140), "internvl2-26b": (19, 23),
                "llama4-scout-17b-a16e": (100, 115),
                "musicgen-medium": (1.2, 2.2)}
    for arch, (lo, hi) in expect_b.items():
        cfg = configs.get_config(arch)
        n = M.param_count(T.model_specs(cfg)) / 1e9
        assert lo <= n <= hi, (arch, n)
