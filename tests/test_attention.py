"""Blockwise attention vs dense reference; decode paths; LSE combine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import (causal_blockwise_attention,
                                    combine_decode_partials,
                                    decode_attention,
                                    decode_attention_partial)


def dense_ref(q, k, v, window=None, cap=None):
    b, s, h, d = q.shape
    hkv = k.shape[2]
    kk = jnp.repeat(k, h // hkv, axis=2)
    vv = jnp.repeat(v, h // hkv, axis=2)
    sc = jnp.einsum("bqhd,bshd->bhqs", q, kk) / np.sqrt(d)
    if cap:
        sc = cap * jnp.tanh(sc / cap)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    m = qp >= kp
    if window:
        m &= (qp - kp) < window
    sc = jnp.where(m, sc, -1e30)
    return jnp.einsum("bhqs,bshd->bqhd", jax.nn.softmax(sc, -1), vv)


class TestBlockwise:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(16, 160), st.sampled_from([1, 2]),
           st.sampled_from([(4, 4), (4, 2), (8, 1)]),
           st.sampled_from([16, 48, 64]),
           st.sampled_from([None, 32, 64]),
           st.sampled_from([None, 30.0]))
    def test_matches_dense(self, s, b, heads, chunk, window, cap):
        h, hkv = heads
        d = 16
        rng = np.random.default_rng(s * 17 + h)
        q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
        out = causal_blockwise_attention(q, k, v, chunk=chunk, window=window,
                                         attn_softcap=cap)
        expect = dense_ref(q, k, v, window, cap)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)

    def test_gradients_flow(self, rng):
        b, s, h, d = 1, 64, 2, 8
        q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))

        def f(q, k, v):
            return causal_blockwise_attention(q, k, v, chunk=32).sum()

        g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        for gi in g:
            assert bool(jnp.isfinite(gi).all())
            assert float(jnp.abs(gi).max()) > 0


class TestDecode:
    def test_matches_dense_last_position(self, rng):
        b, s, h, hkv, d = 2, 48, 4, 2, 16
        q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
        full = dense_ref(q, k, v)
        out = decode_attention(q[:, -1], k, v,
                               jnp.full((b,), s, jnp.int32))
        np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, -1]),
                                   rtol=1e-5, atol=1e-5)

    def test_sharded_combine_equals_monolithic(self, rng):
        b, s, h, hkv, d, shards = 2, 64, 4, 2, 16, 4
        q = jnp.asarray(rng.normal(size=(b, h, d)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
        length = jnp.array([50, 64], jnp.int32)
        ref = decode_attention(q, k, v, length)
        ms, ls, pvs = [], [], []
        cs = s // shards
        for i in range(shards):
            sl = slice(i * cs, (i + 1) * cs)
            vm = jnp.arange(s)[sl][None, :] < length[:, None]
            m, l, pv = decode_attention_partial(q, k[:, sl], v[:, sl], vm)
            ms.append(m)
            ls.append(l)
            pvs.append(pv)
        mg = jnp.stack(ms).max(0)
        corr = jnp.exp(jnp.stack(ms) - mg)
        lg = (jnp.stack(ls) * corr).sum(0)
        pvg = (jnp.stack(pvs) * corr[..., None]).sum(0)
        comb = (pvg / lg[..., None]).reshape(b, h, d)
        np.testing.assert_allclose(np.asarray(comb), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_window_mask(self, rng):
        b, s, h, d = 1, 32, 2, 8
        q = jnp.asarray(rng.normal(size=(b, h, d)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
        length = jnp.array([32], jnp.int32)
        out_w = decode_attention(q, k, v, length, window=8)
        # zeroing keys outside the window must not change the result
        k2 = k.at[:, :24].set(100.0)
        v2 = v.at[:, :24].set(-100.0)
        out_w2 = decode_attention(q, k2, v2, length, window=8)
        np.testing.assert_allclose(np.asarray(out_w), np.asarray(out_w2),
                                   rtol=1e-5, atol=1e-5)
