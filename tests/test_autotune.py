"""Autotuner stack: EngineKnobs consolidation/compat, the TunedConfig
artifact, packed-stream class read-back, the per-layer DVFS report, and a
tiny hardware-in-the-loop search with token parity against the default
engine."""

import dataclasses
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.core import codebooks, deploy
from repro.core.apply import quantize_params
from repro.core.quantize import HaloConfig, halo_quantize_tensor
from repro.kernels import ops
from repro.models import module as M
from repro.models import transformer as T
from repro.serving.engine import Engine
from repro.serving.tuning import (EngineKnobs, TunedConfig,
                                  TUNED_CONFIG_VERSION)


def small_model(arch="granite-8b", seed=0):
    cfg = dataclasses.replace(configs.get_smoke_config(arch),
                              dtype=jnp.float32)
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(seed))
    return cfg, params


@functools.lru_cache(maxsize=1)
def packed_model():
    # the smoke config's matrices are below one 128-tile (pack_params
    # falls back to dense bf16), so widen it until every block leaf packs
    cfg = dataclasses.replace(configs.get_smoke_config("granite-8b"),
                              dtype=jnp.float32, d_model=256, d_ff=384,
                              head_dim=64, vocab=512, vocab_pad_multiple=64)
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(0))
    q = quantize_params(params, None, HaloConfig(tile=128))
    return cfg, deploy.pack_params(q)


class TestEngineKnobs:
    def test_defaults_match_legacy_engine(self):
        k = EngineKnobs()
        assert (k.chunk, k.admit_k, k.paged, k.page_size) == (8, 4, False, 16)
        assert not k.speculative and k.spec_k == 4
        assert k.prefill_chunk_width is None and k.block_m is None

    @pytest.mark.parametrize("bad", [
        dict(chunk=0), dict(admit_k=0), dict(page_size=0),
        dict(prefill_chunk_width=0), dict(spec_k=-1),
        dict(block_m=12), dict(block_m=4),
        dict(priority_levels=0),               # at least the FIFO level
        dict(preempt=True),                    # preemption needs paging
        dict(tenant_slots=0), dict(tenant_pages=0),
    ])
    def test_validation_raises(self, bad):
        with pytest.raises(ValueError):
            EngineKnobs(**bad)

    def test_multitenant_defaults_are_fifo(self):
        k = EngineKnobs()
        assert k.priority_levels == 1 and not k.preempt
        assert k.tenant_slots is None and k.tenant_pages is None

    def test_resolve_precedence(self):
        tuned = TunedConfig(knobs=EngineKnobs(chunk=16, admit_k=2))
        # kwarg > tuned > default
        k = EngineKnobs.resolve(tuned, chunk=4)
        assert k.chunk == 4 and k.admit_k == 2
        assert EngineKnobs.resolve(tuned).chunk == 16
        assert EngineKnobs.resolve(None).chunk == 8

    def test_resolve_rejects_unknown(self):
        with pytest.raises(TypeError):
            EngineKnobs.resolve(None, nope=3)

    def test_validated_strict_and_clamped(self):
        k = EngineKnobs(admit_k=9)
        with pytest.raises(ValueError, match="admit_k"):
            k.validated(capacity=4, max_seq=64, prefill_bucket=16)
        assert k.validated(4, 64, 16, strict=False).admit_k == 4
        bad = EngineKnobs(paged=True, page_size=24)
        with pytest.raises(ValueError, match="page_size"):
            bad.validated(capacity=4, max_seq=64, prefill_bucket=16)

    def test_validated_tenant_quotas(self):
        # tenant_slots no seat count could satisfy: strict raises, the
        # kwarg-compat path clamps to capacity
        k = EngineKnobs(admit_k=2, tenant_slots=8)
        with pytest.raises(ValueError, match="tenant_slots"):
            k.validated(capacity=4, max_seq=64, prefill_bucket=16)
        assert k.validated(4, 64, 16, strict=False).tenant_slots == 4
        # tenant_pages beyond the default page pool (capacity * max_seq /
        # page_size = 4 * 64 / 16 = 16 frames): strict raises, else clamp
        k = EngineKnobs(admit_k=2, paged=True, tenant_pages=99)
        with pytest.raises(ValueError, match="tenant_pages"):
            k.validated(capacity=4, max_seq=64, prefill_bucket=16)
        assert k.validated(4, 64, 16, strict=False).tenant_pages == 16
        # in-bounds quotas survive untouched either way
        ok = EngineKnobs(admit_k=2, paged=True, tenant_slots=2,
                         tenant_pages=8)
        assert ok.validated(4, 64, 16) == ok

    def test_resolve_precedence_multitenant(self):
        tuned = TunedConfig(knobs=EngineKnobs(
            paged=True, priority_levels=3, preempt=True, tenant_slots=2))
        k = EngineKnobs.resolve(tuned, priority_levels=2)
        assert k.priority_levels == 2          # kwarg beats the artifact
        assert k.preempt and k.tenant_slots == 2
        assert EngineKnobs.resolve(tuned).priority_levels == 3
        assert EngineKnobs.resolve(None).priority_levels == 1

    def test_engine_kwargs_still_win(self):
        cfg, packed = packed_model()
        tuned = TunedConfig(knobs=EngineKnobs(chunk=16))
        eng = Engine(packed, cfg, tuned=tuned, chunk=2)
        assert eng.chunk == 2                  # explicit kwarg beats tuned
        eng2 = Engine(packed, cfg, tuned=tuned)
        assert eng2.chunk == 16                # tuned beats default
        assert Engine(packed, cfg).chunk == 8  # legacy default intact


class TestTunedConfig:
    def test_round_trip(self, tmp_path):
        tc = TunedConfig(knobs=EngineKnobs(chunk=16, paged=True,
                                           page_size=8),
                         model="granite-smoke", capacity=4, max_seq=64,
                         prefill_bucket=16, seed=3,
                         probe={"winner": "x"}, dvfs={"totals": {}})
        p = tc.save(tmp_path / "tuned.json")
        tc2 = TunedConfig.load(p)
        assert tc2.knobs == tc.knobs
        assert tc2.version == TUNED_CONFIG_VERSION
        assert (tc2.model, tc2.capacity, tc2.seed) == ("granite-smoke", 4, 3)
        assert tc2.probe["winner"] == "x"

    def test_version_rejected(self, tmp_path):
        tc = TunedConfig(knobs=EngineKnobs())
        p = tc.save(tmp_path / "tuned.json")
        blob = json.loads(open(p).read())
        blob["version"] = TUNED_CONFIG_VERSION + 1
        open(p, "w").write(json.dumps(blob))
        with pytest.raises(ValueError, match="version"):
            TunedConfig.load(p)

    def test_unknown_knob_keys_ignored(self):
        # forward-compat: a newer artifact with extra knob fields loads
        d = TunedConfig(knobs=EngineKnobs()).to_dict()
        d["knobs"]["future_knob"] = 7
        assert TunedConfig.from_dict(d).knobs == EngineKnobs()


class TestPackedClassReadback:
    def test_matches_quantized_index_stream(self, rng):
        w = jnp.asarray(rng.normal(0, 0.05, (256, 384)).astype(np.float32))
        g2 = jnp.asarray((rng.normal(size=(256, 384)) ** 2)
                         .astype(np.float32))
        hq = halo_quantize_tensor(w, g2, HaloConfig(tile=128))
        rb = deploy.packed_tile_classes(ops.pack_halo(hq))
        assert rb.shape == (hq.n_tiles,)
        lo, hi = codebooks.f3_index_range()
        idx = np.asarray(hq.idx)               # (n_tiles, t, t) ground truth
        for t in range(hq.n_tiles):
            in_f3 = idx[t].min() >= lo and idx[t].max() <= hi
            expect = (codebooks.TILE_CLASS_F3 if in_f3
                      else codebooks.TILE_CLASS_F2)
            assert rb[t] == expect

    def test_labeled_f3_implies_readback_f3(self, rng):
        # the conservative-in-reverse direction DVFS planning relies on:
        # an F3-labeled tile only stores F3-range indices, so it must read
        # back F3 (the converse is allowed to differ)
        w = jnp.asarray(rng.normal(0, 0.05, (256, 256)).astype(np.float32))
        g2 = np.ones((256, 256), np.float32)
        g2[:128, :128] = 1e-12                 # drive tile 0 to F3
        hq = halo_quantize_tensor(w, jnp.asarray(g2), HaloConfig(tile=128))
        gt = np.asarray(hq.classes)
        rb = deploy.packed_tile_classes(ops.pack_halo(hq))
        f3 = codebooks.TILE_CLASS_F3
        assert (gt == f3).any()
        assert (rb[gt == f3] == f3).all()

    def test_padded_shape(self, rng):
        w = jnp.asarray(rng.normal(0, 0.05, (300, 260)).astype(np.float32))
        hq = halo_quantize_tensor(w, None, HaloConfig(tile=128))
        rb = deploy.packed_tile_classes(ops.pack_halo(hq))
        assert rb.shape == (3 * 3,)            # ceil(300/128) * ceil(260/128)
        assert set(np.unique(rb)) <= {codebooks.TILE_CLASS_F2,
                                      codebooks.TILE_CLASS_F3}


class TestLayerComposition:
    def test_structure(self):
        cfg, packed = packed_model()
        comp = deploy.layer_class_composition(packed, cfg)
        layer_recs = [r for r in comp if r["layer"] is not None]
        assert [r["layer"] for r in layer_recs] == list(range(cfg.n_layers))
        for r in layer_recs:
            assert r["pattern"] in cfg.block_pattern
            assert r["n_tiles"] == sum(r["counts"].values()) > 0
            for leaf in r["leaves"]:
                assert leaf["classes"].dtype == np.int8
            assert r["n_tiles"] == sum(l["classes"].size
                                       for l in r["leaves"])

    def test_non_packed_tree_is_empty(self):
        assert deploy.layer_class_composition({"w": np.zeros(3)},
                                              object()) == []


class TestBlockM:
    def test_with_block_m_sets_and_validates(self):
        cfg, packed = packed_model()
        tree = ops.with_block_m(packed, 32)
        pred = lambda x: isinstance(x, ops.HaloPacked)
        leaves = [l for l in jax.tree.leaves(tree, is_leaf=pred)
                  if pred(l)]
        assert leaves and all(l.block_m == 32 for l in leaves)
        with pytest.raises(ValueError):
            ops.with_block_m(packed, 12)

    def test_matmul_parity_across_block_m(self, rng):
        w = jnp.asarray(rng.normal(0, 0.05, (256, 256)).astype(np.float32))
        hq = halo_quantize_tensor(w, None, HaloConfig(tile=128))
        packed = ops.pack_halo(hq)
        x = jnp.asarray(rng.normal(size=(16, 256)).astype(np.float32))
        base = ops.halo_matmul(x, packed, interpret=True,
                               out_dtype=jnp.float32)
        for bm in (8, 32, 128):
            tuned = dataclasses.replace(packed, block_m=bm)
            out = ops.halo_matmul(x, tuned, interpret=True,
                                  out_dtype=jnp.float32)
            np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                       rtol=1e-5, atol=1e-5)
        # explicit bm kwarg overrides the embedded default
        out = ops.halo_matmul(x, dataclasses.replace(packed, block_m=8),
                              bm=128, interpret=True, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=1e-5, atol=1e-5)


def _trace(cfg, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, cfg.vocab, (int(rng.integers(4, 12)),))
             .astype(np.int32), int(rng.integers(2, 6))) for _ in range(n)]


def _serve(eng, trace):
    rids = [eng.submit({"tokens": toks}, max_new=mn) for toks, mn in trace]
    done = eng.drain()
    out = [np.asarray(done[r]).tolist() for r in rids]
    eng.pop_finished()
    return out


class TestAutotuneLoop:
    def test_search_produces_consumable_artifact(self, tmp_path):
        from repro.serving.autotune import ProbeSpec, SearchSpace, autotune

        cfg, packed = packed_model()
        space = SearchSpace(chunk=(4, 8), admit_k=(2,), paged=(False,),
                            page_size=(8,), prefill_chunk_width=(None,))
        tc = autotune(packed, cfg, capacity=2, max_seq=32,
                      prefill_bucket=16, space=space,
                      probe=ProbeSpec(n_requests=3, prompt_len=(4, 10),
                                      max_new=(2, 6), repeats=1),
                      n_probe=2)
        assert tc.version == TUNED_CONFIG_VERSION
        assert tc.probe["speedup_vs_default"] >= 1.0   # never regress
        assert tc.probe["n_measured"] >= 1
        assert tc.dvfs["totals"]["n_tiles"] > 0
        assert tc.dvfs["totals"]["mean_freq_headroom"] >= 1.0
        assert all("dvfs_transitions" in l for l in tc.dvfs["layers"])

        p = tc.save(tmp_path / "tuned.json")
        # tuned engine serves token-identically to the default engine
        eng_t = Engine.from_tuned(packed, cfg, p)
        eng_d = Engine(packed, cfg, capacity=tc.capacity,
                       max_seq=tc.max_seq, prefill_bucket=tc.prefill_bucket)
        trace = _trace(cfg)
        assert _serve(eng_t, trace) == _serve(eng_d, trace)

    def test_from_tuned_geometry_defaults(self, tmp_path):
        cfg, packed = packed_model()
        tc = TunedConfig(knobs=EngineKnobs(chunk=16), capacity=3,
                         max_seq=48, prefill_bucket=16)
        p = tc.save(tmp_path / "t.json")
        eng = Engine.from_tuned(packed, cfg, p)
        assert eng.chunk == 16
        assert eng.capacity == 3
        eng2 = Engine.from_tuned(packed, cfg, p, capacity=5)
        assert eng2.capacity == 5             # kwargs still override

    def test_modeled_ranking_prunes(self):
        from repro.serving.autotune import (HostModel, ProbeSpec,
                                            _trace_stats,
                                            make_probe_trace,
                                            modeled_tokens_per_s)
        from repro.hw.dvfs import SYSTOLIC_DOMAIN

        cfg, _ = small_model()
        trace = make_probe_trace(ProbeSpec(n_requests=3), cfg.vocab)
        stats = _trace_stats(trace)
        counts = {"F2": 60, "F3": 4}
        kw = dict(cfg=cfg, capacity=2, prefill_bucket=16,
                  comp_counts=counts, stats=stats, host=HostModel(),
                  domain=SYSTOLIC_DOMAIN)
        t8 = modeled_tokens_per_s(EngineKnobs(chunk=8), **kw)
        t4 = modeled_tokens_per_s(EngineKnobs(chunk=4), **kw)
        assert t8["tokens_per_s"] > 0 and t4["tokens_per_s"] > 0
        # fewer host syncs per token models faster
        assert t8["tokens_per_s"] >= t4["tokens_per_s"]
