"""Baseline quantizers, DVFS scheduling, Pareto machinery, and the
systolic/GPU simulators (paper-claim sanity)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codebooks, pareto, schedule
from repro.core.quantize import HaloConfig, halo_quantize_tensor
from repro.hw import gpu as G
from repro.hw import systolic as sy
from repro.quant import common as qc
from repro.quant import gptq, rtn, smoothquant, zeroquant


@pytest.fixture
def wx(rng):
    w = jnp.asarray(rng.normal(0, 0.05, (192, 160)).astype(np.float32))
    x = rng.normal(0, 1, (1024, 192)).astype(np.float32)
    x[:, 3] *= 25.0
    return w, x


def f_err(wq, w, x):
    d = x @ np.asarray(wq) - x @ np.asarray(w)
    return float(np.linalg.norm(d) / np.linalg.norm(x @ np.asarray(w)))


class TestBaselines:
    def test_bits_monotonic(self, wx):
        w, x = wx
        errs = [f_err(rtn.rtn_quantize_tensor(w, b), w, x) for b in (8, 4, 3)]
        assert errs[0] < errs[1] < errs[2]

    def test_gptq_beats_rtn(self, wx):
        w, x = wx
        gram = x.T @ x / x.shape[0]
        for bits in (4, 3):
            e_rtn = f_err(rtn.rtn_quantize_tensor(w, bits), w, x)
            e_gptq = f_err(gptq.gptq_quantize_matrix(
                np.asarray(w), gram, bits), w, x)
            assert e_gptq <= e_rtn * 1.02

    def test_smoothquant_helps_activation_outliers(self, wx):
        w, x = wx
        am = np.abs(x).max(0)
        sq = smoothquant.smooth_and_quantize_tensor(w, am, 4)
        # functional error with A8 activations: smooth better than plain RTN
        xq = np.asarray(qc.fake_quant_act_per_token(jnp.asarray(x)))
        base = np.asarray(rtn.rtn_quantize_tensor(w, 4))
        e_plain = np.linalg.norm(xq @ base - x @ np.asarray(w))
        e_sq = np.linalg.norm(xq @ np.asarray(sq) - x @ np.asarray(w))
        assert e_sq <= e_plain * 1.1

    def test_zq_local_tilewise(self, wx):
        w, x = wx
        e = f_err(zeroquant.zq_local_tensor(w, 4, tile=64), w, x)
        assert e < 0.2

    def test_act_quant_context(self, rng):
        x = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
        assert qc.maybe_quantize_activation(x) is x
        with qc.activations_quantized(8):
            xq = qc.maybe_quantize_activation(x)
            assert not np.array_equal(np.asarray(xq), np.asarray(x))


class TestDvfsSchedule:
    def test_transitions_per_tensor(self, rng):
        w = jnp.asarray(rng.normal(0, 0.05, (256, 256)).astype(np.float32))
        g2 = jnp.asarray((rng.normal(size=(256, 256)) ** 2).astype(np.float32))
        hq = halo_quantize_tensor(w, g2, HaloConfig(tile=64))
        sch = schedule.schedule_tensor(hq)
        assert sch.num_transitions <= 1            # at most F2->F3
        order = sch.execution_order()
        assert sorted(order.tolist()) == list(range(hq.n_tiles))

    def test_cross_layer_grouping_small(self, rng):
        w = jnp.asarray(rng.normal(0, 0.05, (128, 128)).astype(np.float32))
        g2 = jnp.asarray((rng.normal(size=(128, 128)) ** 2).astype(np.float32))
        qmodel = {f"l{i}": halo_quantize_tensor(w, g2, HaloConfig(tile=32))
                  for i in range(4)}
        res = schedule.schedule_model(qmodel, cross_layer=True)
        # paper SIII-C3: 2-3 distinct levels -> transitions stay tiny
        assert res["num_transitions"] <= 2
        assert res["transition_overhead_s"] < 1e-4

    def test_points_respect_critical_path(self):
        from repro.hw.dvfs import SYSTOLIC_DOMAIN
        for cls, freq in codebooks.CLASS_FREQ_GHZ.items():
            pt = SYSTOLIC_DOMAIN.fastest_point_for_delay(1.0 / freq)
            assert pt.freq_ghz <= freq + 1e-9


class TestPareto:
    def test_sweep_and_knee(self, rng):
        w = {"w": jnp.asarray(rng.normal(0, 0.05, (128, 128))
                              .astype(np.float32))}
        f = {"w": jnp.asarray((rng.normal(size=(128, 128)) ** 2)
                              .astype(np.float32))}
        pts = pareto.sweep_theta(w, f, HaloConfig(tile=32),
                                 thetas=(0.5, 0.9, 0.99))
        assert pts[0].f3_fraction >= pts[-1].f3_fraction
        assert pts[0].est_speedup_vs_f1 >= pts[-1].est_speedup_vs_f1
        knee = pareto.knee_point(pts)
        assert knee in pts

    def test_theta_for_target_bits(self, rng):
        w = {"w": jnp.asarray(rng.normal(0, 0.05, (128, 128))
                              .astype(np.float32))}
        f = {"w": jnp.asarray((rng.normal(size=(128, 128)) ** 2)
                              .astype(np.float32))}
        theta = pareto.theta_for_target_bits(w, f, 3.5,
                                             HaloConfig(tile=32), iters=5)
        assert 0.0 <= theta <= 1.0


class TestSimulators:
    SHAPES = sy.decoder_layer_shapes(1024, 2816, 8, 32000, seq=512)

    def test_halo_faster_than_baselines(self):
        halo = sy.simulate_layers(self.SHAPES, sy.halo_scheme(0.8, 0.2))
        for name in ("fp16", "w8a8", "w4a8", "w3a8"):
            base = sy.simulate_layers(self.SHAPES, sy.baseline_scheme(name))
            assert halo.time_s < base.time_s, name

    def test_fp16_slowest_and_most_energy(self):
        rs = {n: sy.simulate_layers(self.SHAPES, sy.baseline_scheme(n))
              for n in ("fp16", "w8a8", "w4a8", "w3a8")}
        assert rs["fp16"].time_s == max(r.time_s for r in rs.values())
        assert rs["fp16"].energy_j == max(r.energy_j for r in rs.values())

    def test_more_f3_is_faster(self):
        t = [sy.simulate_layers(self.SHAPES, sy.halo_scheme(f, 1 - f)).time_s
             for f in (0.2, 0.5, 0.9)]
        assert t[0] > t[1] > t[2]

    def test_spmv_under_one_percent(self):
        r = sy.simulate_layers(self.SHAPES, sy.halo_scheme(0.8, 0.2))
        assert r.spmv_time_s / r.time_s < 0.03     # paper: <1% at scale

    def test_dvfs_overhead_negligible(self):
        # paper SIII-C3: negligible at real model scale (LLaMA-7B dims)
        shapes = sy.decoder_layer_shapes(4096, 11008, 32, 32000, seq=2048)
        r = sy.simulate_layers(shapes, sy.halo_scheme(0.8, 0.2))
        overhead = r.dvfs_transitions * 1e-6
        assert overhead / r.time_s < 0.005

    def test_gpu_halo_beats_w8a8(self):
        res_b = G.simulate_matmuls(self.SHAPES, G.gpu_baseline("w8a8"))
        res_h = G.simulate_matmuls(self.SHAPES, G.gpu_halo(0.8, 0.2))
        assert res_h.time_s < res_b.time_s

    def test_energy_decomposition_positive(self):
        r = sy.simulate_layers(self.SHAPES, sy.halo_scheme(0.5, 0.5))
        assert all(v >= 0 for v in r.energy_breakdown.values())
        assert r.energy_j == pytest.approx(
            sum(r.energy_breakdown.values()), rel=1e-6)
