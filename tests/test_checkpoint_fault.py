"""Checkpointing (atomic/async/rotate/restore) + fault tolerance + elastic."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.dist.fault import (FailureInjector, StragglerWatchdog,
                              viable_device_counts)
from repro.launch.train import TrainConfig, train_loop


def tree(rng):
    return {"a": jnp.asarray(rng.normal(size=(4, 6)).astype(np.float32)),
            "b": (jnp.arange(3), {"c": jnp.ones((2, 2), jnp.bfloat16)})}


class TestCheckpointManager:
    def test_roundtrip(self, rng, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        t = tree(rng)
        mgr.save(7, t, {"note": "x"})
        restored = mgr.restore(t)
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert mgr.meta()["step"] == 7

    def test_async_and_rotation(self, rng, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        t = tree(rng)
        for s in (1, 2, 3, 4):
            mgr.save_async(s, t)
        mgr.wait()
        assert mgr.all_steps() == [3, 4]

    def test_crash_mid_write_ignored(self, rng, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        t = tree(rng)
        mgr.save(1, t)
        # simulate a crash that left a partial tmp dir
        os.makedirs(os.path.join(str(tmp_path), "step_000000000009.tmp"))
        assert mgr.latest_step() == 1
        mgr.restore(t)   # must not raise

    def test_shape_mismatch_rejected(self, rng, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        t = tree(rng)
        mgr.save(1, t)
        bad = {**t, "a": jnp.zeros((5, 5))}
        with pytest.raises(ValueError):
            mgr.restore(bad)

    def test_elastic_restore_with_shardings(self, rng, tmp_path):
        # restore onto explicit (trivial 1-device) shardings -- exercises the
        # mesh-independent path used for elastic rescale
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((1,), ("data",))
        mgr = CheckpointManager(str(tmp_path))
        t = {"w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))}
        mgr.save(1, t)
        sh = {"w": NamedSharding(mesh, P("data", None))}
        restored = mgr.restore(t, shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(t["w"]))


class TestFault:
    def test_injector_fires_once(self):
        inj = FailureInjector([3])
        inj.check(2)
        with pytest.raises(RuntimeError):
            inj.check(3)
        inj.check(3)   # second pass ok

    def test_watchdog_flags_stragglers(self):
        clock = {"t": 0.0}

        def fake_clock():
            return clock["t"]

        wd = StragglerWatchdog(threshold=2.0, warmup_steps=2,
                               clock=fake_clock)
        flagged = []
        for step in range(10):
            wd.step_start()
            clock["t"] += 10.0 if step == 7 else 1.0
            if wd.step_end(step):
                flagged.append(step)
        assert flagged == [7]

    def test_viable_device_counts(self):
        assert viable_device_counts(512) == [512, 256, 128, 64, 32, 16]
        assert viable_device_counts(300, 16) == [256, 128, 64, 32, 16]
        assert viable_device_counts(8, 16) == []


class TestTrainLoopRecovery:
    def test_failure_injection_recovers(self, tmp_path):
        cfg = configs.get_smoke_config("granite-8b")
        tcfg = TrainConfig(peak_lr=1e-3, warmup_steps=2, total_steps=12,
                           ckpt_every=4, ckpt_dir=str(tmp_path),
                           grad_accum=1)
        corpus = SyntheticCorpus(CorpusConfig(vocab=cfg.vocab, seq_len=32,
                                              batch=4))
        inj = FailureInjector([6, 9])
        hist = train_loop(cfg, tcfg, corpus, injector=inj, log_every=0)
        assert hist["restarts"] == 2
        steps = [s for s, _ in hist["loss"]]
        assert max(steps) == 11                      # reached the end
        assert bool(np.isfinite(hist["loss"][-1][1]))

    def test_resume_matches_uninterrupted(self, tmp_path):
        cfg = configs.get_smoke_config("granite-8b")
        corpus = SyntheticCorpus(CorpusConfig(vocab=cfg.vocab, seq_len=32,
                                              batch=4))
        # uninterrupted run
        t1 = TrainConfig(peak_lr=1e-3, warmup_steps=2, total_steps=8,
                         ckpt_every=4, ckpt_dir=str(tmp_path / "a"),
                         grad_accum=1)
        h1 = train_loop(cfg, t1, corpus, log_every=0)
        # interrupted at 6, recovered from the step-4 checkpoint
        t2 = TrainConfig(peak_lr=1e-3, warmup_steps=2, total_steps=8,
                         ckpt_every=4, ckpt_dir=str(tmp_path / "b"),
                         grad_accum=1)
        h2 = train_loop(cfg, t2, corpus, injector=FailureInjector([6]),
                        log_every=0)
        # the final losses agree (same data replay from checkpoint state)
        assert h1["loss"][-1][1] == pytest.approx(h2["loss"][-1][1],
                                                  rel=1e-5)
