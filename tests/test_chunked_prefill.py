"""Chunked incremental prefill: the cache-append primitive
(T.prefill_chunk), the fused k-way admission path (B.prefill_append +
deploy cache row helpers), and the shared bucket-rounding utility.

Covers the contracts docs/serving.md promises:
  - streaming a prompt window-by-window into a fresh cache reproduces the
    one-shot ``T.prefill`` (logits + cache + lengths) across chunk widths,
    position offsets, and every cache family (linear KV, ring local KV,
    SSM, RG-LRU);
  - one fused ``prefill_append`` call admits several same-bucket requests;
  - interleaved prefill windows never write another slot's cache rows
    (hypothesis(-stub) sweep over random seat subsets and widths).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.configs as configs
from repro.core import deploy
from repro.models import module as M
from repro.models import transformer as T
from repro.serving import batch as B
from repro.serving.engine import Engine, SamplerConfig
from repro.utils import next_pow2, round_up


def small_model(arch="granite-8b", seed=0):
    cfg = dataclasses.replace(configs.get_smoke_config(arch),
                              dtype=jnp.float32)
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(seed))
    return cfg, params


@pytest.fixture(scope="module")
def granite():
    return _granite_cached()


_GRANITE = []


def _granite_cached():
    """Module cache usable from @given tests (the hypothesis stub cannot
    mix drawn arguments with pytest fixtures)."""
    if not _GRANITE:
        _GRANITE.append(small_model())
    return _GRANITE[0]


def make_prompt(cfg, rng, b, s):
    if cfg.embeds_input:
        return {"embeds": jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)).astype(np.float32))}
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (b, s)).astype(np.int32))}


def stream_chunks(cfg, params, batch, s, widths, max_seq, active=None):
    """Feed ``batch`` through prefill_chunk in windows of ``widths``."""
    b = (batch["embeds"] if cfg.embeds_input else batch["tokens"]).shape[0]
    cache = T.init_cache(cfg, b, max_seq)
    lengths = jnp.zeros((b,), jnp.int32)
    logits, start = None, 0
    for wdt in widths:
        take = min(s - start, wdt)
        win = {}
        for kk, vv in batch.items():
            arr = np.zeros((b, wdt) + vv.shape[2:], np.asarray(vv).dtype)
            arr[:, :take] = np.asarray(vv)[:, start:start + take]
            win[kk] = jnp.asarray(arr)
        win["chunk_lengths"] = jnp.full((b,), take, jnp.int32)
        logits, cache, lengths = T.prefill_chunk(params, cfg, win, cache,
                                                 lengths, active=active)
        start += take
    assert start == s, "widths must cover the prompt"
    return logits, cache, lengths


class TestChunkedPrefillParity:
    """Golden parity: chunked prefill == one-shot prefill, >=2 chunk
    widths (uneven last window) and several position offsets, across the
    cache families."""

    @pytest.mark.parametrize("arch", ["granite-8b",     # linear KV
                                      "gemma2-2b",      # ring local KV
                                      "falcon-mamba-7b",  # SSM state
                                      "recurrentgemma-2b"])  # RG-LRU + ring
    @pytest.mark.parametrize("widths", [(4, 4, 4, 4), (8, 8)])
    def test_matches_oneshot_prefill(self, arch, widths):
        cfg, params = small_model(arch)
        rng = np.random.default_rng(7)
        b, s, max_seq = 2, 13, 32
        batch = make_prompt(cfg, rng, b, s)
        lg_ref, cache_ref, len_ref = T.prefill(params, cfg, dict(batch),
                                               max_seq=max_seq)
        lg, cache, lengths = stream_chunks(cfg, params, batch, s,
                                           widths, max_seq)
        np.testing.assert_array_equal(np.asarray(lengths),
                                      np.asarray(len_ref))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref),
                                   rtol=2e-4, atol=2e-5)
        for a, r in zip(jax.tree.leaves(cache), jax.tree.leaves(cache_ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                       rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("offset", [3, 9])
    def test_position_offset_appends_after_existing_prompt(self, granite,
                                                           offset):
        """Appending the prompt tail at offset ``offset`` into a cache
        already holding the prompt head == one-shot over the whole
        prompt: the causal mask offset and cache writes line up."""
        cfg, params = granite
        rng = np.random.default_rng(offset)
        b, s, max_seq = 2, 13, 32
        batch = make_prompt(cfg, rng, b, s)
        lg_ref, cache_ref, len_ref = T.prefill(params, cfg, dict(batch),
                                               max_seq=max_seq)
        lg, cache, lengths = stream_chunks(cfg, params, batch, s,
                                           (offset, s - offset), max_seq)
        np.testing.assert_array_equal(np.asarray(lengths),
                                      np.asarray(len_ref))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref),
                                   rtol=2e-4, atol=2e-5)

    def test_engine_long_prompt_matches_batch_mode(self, granite):
        """Engine-level golden parity at two chunk widths: a prompt longer
        than every window streams through the scheduler and emits exactly
        the one-shot padded-batch tokens (greedy)."""
        cfg, params = granite
        rng = np.random.default_rng(3)
        prompts = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (2, 21)).astype(np.int32))}
        oracle = Engine(params, cfg, prefill_bucket=8)
        want = oracle.generate(dict(prompts), max_new=6, mode="batch")
        for width in (8, 16):
            eng = Engine(params, cfg, prefill_bucket=8,
                         prefill_chunk_width=width)
            got = eng.generate(dict(prompts), max_new=6)
            np.testing.assert_array_equal(got, want)


class TestKWayAdmission:
    def test_same_bucket_requests_share_one_fused_call(self, granite):
        """>= 2 queued same-bucket requests prefill in ONE prefill_append
        call, and each emits exactly its fresh single-request tokens."""
        cfg, params = granite
        rng = np.random.default_rng(5)
        reqs = [rng.integers(0, cfg.vocab, (1, 6)) for _ in range(3)]
        eng = Engine(params, cfg, prefill_bucket=8, capacity=4, admit_k=4,
                     max_seq=32)
        rids = [eng.submit({"tokens": p}, max_new=5) for p in reqs]
        res = eng.drain()
        log = eng._sched.ex.append_log
        assert log[0] == (8, 3), \
            f"expected one fused 3-seat admission, got {log}"
        oracle = Engine(params, cfg, prefill_bucket=8)
        for rid, p in zip(rids, reqs):
            fresh = oracle.generate({"tokens": jnp.asarray(p)}, max_new=5,
                                    mode="batch")[0]
            np.testing.assert_array_equal(res[rid], fresh)

    def test_admit_k_splits_oversized_groups(self, granite):
        """A same-width group larger than admit_k splits across fused
        calls instead of recompiling a wider seat shape."""
        cfg, params = granite
        rng = np.random.default_rng(6)
        reqs = [rng.integers(0, cfg.vocab, (1, 5)) for _ in range(3)]
        eng = Engine(params, cfg, prefill_bucket=8, capacity=4, admit_k=2,
                     max_seq=32)
        rids = [eng.submit({"tokens": p}, max_new=4) for p in reqs]
        res = eng.drain()
        assert list(eng._sched.ex.append_log)[:2] == [(8, 2), (8, 1)]
        oracle = Engine(params, cfg, prefill_bucket=8)
        for rid, p in zip(rids, reqs):
            fresh = oracle.generate({"tokens": jnp.asarray(p)}, max_new=4,
                                    mode="batch")[0]
            np.testing.assert_array_equal(res[rid], fresh)


class TestSlotIsolation:
    """prefill_append must never touch a row it was not handed: the
    bystander invariant behind interleaving prefill with decode."""

    @given(st.integers(0, 10 ** 6), st.integers(1, 2), st.integers(1, 2))
    @settings(max_examples=8, deadline=None)
    def test_append_never_writes_bystander_rows(self, seed, n_seats,
                                                n_windows):
        cfg, params = _granite_cached()
        rnd = np.random.default_rng(seed)
        cap, max_seq, width = 4, 16, 4
        state = B.init_slots(cfg, cap, max_seq)
        # occupy every row with distinct junk so "unchanged" is meaningful
        state = state._replace(
            tok=jnp.arange(cap, dtype=jnp.int32),
            lengths=jnp.full((cap,), 3, jnp.int32),
            keys=jnp.arange(2 * cap, dtype=jnp.uint32).reshape(cap, 2),
            cache=jax.tree.map(
                lambda l: jnp.asarray(
                    rnd.normal(size=l.shape).astype(np.asarray(l).dtype))
                if l.dtype != jnp.uint32 else l, state.cache))
        seats = rnd.choice(cap, size=n_seats, replace=False)
        others = np.setdiff1d(np.arange(cap), seats)
        k = 2                                     # fixed seat count, padded
        slots = np.full((k,), cap, np.int32)
        slots[:n_seats] = seats
        seat = np.zeros((k,), bool)
        seat[:n_seats] = True
        before = jax.device_get(deploy.cache_rows_gather(
            cfg, state.cache, jnp.asarray(others)))
        for w in range(n_windows):
            window = {"tokens": jnp.asarray(
                rnd.integers(0, cfg.vocab, (k, width)).astype(np.int32))}
            state, _, _ = B.prefill_append(
                params, state, jnp.asarray(slots), window,
                jnp.full((k,), width, jnp.int32),          # chunk_lens
                jnp.full((k,), n_windows * width + 1, jnp.int32),  # total
                jnp.asarray(seat),
                jnp.arange(k, dtype=jnp.int32),        # rids
                jnp.asarray([w == 0] * k),
                cfg=cfg, sampler=SamplerConfig())
        after = jax.device_get(deploy.cache_rows_gather(
            cfg, state.cache, jnp.asarray(others)))
        for bb, aa in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
            np.testing.assert_array_equal(bb, aa)
        # host-visible slot state of bystanders is untouched too
        st_ = jax.device_get(state)
        np.testing.assert_array_equal(st_.tok[others], others)
        np.testing.assert_array_equal(st_.lengths[others], 3)

    def test_rows_gather_scatter_roundtrip(self, granite):
        """cache_rows_scatter(cache_rows_gather(...)) is the identity, and
        masked/out-of-range seats drop their writes."""
        cfg, params = granite
        rnd = np.random.default_rng(0)
        cache = jax.tree.map(
            lambda l: jnp.asarray(rnd.normal(size=l.shape)
                                  .astype(np.asarray(l).dtype)),
            T.init_cache(cfg, 3, 8))
        slots = jnp.asarray([2, 0], jnp.int32)
        sub = deploy.cache_rows_gather(cfg, cache, slots)
        back = deploy.cache_rows_scatter(cfg, cache, sub, slots)
        for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(cache)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # masked + OOB seats: nothing changes even with garbage payloads
        junk = jax.tree.map(lambda l: l + 1 if l.dtype != jnp.uint32
                            else l, sub)
        kept = deploy.cache_rows_scatter(
            cfg, cache, junk, jnp.asarray([1, 3], jnp.int32),
            mask=jnp.asarray([False, True]))
        for a, b in zip(jax.tree.leaves(kept), jax.tree.leaves(cache)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestSharedRounding:
    def test_next_pow2(self):
        assert [next_pow2(n) for n in (0, 1, 2, 3, 8, 9, 128, 129)] \
            == [1, 1, 2, 4, 8, 16, 128, 256]

    def test_round_up(self):
        assert round_up(0, 8) == 8
        assert round_up(1, 8) == 8
        assert round_up(8, 8) == 8
        assert round_up(9, 8) == 16
        assert round_up(13, 5) == 15
        with pytest.raises(ValueError):
            round_up(4, 0)

    def test_engine_and_kernels_share_the_definition(self, granite):
        from repro.kernels import ops
        cfg, params = granite
        eng = Engine(params, cfg, prefill_bucket=12)
        assert eng._round_bucket(13) == round_up(13, 12) == 24
        assert eng._decode_steps(5) == next_pow2(5) == 8
        assert ops._next_pow2 is next_pow2
