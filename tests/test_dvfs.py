"""DVFS schedule / operating-point edge cases + MAC-model paper anchors.

Covers the autotuner's hw-model dependencies: ``schedule_transitions`` on
degenerate tile lists, ``plan_for_classes`` headroom semantics (all-F1 has
none), ``DvfsDomain`` fallback when no operating point is feasible, the
reorder-invariance property the class-grouped schedule relies on, and the
lru-cached ``achievable_freq_ghz`` identity.
"""

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.hw import mac_model as mm
from repro.hw.dvfs import (DvfsDomain, OperatingPoint, SYSTOLIC_DOMAIN,
                           plan_for_classes, schedule_transitions)


class TestScheduleTransitions:
    def test_empty(self):
        s = schedule_transitions([])
        assert s["num_transitions"] == 0
        assert s["order"].size == 0
        assert s["classes"].size == 0

    def test_single_class(self):
        s = schedule_transitions([mm.CLASS_IDS["F2"]] * 7)
        assert s["num_transitions"] == 0
        assert s["classes"].tolist() == [mm.CLASS_IDS["F2"]]
        assert s["counts"].tolist() == [7]

    def test_three_classes(self):
        ids = [mm.CLASS_IDS[c] for c in ("F3", "F1", "F2", "F3", "F1")]
        s = schedule_transitions(ids)
        assert s["num_transitions"] == 2
        # slowest class first: the order must be non-decreasing in class id
        executed = np.asarray(ids)[s["order"]]
        assert (np.diff(executed) >= 0).all()

    @given(st.integers(min_value=0, max_value=2 ** 30))
    def test_reorder_never_changes_counts(self, seed):
        rnd = np.random.default_rng(seed)
        ids = rnd.integers(0, 3, size=rnd.integers(1, 40))
        perm = rnd.permutation(ids.size)
        a = schedule_transitions(ids)
        b = schedule_transitions(ids[perm])
        assert a["classes"].tolist() == b["classes"].tolist()
        assert a["counts"].tolist() == b["counts"].tolist()
        assert a["num_transitions"] == b["num_transitions"]


class TestPlanForClasses:
    def test_all_f1_no_headroom(self):
        plan = plan_for_classes([mm.CLASS_IDS["F1"]] * 5)
        assert plan["num_transitions"] == 0
        assert plan["achievable_freq_ghz"] == pytest.approx(
            plan["nominal_freq_ghz"])
        assert plan["freq_headroom"] == pytest.approx(1.0)

    def test_empty_defaults_to_nominal(self):
        plan = plan_for_classes([])
        assert plan["achievable_freq_ghz"] == pytest.approx(
            plan["nominal_freq_ghz"])
        assert plan["num_transitions"] == 0

    def test_all_f3_max_headroom(self):
        plan = plan_for_classes([mm.CLASS_IDS["F3"]] * 4)
        assert plan["achievable_freq_ghz"] == pytest.approx(3.7)
        assert plan["freq_headroom"] == pytest.approx(3.7 / 1.9)
        assert plan["points"]["F3"].freq_ghz == pytest.approx(3.7)

    def test_mixed_is_tile_weighted(self):
        ids = ([mm.CLASS_IDS["F3"]] * 3 + [mm.CLASS_IDS["F1"]])
        plan = plan_for_classes(ids)
        assert plan["achievable_freq_ghz"] == pytest.approx(
            (3 * 3.7 + 1 * 1.9) / 4)
        assert plan["num_transitions"] == 1


class TestDvfsDomain:
    def test_infeasible_delay_falls_back_to_slowest(self):
        # a critical path slower than every point's period: the domain must
        # still return something -- its slowest (safest) point
        pt = SYSTOLIC_DOMAIN.fastest_point_for_delay(10.0)
        assert pt.freq_ghz == pytest.approx(1.9)
        pt = SYSTOLIC_DOMAIN.best_point_for_delay(10.0)
        assert pt.freq_ghz == pytest.approx(1.9)

    def test_fastest_picks_highest_feasible(self):
        # F2 critical path (1/2.4 ns): F3's period is too short, F2 fits
        pt = SYSTOLIC_DOMAIN.fastest_point_for_delay(1.0 / 2.4)
        assert pt.name == "F2"

    def test_energy_scale_quadratic(self):
        p = OperatingPoint("x", voltage_v=1.2, freq_ghz=3.7)
        assert p.energy_scale(1.0) == pytest.approx(1.44)

    def test_single_point_domain(self):
        dom = DvfsDomain(name="one",
                         points=(OperatingPoint("only", 1.0, 2.0),),
                         v_nominal=1.0)
        assert dom.fastest_point_for_delay(0.1).name == "only"
        assert dom.fastest_point_for_delay(99.0).name == "only"


class TestMacModelAnchors:
    def test_paper_tolerances(self):
        v = mm.validate_against_paper()
        assert v["f3_ghz"] == pytest.approx(3.7, abs=0.05)
        assert v["f2_ghz"] == pytest.approx(2.4, abs=0.05)
        assert v["f1_ghz"] == pytest.approx(1.9, abs=0.05)
        assert v["f3_size"] == 9 and v["f2_size"] == 16
        # paper Fig. 3 direction: the 1-partial-product weight clocks
        # faster than the dense-CSD one (the behavioral model is shallower
        # than the paper's circuit, so only the ordering is asserted)
        assert v["w64_over_wm127"] > 1.0
        assert v["delay_energy_corr"] > 0.5

    def test_luts_are_cached(self):
        # satellite: the autotuner hits these in its inner loop -- the same
        # params object must return the identical cached array
        p = mm.DEFAULT_PARAMS
        assert mm.delay_lut(p) is mm.delay_lut(p)
        assert mm.energy_lut(p) is mm.energy_lut(p)
        assert mm.achievable_freq_ghz(p) is mm.achievable_freq_ghz(p)
