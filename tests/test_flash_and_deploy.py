"""Flash attention (pure-JAX custom VJP + Pallas kernels), a2a MoE,
deploy-format weights, int8 KV cache."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.configs as configs
from repro.models import module as M
from repro.models import transformer as T
from repro.models.attention import causal_blockwise_attention, decode_attention
from repro.models.flash import flash_attention


class TestFlashVjp:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(32, 160), st.sampled_from([(4, 4), (4, 2)]),
           st.sampled_from([None, 64]), st.sampled_from([None, 30.0]))
    def test_forward_matches_blockwise(self, s, heads, window, cap):
        h, hkv = heads
        rng = np.random.default_rng(s)
        q = jnp.asarray(rng.normal(size=(1, s, h, 16)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(1, s, hkv, 16)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(1, s, hkv, 16)).astype(np.float32))
        a = flash_attention(q, k, v, chunk=32, window=window,
                            attn_softcap=cap)
        b = causal_blockwise_attention(q, k, v, chunk=32, window=window,
                                       attn_softcap=cap)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)

    def test_gradients_match_autodiff(self, rng):
        q = jnp.asarray(rng.normal(size=(1, 96, 2, 8)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(1, 96, 2, 8)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(1, 96, 2, 8)).astype(np.float32))

        def f_flash(q, k, v):
            return (flash_attention(q, k, v, chunk=32) ** 2).sum()

        def f_block(q, k, v):
            return (causal_blockwise_attention(q, k, v, chunk=32) ** 2).sum()

        gf = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
        gb = jax.grad(f_block, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gf, gb):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)


class TestPallasFlash:
    def test_fwd_bwd_vs_pure_jax(self, rng):
        from repro.kernels.flash_attention import flash_bwd, flash_fwd
        BH, S, D = 2, 128, 16
        q = jnp.asarray(rng.normal(size=(BH, S, D)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(BH, S, D)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(BH, S, D)).astype(np.float32))
        do = jnp.asarray(rng.normal(size=(BH, S, D)).astype(np.float32))
        out, lse = flash_fwd(q, k, v, bq=32, bk=32, interpret=True)
        ref = flash_attention(q.reshape(BH, S, 1, D),
                              k.reshape(BH, S, 1, D),
                              v.reshape(BH, S, 1, D),
                              chunk=32).reshape(BH, S, D)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        dq, dk, dv = flash_bwd(q, k, v, out, lse, do, bq=32, bk=32,
                               interpret=True)

        def loss(q, k, v):
            o = flash_attention(q.reshape(BH, S, 1, D),
                                k.reshape(BH, S, 1, D),
                                v.reshape(BH, S, 1, D), chunk=32)
            return (o.reshape(BH, S, D) * do).sum()

        gq, gk, gv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip((dq, dk, dv), (gq, gk, gv)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-3)

    def test_flash_decode_int8(self, rng):
        from repro.kernels.flash_decode import flash_decode_int8
        from repro.models.transformer import _dequantize_kv, _quantize_kv
        B, S, H, Hkv, D = 2, 64, 4, 2, 16
        G = H // Hkv
        q = jnp.asarray(rng.normal(size=(B, H, D)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
        length = jnp.array([50, 64], jnp.int32)
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        ref = decode_attention(q, _dequantize_kv(kq, ks, jnp.float32),
                               _dequantize_kv(vq, vs, jnp.float32), length)
        out = flash_decode_int8(
            q.reshape(B, Hkv, G, D).reshape(B * Hkv, G, D),
            kq.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D),
            ks.transpose(0, 2, 1).reshape(B * Hkv, S),
            vq.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D),
            vs.transpose(0, 2, 1).reshape(B * Hkv, S),
            jnp.repeat(length, Hkv), bs=32, interpret=True)
        out = out.reshape(B, Hkv, G, D).reshape(B, H, D)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


class TestA2aMoe:
    def test_matches_reference_single_device(self):
        from repro.configs.base import MoeConfig
        from repro.models.moe import moe_ffn, moe_ffn_specs
        from repro.models.moe_shardmap import moe_ffn_a2a
        cfg = MoeConfig(n_experts=4, top_k=2, capacity_factor=4.0)
        p = M.init_params(moe_ffn_specs(16, 32, cfg, jnp.float32),
                          jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with mesh:
            ref, _ = moe_ffn(p, x, cfg)
            out, _ = moe_ffn_a2a(p, x, cfg, mesh)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=1e-5, atol=1e-5)


class TestDeployWeights:
    def test_dequant_matches_dense_part(self, rng):
        from repro.core.deploy import pack_from_quantized
        from repro.core.quantize import HaloConfig, halo_quantize_tensor
        w = jnp.asarray(rng.normal(0, 0.05, (260, 140)).astype(np.float32))
        hq = halo_quantize_tensor(w, None, HaloConfig())
        dq = pack_from_quantized(hq)
        np.testing.assert_allclose(
            np.asarray(dq.dequantize(jnp.float32)),
            np.asarray(hq.dense_part()), rtol=1e-6, atol=1e-6)

    def test_deploy_specs_structure(self):
        from repro.core.deploy import DeployQuantWeight, deploy_model_specs
        cfg = configs.get_config("mistral-large-123b")
        specs = deploy_model_specs(T.model_specs(cfg))
        found = [l for l in jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, DeployQuantWeight))
            if isinstance(x := l, DeployQuantWeight)]
        assert len(found) > 0
        # idx arrays must be uint8 with halved last dims
        for dw in found:
            assert dw.idx_packed.dtype == jnp.uint8


class TestInt8KvCache:
    def test_decode_close_to_fp_cache(self):
        cfg = dataclasses.replace(configs.get_smoke_config("granite-8b"),
                                  dtype=jnp.float32)
        cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
        params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                  cfg.vocab)
        batch = {"tokens": toks,
                 "positions": jnp.broadcast_to(jnp.arange(32), (2, 32))}
        lg1, c1, l1 = T.prefill(params, cfg, batch, max_seq=48)
        lg2, c2, l2 = T.prefill(params, cfg8, batch, max_seq=48)
        assert c2["period"][0].k.dtype == jnp.int8
        d1 = T.decode_step(params, cfg, {"tokens": toks[:, -1]}, c1, l1)[0]
        d2 = T.decode_step(params, cfg8, {"tokens": toks[:, -1]}, c2, l2)[0]
        rel = float(jnp.abs(d1 - d2).max() / (jnp.abs(d1).max() + 1e-9))
        assert rel < 0.05

    def test_quantize_roundtrip_error_bounded(self, rng):
        from repro.models.transformer import _dequantize_kv, _quantize_kv
        x = jnp.asarray(rng.normal(size=(2, 8, 4, 16)).astype(np.float32))
        q, s = _quantize_kv(x)
        back = _dequantize_kv(q, s, jnp.float32)
        err = np.abs(np.asarray(back - x))
        step = np.asarray(s)[..., None]
        assert (err <= step * 0.51 + 1e-7).all()
