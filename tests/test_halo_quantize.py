"""HALO quantizer invariants (unit + hypothesis property tests)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import assign, codebooks, outliers, tiling
from repro.core.quantize import HaloConfig, effective_bits, halo_quantize_tensor, quant_error


def make_weight(rng, k, n, scale=0.02):
    return jnp.asarray(rng.normal(0, scale, (k, n)).astype(np.float32))


def make_fisher(rng, k, n):
    return jnp.asarray((rng.normal(0, 1, (k, n)) ** 2).astype(np.float32))


class TestTiling:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(5, 200), st.integers(5, 200),
           st.sampled_from([16, 32, 64, 128]))
    def test_roundtrip(self, k, n, tile):
        rng = np.random.default_rng(k * 1000 + n)
        w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        tiles = tiling.to_tiles(w, tile)
        back = tiling.from_tiles(tiles, (k, n), tile)
        assert back.shape == (k, n)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(w))

    @given(st.integers(1, 300), st.integers(1, 300))
    @settings(max_examples=25, deadline=None)
    def test_grid_dims(self, k, n):
        kt, nt = tiling.grid_dims(k, n, 64)
        assert kt * 64 >= k and (kt - 1) * 64 < k
        assert nt * 64 >= n and (nt - 1) * 64 < n


class TestAssign:
    def test_theta_monotone(self):
        rng = np.random.default_rng(3)
        scores = jnp.asarray(rng.exponential(size=200).astype(np.float32))
        fracs = []
        for theta in (0.5, 0.8, 0.95, 0.999):
            res = assign.assign_classes(scores, theta)
            f3 = float((np.asarray(res.classes)
                        == codebooks.TILE_CLASS_F3).mean())
            fracs.append(f3)
        # higher retention -> fewer low-sensitivity (F3) tiles
        assert all(a >= b - 1e-9 for a, b in zip(fracs, fracs[1:]))

    def test_low_mask_is_bottom_of_ranking(self):
        scores = jnp.asarray(np.array([5.0, 0.1, 3.0, 0.2, 0.1], np.float32))
        low, k = assign.compute_adaptive_k(scores, theta=0.9)
        low = np.asarray(low)
        # the large-score tiles must not be classified low-sensitive
        assert not low[0] and not low[2]

    def test_retention_bound(self):
        rng = np.random.default_rng(4)
        scores = jnp.asarray(rng.exponential(size=500).astype(np.float32))
        theta = 0.95
        low, _ = assign.compute_adaptive_k(scores, theta)
        retained = float(scores[~np.asarray(low)].sum() / scores.sum())
        assert retained >= theta - 1e-5


class TestOutliers:
    def test_three_sigma(self, rng):
        w = rng.normal(0, 1, (100, 100)).astype(np.float32)
        w[3, 5] = 25.0
        m = np.asarray(outliers.outlier_mask(jnp.asarray(w)))
        assert m[3, 5]
        assert m.mean() < 0.05

    def test_sparse_roundtrip(self, rng):
        w = jnp.asarray(rng.normal(0, 1, (64, 48)).astype(np.float32))
        mask = jnp.asarray(rng.random((64, 48)) < 0.02)
        dense, sp = outliers.extract_sparse(w, mask)
        # dense part zeroed at mask
        assert float(jnp.abs(jnp.where(mask, dense, 0)).max()) == 0
        # reconstruction error bounded by 8-bit per-channel step
        rec = dense + sp.to_dense()
        err = np.asarray(jnp.abs(rec - w))[np.asarray(mask)]
        step = np.asarray(sp.chan_scale).max()
        assert err.max() <= step * 0.5 + 1e-6

    def test_sparse_matmul_matches_dense(self, rng):
        w = jnp.asarray(rng.normal(0, 1, (32, 40)).astype(np.float32))
        mask = jnp.asarray(rng.random((32, 40)) < 0.05)
        _, sp = outliers.extract_sparse(w, mask)
        x = jnp.asarray(rng.normal(size=(7, 32)).astype(np.float32))
        np.testing.assert_allclose(np.asarray(sp.matmul(x)),
                                   np.asarray(x @ sp.to_dense()),
                                   rtol=1e-5, atol=1e-5)


class TestHaloQuantize:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(40, 200), st.integers(40, 200),
           st.sampled_from([32, 64]))
    def test_invariants(self, k, n, tile):
        rng = np.random.default_rng(k * 7 + n)
        w = make_weight(rng, k, n)
        g2 = make_fisher(rng, k, n)
        hq = halo_quantize_tensor(w, g2, HaloConfig(tile=tile))
        idx = np.asarray(hq.idx)
        cls = np.asarray(hq.classes)
        lo, hi = codebooks.f3_index_range()
        # all indices fit 4 bits
        assert idx.min() >= 0 and idx.max() <= 15
        # F3 tiles use only the 9-value contiguous range
        f3 = idx[cls == codebooks.TILE_CLASS_F3]
        if f3.size:
            assert f3.min() >= lo and f3.max() <= hi
        # scales positive
        assert np.asarray(hq.scale).min() > 0
        # sparse fraction below 1.5% (0.45% nominal + slack for tiny tensors)
        assert hq.sparse.nnz <= max(0.015 * k * n, 8)

    def test_error_reasonable(self, rng):
        w = make_weight(rng, 256, 256)
        g2 = make_fisher(rng, 256, 256)
        hq = halo_quantize_tensor(w, g2, HaloConfig(tile=64))
        # log-codebook worst-case relative step is 1/3 -> rms err well below
        assert quant_error(hq, w) < 0.25

    def test_theta_tradesoff_bits_for_error(self, rng):
        w = make_weight(rng, 256, 192)
        g2 = make_fisher(rng, 256, 192)
        cfg = HaloConfig(tile=32)
        hq_perf = halo_quantize_tensor(w, g2, cfg, theta=0.5)
        hq_acc = halo_quantize_tensor(w, g2, cfg, theta=0.999)
        assert effective_bits(hq_perf) <= effective_bits(hq_acc) + 1e-9
        assert quant_error(hq_acc, w) <= quant_error(hq_perf, w) + 1e-6

    def test_effective_bits_in_paper_range(self, rng):
        w = make_weight(rng, 512, 384)
        g2 = make_fisher(rng, 512, 384)
        hq = halo_quantize_tensor(w, g2, HaloConfig(tile=64))
        bits = effective_bits(hq)
        assert 3.0 <= bits <= 4.5      # paper Table II: 3.0-4.0 + overheads

    def test_calibration_free_mode(self, rng):
        w = make_weight(rng, 130, 70)
        hq = halo_quantize_tensor(w, None, HaloConfig(tile=32))
        assert quant_error(hq, w) < 0.3

    def test_smaller_tiles_reduce_error(self, rng):
        # paper SIV-D: finer tiles -> better fidelity
        w = make_weight(rng, 256, 256)
        g2 = make_fisher(rng, 256, 256)
        errs = [quant_error(halo_quantize_tensor(
            w, g2, HaloConfig(tile=t)), w) for t in (128, 32)]
        assert errs[1] <= errs[0] + 1e-6
