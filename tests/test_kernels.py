"""Pallas kernel validation: interpret-mode vs pure-jnp oracles, with
hypothesis shape/dtype sweeps (per-kernel allclose against ref.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quantize import HaloConfig, halo_quantize_tensor
from repro.kernels import ops, ref
from repro.kernels.halo_matmul import halo_matmul_packed, make_schedule, natural_schedule
from repro.kernels.spmv import bucket_sparse, spmv_matmul


def quantized(rng, k, n, tile=128):
    w = jnp.asarray(rng.normal(0, 0.05, (k, n)).astype(np.float32))
    g2 = jnp.asarray((rng.normal(size=(k, n)) ** 2).astype(np.float32))
    return w, halo_quantize_tensor(w, g2, HaloConfig(tile=tile))


class TestHaloMatmul:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(10, 300), st.integers(100, 400), st.integers(1, 40),
           st.sampled_from([jnp.float32, jnp.bfloat16]))
    def test_vs_dequant(self, k, n, m, dtype):
        rng = np.random.default_rng(k + n + m)
        w, hq = quantized(rng, k, n)
        packed = ops.pack_halo(hq)
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(dtype)
        out = ops.halo_matmul(x, packed, interpret=True, out_dtype=jnp.float32)
        expect = x.astype(jnp.float32) @ hq.dequantize()
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        scale = float(jnp.abs(expect).max()) + 1e-6
        assert float(jnp.abs(out - expect).max()) / scale < tol

    def test_schedule_order_invariance(self, rng):
        w, hq = quantized(rng, 300, 260)
        x = jnp.asarray(rng.normal(size=(16, 300)).astype(np.float32))
        a = ops.halo_matmul(x, ops.pack_halo(hq, scheduled=True),
                            interpret=True)
        b = ops.halo_matmul(x, ops.pack_halo(hq, scheduled=False),
                            interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)

    def test_schedule_is_class_grouped(self, rng):
        _, hq = quantized(rng, 512, 384)
        classes = np.asarray(hq.classes).reshape(4, 3)
        okt, ont, first, last = make_schedule(classes.reshape(-1), 4, 3)
        # per output column, classes must be non-decreasing in the order
        for ni in range(3):
            cls_seq = [classes[okt[i], ont[i]]
                       for i in range(len(okt)) if ont[i] == ni]
            assert cls_seq == sorted(cls_seq)
        # flags well-formed
        assert first.sum() == 3 and last.sum() == 3

    def test_batched_leading_dims(self, rng):
        w, hq = quantized(rng, 140, 150)
        packed = ops.pack_halo(hq)
        x = jnp.asarray(rng.normal(size=(2, 3, 140)).astype(np.float32))
        out = ops.halo_matmul(x, packed, interpret=True)
        assert out.shape == (2, 3, 150)


class TestSpmv:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(100, 500), st.integers(100, 500),
           st.floats(0.001, 0.02), st.integers(1, 24))
    def test_vs_ref(self, k, n, density, m):
        rng = np.random.default_rng(int(k * n * density))
        nnz = max(int(k * n * density), 1)
        rows = rng.integers(0, k, nnz)
        cols = rng.integers(0, n, nnz)
        vals = rng.normal(size=nnz).astype(np.float32)
        chunks = bucket_sparse(rows, cols, vals, (k, n))
        kp, np_ = chunks.shape
        x = jnp.asarray(rng.normal(size=(m, kp)).astype(np.float32))
        out = spmv_matmul(x, chunks, interpret=True)
        expect = ref.spmv_ref(x, chunks)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)

    def test_duplicate_coordinates_accumulate(self):
        rows = np.array([0, 0, 0])
        cols = np.array([1, 1, 2])
        vals = np.array([1.0, 2.0, 4.0], np.float32)
        chunks = bucket_sparse(rows, cols, vals, (4, 4))
        x = jnp.eye(chunks.shape[0], dtype=jnp.float32)[:4]
        out = np.asarray(spmv_matmul(x, chunks, interpret=True, bm=8))
        assert out[0, 1] == pytest.approx(3.0)
        assert out[0, 2] == pytest.approx(4.0)


class TestInt8Matmul:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(8, 300), st.integers(8, 300), st.integers(1, 33))
    def test_vs_ref(self, k, n, m):
        rng = np.random.default_rng(k * 31 + n)
        x = jnp.asarray(rng.normal(0, 2, (m, k)).astype(np.float32))
        w_q = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        w_s = jnp.asarray((rng.random(n) * 0.01 + 1e-3).astype(np.float32))
        out = ops.w8a8_matmul(x, w_q, w_s, interpret=True)
        x_q, x_s = ops.quantize_activations_int8(x)
        expect = ref.int8_matmul_ref(x_q, w_q, x_s, w_s.reshape(1, -1))
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect), rtol=1e-3, atol=1e-3)

    def test_quantize_activations_range(self, rng):
        x = jnp.asarray(rng.normal(0, 10, (5, 64)).astype(np.float32))
        q, s = ops.quantize_activations_int8(x)
        assert q.dtype == jnp.int8
        np.testing.assert_allclose(np.asarray(q * s), np.asarray(x),
                                   atol=float(s.max()) * 0.51)


class TestPacking:
    def test_pack_halo_dequant_identity(self, rng):
        w, hq = quantized(rng, 200, 140)
        packed = ops.pack_halo(hq)
        expect = ref.halo_matmul_padded_ref(
            jnp.eye(packed.padded_shape[0], dtype=jnp.float32),
            packed.idx_packed, packed.scale)
        dense = hq.dense_part()
        np.testing.assert_allclose(
            np.asarray(expect)[:200, :140], np.asarray(dense),
            rtol=1e-6, atol=1e-6)
