"""Unit + property tests for the Booth MAC timing/energy model."""

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.hw import mac_model as mm


class TestCsdRecoding:
    @given(st.integers(min_value=-128, max_value=127))
    def test_roundtrip(self, w):
        d = mm.csd_digits(w)
        assert sum(di * 2**i for i, di in enumerate(d)) == w

    @given(st.integers(min_value=-128, max_value=127))
    def test_nonadjacent(self, w):
        d = mm.csd_digits(w)
        for i in range(len(d) - 1):
            assert not (d[i] != 0 and d[i + 1] != 0)

    @given(st.integers(min_value=-128, max_value=127))
    def test_digits_in_range(self, w):
        assert all(di in (-1, 0, 1) for di in mm.csd_digits(w))

    def test_minimality_examples(self):
        # CSD is the minimal-nonzero signed-digit form
        assert mm.nnz_pp(0) == 0
        for k in range(8):
            if -128 <= 2**k <= 127:
                assert mm.nnz_pp(2**k) == 1
            assert mm.nnz_pp(-(2**k)) == 1
        assert mm.nnz_pp(85) == 4          # 0b1010101
        assert mm.nnz_pp(-127) == 2        # -128 + 1


class TestFrequencyClasses:
    def test_paper_anchors(self):
        v = mm.validate_against_paper()
        assert v["f3_size"] == 9
        assert v["f2_size"] == 16
        assert v["f3_ghz"] == pytest.approx(3.7, abs=1e-3)
        assert v["f2_ghz"] == pytest.approx(2.4, abs=1e-3)
        assert v["f1_ghz"] == pytest.approx(1.9, abs=1e-3)

    def test_class_contents(self):
        cls = mm.frequency_classes()
        assert set(cls["F3"].tolist()) == {0, 1, -1, 2, -2, 4, -4, 8, -8}
        f2 = set(cls["F2"].tolist())
        assert f2 == {0, 1, -1, 2, -2, 4, -4, 8, -8,
                      16, -16, 32, -32, 64, -64, -128}
        assert set(cls["F3"].tolist()) <= f2

    def test_f1_covers_all(self):
        assert mm.frequency_classes()["F1"].size == 256

    def test_delay_energy_correlation(self):
        # paper Fig. 5: faster values also switch less
        v = mm.validate_against_paper()
        assert v["delay_energy_corr"] > 0.5

    @given(st.integers(min_value=-128, max_value=127))
    def test_luts_positive(self, w):
        assert mm.delay_lut()[w + 128] > 0
        assert mm.energy_lut()[w + 128] > 0

    def test_class_freq_is_min_over_values(self):
        cls = mm.frequency_classes()
        f = mm.achievable_freq_ghz()
        for name, vals in cls.items():
            expect = min(f[v + 128] for v in vals)
            assert mm.max_freq_for_values(vals) == pytest.approx(
                float(expect), rel=1e-6)
