"""Multi-tenant serving control plane: policy properties, quotas,
preemption, and streaming TTFT.

Host-side halves run on the scripted executor from test_scheduler (no
JAX in the loop): FIFO-default equivalence, priority ordering, weighted
fair share, aging/no-starvation, per-tenant quota enforcement with
``QuotaExceeded`` backpressure at submit, and preempt/resume cursor
continuity.  Device-side halves run the real engine: the preempt/resume
token-parity matrix across {paged, paged+share_prefix, paged+spec}
modes against an un-preempted contiguous FIFO oracle, the
``Engine.stream()`` TokenEvent/TTFT contract, ``Engine.stats()``, and
the ``PageAllocator`` swap-state unit tests.

Run via ``make test-multitenant`` or as part of the serving CI tier.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from test_scheduler import ScriptedExecutor, stream

import repro.configs as configs
from repro.models import module as M
from repro.models import transformer as T
from repro.serving.engine import Engine, TokenEvent
from repro.serving.scheduler import (PREEMPTED, RUNNING, FifoAdmission,
                                     PageAllocator, PriorityAdmission,
                                     QuotaExceeded, Scheduler, TenantQuota)
from repro.serving.tuning import EngineKnobs

PAGE = 8
ENGINE_KW = dict(prefill_bucket=4, prefill_chunk_width=8, capacity=2,
                 max_seq=32, chunk=3)


def small_model(seed=0):
    cfg = dataclasses.replace(configs.get_smoke_config("granite-8b"),
                              dtype=jnp.float32)
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(seed))
    return cfg, params


@pytest.fixture(scope="module")
def granite():
    return small_model()


class PreemptableScripted(ScriptedExecutor):
    """Scripted executor with the optional preempt/resume contract: a
    victim's cursor parks in a host dict keyed by rid and resumes into
    whatever slot the scheduler hands back -- mirroring what the device
    executor does with KV pages, minus the pages."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self._swap = {}
        self.resume_ok = True          # tests flip this to block resume

    def preempt(self, slot, req):
        rid, cursor = self.slots[slot]
        assert rid == req.rid, "preempt of the wrong seat"
        self._swap[req.rid] = cursor
        self.slots[slot] = None

    def resume(self, slot, req):
        if not self.resume_ok:
            return False
        assert self.slots[slot] is None, "resume into an occupied slot"
        self.slots[slot] = [req.rid, self._swap.pop(req.rid)]
        self._note_occupancy()
        return True


# ---------------------------------------------------------------------------
# policy properties (scripted executor)
# ---------------------------------------------------------------------------

class TestPolicyProperties:
    def test_default_policy_is_fifo(self):
        sched = Scheduler(ScriptedExecutor(1, 2, {}))
        assert isinstance(sched.policy, FifoAdmission)
        assert sched.policy.levels == 1 and sched.policy.head_of_line

    def test_fifo_rejects_nonzero_priority(self):
        sched = Scheduler(ScriptedExecutor(1, 2, {}))
        with pytest.raises(ValueError, match="priority"):
            sched.submit(None, prompt_len=1, max_new=2, priority=1)

    def test_priority_orders_admission(self):
        """capacity 1: a later high-priority submit admits before an
        earlier low-priority one (the FIFO property tests assert the
        opposite for the default policy -- both must hold)."""
        streams = {0: stream(0, 2), 1: stream(1, 2)}
        ex = ScriptedExecutor(1, 4, streams)
        sched = Scheduler(ex, policy=PriorityAdmission(levels=2))
        sched.submit(None, prompt_len=1, max_new=2, priority=0)
        sched.submit(None, prompt_len=1, max_new=2, priority=1)
        sched.drain()
        assert ex.prefill_order == [1, 0]
        assert sched.requests[0].tokens == streams[0]
        assert sched.requests[1].tokens == streams[1]

    def test_weighted_fair_share(self):
        """Tenant A at weight 3 vs B at weight 1, equal priorities and
        request costs: admissions interleave ~3:1 by virtual service
        time, not submit order."""
        n_a, n_b = 6, 2
        streams = {rid: stream(rid, 2) for rid in range(n_a + n_b)}
        ex = ScriptedExecutor(1, 4, streams)
        sched = Scheduler(ex, policy=PriorityAdmission(
            levels=1, weights={"A": 3.0, "B": 1.0}))
        for rid in range(n_a):
            sched.submit(None, prompt_len=1, max_new=2, tenant="A")
        for rid in range(n_b):
            sched.submit(None, prompt_len=1, max_new=2, tenant="B")
        sched.drain()
        # vtime walk: A pays cost/3 per admit, B pays cost -- B's first
        # admit lands after A's first (tie at 0 broken by rid), then B
        # waits out three A admissions before its vtime is lowest again
        assert ex.prefill_order == [0, 6, 1, 2, 3, 7, 4, 5]

    def test_aging_prevents_starvation_scripted(self):
        """A lone priority-0 request behind a deep priority-1 backlog:
        aging bumps its effective priority so it admits after a bounded
        number of pass-overs, not last."""
        n_hi = 10
        streams = {rid: stream(rid, 2) for rid in range(n_hi + 1)}
        ex = ScriptedExecutor(1, 4, streams)
        sched = Scheduler(ex, policy=PriorityAdmission(levels=2, aging=2))
        lo = sched.submit(None, prompt_len=1, max_new=2, priority=0)
        for _ in range(n_hi):
            sched.submit(None, prompt_len=1, max_new=2, priority=1,
                         tenant="hot")
        sched.drain()
        # 2 skips lift it into the top band; fair share (vtime 0 vs the
        # hot tenant's accumulation) admits it right after
        assert ex.prefill_order.index(lo) <= 3
        assert sched.requests[lo].tokens == streams[lo]

    def test_aging_zero_disables(self):
        """aging=0: effective priority never moves; the low-priority
        request admits dead last."""
        streams = {rid: stream(rid, 2) for rid in range(4)}
        ex = ScriptedExecutor(1, 4, streams)
        sched = Scheduler(ex, policy=PriorityAdmission(levels=2, aging=0))
        lo = sched.submit(None, prompt_len=1, max_new=2, priority=0)
        for _ in range(3):
            sched.submit(None, prompt_len=1, max_new=2, priority=1)
        sched.drain()
        assert ex.prefill_order[-1] == lo


# ---------------------------------------------------------------------------
# quotas + backpressure (scripted executor)
# ---------------------------------------------------------------------------

class TestQuotas:
    def test_slot_quota_bounds_residency(self):
        """slots=1 for a tenant submitting 3 requests into a capacity-3
        scheduler: never more than one seated at once, all complete."""
        streams = {rid: stream(rid, 4) for rid in range(3)}
        ex = ScriptedExecutor(3, 1, streams)
        sched = Scheduler(ex, policy=PriorityAdmission(levels=1),
                          quotas={"t": TenantQuota(slots=1)})
        for rid in range(3):
            sched.submit(None, prompt_len=1, max_new=4, tenant="t")
        guard = 0
        while sched.pending:
            sched.tick()
            seats, _ = sched.tenant_usage.get("t", (0, 0))
            assert seats <= 1, "slot quota exceeded"
            guard += 1
            assert guard < 100
        assert all(sched.requests[r].tokens == streams[r] for r in range(3))

    def test_pages_quota_bounds_reservations(self):
        """Page quotas account host-side even on a scripted executor
        flagged paged: two 2-page requests under a 3-page quota
        serialize."""
        streams = {rid: stream(rid, 4) for rid in range(2)}
        ex = ScriptedExecutor(2, 1, streams)
        ex.paged, ex.page_size = True, 4       # host accounting only
        sched = Scheduler(ex, policy=PriorityAdmission(levels=1),
                          quotas={"t": TenantQuota(pages=3)})
        for rid in range(2):
            sched.submit(None, prompt_len=4, max_new=4, tenant="t")
        guard = 0
        while sched.pending:
            sched.tick()
            _, pages = sched.tenant_usage.get("t", (0, 0))
            assert pages <= 3, "page quota exceeded"
            guard += 1
            assert guard < 100
        assert ex.max_occupied == 1            # quota serialized the seats

    def test_queue_quota_backpressure_at_submit(self):
        streams = {rid: stream(rid, 2) for rid in range(3)}
        ex = ScriptedExecutor(1, 4, streams)
        sched = Scheduler(ex, quotas={"t": TenantQuota(queue=2)})
        sched.submit(None, prompt_len=1, max_new=2, tenant="t")
        sched.submit(None, prompt_len=1, max_new=2, tenant="t")
        with pytest.raises(QuotaExceeded, match="queue quota"):
            sched.submit(None, prompt_len=1, max_new=2, tenant="t")
        # other tenants are not backpressured by t's quota
        sched.submit(None, prompt_len=1, max_new=2, tenant="u")
        sched.drain()
        # completions release outstanding budget: submit admits again
        rid = sched.submit(None, prompt_len=1, max_new=2, tenant="t")
        assert rid == 3

    def test_default_quota_applies_to_unlisted_tenants(self):
        streams = {rid: stream(rid, 2) for rid in range(2)}
        ex = ScriptedExecutor(1, 4, streams)
        sched = Scheduler(ex, default_quota=TenantQuota(queue=1))
        sched.submit(None, prompt_len=1, max_new=2, tenant="anyone")
        with pytest.raises(QuotaExceeded):
            sched.submit(None, prompt_len=1, max_new=2, tenant="anyone")

    def test_fifo_quota_blocked_head_waits(self):
        """Under the default FIFO policy a quota-blocked queue head
        stalls admission (head-of-line is the FIFO contract); under
        PriorityAdmission the request behind it admits instead."""
        for policy, expect_first in ((None, False),
                                     (PriorityAdmission(levels=1), True)):
            streams = {0: stream(0, 8), 1: stream(1, 2), 2: stream(2, 2)}
            ex = ScriptedExecutor(2, 1, streams)
            sched = Scheduler(ex, policy=policy,
                              quotas={"t": TenantQuota(slots=1)})
            # seat a long-running request to pin tenant t at its quota
            blocker = sched.submit(None, prompt_len=1, max_new=8,
                                   tenant="t")
            sched.tick()
            assert sched.requests[blocker].status == RUNNING
            sched.submit(None, prompt_len=1, max_new=2, tenant="t")
            other = sched.submit(None, prompt_len=1, max_new=2, tenant="u")
            sched.tick()
            got = [r for r in ex.prefill_order if r != blocker]
            assert got == ([other] if expect_first else []), \
                f"policy={policy}: head-of-line contract broken"
            sched.drain()


# ---------------------------------------------------------------------------
# preemption lifecycle (scripted executor)
# ---------------------------------------------------------------------------

class TestScriptedPreemption:
    def _contended(self, max_new_lo=8):
        streams = {0: stream(0, max_new_lo), 1: stream(1, 2)}
        ex = PreemptableScripted(1, 2, streams)
        sched = Scheduler(ex, policy=PriorityAdmission(levels=2, aging=4,
                                                       preempt=True))
        lo = sched.submit(None, prompt_len=1, max_new=max_new_lo,
                          priority=0)
        sched.tick()                           # seat the victim first
        assert sched.requests[lo].status == RUNNING
        hi = sched.submit(None, prompt_len=1, max_new=2, priority=1)
        return sched, ex, lo, hi

    def test_preempt_resume_cursor_continuity(self):
        """The victim's token stream continues exactly where it stopped:
        no token dropped, duplicated, or reordered across the swap."""
        sched, ex, lo, hi = self._contended()
        sched.tick()                           # preempts lo, seats hi
        assert sched.requests[lo].status == PREEMPTED
        assert sched.requests[lo].slot is None
        assert sched.preemptions == 1
        assert sched.requests[lo].preempt_count == 1
        assert lo in ex._swap
        sched.drain()
        assert not ex._swap                    # resumed, swap pool empty
        assert sched.requests[lo].tokens == ex.streams[lo]
        assert sched.requests[hi].tokens == ex.streams[hi]

    def test_blocked_resume_retries(self):
        """resume() returning False parks the request PREEMPTED (nothing
        lost) and it retries until the executor admits it."""
        sched, ex, lo, hi = self._contended()
        sched.tick()
        ex.resume_ok = False
        for _ in range(3):
            sched.tick()
            assert sched.requests[lo].status == PREEMPTED
        ex.resume_ok = True
        sched.drain()
        assert sched.requests[lo].tokens == ex.streams[lo]

    def test_no_preempt_without_executor_support(self):
        """A preempt=True policy over an executor without the optional
        preempt/resume methods never preempts (capability-gated), and
        everything still completes."""
        streams = {0: stream(0, 6), 1: stream(1, 2)}
        ex = ScriptedExecutor(1, 2, streams)
        sched = Scheduler(ex, policy=PriorityAdmission(levels=2,
                                                       preempt=True))
        sched.submit(None, prompt_len=1, max_new=6, priority=0)
        sched.tick()
        sched.submit(None, prompt_len=1, max_new=2, priority=1)
        sched.drain()
        assert sched.preemptions == 0
        assert sched.requests[0].tokens == streams[0]
        assert sched.requests[1].tokens == streams[1]

    def test_fifo_never_preempts(self):
        """The default policy never selects a victim even on a
        preemption-capable executor."""
        streams = {0: stream(0, 6), 1: stream(1, 2)}
        ex = PreemptableScripted(1, 2, streams)
        sched = Scheduler(ex)
        sched.submit(None, prompt_len=1, max_new=6)
        sched.submit(None, prompt_len=1, max_new=2)
        sched.drain()
        assert sched.preemptions == 0 and not ex._swap


# ---------------------------------------------------------------------------
# PageAllocator swap states
# ---------------------------------------------------------------------------

class TestAllocatorSwap:
    def test_swap_out_and_conservation(self):
        alloc = PageAllocator(6)
        frames = alloc.alloc(4)
        alloc.swap_out(frames[:2])
        s = alloc.stats()
        assert s == {"n_pages": 6, "free": 2, "live": 2, "pinned": 0,
                     "swapped": 2}
        assert s["free"] + s["live"] + s["swapped"] == 6

    def test_alloc_draws_free_then_swapped(self):
        alloc = PageAllocator(4)
        first = alloc.alloc(4)
        alloc.swap_out(first)                  # all 4 vacated
        assert alloc.n_free == 0 and alloc.n_swapped == 4
        got = alloc.alloc(3)                   # must draw swapped frames
        assert got is not None and alloc.n_swapped == 1
        assert alloc.alloc(2) is None          # 1 swapped + 0 free < 2

    def test_swap_out_refuses_shared_frames(self):
        alloc = PageAllocator(4)
        frames = alloc.alloc(2)
        alloc.share([frames[0]])
        with pytest.raises(ValueError, match="refcount"):
            alloc.swap_out(frames)             # frames[0] is pinned
        # the failed call must not have half-applied
        assert alloc.n_swapped == 0 and alloc.refcount(frames[1]) == 1

    def test_pinned_counter(self):
        alloc = PageAllocator(4)
        frames = alloc.alloc(3)
        alloc.share(frames[:2])
        assert alloc.stats()["pinned"] == 2
        alloc.free(frames[:2])
        assert alloc.stats()["pinned"] == 0 and alloc.n_live == 3


# ---------------------------------------------------------------------------
# engine-backed: preempt/resume token parity matrix + streaming TTFT
# ---------------------------------------------------------------------------

def _mt_kw(mode):
    kw = dict(ENGINE_KW, paged=True, page_size=PAGE, priority_levels=2,
              preempt=True)
    if mode == "paged_share":
        kw["share_prefix"] = True
    elif mode == "paged_spec":
        kw.update(speculative=True, k=3)
    return kw


class TestEnginePreemptionParity:
    @pytest.mark.parametrize("mode", ["paged", "paged_share", "paged_spec"])
    def test_preempt_resume_token_parity(self, granite, mode):
        """The acceptance matrix: preempted-and-resumed requests emit
        token-identical output to an un-preempted contiguous FIFO oracle
        in every paged engine mode, and the trace really preempted."""
        cfg, params = granite
        rng = np.random.default_rng(23)
        prompts = [rng.integers(0, cfg.vocab, (1, n)).astype(np.int32)
                   for n in (6, 5, 4)]
        eng = Engine(params, cfg, **_mt_kw(mode))
        r0 = eng.submit({"tokens": prompts[0]}, max_new=8, priority=0,
                        tenant="batch")
        r1 = eng.submit({"tokens": prompts[1]}, max_new=8, priority=0,
                        tenant="batch")
        eng.step()                             # both victims RUNNING
        sched = eng._sched
        assert sched.requests[r0].status == RUNNING
        assert sched.requests[r1].status == RUNNING
        r2 = eng.submit({"tokens": prompts[2]}, max_new=4, priority=1,
                        tenant="lat")
        eng.step()                             # preempts the newest victim
        assert sched.preemptions >= 1, f"{mode}: preemption never fired"
        assert sched.requests[r1].preempt_count >= 1
        res = eng.drain()
        oracle = Engine(params, cfg, **ENGINE_KW)
        o0 = oracle.submit({"tokens": prompts[0]}, max_new=8)
        o1 = oracle.submit({"tokens": prompts[1]}, max_new=8)
        o2 = oracle.submit({"tokens": prompts[2]}, max_new=4)
        want = oracle.drain()
        for rid, oid in ((r0, o0), (r1, o1), (r2, o2)):
            np.testing.assert_array_equal(
                res[rid], want[oid],
                err_msg=f"{mode}: rid {rid} diverged across preemption")
        stats = eng.stats()
        assert stats["preemptions"] >= 1 and stats["swap_ins"] >= 1
        s = stats["pages"]
        assert s["free"] + s["live"] + s["swapped"] == s["n_pages"]

    def test_preempt_requires_paged(self, granite):
        cfg, params = granite
        with pytest.raises(ValueError, match="preempt"):
            Engine(params, cfg, preempt=True)

    def test_default_engine_policy_is_fifo(self, granite):
        """No tenants/priorities given: the engine hands the scheduler
        no policy (FIFO default) and no quotas -- behavioral identity
        with the pre-policy engine."""
        cfg, params = granite
        eng = Engine(params, cfg, **ENGINE_KW)
        assert eng._make_policy() is None
        assert eng._make_quotas() == ({}, None)
        mt = Engine(params, cfg, **ENGINE_KW,
                    priority_levels=2,
                    tenants={"lat": {"weight": 2.0, "slots": 1}})
        policy = mt._make_policy()
        assert isinstance(policy, PriorityAdmission)
        assert policy.levels == 2 and policy.weight("lat") == 2.0
        quotas, default = mt._make_quotas()
        assert quotas["lat"].slots == 1 and default is None

    def test_tenant_quota_knobs_flow_through(self, granite):
        cfg, params = granite
        eng = Engine(params, cfg, **ENGINE_KW, tenant_slots=1)
        quotas, default = eng._make_quotas()
        assert default == TenantQuota(slots=1) and quotas == {}
        with pytest.raises(ValueError, match="unknown spec key"):
            Engine(params, cfg, **ENGINE_KW, tenants={"t": {"wieght": 2}})

    def test_engine_queue_quota_backpressure(self, granite):
        cfg, params = granite
        eng = Engine(params, cfg, **ENGINE_KW,
                     tenants={"t": {"queue": 1}})
        p = np.zeros((1, 4), np.int32)
        eng.submit({"tokens": p}, max_new=2, tenant="t")
        with pytest.raises(QuotaExceeded):
            eng.submit({"tokens": p}, max_new=2, tenant="t")
        eng.drain()


class TestStreaming:
    def test_stream_events_and_ttft(self, granite):
        """Engine.stream() yields every token exactly once, in per-rid
        order, with TTFT on each request's first event and ``done`` on
        its last -- and drain/pop_finished semantics are untouched."""
        cfg, params = granite
        eng = Engine(params, cfg, **ENGINE_KW)
        rng = np.random.default_rng(31)
        r0 = eng.submit({"tokens": rng.integers(
            0, cfg.vocab, (1, 5)).astype(np.int32)}, max_new=4)
        r1 = eng.submit({"tokens": rng.integers(
            0, cfg.vocab, (1, 3)).astype(np.int32)}, max_new=2)
        events = list(eng.stream())
        assert all(isinstance(e, TokenEvent) for e in events)
        by_rid = {r0: [], r1: []}
        for e in events:
            by_rid[e.rid].append(e)
        res = eng.pop_finished()               # still collectible after
        for rid, want_n in ((r0, 4), (r1, 2)):
            evs = by_rid[rid]
            assert [e.index for e in evs] == list(range(want_n))
            assert [e.token for e in evs] == list(res[rid])
            assert evs[0].ttft is not None and evs[0].ttft > 0
            assert all(e.ttft is None for e in evs[1:])
            assert [e.done for e in evs] == [False] * (want_n - 1) + [True]
            assert all(e.tenant == "default" for e in evs)

    def test_stream_empty_engine(self, granite):
        cfg, params = granite
        eng = Engine(params, cfg, **ENGINE_KW)
        assert list(eng.stream()) == []

    def test_ttft_recorded_on_drain_too(self, granite):
        """TTFT is a Request-level stamp, not a stream()-only artifact:
        plain drain() populates it for bench reporting."""
        cfg, params = granite
        eng = Engine(params, cfg, **ENGINE_KW)
        rid = eng.submit({"tokens": np.zeros((1, 4), np.int32)}, max_new=2)
        eng.drain()
        req = eng._sched.requests[rid]
        assert req.ttft is not None and req.ttft > 0
        assert req.done_wall is not None \
            and req.done_wall >= req.first_token_wall


class TestKnobValidation:
    """Engine-level guards for the new knobs (the EngineKnobs unit
    matrix lives in test_autotune.py)."""

    def test_priority_levels_floor(self, granite):
        cfg, params = granite
        with pytest.raises(ValueError, match="priority_levels"):
            Engine(params, cfg, priority_levels=0)

    def test_submit_priority_range(self, granite):
        cfg, params = granite
        eng = Engine(params, cfg, **ENGINE_KW, priority_levels=2)
        p = np.zeros((1, 4), np.int32)
        eng.submit({"tokens": p}, max_new=2, priority=1)
        with pytest.raises(ValueError, match="priority"):
            eng.submit({"tokens": p}, max_new=2, priority=2)
        eng.drain()

    def test_knobs_strict_quota_validation(self):
        with pytest.raises(ValueError, match="tenant_slots"):
            EngineKnobs(admit_k=2, tenant_slots=4).validated(capacity=2,
                                                             strict=True)
        clamped = EngineKnobs(admit_k=2, tenant_slots=4).validated(
            capacity=2, strict=False)
        assert clamped.tenant_slots == 2
