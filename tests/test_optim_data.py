"""Optimizer, schedule, PowerSGD compression, synthetic data pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.optim import adamw
from repro.optim.compression import (PowerSGDConfig, compressed_mean,
                                     compression_ratio, init_state)
from repro.optim.schedule import warmup_cosine


class TestAdamW:
    def test_quadratic_convergence(self):
        target = jnp.asarray(np.random.default_rng(0)
                             .normal(size=(8, 8)).astype(np.float32))
        params = {"w": jnp.zeros((8, 8))}
        cfg = adamw.AdamWConfig(weight_decay=0.0, clip_norm=None)
        state = adamw.init(params, cfg)

        def loss(p):
            return jnp.sum((p["w"] - target) ** 2)

        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state, _ = adamw.update(g, state, params, 0.05, cfg)
        assert float(loss(params)) < 1e-2

    def test_clip_bounds_update(self):
        params = {"w": jnp.zeros((4,))}
        cfg = adamw.AdamWConfig(clip_norm=1.0, weight_decay=0.0)
        state = adamw.init(params, cfg)
        g = {"w": jnp.full((4,), 1e6)}
        _, _, metrics = adamw.update(g, state, params, 1e-3, cfg)
        assert float(metrics["grad_norm"]) > 1e5   # reported pre-clip

    def test_bf16_moments(self):
        params = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
        cfg = adamw.AdamWConfig(moment_dtype=jnp.bfloat16)
        state = adamw.init(params, cfg)
        assert state.mu["w"].dtype == jnp.bfloat16
        g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
        p2, s2, _ = adamw.update(g, state, params, 1e-2, cfg)
        assert p2["w"].dtype == jnp.bfloat16
        assert s2.mu["w"].dtype == jnp.bfloat16

    def test_tuple_pytrees_supported(self):
        # period-stacked params live in tuples; the update must not confuse
        # structural tuples with leaf tuples
        params = {"period": ({"w": jnp.ones((2, 2))},
                             {"w": jnp.ones((3, 3))})}
        state = adamw.init(params)
        g = jax.tree.map(jnp.ones_like, params)
        p2, _, _ = adamw.update(g, state, params, 1e-2)
        assert p2["period"][1]["w"].shape == (3, 3)


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, 1e-3, 10, 100)) for s in range(100)]
    assert lrs[0] == 0.0
    assert lrs[10] == pytest.approx(1e-3, rel=1e-5)
    assert lrs[99] < lrs[10]
    assert min(lrs[10:]) >= 1e-4 - 1e-9     # floor 0.1 * peak


class TestPowerSGD:
    def test_single_worker_error_feedback_converges(self):
        """With one worker, repeated compress+EF must recover the gradient:
        accumulated reconstruction -> g as steps grow."""
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
        cfg = PowerSGDConfig(rank=4, min_size=16)
        state = init_state(g, cfg)

        def run(g, state):
            # axis over a singleton mesh ~ identity psum
            from jax.sharding import Mesh
            import jax
            mesh = jax.make_mesh((1,), ("dp",))
            from repro.models.moe_shardmap import _shard_map as shard_map
            from jax.sharding import PartitionSpec as P
            f = shard_map(
                lambda gg, ss: compressed_mean(gg, ss, "dp", cfg),
                mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
            return f(g, state)

        recon_total = jnp.zeros((64, 64))
        out1 = None
        for i in range(12):
            out, state = run(g, state)
            if i == 0:
                out1 = out["w"]
            recon_total = recon_total + out["w"]
            # next-step gradient is the same g (EF accumulates the residual)
        # average reconstruction approaches g; must beat single-shot rank-4
        err = float(jnp.linalg.norm(recon_total / 12 - g["w"])
                    / jnp.linalg.norm(g["w"]))
        err_single = float(jnp.linalg.norm(out1 - g["w"])
                           / jnp.linalg.norm(g["w"]))
        assert err < err_single        # EF recovers residual energy
        assert err < 0.75

    def test_low_rank_output(self):
        rng = np.random.default_rng(1)
        g = {"w": jnp.asarray(rng.normal(size=(32, 48)).astype(np.float32))}
        cfg = PowerSGDConfig(rank=2, min_size=16)
        state = init_state(g, cfg)
        from repro.models.moe_shardmap import _shard_map as shard_map
        from jax.sharding import PartitionSpec as P
        mesh = jax.make_mesh((1,), ("dp",))
        f = shard_map(lambda gg, ss: compressed_mean(gg, ss, "dp", cfg),
                      mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
        out, _ = f(g, state)
        assert int(jnp.linalg.matrix_rank(out["w"])) <= 2

    def test_ratio(self):
        g = {"w": jnp.zeros((1024, 1024)), "b": jnp.zeros((10,))}
        r = compression_ratio(g, PowerSGDConfig(rank=4))
        assert r > 50


class TestSyntheticCorpus:
    def test_deterministic_resume(self):
        c = SyntheticCorpus(CorpusConfig(vocab=100, seq_len=16, batch=2))
        a = c.batch_at(5)
        b = c.batch_at(5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_shifted(self):
        c = SyntheticCorpus(CorpusConfig(vocab=100, seq_len=16, batch=2))
        b = c.batch_at(0)
        assert b["tokens"].shape == b["labels"].shape == (2, 16)
        # label t == token t+1 within the underlying sequence
        b2 = c.batch_at(0)
        np.testing.assert_array_equal(b["labels"][:, :-1],
                                      b2["tokens"][:, 1:])

    def test_structure_learnable(self):
        c = SyntheticCorpus(CorpusConfig(vocab=100, seq_len=64, batch=4,
                                         p_structured=0.9))
        floor = c.floor_perplexity()
        assert 1.0 < floor < 100.0
        # an order-2 oracle predicts the deterministic branch exactly
        b = c.batch_at(0)
        toks, labs = b["tokens"], b["labels"]
        det = (toks[:, 1:] * c._a + toks[:, :-1] * c._b + c._c) % 100
        frac = (det == labs[:, 1:]).mean()
        assert frac > 0.8

    def test_eval_disjoint_from_train(self):
        c = SyntheticCorpus(CorpusConfig(vocab=100, seq_len=16, batch=2))
        train0 = c.batch_at(0)["tokens"]
        ev = next(iter(c.eval_batches(1)))["tokens"]
        assert not np.array_equal(train0, ev)
