"""Packed serving fast path: pack-at-load tree transform, kernel parity
against the XLA dequant path, and scan-based generate vs the legacy loop."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.core import deploy
from repro.core.apply import StackedHalo, dequantize_params, quantize_params
from repro.core.quantize import HaloConfig, halo_quantize_tensor
from repro.kernels import ops
from repro.models import module as M
from repro.models import transformer as T
from repro.serving.engine import Engine, SamplerConfig


def quantized(rng, k, n, with_fisher=True):
    w = jnp.asarray(rng.normal(0, 0.05, (k, n)).astype(np.float32))
    g2 = None
    if with_fisher:
        g2 = jnp.asarray((rng.normal(size=(k, n)) ** 2).astype(np.float32))
    return w, halo_quantize_tensor(w, g2, HaloConfig(tile=128))


class TestKernelVsDequant:
    # interpret=True pins the Pallas kernel; interpret=None pins whatever
    # the backend resolves to (the XLA fallback on this CPU container) --
    # the branch production serving actually takes off-TPU
    @pytest.mark.parametrize("interpret", [True, None])
    @pytest.mark.parametrize("k,n,m", [
        (300, 260, 4),      # non-multiple-of-128 K and N
        (256, 140, 1),      # M=1 decode row
        (130, 384, 16),
    ])
    def test_matmul_matches_dequant(self, rng, k, n, m, interpret):
        """halo_matmul == DeployQuantWeight.dequantize + matmul + the
        sparse outlier stream, to <= 1e-4."""
        w, hq = quantized(rng, k, n)
        packed = ops.pack_halo(hq)
        dq = deploy.pack_from_quantized(hq)
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        out = ops.halo_matmul(x, packed, interpret=interpret,
                              out_dtype=jnp.float32)
        # DeployQuantWeight carries only the dense 4-bit stream; the packed
        # kernel path adds the bucketed outliers, so the oracle adds them too
        expect = x @ dq.dequantize(jnp.float32) + x @ hq.sparse.to_dense()
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)

    def test_m1_decode_uses_small_block(self, rng):
        """bm_eff heuristic: M=1 must not fall back to a full 128 block."""
        w, hq = quantized(rng, 256, 256, with_fisher=False)
        packed = ops.pack_halo(hq)
        x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
        out = ops.halo_matmul(x[None, :], packed, interpret=True,
                              out_dtype=jnp.float32)
        expect = x[None, :] @ hq.dequantize()
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)
        assert ops._next_pow2(1) == 1
        assert ops._next_pow2(8) == 8
        assert ops._next_pow2(9) == 16
        assert ops._next_pow2(128) == 128

    def test_stacked_pack_params_matches_per_slice(self, rng):
        tree = {"w": jnp.asarray(
            rng.normal(0, 0.05, (3, 256, 260)).astype(np.float32))}
        q = quantize_params(tree, None, HaloConfig(tile=128))
        assert isinstance(q["w"], StackedHalo)
        pk = deploy.pack_params(q)["w"]
        assert isinstance(pk, ops.HaloPacked) and pk.is_stacked
        x = jnp.asarray(rng.normal(size=(2, 256)).astype(np.float32))

        def body(_, wslice):
            return None, ops.halo_matmul(x, wslice, interpret=True,
                                         out_dtype=jnp.float32)

        _, outs = jax.lax.scan(body, None, pk)
        for i, s in enumerate(q["w"].slices):
            expect = x @ s.dequantize()
            np.testing.assert_allclose(np.asarray(outs[i]),
                                       np.asarray(expect),
                                       rtol=1e-4, atol=1e-4)

    def test_packed_dequantize_matches_quantized(self, rng):
        w, hq = quantized(rng, 300, 140)
        packed = ops.pack_halo(hq)
        np.testing.assert_allclose(
            np.asarray(packed.dequantize(jnp.float32)),
            np.asarray(hq.dequantize()), rtol=1e-6, atol=1e-6)


def small_model(arch="granite-8b", seed=0):
    cfg = dataclasses.replace(configs.get_smoke_config(arch),
                              dtype=jnp.float32)
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(seed))
    return cfg, params


class TestScanGenerate:
    def test_scan_matches_legacy_loop_greedy(self):
        """The jitted lax.scan decode emits exactly the legacy loop's
        tokens under greedy sampling (incl. bucketed prefill padding);
        the default (continuous-scheduler) path matches both."""
        cfg, params = small_model()
        eng = Engine(params, cfg)
        prompts = {"tokens": jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (2, 13)))}
        fast = eng.generate(dict(prompts), max_new=6, mode="batch")
        legacy = eng.generate(dict(prompts), max_new=6, legacy_loop=True)
        cont = eng.generate(dict(prompts), max_new=6)
        np.testing.assert_array_equal(fast, legacy)
        np.testing.assert_array_equal(cont, legacy)

    def test_scan_matches_legacy_loop_temperature(self):
        """Temperature parity is a batch-loop property: the scan and the
        legacy loop share one batch-wide key stream.  (The continuous
        scheduler deliberately uses per-slot streams keyed by request id
        -- see docs/serving.md -- so it is excluded here.)"""
        cfg, params = small_model()
        eng = Engine(params, cfg, SamplerConfig(temperature=0.7, seed=11))
        prompts = {"tokens": jnp.asarray(
            np.random.default_rng(1).integers(0, cfg.vocab, (2, 16)))}
        fast = eng.generate(dict(prompts), max_new=5, mode="batch")
        legacy = eng.generate(dict(prompts), max_new=5, legacy_loop=True)
        np.testing.assert_array_equal(fast, legacy)

    def test_packed_engine_matches_full_dequant(self, quantized_llama):
        """End-to-end: serving a pack_params tree through the kernel path
        emits the same greedy tokens as serving the fully dequantized
        weights (dense incl. outliers) through the dense path."""
        cfg, q = quantized_llama
        prompts = {"tokens": jnp.asarray(
            np.random.default_rng(2).integers(0, cfg.vocab, (2, 12)))}
        toks_packed = Engine(deploy.pack_params(q), cfg).generate(
            dict(prompts), max_new=4)
        toks_dense = Engine(dequantize_params(q), cfg).generate(
            dict(prompts), max_new=4)
        np.testing.assert_array_equal(toks_packed, toks_dense)


@pytest.fixture(scope="module")
def quantized_llama():
    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.common import bench_config
    cfg = bench_config("llama")
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(0))
    return cfg, quantize_params(params, None, HaloConfig(tile=128))


class TestContinuousRecyclingQuantized:
    """KV-cache slot recycling on the real quantized serving trees: after
    a slot is evicted and refilled, the new request's tokens match a
    fresh single-request run (no stale-cache leakage), for both the
    packed-kernel and the XLA-dequant weight paths."""

    @pytest.mark.parametrize("path", ["packed", "dequant"])
    def test_recycled_slot_matches_fresh_run(self, quantized_llama, path):
        cfg, q = quantized_llama
        tree = (deploy.pack_params(q) if path == "packed"
                else deploy.deploy_params(q))
        rng = np.random.default_rng(4)
        reqs = [rng.integers(0, cfg.vocab, (1, n)) for n in (10, 18, 7)]
        eng = Engine(tree, cfg, prefill_bucket=16, capacity=1, max_seq=48,
                     chunk=4)
        rids = [eng.submit({"tokens": p}, max_new=4) for p in reqs]
        res = eng.drain()
        oracle = Engine(tree, cfg, prefill_bucket=16)
        for rid, p in zip(rids, reqs):
            fresh = oracle.generate({"tokens": jnp.asarray(p)}, max_new=4,
                                    mode="batch")[0]
            np.testing.assert_array_equal(res[rid], fresh)
