"""Paged KV cache: paged==contiguous token parity across the cache
families x chunked prefill x mid-decode recycling, the host page
allocator's invariants (no double allocation, frees on evict, admission
blocks when the pool is exhausted), and the Pallas paged decode kernel
against its XLA gather lowering.

The contiguous layout is the parity oracle: on the XLA fallback the paged
read path gathers frames back into exactly the dense (B, S, ...) layout
the contiguous cache stores, so greedy outputs must match token for
token -- any drift means a page remap bug, not fp noise.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.configs as configs
from repro.core import deploy
from repro.models import module as M
from repro.models import transformer as T
from repro.models.attention import decode_attention, gather_pages
from repro.serving.engine import Engine
from repro.serving.scheduler import PageAllocator, Scheduler

ARCHS = ["granite-8b",          # linear KV
         "gemma2-2b",           # ring local KV + global KV mix
         "falcon-mamba-7b",     # SSM state
         "recurrentgemma-2b"]   # RG-LRU + ring


def small_model(arch="granite-8b", seed=0, **over):
    cfg = dataclasses.replace(configs.get_smoke_config(arch),
                              dtype=jnp.float32, **over)
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(seed))
    return cfg, params


_CACHE = {}


def cached_model(arch="granite-8b", **over):
    key = (arch, tuple(sorted(over.items())))
    if key not in _CACHE:
        _CACHE[key] = small_model(arch, **over)
    return _CACHE[key]


@pytest.fixture(scope="module")
def granite():
    return cached_model()


# ---------------------------------------------------------------------------
# parity matrix: paged == contiguous, token for token
# ---------------------------------------------------------------------------

class TestPagedParity:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_families_chunked_prefill_and_recycling(self, arch):
        """Every cache family, exercised through the full serving life:
        a prompt longer than the prefill window (chunked PREFILLING),
        short prompts (the fresh fast path), and more requests than
        slots (mid-decode recycling) -- paged greedy tokens == contiguous
        greedy tokens for every request."""
        cfg, params = cached_model(arch)
        rng = np.random.default_rng(17)
        reqs = [rng.integers(0, cfg.vocab, (1, n)) for n in (21, 5, 11)]
        kw = dict(prefill_bucket=8, prefill_chunk_width=8, capacity=2,
                  max_seq=32, chunk=4)
        eng_c = Engine(params, cfg, **kw)
        eng_p = Engine(params, cfg, paged=True, page_size=8, **kw)
        rc = [eng_c.submit({"tokens": p}, max_new=5) for p in reqs]
        rp = [eng_p.submit({"tokens": p}, max_new=5) for p in reqs]
        res_c, res_p = eng_c.drain(), eng_p.drain()
        for a, b in zip(rc, rp):
            np.testing.assert_array_equal(res_p[b], res_c[a])

    def test_generate_wrapper_parity(self, granite):
        """Engine.generate on a paged engine == contiguous == one-shot
        batch mode (greedy), across a two-bucket prompt batch."""
        cfg, params = granite
        rng = np.random.default_rng(3)
        prompts = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (2, 13)).astype(np.int32))}
        eng_c = Engine(params, cfg, prefill_bucket=8)
        eng_p = Engine(params, cfg, prefill_bucket=8, paged=True,
                       page_size=8)
        want = eng_c.generate(dict(prompts), max_new=6, mode="batch")
        np.testing.assert_array_equal(
            eng_p.generate(dict(prompts), max_new=6), want)

    def test_int8_kv_paged_parity(self):
        """int8 KV pools (values + per-position scale pools) stay
        token-identical to the contiguous int8 cache."""
        cfg, params = cached_model("granite-8b", kv_cache_dtype="int8")
        rng = np.random.default_rng(23)
        prompts = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (2, 11)).astype(np.int32))}
        kw = dict(prefill_bucket=8, prefill_chunk_width=8)
        want = Engine(params, cfg, **kw).generate(dict(prompts), max_new=5)
        got = Engine(params, cfg, paged=True, page_size=8,
                     **kw).generate(dict(prompts), max_new=5)
        np.testing.assert_array_equal(got, want)

    def test_unit_prefill_chunk_decode_bitwise(self, granite):
        """Below the engine: paged prefill_chunk windows + decode_step
        produce BIT-identical logits to the contiguous run (the gather
        lowering reconstructs the exact dense layout)."""
        cfg, params = granite
        rng = np.random.default_rng(1)
        b, s, max_seq, w = 2, 12, 16, 4
        toks = rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)
        outs = []
        for paged in (False, True):
            cache = T.init_cache(cfg, b, max_seq, paged=paged, page_size=4)
            if paged:
                pps = max_seq // 4
                pt = np.arange(b * pps, dtype=np.int32).reshape(b, pps)
                cache["page_table"] = jnp.asarray(pt)
            lengths = jnp.zeros((b,), jnp.int32)
            logits = None
            for start in range(0, s, w):
                win = {"tokens": jnp.asarray(toks[:, start:start + w])}
                logits, cache, lengths = T.prefill_chunk(
                    params, cfg, win, cache, lengths)
            step_logits, cache, lengths = T.decode_step(
                params, cfg, {"tokens": jnp.argmax(logits, -1)
                              .astype(jnp.int32)}, cache, lengths)
            outs.append((np.asarray(logits), np.asarray(step_logits),
                         np.asarray(lengths)))
        for a, b_ in zip(outs[0], outs[1]):
            np.testing.assert_array_equal(a, b_)

    def test_empty_prompt_paged(self, granite):
        """prompt_len == 0 admits, reserves pages for max_new alone,
        samples tok0 from the padded window and finishes."""
        cfg, params = granite
        eng = Engine(params, cfg, prefill_bucket=8, capacity=1, max_seq=16,
                     paged=True, page_size=8)
        rid = eng.submit({"tokens": jnp.zeros((0,), jnp.int32)}, max_new=2)
        res = eng.drain()
        assert res[rid].shape == (2,)
        assert eng._sched.ex.allocator.n_free == eng._sched.ex.n_pages


# ---------------------------------------------------------------------------
# page allocator + admission
# ---------------------------------------------------------------------------

class TestPageAllocator:
    @given(st.integers(1, 64), st.integers(0, 10 ** 6))
    @settings(max_examples=20, deadline=None)
    def test_random_alloc_free_invariants(self, n_pages, seed):
        """No frame is ever handed out twice while live; alloc fails iff
        the request exceeds the free count (and then changes nothing);
        frees return frames for reuse; double frees raise."""
        import random
        rnd = random.Random(seed)
        alloc = PageAllocator(n_pages)
        live = {}
        for i in range(40):
            if rnd.random() < 0.6:
                want = rnd.randint(0, n_pages)
                before = alloc.n_free
                got = alloc.alloc(want)
                if want > before:
                    assert got is None and alloc.n_free == before
                else:
                    assert got is not None and len(got) == want
                    for f in got:
                        assert 0 <= f < n_pages
                        assert all(f not in v for v in live.values()), \
                            "double allocation"
                    live[i] = got
            elif live:
                key = rnd.choice(list(live))
                alloc.free(live.pop(key))
        for key in list(live):
            alloc.free(live.pop(key))
        assert alloc.n_free == n_pages
        with pytest.raises(ValueError, match="double free"):
            alloc.free([0, 0])

    def test_admission_blocks_until_pages_free(self):
        """Scheduler-level: a reserve()-bearing executor gates admission
        on pages, head-of-line -- the second request waits for the first
        release even though a SEAT is free the whole time."""

        class PagedScripted:
            capacity, chunk = 2, 2

            def __init__(self):
                self.alloc = PageAllocator(4)
                self.frames = {}
                self.admitted = []
                self.slots = {}

            def reserve(self, slot, req):
                got = self.alloc.alloc(3)      # every request needs 3/4
                if got is None:
                    return False
                self.frames[slot] = got
                return True

            def prefill_step(self, seats):
                out = {}
                for slot, req, start in seats:
                    if start == 0:
                        self.admitted.append(req.rid)
                        self.slots[slot] = req.rid
                    out[slot] = (req.prompt_len, req.rid * 100)
                return out

            def run_chunk(self, active, remaining, eos_ids):
                toks = np.zeros((self.chunk, self.capacity), np.int32)
                emitted = np.zeros((self.chunk, self.capacity), bool)
                alive, rem = active.copy(), remaining.copy()
                for t in range(self.chunk):
                    for s in range(self.capacity):
                        if not alive[s]:
                            continue
                        toks[t, s] = self.slots[s] * 100 + 1
                        emitted[t, s] = True
                        rem[s] -= 1
                        alive[s] = rem[s] > 0
                return toks, emitted

            def release(self, slot):
                self.alloc.free(self.frames.pop(slot))

        ex = PagedScripted()
        sched = Scheduler(ex)
        sched.submit({"tokens": None}, prompt_len=2, max_new=3)
        sched.submit({"tokens": None}, prompt_len=2, max_new=3)
        sched.tick()
        # seat 1 is free but the pool (1 frame left) blocks request 1
        assert ex.admitted == [0]
        assert sched.requests[1].status == "queued"
        sched.drain()
        assert ex.admitted == [0, 1]
        assert ex.alloc.n_free == 4
        assert sched.requests[1].done

    def test_engine_pool_smaller_than_capacity(self, granite):
        """Engine-level exhaustion: capacity 3 seats over a pool holding
        2 full-length requests -- all requests complete correctly and the
        third is admitted only after an eviction frees frames."""
        cfg, params = granite
        rng = np.random.default_rng(31)
        reqs = [rng.integers(0, cfg.vocab, (1, 10)) for _ in range(3)]
        eng = Engine(params, cfg, prefill_bucket=8, capacity=3, max_seq=16,
                     chunk=2, paged=True, page_size=8, cache_pages=4)
        rids = [eng.submit({"tokens": p}, max_new=4) for p in reqs]
        res = eng.drain()
        oracle = Engine(params, cfg, prefill_bucket=8)
        for rid, p in zip(rids, reqs):
            fresh = oracle.generate({"tokens": jnp.asarray(p)}, max_new=4,
                                    mode="batch")[0]
            np.testing.assert_array_equal(res[rid], fresh)
        ex = eng._sched.ex
        assert ex.allocator.n_free == ex.n_pages

    def test_oversized_request_rejected_at_submit(self, granite):
        """A request that could never fit the pool is rejected at submit
        time -- a late raise at its queue-head turn would strand every
        request behind it -- and valid neighbors still complete."""
        cfg, params = granite
        rng = np.random.default_rng(37)
        eng = Engine(params, cfg, prefill_bucket=8, capacity=2, max_seq=32,
                     paged=True, page_size=8, cache_pages=2)
        p_ok = rng.integers(0, cfg.vocab, (1, 6))
        rid = eng.submit({"tokens": p_ok}, max_new=4)
        with pytest.raises(ValueError, match="pool"):
            eng.submit({"tokens": jnp.zeros((20,), jnp.int32)}, max_new=4)
        res = eng.drain()
        oracle = Engine(params, cfg, prefill_bucket=8)
        np.testing.assert_array_equal(
            res[rid],
            oracle.generate({"tokens": jnp.asarray(p_ok)}, max_new=4,
                            mode="batch")[0])

    def test_oversized_request_backstop_for_direct_scheduler(self, granite):
        """Callers driving the Scheduler directly still hit the reserve()
        guard instead of a silent admission deadlock."""
        cfg, params = granite
        eng = Engine(params, cfg, prefill_bucket=8, capacity=2,
                     paged=True, page_size=8, cache_pages=2)
        ex = eng._executor(capacity=2, max_seq=32)
        sched = Scheduler(ex)
        sched.submit({"tokens": np.zeros((1, 20), np.int32)},
                     prompt_len=20, max_new=4)
        with pytest.raises(ValueError, match="pool"):
            sched.drain()

    def test_evict_resets_page_table_only(self, granite):
        """cache_slot_evict in paged mode: the slot's page-table row goes
        back to the sentinel, pools are untouched (O(pages) eviction),
        batch-major leaves are zeroed."""
        cfg, params = granite
        cache = T.init_cache(cfg, 2, 16, paged=True, page_size=4)
        cache["page_table"] = jnp.asarray([[0, 1, 2, 3], [4, 5, 6, 7]],
                                          jnp.int32)
        rng = np.random.default_rng(0)
        cache = {k: (jax.tree.map(lambda l: jnp.asarray(
            rng.normal(size=l.shape).astype(np.asarray(l).dtype)), v)
            if k != "page_table" else v) for k, v in cache.items()}
        out = deploy.cache_slot_evict(cfg, cache, 0)
        pt = np.asarray(out["page_table"])
        assert (pt[0] >= T.PAGE_SENTINEL).all()
        np.testing.assert_array_equal(pt[1], [4, 5, 6, 7])
        for a, b in zip(jax.tree.leaves(out["period"]),
                        jax.tree.leaves(cache["period"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_cache_pages_zero_rejected(self, granite):
        """cache_pages=0 is an error, not a silent fall-through to the
        full default pool."""
        cfg, params = granite
        eng = Engine(params, cfg, prefill_bucket=8, capacity=1, max_seq=16,
                     paged=True, page_size=8, cache_pages=0)
        with pytest.raises(ValueError, match="n_pages"):
            eng._executor(capacity=1, max_seq=16)

    def test_slot_ops_reject_paged(self, granite):
        cfg, params = granite
        cache = T.init_cache(cfg, 2, 16, paged=True, page_size=4)
        with pytest.raises(NotImplementedError):
            deploy.cache_slot_slice(cfg, cache, 0)
        with pytest.raises(NotImplementedError):
            deploy.cache_slot_insert(cfg, cache, cache, 0)


# ---------------------------------------------------------------------------
# Pallas paged decode kernel (interpret) vs the XLA gather lowering
# ---------------------------------------------------------------------------

class TestPagedDecodeKernel:
    @pytest.mark.parametrize("window,softcap", [(None, None), (6, None),
                                                (None, 5.0), (6, 5.0)])
    def test_matches_gather_lowering(self, window, softcap):
        from repro.kernels.paged_decode import paged_flash_decode
        rng = np.random.default_rng(0)
        b, h, hkv, d, ps, p, npg = 3, 4, 2, 8, 4, 6, 18
        q = jnp.asarray(rng.normal(size=(b, h, d)).astype(np.float32))
        kp = jnp.asarray(rng.normal(size=(npg, ps, hkv, d))
                         .astype(np.float32))
        vp = jnp.asarray(rng.normal(size=(npg, ps, hkv, d))
                         .astype(np.float32))
        pt = jnp.asarray(rng.permutation(npg)[:b * p].reshape(b, p)
                         .astype(np.int32))
        length = jnp.asarray([5, 17, 1], jnp.int32)
        out = paged_flash_decode(q, kp, vp, pt, length, window=window,
                                 softcap=softcap, interpret=True)
        ref = decode_attention(q, gather_pages(kp, pt),
                               gather_pages(vp, pt), length,
                               window=window, attn_softcap=softcap)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_int8_pools_dequantize_in_kernel(self):
        """int8 K/V pools + per-position scale pools: the kernel's
        in-VMEM dequant matches gather-then-dequant."""
        from repro.kernels.paged_decode import paged_flash_decode
        rng = np.random.default_rng(7)
        b, h, hkv, d, ps, p, npg = 2, 4, 2, 8, 4, 4, 10
        q = jnp.asarray(rng.normal(size=(b, h, d)).astype(np.float32))
        kq = jnp.asarray(rng.integers(-127, 128, (npg, ps, hkv, d))
                         .astype(np.int8))
        vq = jnp.asarray(rng.integers(-127, 128, (npg, ps, hkv, d))
                         .astype(np.int8))
        ks = jnp.asarray(rng.uniform(0.01, 0.1, (npg, ps, hkv))
                         .astype(np.float32))
        vs = jnp.asarray(rng.uniform(0.01, 0.1, (npg, ps, hkv))
                         .astype(np.float32))
        pt = jnp.asarray(rng.permutation(npg)[:b * p].reshape(b, p)
                         .astype(np.int32))
        length = jnp.asarray([13, 3], jnp.int32)
        out = paged_flash_decode(q, kq, vq, pt, length, k_scale=ks,
                                 v_scale=vs, interpret=True)
        kd = (gather_pages(kq, pt).astype(jnp.float32)
              * gather_pages(ks, pt)[..., None])
        vd = (gather_pages(vq, pt).astype(jnp.float32)
              * gather_pages(vs, pt)[..., None])
        ref = decode_attention(q, kd, vd, length)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_sentinel_pages_are_masked(self):
        """Page-table entries past the reservation carry the sentinel;
        the kernel clamps the frame id and the length mask keeps the junk
        out of the softmax."""
        from repro.kernels.paged_decode import paged_flash_decode
        rng = np.random.default_rng(4)
        b, h, hkv, d, ps, p, npg = 2, 2, 1, 8, 4, 4, 8
        q = jnp.asarray(rng.normal(size=(b, h, d)).astype(np.float32))
        kp = jnp.asarray(rng.normal(size=(npg, ps, hkv, d))
                         .astype(np.float32))
        vp = jnp.asarray(rng.normal(size=(npg, ps, hkv, d))
                         .astype(np.float32))
        pt = np.full((b, p), T.PAGE_SENTINEL, np.int32)
        pt[0, :2] = [3, 5]
        pt[1, :1] = [1]
        length = jnp.asarray([7, 2], jnp.int32)
        out = paged_flash_decode(q, kp, vp, jnp.asarray(pt), length,
                                 interpret=True)
        ref = decode_attention(q, gather_pages(kp, jnp.asarray(pt)),
                               gather_pages(vp, jnp.asarray(pt)), length)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        assert np.isfinite(np.asarray(out)).all()


class TestPageCopy:
    """Fork-on-write page-copy primitive: the Pallas DMA kernel is
    bitwise-identical to the XLA ``pool.at[dst].set(pool[src])``
    lowering for fp and int8 pools, stacked and unstacked."""

    @pytest.mark.parametrize("dtype,shape,stacked", [
        (np.float32, (6, 4, 2, 3), False),       # unstacked K/V pool
        (np.float32, (3, 6, 4, 2, 3), True),     # layer-stacked K/V pool
        (np.int8, (6, 4, 2, 8), False),          # int8 value pool
        (np.int8, (2, 6, 4, 2, 8), True),
        (np.float32, (6, 4, 2), False),          # scale pool (no Dh)
        (np.float32, (3, 6, 4, 2), True),        # stacked scale pool
    ])
    def test_kernel_matches_xla(self, dtype, shape, stacked):
        from repro.kernels.paged_decode import page_copy
        rng = np.random.default_rng(9)
        if dtype == np.int8:
            pool = rng.integers(-127, 128, shape).astype(dtype)
        else:
            pool = rng.normal(size=shape).astype(dtype)
        src, dst = 2, 5
        got = page_copy(jnp.asarray(pool), src, dst, stacked=stacked,
                        interpret=True)
        want = pool.copy()
        if stacked:
            want[:, dst] = want[:, src]
        else:
            want[dst] = want[src]
        np.testing.assert_array_equal(np.asarray(got), want)

    def test_cache_page_copy_full_tree(self, granite):
        """deploy.cache_page_copy duplicates the frame in EVERY paged
        pool leaf and leaves the page table and batch-major leaves
        untouched."""
        cfg, params = granite
        cache = T.init_cache(cfg, 2, 16, paged=True, page_size=4)
        rng = np.random.default_rng(1)
        cache = {k: (jax.tree.map(lambda l: jnp.asarray(
            rng.normal(size=l.shape).astype(np.asarray(l).dtype)), v)
            if k != "page_table" else v) for k, v in cache.items()}
        pt = jnp.asarray([[0, 1, 2, 3], [4, 5, 6, 7]], jnp.int32)
        cache["page_table"] = pt
        out = deploy.cache_page_copy(cfg, cache, 1, 6)
        np.testing.assert_array_equal(np.asarray(out["page_table"]),
                                      np.asarray(pt))
        for a, b in zip(jax.tree.leaves(out["period"]),
                        jax.tree.leaves(cache["period"])):
            a, b = np.asarray(a), np.asarray(b)
            # pageable leaves are layer-stacked: pages axis is 1
            np.testing.assert_array_equal(a[:, 6], b[:, 1])
            mask = np.ones(a.shape[1], bool)
            mask[6] = False
            np.testing.assert_array_equal(a[:, mask], b[:, mask])

    def test_int8_pools_copy_scales(self):
        """int8 KV mode: the scale pools fork alongside the value pools
        (a fork that dropped scales would dequantize the copy wrong)."""
        cfg, params = cached_model("granite-8b", kv_cache_dtype="int8")
        cache = T.init_cache(cfg, 1, 16, paged=True, page_size=4)
        rng = np.random.default_rng(2)
        cache = {k: (jax.tree.map(lambda l: jnp.asarray(
            (rng.integers(-127, 128, l.shape)
             if np.asarray(l).dtype == np.int8
             else rng.uniform(0.01, 0.1, l.shape)).astype(
                np.asarray(l).dtype)), v)
            if k != "page_table" else v) for k, v in cache.items()}
        out = deploy.cache_page_copy(cfg, cache, 0, 3)
        for leaf in jax.tree.leaves(out["period"]):
            leaf = np.asarray(leaf)
            src = np.asarray(leaf)[:, 0]
            np.testing.assert_array_equal(leaf[:, 3], src)


class TestGatherPages:
    def test_roundtrip_layout(self):
        """gather_pages reconstructs exactly the contiguous layout for an
        identity page table, and remaps frames for a permuted one."""
        rng = np.random.default_rng(2)
        npg, ps = 6, 4
        pool = jnp.asarray(rng.normal(size=(npg, ps, 2, 3))
                           .astype(np.float32))
        ident = jnp.arange(6, dtype=jnp.int32).reshape(1, 6)
        np.testing.assert_array_equal(
            np.asarray(gather_pages(pool, ident))[0],
            np.asarray(pool).reshape(npg * ps, 2, 3))
        perm = jnp.asarray([[2, 0, 1]], jnp.int32)
        got = np.asarray(gather_pages(pool, perm))[0]
        want = np.concatenate([np.asarray(pool)[i] for i in (2, 0, 1)])
        np.testing.assert_array_equal(got, want)
