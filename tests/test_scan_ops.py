"""Chunked diagonal scan == naive sequential recurrence (property)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.scan_ops import chunked_diag_scan, diag_scan_step


def naive(a, b, h0):
    hs = []
    h = h0
    for t in range(a.shape[1]):
        h = a[:, t] * h + b[:, t]
        hs.append(h)
    return np.stack(hs, axis=1), h


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(1, 70), st.integers(1, 6),
       st.sampled_from([4, 16, 256]))
def test_matches_naive(bsz, s, d, chunk):
    rng = np.random.default_rng(bsz * 100 + s)
    a = rng.uniform(0.2, 1.0, (bsz, s, d)).astype(np.float32)
    b = rng.normal(size=(bsz, s, d)).astype(np.float32)
    h0 = rng.normal(size=(bsz, d)).astype(np.float32)
    hs, hl = chunked_diag_scan(jnp.asarray(a), jnp.asarray(b),
                               jnp.asarray(h0), chunk=chunk)
    ref_hs, ref_hl = naive(a, b, h0)
    np.testing.assert_allclose(np.asarray(hs), ref_hs, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hl), ref_hl, rtol=2e-4, atol=2e-4)


def test_decode_step_continues_scan():
    rng = np.random.default_rng(0)
    a = rng.uniform(0.5, 1.0, (2, 10, 3)).astype(np.float32)
    b = rng.normal(size=(2, 10, 3)).astype(np.float32)
    h0 = np.zeros((2, 3), np.float32)
    _, h_mid = chunked_diag_scan(jnp.asarray(a[:, :7]), jnp.asarray(b[:, :7]),
                                 jnp.asarray(h0), chunk=4)
    h = h_mid
    for t in range(7, 10):
        h = diag_scan_step(jnp.asarray(a[:, t]), jnp.asarray(b[:, t]), h)
    _, h_full = chunked_diag_scan(jnp.asarray(a), jnp.asarray(b),
                                  jnp.asarray(h0), chunk=4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full),
                               rtol=1e-5, atol=1e-5)
