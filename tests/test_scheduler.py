"""Continuous-batching scheduler: property-based invariants (scripted
executor, no JAX in the loop), golden parity against the one-shot paths,
and KV-cache slot-recycling correctness on the real engine.

The property sweep uses the `hypothesis` API (the deterministic
`_hypothesis_stub` sweep when the real package is absent): random
arrival/length/EOS traces must never drop, duplicate, or reorder a
request's tokens, and slot occupancy never exceeds capacity.
"""

import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.configs as configs
from repro.models import module as M
from repro.models import transformer as T
from repro.serving.engine import Engine, SamplerConfig
from repro.serving.scheduler import Scheduler

EOS = 7777


def stream(rid, n):
    """Scripted token stream for request rid (unique, order-revealing)."""
    return [rid * 10_000 + i for i in range(n)]


class ScriptedExecutor:
    """Fake device executor honoring the scheduler's contract: a slot
    emits one scripted token per step while alive; it dies after its
    remaining budget or an EOS match (EOS emitted).  Tracks occupancy so
    tests can assert capacity is never exceeded."""

    def __init__(self, capacity, chunk, streams):
        self.capacity, self.chunk = capacity, chunk
        self.streams = streams                  # rid -> list of tokens
        self.slots = [None] * capacity          # [rid, cursor] or None
        self.prefill_order = []
        self.max_occupied = 0

    def _note_occupancy(self):
        n = sum(s is not None for s in self.slots)
        self.max_occupied = max(self.max_occupied, n)

    def prefill(self, slot, req):
        assert self.slots[slot] is None, "admission into an occupied slot"
        self.slots[slot] = [req.rid, 1]
        self.prefill_order.append(req.rid)
        self._note_occupancy()
        return self.streams[req.rid][0]

    def run_chunk(self, active, remaining, eos_ids):
        toks = np.zeros((self.chunk, self.capacity), np.int32)
        emitted = np.zeros((self.chunk, self.capacity), bool)
        alive, rem = active.copy(), remaining.copy()
        for t in range(self.chunk):
            for s in range(self.capacity):
                if not alive[s]:
                    continue
                rid, cur = self.slots[s]
                tok = self.streams[rid][cur]
                self.slots[s][1] += 1
                toks[t, s], emitted[t, s] = tok, True
                rem[s] -= 1
                if rem[s] <= 0 or (eos_ids[s] >= 0 and tok == eos_ids[s]):
                    alive[s] = False
        return toks, emitted

    def release(self, slot):
        assert self.slots[slot] is not None, "double release"
        self.slots[slot] = None


def expected_tokens(toks, max_new, eos_id):
    """Reference semantics: emit until max_new or through the first EOS."""
    out = []
    for tok in toks[:max_new]:
        out.append(tok)
        if eos_id is not None and tok == eos_id:
            break
    return out


class TestSchedulerInvariants:
    @given(st.integers(1, 4), st.integers(1, 12), st.integers(1, 5),
           st.integers(0, 10 ** 6))
    @settings(max_examples=20, deadline=None)
    def test_random_traces(self, capacity, n_requests, chunk, seed):
        """Random arrival/length/EOS traces: every request completes with
        exactly its scripted prefix -- nothing dropped, duplicated, or
        reordered -- and occupancy never exceeds capacity."""
        rnd = random.Random(seed)
        streams, plans = {}, []
        for rid in range(n_requests):
            max_new = rnd.randint(1, 7)
            toks = stream(rid, max_new)
            eos_at = rnd.randrange(max_new) if rnd.random() < 0.4 else None
            if eos_at is not None:
                toks[eos_at] = EOS
            streams[rid] = toks
            plans.append((max_new, eos_at))
        ex = ScriptedExecutor(capacity, chunk, streams)
        sched = Scheduler(ex)
        arrivals = sorted(rnd.uniform(0, 3) for _ in range(n_requests))
        for rid, (max_new, _) in enumerate(plans):
            got = sched.submit({"tokens": None}, prompt_len=4,
                               max_new=max_new, eos_id=EOS,
                               arrival=arrivals[rid])
            assert got == rid
        finished = sched.drain()

        assert sorted(finished) == list(range(n_requests))
        assert not sched.pending
        assert all(s is None for s in sched.slots), "slot leaked at drain"
        assert ex.max_occupied <= capacity
        assert all(n <= capacity for n in sched.occupancy_trace)
        # FIFO admission: prefills happen in submit order, never reordered
        assert ex.prefill_order == sorted(ex.prefill_order)
        for rid, (max_new, _) in enumerate(plans):
            want = expected_tokens(streams[rid], max_new, EOS)
            assert sched.requests[rid].tokens == want, \
                f"request {rid}: got {sched.requests[rid].tokens}, " \
                f"want {want}"

    @given(st.integers(1, 3), st.integers(1, 4), st.integers(0, 10 ** 6))
    @settings(max_examples=10, deadline=None)
    def test_arrival_gating(self, capacity, chunk, seed):
        """A request is never admitted before its arrival time, even with
        free slots; ticking with an advancing clock admits in order."""
        rnd = random.Random(seed)
        n = 6
        streams = {rid: stream(rid, 3) for rid in range(n)}
        ex = ScriptedExecutor(capacity, chunk, streams)
        sched = Scheduler(ex)
        arrivals = sorted(round(rnd.uniform(0, 5), 3) for _ in range(n))
        for rid in range(n):
            sched.submit(None, prompt_len=1, max_new=3,
                         arrival=arrivals[rid])
        now = 0.0
        while sched.pending:
            sched.tick(now)
            admitted = set(ex.prefill_order)
            for rid in admitted:
                assert arrivals[rid] <= now
            now += 0.5
        assert len(ex.prefill_order) == n

    def test_mid_decode_recycling(self):
        """A slot freed mid-trace is recycled while other slots keep
        decoding; the newcomer's stream is untouched by the tenant swap."""
        streams = {0: stream(0, 2), 1: stream(1, 8), 2: stream(2, 4)}
        ex = ScriptedExecutor(capacity=2, chunk=3, streams=streams)
        sched = Scheduler(ex)
        for rid, max_new in ((0, 2), (1, 8), (2, 4)):
            sched.submit(None, prompt_len=1, max_new=max_new)
        sched.drain()
        assert sched.requests[0].tokens == streams[0]
        assert sched.requests[1].tokens == streams[1]
        assert sched.requests[2].tokens == streams[2]
        # request 2 was admitted only after request 0's slot freed
        assert ex.prefill_order == [0, 1, 2]
        assert ex.max_occupied == 2


# ---------------------------------------------------------------------------
# engine-backed: golden parity + recycling on the real model
# ---------------------------------------------------------------------------

def small_model(arch="granite-8b", seed=0):
    cfg = dataclasses.replace(configs.get_smoke_config(arch),
                              dtype=jnp.float32)
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(seed))
    return cfg, params


@pytest.fixture(scope="module")
def granite():
    return small_model()


class TestGoldenParity:
    @pytest.mark.parametrize("prompt_len", [5, 13])
    def test_continuous_matches_batch_and_legacy(self, granite, prompt_len):
        """Engine.generate via the continuous scheduler is token-for-token
        identical to the one-shot padded batch loop AND the per-token
        legacy loop, greedy, fixed seed, across two length buckets
        (prefill_bucket=8: lens 5 and 13 pad to 8 and 16)."""
        cfg, params = granite
        eng = Engine(params, cfg, prefill_bucket=8)
        prompts = {"tokens": jnp.asarray(
            np.random.default_rng(prompt_len).integers(
                0, cfg.vocab, (2, prompt_len)))}
        cont = eng.generate(dict(prompts), max_new=6)
        bat = eng.generate(dict(prompts), max_new=6, mode="batch")
        leg = eng.generate(dict(prompts), max_new=6, legacy_loop=True)
        np.testing.assert_array_equal(cont, bat)
        np.testing.assert_array_equal(bat, leg)

    def test_mixed_buckets_one_scheduler_run(self, granite):
        """Requests from different length buckets interleaved in ONE
        scheduler run each match their own fresh one-shot runs."""
        cfg, params = granite
        rng = np.random.default_rng(3)
        p_short = rng.integers(0, cfg.vocab, (1, 5))
        p_long = rng.integers(0, cfg.vocab, (1, 13))
        eng = Engine(params, cfg, prefill_bucket=8, capacity=2,
                     max_seq=32, chunk=4)
        r_short = eng.submit({"tokens": p_short}, max_new=6)
        r_long = eng.submit({"tokens": p_long}, max_new=4)
        res = eng.drain()
        oracle = Engine(params, cfg, prefill_bucket=8)
        np.testing.assert_array_equal(
            res[r_short],
            oracle.generate({"tokens": jnp.asarray(p_short)}, max_new=6,
                            mode="batch")[0])
        np.testing.assert_array_equal(
            res[r_long],
            oracle.generate({"tokens": jnp.asarray(p_long)}, max_new=4,
                            mode="batch")[0])


class TestEngineRecycling:
    def test_slot_recycle_no_stale_cache(self, granite):
        """capacity=1: the third request reuses a slot evicted twice; its
        tokens match a fresh single-request run (no stale-KV leakage)."""
        cfg, params = granite
        rng = np.random.default_rng(11)
        reqs = [rng.integers(0, cfg.vocab, (1, n)) for n in (6, 11, 9)]
        eng = Engine(params, cfg, prefill_bucket=8, capacity=1,
                     max_seq=32, chunk=4)
        rids = [eng.submit({"tokens": p}, max_new=5) for p in reqs]
        res = eng.drain()
        oracle = Engine(params, cfg, prefill_bucket=8)
        for rid, p in zip(rids, reqs):
            fresh = oracle.generate({"tokens": jnp.asarray(p)}, max_new=5,
                                    mode="batch")[0]
            np.testing.assert_array_equal(res[rid], fresh)

    def test_inactive_slot_state_frozen(self, granite):
        """decode_step with active=False must not advance a row's length
        or overwrite its KV entries (the slot-parking contract)."""
        cfg, params = granite
        cache = T.init_cache(cfg, batch=2, max_seq=16)
        lengths = jnp.asarray([4, 4], jnp.int32)
        inputs = {"tokens": jnp.asarray([3, 3], jnp.int32)}
        active = jnp.asarray([True, False])
        _, new_cache, new_len = T.decode_step(params, cfg, inputs, cache,
                                              lengths, active=active)
        np.testing.assert_array_equal(np.asarray(new_len), [5, 4])
        k_new = jax.tree.leaves(new_cache)[0]
        k_old = jax.tree.leaves(cache)[0]
        # row 0 written at position 4, row 1 untouched
        assert not np.array_equal(np.asarray(k_new[:, 0]),
                                  np.asarray(k_old[:, 0]))
        np.testing.assert_array_equal(np.asarray(k_new[:, 1]),
                                      np.asarray(k_old[:, 1]))


class TestPadPromptsRejects:
    def test_reject_prompt_longer_than_largest_bucket(self, granite):
        """Regression: prompts longer than the largest bucket raise
        instead of silently truncating."""
        cfg, params = granite
        eng = Engine(params, cfg, prefill_bucket=8, max_prompt_len=16)
        long_prompt = {"tokens": jnp.zeros((1, 20), jnp.int32)}
        with pytest.raises(ValueError, match="largest prefill bucket"):
            eng.generate(long_prompt, max_new=2, mode="batch")
        with pytest.raises(ValueError, match="largest prefill bucket"):
            eng.submit({"tokens": jnp.zeros((20,), jnp.int32)}, max_new=2)
        # within the largest bucket still serves
        ok = eng.generate({"tokens": jnp.zeros((1, 16), jnp.int32)},
                          max_new=2, mode="batch")
        assert ok.shape == (1, 2)

    def test_pad_prompts_raises_on_truncation(self, granite):
        cfg, params = granite
        eng = Engine(params, cfg, prefill_bucket=8)
        with pytest.raises(ValueError, match="refusing to silently"):
            eng._pad_prompts({"tokens": jnp.zeros((1, 12), jnp.int32)},
                             s=12, s_pad=8)

    def test_submit_rejects_overflowing_max_seq(self, granite):
        cfg, params = granite
        eng = Engine(params, cfg, prefill_bucket=8, capacity=1, max_seq=16)
        eng.submit({"tokens": jnp.zeros((4,), jnp.int32)}, max_new=4)
        with pytest.raises(ValueError, match="cache length"):
            eng.submit({"tokens": jnp.zeros((14,), jnp.int32)}, max_new=8)
