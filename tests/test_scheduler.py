"""Continuous-batching scheduler: property-based invariants (scripted
executor, no JAX in the loop), golden parity against the one-shot paths,
and KV-cache slot-recycling correctness on the real engine.

The property sweep uses the `hypothesis` API (the deterministic
`_hypothesis_stub` sweep when the real package is absent): random
arrival/length/EOS traces must never drop, duplicate, or reorder a
request's tokens, and slot occupancy never exceeds capacity.
"""

import dataclasses
import random
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.configs as configs
from repro.models import module as M
from repro.models import transformer as T
from repro.serving.engine import Engine, SamplerConfig
from repro.serving.scheduler import Scheduler

EOS = 7777


def stream(rid, n):
    """Scripted token stream for request rid (unique, order-revealing)."""
    return [rid * 10_000 + i for i in range(n)]


class ScriptedExecutor:
    """Fake device executor honoring the scheduler's contract: a slot
    emits one scripted token per step while alive; it dies after its
    remaining budget or an EOS match (EOS emitted).  Tracks occupancy so
    tests can assert capacity is never exceeded.

    ``prefill_width`` bounds the prompt tokens consumed per prefill_step
    per seat, so prompts longer than it stream across multiple ticks (the
    chunked-prefill contract); the default swallows any prompt in one
    step (the classic one-shot admission)."""

    def __init__(self, capacity, chunk, streams, prefill_width=10 ** 9):
        self.capacity, self.chunk = capacity, chunk
        self.streams = streams                  # rid -> list of tokens
        self.slots = [None] * capacity          # [rid, cursor] or None
        self.prefill_width = prefill_width
        self.prefill_order = []                 # rids, at first chunk
        self.prefill_calls = []                 # rids per prefill_step
        self.max_occupied = 0

    def _note_occupancy(self):
        n = sum(s is not None for s in self.slots)
        self.max_occupied = max(self.max_occupied, n)

    def prefill_step(self, seats):
        self.prefill_calls.append([req.rid for _, req, _ in seats])
        out = {}
        for slot, req, start in seats:
            if start == 0:
                assert self.slots[slot] is None, \
                    "admission into an occupied slot"
                self.slots[slot] = [req.rid, 0]
                self.prefill_order.append(req.rid)
                self._note_occupancy()
            assert self.slots[slot][0] == req.rid, "seat/slot mismatch"
            assert self.slots[slot][1] == 0, "prefill after decode began"
            take = min(self.prefill_width, req.prompt_len - start)
            tok0 = None
            if start + take >= req.prompt_len:  # prompt complete: emit tok0
                self.slots[slot][1] = 1
                tok0 = self.streams[req.rid][0]
            out[slot] = (take, tok0)
        return out

    def run_chunk(self, active, remaining, eos_ids):
        toks = np.zeros((self.chunk, self.capacity), np.int32)
        emitted = np.zeros((self.chunk, self.capacity), bool)
        alive, rem = active.copy(), remaining.copy()
        for t in range(self.chunk):
            for s in range(self.capacity):
                if not alive[s]:
                    continue
                rid, cur = self.slots[s]
                tok = self.streams[rid][cur]
                self.slots[s][1] += 1
                toks[t, s], emitted[t, s] = tok, True
                rem[s] -= 1
                if rem[s] <= 0 or (eos_ids[s] >= 0 and tok == eos_ids[s]):
                    alive[s] = False
        return toks, emitted

    def release(self, slot):
        assert self.slots[slot] is not None, "double release"
        self.slots[slot] = None


def expected_tokens(toks, max_new, eos_id):
    """Reference semantics: emit until max_new or through the first EOS."""
    out = []
    for tok in toks[:max_new]:
        out.append(tok)
        if eos_id is not None and tok == eos_id:
            break
    return out


class TestSchedulerInvariants:
    @given(st.integers(1, 4), st.integers(1, 12), st.integers(1, 5),
           st.integers(1, 5), st.integers(0, 10 ** 6))
    @settings(max_examples=20, deadline=None)
    def test_random_traces(self, capacity, n_requests, chunk,
                           prefill_width, seed):
        """Random arrival/length/EOS traces with chunk-streamed prefill
        (prompts up to several prefill widths long): every request
        completes with exactly its scripted prefix -- nothing dropped,
        duplicated, or reordered -- and occupancy never exceeds
        capacity."""
        rnd = random.Random(seed)
        streams, plans = {}, []
        for rid in range(n_requests):
            max_new = rnd.randint(1, 7)
            toks = stream(rid, max_new)
            eos_at = rnd.randrange(max_new) if rnd.random() < 0.4 else None
            if eos_at is not None:
                toks[eos_at] = EOS
            streams[rid] = toks
            plans.append((max_new, eos_at))
        ex = ScriptedExecutor(capacity, chunk, streams,
                              prefill_width=prefill_width)
        sched = Scheduler(ex)
        arrivals = sorted(rnd.uniform(0, 3) for _ in range(n_requests))
        for rid, (max_new, _) in enumerate(plans):
            got = sched.submit({"tokens": None},
                               prompt_len=rnd.randint(1, 12),
                               max_new=max_new, eos_id=EOS,
                               arrival=arrivals[rid])
            assert got == rid
        finished = sched.drain()

        assert sorted(finished) == list(range(n_requests))
        assert not sched.pending
        assert all(s is None for s in sched.slots), "slot leaked at drain"
        assert ex.max_occupied <= capacity
        assert all(n <= capacity for n in sched.occupancy_trace)
        # FIFO admission: prefills happen in submit order, never reordered
        assert ex.prefill_order == sorted(ex.prefill_order)
        # every prompt was streamed in fully before its first decode token
        assert all(sched.requests[r].prefilled
                   == sched.requests[r].prompt_len
                   for r in range(n_requests))
        for rid, (max_new, _) in enumerate(plans):
            want = expected_tokens(streams[rid], max_new, EOS)
            assert sched.requests[rid].tokens == want, \
                f"request {rid}: got {sched.requests[rid].tokens}, " \
                f"want {want}"

    @given(st.integers(1, 3), st.integers(1, 4), st.integers(0, 10 ** 6))
    @settings(max_examples=10, deadline=None)
    def test_arrival_gating(self, capacity, chunk, seed):
        """A request is never admitted before its arrival time, even with
        free slots; ticking with an advancing clock admits in order."""
        rnd = random.Random(seed)
        n = 6
        streams = {rid: stream(rid, 3) for rid in range(n)}
        ex = ScriptedExecutor(capacity, chunk, streams)
        sched = Scheduler(ex)
        arrivals = sorted(round(rnd.uniform(0, 5), 3) for _ in range(n))
        for rid in range(n):
            sched.submit(None, prompt_len=1, max_new=3,
                         arrival=arrivals[rid])
        now = 0.0
        while sched.pending:
            sched.tick(now)
            admitted = set(ex.prefill_order)
            for rid in admitted:
                assert arrivals[rid] <= now
            now += 0.5
        assert len(ex.prefill_order) == n

    def test_prefill_overlaps_decode(self):
        """A long prompt streams in window-by-window while a resident slot
        keeps decoding: admission no longer serializes ahead of decode."""
        streams = {0: stream(0, 12), 1: stream(1, 3)}
        ex = ScriptedExecutor(capacity=2, chunk=2, streams=streams,
                              prefill_width=2)
        sched = Scheduler(ex)
        sched.submit(None, prompt_len=1, max_new=12)
        sched.submit(None, prompt_len=6, max_new=3)   # 3 windows of 2
        sched.tick()
        assert sched.requests[0].tokens, "short request should be decoding"
        assert sched.requests[1].status == "prefilling"
        assert sched.requests[1].prefilled == 2
        n0 = len(sched.requests[0].tokens)
        sched.tick()
        # decode progressed in the same ticks that streamed the prompt
        assert len(sched.requests[0].tokens) > n0
        assert sched.requests[1].prefilled == 4
        sched.drain()
        assert sched.requests[0].tokens == streams[0]
        assert sched.requests[1].tokens == streams[1]

    def test_occupancy_counts_prefilling_slots(self):
        """Regression: occupancy() only counted decode ``emitted`` steps,
        so a slot streaming a long prompt window-by-window read as IDLE
        and the prefill-heavy bench misreported utilization.  With one
        slot decoding and one prefilling every tick, occupancy must be
        near-full, not ~0.5."""
        streams = {0: stream(0, 12), 1: stream(1, 2)}
        ex = ScriptedExecutor(capacity=2, chunk=2, streams=streams,
                              prefill_width=2)
        sched = Scheduler(ex)
        sched.submit(None, prompt_len=1, max_new=12)
        sched.submit(None, prompt_len=12, max_new=2)   # 6 windows of 2
        sched.drain()
        assert sched.requests[0].tokens == streams[0]
        assert sched.requests[1].tokens == streams[1]
        # ticks 1-5: slot 0 emits 2/chunk while slot 1 appends windows
        # (both busy); tick 6: slot 1 completes and both die on step 1
        assert list(sched.occupancy_trace) == [2, 2] * 5 + [2, 0]
        assert np.isclose(sched.occupancy(), 11 / 12)
        # the parallel prefill trace records the busy prefill seats
        assert list(sched.prefill_trace) == [1] * 5 + [0]
        assert all(n <= ex.capacity for n in sched.occupancy_trace)

    def test_prefill_only_ticks_count_as_busy(self):
        """A tick with no RUNNING slot but active prompt streaming still
        contributes occupancy (previously such ticks vanished from the
        trace entirely)."""
        streams = {0: stream(0, 2)}
        ex = ScriptedExecutor(capacity=1, chunk=2, streams=streams,
                              prefill_width=2)
        sched = Scheduler(ex)
        sched.submit(None, prompt_len=6, max_new=2)    # 3 windows, alone
        sched.tick()
        sched.tick()
        # two prefill-only ticks: one busy slot each, no decode steps
        assert list(sched.occupancy_trace) == [1, 1]
        sched.drain()
        assert sched.requests[0].tokens == streams[0]

    def test_prefill_finish_outright_counts_as_busy(self):
        """max_new == 1 requests do all their work in the prefill phase
        (append + tok0, never a decode chunk); occupancy must count those
        ticks as busy, not idle."""
        streams = {0: stream(0, 1), 1: stream(1, 1)}
        ex = ScriptedExecutor(capacity=1, chunk=2, streams=streams)
        sched = Scheduler(ex)
        sched.submit(None, prompt_len=3, max_new=1)
        sched.submit(None, prompt_len=3, max_new=1)
        sched.drain()
        assert sched.requests[0].tokens == streams[0]
        assert sched.requests[1].tokens == streams[1]
        # two ticks, each: one seat appends its whole prompt and finishes
        assert list(sched.occupancy_trace) == [1, 1]
        assert sched.occupancy() == 1.0

    def test_mid_decode_recycling(self):
        """A slot freed mid-trace is recycled while other slots keep
        decoding; the newcomer's stream is untouched by the tenant swap."""
        streams = {0: stream(0, 2), 1: stream(1, 8), 2: stream(2, 4)}
        ex = ScriptedExecutor(capacity=2, chunk=3, streams=streams)
        sched = Scheduler(ex)
        for rid, max_new in ((0, 2), (1, 8), (2, 4)):
            sched.submit(None, prompt_len=1, max_new=max_new)
        sched.drain()
        assert sched.requests[0].tokens == streams[0]
        assert sched.requests[1].tokens == streams[1]
        assert sched.requests[2].tokens == streams[2]
        # request 2 was admitted only after request 0's slot freed
        assert ex.prefill_order == [0, 1, 2]
        assert ex.max_occupied == 2


# ---------------------------------------------------------------------------
# engine-backed: golden parity + recycling on the real model
# ---------------------------------------------------------------------------

def small_model(arch="granite-8b", seed=0):
    cfg = dataclasses.replace(configs.get_smoke_config(arch),
                              dtype=jnp.float32)
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(seed))
    return cfg, params


@pytest.fixture(scope="module")
def granite():
    return small_model()


class TestGoldenParity:
    @pytest.mark.parametrize("prompt_len", [5, 13])
    def test_continuous_matches_batch_and_legacy(self, granite, prompt_len):
        """Engine.generate via the continuous scheduler is token-for-token
        identical to the one-shot padded batch loop AND the per-token
        legacy loop, greedy, fixed seed, across two length buckets
        (prefill_bucket=8: lens 5 and 13 pad to 8 and 16)."""
        cfg, params = granite
        eng = Engine(params, cfg, prefill_bucket=8)
        prompts = {"tokens": jnp.asarray(
            np.random.default_rng(prompt_len).integers(
                0, cfg.vocab, (2, prompt_len)))}
        cont = eng.generate(dict(prompts), max_new=6)
        bat = eng.generate(dict(prompts), max_new=6, mode="batch")
        leg = eng.generate(dict(prompts), max_new=6, legacy_loop=True)
        np.testing.assert_array_equal(cont, bat)
        np.testing.assert_array_equal(bat, leg)

    def test_mixed_buckets_one_scheduler_run(self, granite):
        """Requests from different length buckets interleaved in ONE
        scheduler run each match their own fresh one-shot runs."""
        cfg, params = granite
        rng = np.random.default_rng(3)
        p_short = rng.integers(0, cfg.vocab, (1, 5))
        p_long = rng.integers(0, cfg.vocab, (1, 13))
        eng = Engine(params, cfg, prefill_bucket=8, capacity=2,
                     max_seq=32, chunk=4)
        r_short = eng.submit({"tokens": p_short}, max_new=6)
        r_long = eng.submit({"tokens": p_long}, max_new=4)
        res = eng.drain()
        oracle = Engine(params, cfg, prefill_bucket=8)
        np.testing.assert_array_equal(
            res[r_short],
            oracle.generate({"tokens": jnp.asarray(p_short)}, max_new=6,
                            mode="batch")[0])
        np.testing.assert_array_equal(
            res[r_long],
            oracle.generate({"tokens": jnp.asarray(p_long)}, max_new=4,
                            mode="batch")[0])


class TestEngineRecycling:
    def test_slot_recycle_no_stale_cache(self, granite):
        """capacity=1: the third request reuses a slot evicted twice; its
        tokens match a fresh single-request run (no stale-KV leakage)."""
        cfg, params = granite
        rng = np.random.default_rng(11)
        reqs = [rng.integers(0, cfg.vocab, (1, n)) for n in (6, 11, 9)]
        eng = Engine(params, cfg, prefill_bucket=8, capacity=1,
                     max_seq=32, chunk=4)
        rids = [eng.submit({"tokens": p}, max_new=5) for p in reqs]
        res = eng.drain()
        oracle = Engine(params, cfg, prefill_bucket=8)
        for rid, p in zip(rids, reqs):
            fresh = oracle.generate({"tokens": jnp.asarray(p)}, max_new=5,
                                    mode="batch")[0]
            np.testing.assert_array_equal(res[rid], fresh)

    def test_inactive_slot_state_frozen(self, granite):
        """decode_step with active=False must not advance a row's length
        or overwrite its KV entries (the slot-parking contract)."""
        cfg, params = granite
        cache = T.init_cache(cfg, batch=2, max_seq=16)
        lengths = jnp.asarray([4, 4], jnp.int32)
        inputs = {"tokens": jnp.asarray([3, 3], jnp.int32)}
        active = jnp.asarray([True, False])
        _, new_cache, new_len = T.decode_step(params, cfg, inputs, cache,
                                              lengths, active=active)
        np.testing.assert_array_equal(np.asarray(new_len), [5, 4])
        k_new = jax.tree.leaves(new_cache)[0]
        k_old = jax.tree.leaves(cache)[0]
        # row 0 written at position 4, row 1 untouched
        assert not np.array_equal(np.asarray(k_new[:, 0]),
                                  np.asarray(k_old[:, 0]))
        np.testing.assert_array_equal(np.asarray(k_new[:, 1]),
                                      np.asarray(k_old[:, 1]))


class TestPromptAdmissionPolicy:
    def test_long_prompt_admitted_via_chunking(self, granite):
        """Regression (was: rejected at submit): a prompt longer than the
        widest prefill window is admitted and completes via chunked
        prefill, matching the one-shot oracle."""
        cfg, params = granite
        rng = np.random.default_rng(21)
        p = rng.integers(0, cfg.vocab, (1, 20))
        eng = Engine(params, cfg, prefill_bucket=8, prefill_chunk_width=8,
                     capacity=1, max_seq=32)
        rid = eng.submit({"tokens": p}, max_new=4)
        res = eng.drain()
        # the prompt streamed across ceil(20/8) = 3 append windows
        widths = [w for w, _ in eng._sched.ex.append_log]
        assert widths == [8, 8, 8]
        oracle = Engine(params, cfg, prefill_bucket=8)
        np.testing.assert_array_equal(
            res[rid],
            oracle.generate({"tokens": jnp.asarray(p)}, max_new=4,
                            mode="batch")[0])

    def test_max_prompt_len_removed(self, granite):
        """The max_prompt_len deprecation shim (warned since PR 3) is
        gone: the kwarg is now an ordinary TypeError, no Engine warns,
        and over-"bucket" prompts still serve through the chunked path."""
        cfg, params = granite
        with pytest.raises(TypeError, match="max_prompt_len"):
            Engine(params, cfg, prefill_bucket=8, max_prompt_len=16,
                   capacity=1, max_seq=32)
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            eng = Engine(params, cfg, prefill_bucket=8, capacity=1,
                         max_seq=32)
            rid = eng.submit({"tokens": jnp.zeros((20,), jnp.int32)},
                             max_new=2)
            res = eng.drain()
        assert res[rid].shape == (2,)
        assert not [w for w in rec
                    if issubclass(w.category, DeprecationWarning)]

    def test_empty_prompt_generate_path(self, granite):
        """End-to-end empty prompt through generate(): a (B, 0) token
        batch admits via the degenerate window, samples tok0 and emits
        exactly max_new tokens, matching repeated runs."""
        cfg, params = granite
        eng = Engine(params, cfg, prefill_bucket=8)
        prompts = {"tokens": jnp.zeros((2, 0), jnp.int32)}
        a = eng.generate(dict(prompts), max_new=3)
        b = eng.generate(dict(prompts), max_new=3)
        assert a.shape == (2, 3)
        np.testing.assert_array_equal(a, b)
        assert a.max() < cfg.vocab

    def test_empty_prompt_max_new_one(self, granite):
        """prompt_len == 0 with max_new == 1: tok0 is the entire output;
        the request must finish in the prefill phase without tripping the
        no-progress guard."""
        cfg, params = granite
        eng = Engine(params, cfg, prefill_bucket=8, capacity=1, max_seq=16)
        rid = eng.submit({"tokens": jnp.zeros((0,), jnp.int32)}, max_new=1)
        res = eng.drain()
        assert res[rid].shape == (1,)

    def test_empty_prompt_completes(self, granite):
        """Degenerate prompt_len == 0: the admission window consumes zero
        tokens but must still complete (tok0 from the padded window's
        logits), not trip the no-progress guard."""
        cfg, params = granite
        eng = Engine(params, cfg, prefill_bucket=8, capacity=1, max_seq=16)
        rid = eng.submit({"tokens": jnp.zeros((0,), jnp.int32)}, max_new=2)
        res = eng.drain()
        assert res[rid].shape == (2,)

    def test_pad_prompts_raises_on_truncation(self, granite):
        """_pad_prompts stays a shape guard: padding below the true length
        raises rather than silently truncating."""
        cfg, params = granite
        eng = Engine(params, cfg, prefill_bucket=8)
        with pytest.raises(ValueError, match="refusing to silently"):
            eng._pad_prompts({"tokens": jnp.zeros((1, 12), jnp.int32)},
                             s=12, s_pad=8)

    def test_submit_rejects_overflowing_max_seq(self, granite):
        """The one remaining hard limit: prompt_len + max_new must fit the
        slot cache."""
        cfg, params = granite
        eng = Engine(params, cfg, prefill_bucket=8, capacity=1, max_seq=16)
        eng.submit({"tokens": jnp.zeros((4,), jnp.int32)}, max_new=4)
        with pytest.raises(ValueError, match="cache length"):
            eng.submit({"tokens": jnp.zeros((14,), jnp.int32)}, max_new=8)

    def test_executor_guards_direct_scheduler_overflow(self, granite):
        """Callers driving the Scheduler directly (bypassing Engine.submit,
        as the benchmark does) still hit a hard error instead of silently
        clamping overflow writes onto the last cache row."""
        cfg, params = granite
        eng = Engine(params, cfg, prefill_bucket=8)
        ex = eng._executor(capacity=1, max_seq=16)
        sched = Scheduler(ex)
        sched.submit({"tokens": np.zeros((1, 14), np.int32)},
                     prompt_len=14, max_new=8)
        with pytest.raises(ValueError, match="cache length"):
            sched.drain()
