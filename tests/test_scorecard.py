"""Serving-path eval stack: Engine.score oracle parity and cross-mode
determinism, the eval datasets, the versioned Scorecard artifact + drift
gate, pack-visibility counters, drain(fresh_only=) semantics, and the
bench section stamping/staleness helpers."""

import dataclasses
import functools
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import repro.configs as configs
from repro.core import deploy
from repro.core.apply import effective_bits_of, quantize_params
from repro.core.quantize import HaloConfig, halo_quantize_tensor
from repro.eval import (MultipleChoiceProbe, PerplexityStream,
                        SCORECARD_VERSION, Scorecard, ScorecardEntry,
                        mc_accuracy, ppl_from_logprobs,
                        raw_sequence_logprobs, run_scorecard)
from repro.eval.harness import ENGINE_MODES, EvalProtocol, Variant
from repro.models import module as M
from repro.models import transformer as T
from repro.serving.engine import Engine


@functools.lru_cache(maxsize=1)
def small_model():
    cfg = dataclasses.replace(configs.get_smoke_config("granite-8b"),
                              dtype=jnp.float32)
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def make_engine(mode="contiguous", **kw):
    cfg, params = small_model()
    kwargs = dict(ENGINE_MODES[mode])
    kwargs.update(kw)
    return Engine(params, cfg, prefill_bucket=16, decode_bucket=16,
                  capacity=2, chunk=4, max_seq=32, **kwargs)


@functools.lru_cache(maxsize=1)
def ppl_sequences():
    cfg, _ = small_model()
    return tuple(PerplexityStream(cfg.vocab, 12, 2).sequences())


# ---------------------------------------------------------------------------
# Engine.score: oracle parity, cross-mode determinism, hygiene
# ---------------------------------------------------------------------------

class TestEngineScore:
    def test_dense_contiguous_matches_raw_oracle(self):
        """The acceptance bar: serving-path logprobs through submit/
        step/drain on the dense contiguous engine equal a plain
        T.forward to float32 tolerance, so the whole scheduler/window/
        capture pipeline adds no numeric error."""
        cfg, params = small_model()
        seqs = list(ppl_sequences())
        oracle = raw_sequence_logprobs(params, cfg, seqs)
        got = make_engine().score(seqs)
        for o, g in zip(oracle, got):
            np.testing.assert_allclose(g, o, atol=1e-4, rtol=1e-4)
        assert abs(ppl_from_logprobs(got) - ppl_from_logprobs(oracle)) \
            < 1e-3 * ppl_from_logprobs(oracle)

    @pytest.mark.parametrize("mode", ["paged", "paged_share", "spec"])
    def test_cross_mode_parity(self, mode):
        seqs = list(ppl_sequences())
        ref = make_engine().score(seqs)
        got = make_engine(mode).score(seqs)
        for r, g in zip(ref, got):
            np.testing.assert_allclose(g, r, atol=1e-5)

    def test_deterministic_on_one_engine(self):
        eng = make_engine("paged")
        seqs = list(ppl_sequences())
        a, b = eng.score(seqs), eng.score(seqs)
        for x, y in zip(a, b):
            assert (x == y).all()

    def test_score_leaves_no_bookkeeping(self):
        eng = make_engine()
        eng.score(list(ppl_sequences()))
        assert eng.pop_finished() == {}
        # and serving still works afterwards
        rid = eng.submit({"tokens": np.arange(4, dtype=np.int32)[None]},
                         max_new=2)
        out = eng.drain()
        assert set(out) == {rid} and len(out[rid]) == 2

    def test_score_rejects_short_and_busy(self):
        eng = make_engine()
        with pytest.raises(ValueError, match=">= 2 tokens"):
            eng.score([np.array([5], np.int32)])
        eng.submit({"tokens": np.arange(4, dtype=np.int32)[None]},
                   max_new=2)
        with pytest.raises(RuntimeError, match="idle"):
            eng.score(list(ppl_sequences()))
        eng.drain()
        eng.pop_finished()


# ---------------------------------------------------------------------------
# datasets
# ---------------------------------------------------------------------------

class TestDatasets:
    def test_ppl_stream_shapes_and_determinism(self):
        s1 = PerplexityStream(256, 12, 3).sequences()
        s2 = PerplexityStream(256, 12, 3).sequences()
        assert len(s1) == 3 and all(len(s) == 13 for s in s1)
        assert all((a == b).all() for a, b in zip(s1, s2))

    def test_mc_probe_items(self):
        probe = MultipleChoiceProbe(256, 8, 3, 5)
        items = probe.items()
        assert len(items) == 5
        for it in items:
            assert len(it.options) == 4 and 0 <= it.answer < 4
            assert all(len(o) == 3 for o in it.options)
            # distractors never equal the correct continuation
            correct = it.options[it.answer]
            others = [o for i, o in enumerate(it.options) if i != it.answer]
            assert not any(np.array_equal(o, correct) for o in others)
            assert all(len(s) == 11 for s in it.option_sequences())
        # deterministic across constructions
        again = MultipleChoiceProbe(256, 8, 3, 5).items()
        assert all(a.answer == b.answer
                   and (a.question == b.question).all()
                   for a, b in zip(items, again))

    def test_mc_accuracy_on_oracle(self):
        cfg, params = small_model()
        probe = MultipleChoiceProbe(cfg.vocab, 8, 2, 4)
        acc = mc_accuracy(
            lambda ss: raw_sequence_logprobs(params, cfg, ss), probe)
        assert 0.0 <= acc <= 1.0


# ---------------------------------------------------------------------------
# Scorecard artifact + drift gate
# ---------------------------------------------------------------------------

def _card(**over):
    entry = ScorecardEntry(
        variant="dense", engine_mode="contiguous", ppl=10.0,
        mc_accuracy=0.75, effective_bits=16.0, n_packed_leaves=0,
        packed=False, tokens_per_s=100.0, n_ppl_tokens=64, n_mc_items=8)
    kw = dict(model="m", backend="cpu", git_sha="abc", written_at="t",
              seed=42, protocol={"ppl_seq_len": 16},
              entries=[entry])
    kw.update(over)
    return Scorecard(**kw)


class TestScorecardArtifact:
    def test_round_trip(self, tmp_path):
        card = _card()
        p = tmp_path / "sc.json"
        card.save(p)
        back = Scorecard.load(p)
        assert back == card

    def test_version_reject(self, tmp_path):
        d = _card().to_dict()
        d["version"] = SCORECARD_VERSION + 1
        with pytest.raises(ValueError, match="unsupported Scorecard"):
            Scorecard.from_dict(d)

    def test_unknown_keys_tolerated(self):
        d = _card().to_dict()
        d["future_field"] = 1
        d["entries"][0]["future_metric"] = 2.0
        back = Scorecard.from_dict(d)
        assert back.entries[0].ppl == 10.0

    def test_gate_passes_identical(self):
        assert _card().compare(_card()) == []

    def test_gate_fails_on_injected_ppl_regression(self):
        base = _card()
        cur = _card()
        cur.entries[0].ppl = base.entries[0].ppl * 1.05   # +5% > 2% tol
        bad = cur.compare(base)
        assert len(bad) == 1 and "ppl drift" in bad[0]
        # two-sided: a suspicious improvement also trips the gate
        cur.entries[0].ppl = base.entries[0].ppl * 0.9
        assert any("ppl drift" in v for v in cur.compare(base))

    def test_gate_fails_on_accuracy_drop_and_missing_entry(self):
        base = _card()
        cur = _card()
        cur.entries[0].mc_accuracy = 0.5
        assert any("mc_accuracy drift" in v for v in cur.compare(base))
        cur2 = _card(entries=[])
        assert any("missing" in v for v in cur2.compare(base))

    def test_gate_fails_on_protocol_mismatch(self):
        cur = _card(protocol={"ppl_seq_len": 32})
        assert any("protocol mismatch" in v for v in cur.compare(_card()))

    def test_gate_fails_when_packed_becomes_dense(self):
        base = _card()
        base.entries[0].packed = True
        base.entries[0].n_packed_leaves = 4
        assert any("all-dense" in v for v in _card().compare(base))

    def test_gate_uses_baseline_tolerances(self):
        base = _card(tolerances={"ppl_rel": 0.5, "mc_acc_abs": 0.5})
        cur = _card()
        cur.entries[0].ppl = 12.0                        # +20% < 50% tol
        assert cur.compare(base) == []

    def test_tokens_per_s_not_gated(self):
        cur = _card()
        cur.entries[0].tokens_per_s = 1.0                # 100x slower
        assert cur.compare(_card()) == []


# ---------------------------------------------------------------------------
# pack visibility: n_packed_leaves + the one-time all-dense warning
# ---------------------------------------------------------------------------

class TestPackVisibility:
    def test_n_packed_leaves_counts(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (128, 128))
        hq = halo_quantize_tensor(w, None, HaloConfig(tile=128))
        packed = deploy.pack_params({"a": hq, "b": w})
        assert deploy.n_packed_leaves(packed) == 1
        assert deploy.n_packed_leaves({"b": w}) == 0

    def test_all_dense_pack_warns_once(self, monkeypatch):
        monkeypatch.setattr(deploy, "_warned_all_dense", False)
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
        hq = halo_quantize_tensor(w, None, HaloConfig(tile=64))
        with pytest.warns(UserWarning, match="0 of 1 quantized leaves"):
            out = deploy.pack_params({"a": hq})
        assert deploy.n_packed_leaves(out) == 0
        # once per process: the second all-dense pack stays silent
        import warnings as W
        with W.catch_warnings():
            W.simplefilter("error")
            deploy.pack_params({"a": hq})

    def test_effective_bits_of(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (128, 128))
        hq = halo_quantize_tensor(w, None, HaloConfig(tile=128))
        b = effective_bits_of({"a": hq})
        assert 2.0 < b < 9.0
        assert effective_bits_of({"a": w}) == 16.0


# ---------------------------------------------------------------------------
# drain(fresh_only=) contract
# ---------------------------------------------------------------------------

class TestDrainFreshOnly:
    def test_fresh_only_excludes_previous_replays(self):
        eng = make_engine()
        p = np.arange(4, dtype=np.int32)[None]
        r1 = eng.submit({"tokens": p}, max_new=2)
        first = eng.drain(fresh_only=True)
        assert set(first) == {r1}
        # second replay WITHOUT pop_finished: the old default would
        # return both requests' tokens here (the double-count bug)
        r2 = eng.submit({"tokens": p}, max_new=2)
        second = eng.drain(fresh_only=True)
        assert set(second) == {r2}
        # default drain stays cumulative, and fresh results remained
        # collectible (bookkeeping untouched)
        assert set(eng.drain()) == {r1, r2}
        assert set(eng.pop_finished()) == {r1, r2}

    def test_fresh_only_token_parity_with_results(self):
        eng = make_engine()
        p = np.arange(5, dtype=np.int32)[None]
        rid = eng.submit({"tokens": p}, max_new=3)
        fresh = eng.drain(fresh_only=True)
        assert (fresh[rid] == eng.drain()[rid]).all()


# ---------------------------------------------------------------------------
# run_scorecard end-to-end on the tiny model
# ---------------------------------------------------------------------------

class TestRunScorecard:
    @functools.lru_cache(maxsize=1)
    def _cards():
        cfg, params = small_model()
        q = quantize_params(params, None, HaloConfig(tile=128))
        variants = [
            Variant("dense", params),
            # the smoke config is below the 128-tile floor on purpose:
            # the quantized variant deploys all-dense and must say so
            Variant("halo-bal", deploy.pack_params(q),
                    effective_bits=effective_bits_of(q), quantized=True),
        ]
        protocol = EvalProtocol(
            ppl_seq_len=12, n_ppl_sequences=2, mc_question_len=8,
            mc_option_len=2, n_mc_items=3, tps_requests=2,
            tps_prompt_len=8, tps_max_new=4, tps_repeats=1)
        mk = lambda: run_scorecard(
            variants, cfg, modes=("contiguous", "paged"),
            protocol=protocol, oracle_params=params)
        return mk(), mk()

    def test_entries_and_oracle_parity(self):
        card, _ = TestRunScorecard._cards()
        assert {(e.variant, e.engine_mode) for e in card.entries} == {
            (v, m) for v in ("dense", "halo-bal")
            for m in ("contiguous", "paged")}
        dense = card.key("dense", "contiguous")
        assert dense.oracle_ppl is not None
        assert dense.oracle_ppl_rel_err < 1e-3
        assert dense.tokens_per_s > 0

    def test_all_dense_quantized_run_refuses_packed_label(self):
        card, _ = TestRunScorecard._cards()
        qe = card.key("halo-bal", "paged")
        assert not qe.packed and qe.n_packed_leaves == 0
        assert "NOT PACKED" in qe.note
        assert qe.effective_bits < 16.0

    def test_quality_metrics_deterministic_across_runs(self):
        a, b = TestRunScorecard._cards()
        for ea, eb in zip(a.entries, b.entries):
            assert (ea.variant, ea.engine_mode) == (eb.variant,
                                                    eb.engine_mode)
            assert ea.ppl == eb.ppl
            assert ea.mc_accuracy == eb.mc_accuracy


# ---------------------------------------------------------------------------
# bench section stamping + staleness audit
# ---------------------------------------------------------------------------

class TestBenchStamping:
    def test_stamp_section(self):
        from benchmarks.common import stamp_section
        sec = stamp_section({"x": 1})
        assert sec["x"] == 1
        assert sec["git_sha"] and sec["written_at"].endswith("Z")

    def test_staleness_note_flags_mixed_shas(self):
        from benchmarks.common import staleness_note
        clean = {"a": {"git_sha": "s1"}, "b": {"git_sha": "s1"}}
        assert staleness_note(clean) == ""
        mixed = {"a": {"git_sha": "s1"}, "b": {"git_sha": "s2"}}
        note = staleness_note(mixed)
        assert "MIXED-SHA" in note and "s1" in note and "s2" in note
        # unstamped legacy sections count as their own (stale) commit
        assert "MIXED-SHA" in staleness_note(
            {"a": {"git_sha": "s1"}, "b": {"other": 1}})

    def test_staleness_note_keys_filter(self):
        from benchmarks.common import staleness_note
        rep = {"a": {"git_sha": "s1"}, "host": {"cpu": "x"},
               "scalar": 3}
        assert staleness_note(rep, keys=("a",)) == ""
        assert "MIXED-SHA" in staleness_note(rep)
