"""Serving engine + end-to-end HALO integration (train -> calibrate ->
quantize -> eval -> serve with the kernel path)."""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.core.apply import dequantize_params, quantize_params
from repro.core.quantize import HaloConfig
from repro.models import module as M
from repro.models import transformer as T
from repro.serving.engine import Engine, SamplerConfig, serve_step


def small_model(arch="granite-8b", seed=0):
    cfg = dataclasses.replace(configs.get_smoke_config(arch),
                              dtype=jnp.float32)
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(seed))
    return cfg, params


class TestEngine:
    def test_greedy_deterministic(self):
        cfg, params = small_model()
        eng = Engine(params, cfg)
        prompts = {"tokens": jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (2, 16)))}
        a = eng.generate(dict(prompts), max_new=8)
        b = eng.generate(dict(prompts), max_new=8)
        assert a.shape == (2, 8)
        np.testing.assert_array_equal(a, b)
        assert a.max() < cfg.vocab      # padded vocab ids never sampled

    def test_temperature_sampling_valid(self):
        cfg, params = small_model()
        eng = Engine(params, cfg, SamplerConfig(temperature=1.0, seed=3))
        prompts = {"tokens": jnp.zeros((1, 8), jnp.int32)}
        out = eng.generate(prompts, max_new=4)
        assert out.shape == (1, 4)
        assert out.max() < cfg.vocab

    def test_embeds_input_arch(self):
        cfg, params = small_model("musicgen-medium")
        eng = Engine(params, cfg)
        prompts = {"embeds": jnp.asarray(
            np.random.default_rng(1).normal(size=(2, 12, cfg.d_model))
            .astype(np.float32))}
        out = eng.generate(prompts, max_new=4)
        assert out.shape == (2, 4)

    def test_quantized_params_serve(self):
        cfg, params = small_model()
        q = quantize_params(params, None, HaloConfig(tile=32))
        dense = dequantize_params(q)
        eng_fp = Engine(params, cfg)
        eng_q = Engine(dense, cfg)
        prompts = {"tokens": jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (1, 16)))}
        out_fp = eng_fp.generate(dict(prompts), max_new=4)
        out_q = eng_q.generate(dict(prompts), max_new=4)
        assert out_q.shape == out_fp.shape     # tokens may differ; shape ok


class TestHaloEndToEnd:
    def test_quantize_model_and_eval(self):
        """HALO keeps the smoke model's loss close to fp32 and beats RTN-3."""
        from repro.quant import rtn
        cfg, params = small_model()
        key = jax.random.PRNGKey(5)
        batch = {
            "tokens": jax.random.randint(key, (4, 64), 0, cfg.vocab),
            "labels": jax.random.randint(key, (4, 64), 0, cfg.vocab),
            "positions": jnp.broadcast_to(jnp.arange(64), (4, 64)),
        }
        # give the fisher a forward-backward estimate
        from repro.core.sensitivity import fisher_diag
        fisher = fisher_diag(lambda p, b: T.loss_fn(p, cfg, b), params,
                             [batch])
        q = quantize_params(params, fisher, HaloConfig(tile=32), theta=0.99)
        loss_fp = float(T.loss_fn(params, cfg, batch))
        loss_halo = float(T.loss_fn(dequantize_params(q), cfg, batch))
        loss_rtn3 = float(T.loss_fn(
            rtn.rtn_quantize_params(params, 3), cfg, batch))
        assert abs(loss_halo - loss_fp) < abs(loss_rtn3 - loss_fp) + 0.05
        assert np.isfinite(loss_halo)

    def test_kernel_path_matches_dequant_forward(self):
        """halo_matmul kernels == dequantized dense matmul inside a layer."""
        from repro.kernels import ops
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(0, 0.05, (256, 256)).astype(np.float32))
        from repro.core.quantize import halo_quantize_tensor
        hq = halo_quantize_tensor(w, None, HaloConfig(tile=128))
        packed = ops.pack_halo(hq)
        x = jnp.asarray(rng.normal(size=(4, 256)).astype(np.float32))
        out_kernel = ops.halo_matmul(x, packed, interpret=True)
        out_dense = x @ hq.dequantize()
        np.testing.assert_allclose(np.asarray(out_kernel),
                                   np.asarray(out_dense),
                                   rtol=1e-4, atol=1e-4)


class TestServeStepContract:
    def test_serve_step_signature(self):
        cfg, params = small_model()
        cache = T.init_cache(cfg, batch=2, max_seq=32)
        lengths = jnp.zeros((2,), jnp.int32)
        inputs = {"tokens": jnp.zeros((2,), jnp.int32)}
        logits, cache2, l2 = serve_step(params, cfg, inputs, cache, lengths)
        assert logits.shape == (2, cfg.padded_vocab)
        assert int(l2[0]) == 1
        assert jax.tree.structure(cache) == jax.tree.structure(cache2)
