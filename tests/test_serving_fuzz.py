"""Cross-mode differential serving fuzzer + prefix-sharing invariants.

With four cache families x three serving modes x paging x prefix sharing
in the tree, per-feature parity tests no longer cover the cross products.
This file is the standing oracle: randomized request traces (empty,
shared-prefix, page-aligned, long/chunked prompts; staggered arrivals;
mid-decode recycling) replayed through the continuous contiguous engine,
the paged engine, and the paged + share_prefix engine (plus a
pool-starved share engine that must reclaim index-cached frames, and
two self-speculative engines -- contiguous and paged+share -- whose
draft/verify/commit loop must never change a single token, and, when
the runtime exposes >= 2 devices, tensor-parallel ``sharded`` /
``paged_sharded`` rigs over a (1, N) mesh -- run via
``make test-sharded``), all
held to token-identical outputs plus the invariant bundle:

  - no request dropped, duplicated, or reordered (exact token equality
    against the contiguous replay, every rid present exactly once);
  - occupancy never exceeds capacity;
  - FIFO admission (first prefill windows in submit order);
  - page accounting conserves: free + refcounted == n_pages after every
    drain, with only prefix-index pins left alive;
  - sharing is observable (the sweep must actually skip prefill work).

Every assertion message carries the example's replay seed, so a failure
reproduces with ``make_trace(seed)`` directly.

The refcount/leak property sweep (``PageAllocator`` + ``PrefixIndex``
under random share/fork/evict/recycle interleavings) and the
fork-on-write isolation tests live here too -- they are the host-side
half of the same contract.

Run via ``make test-fuzz`` (fixed seed budget; FUZZ_EXAMPLES scales the
sweep) or as part of the serving CI tier.
"""

import dataclasses
import os
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.configs as configs
from repro.models import module as M
from repro.models import transformer as T
from repro.serving.engine import Engine
from repro.serving.scheduler import (PageAllocator, PrefixIndex,
                                     PriorityAdmission, Scheduler,
                                     TenantQuota, prefix_keys)
from repro.serving.tuning import EngineKnobs, TunedConfig

FUZZ_EXAMPLES = int(os.environ.get("FUZZ_EXAMPLES", "4"))

ARCHS = ["granite-8b",          # linear KV (fully pageable: sharing live)
         "gemma2-2b",           # ring local KV + global KV mix
         "falcon-mamba-7b",     # SSM state
         "recurrentgemma-2b"]   # RG-LRU + ring

# one fixed engine geometry for the whole sweep: compiles once, every
# drawn trace replays over the warm executors
PAGE, MAX_SEQ, CAP = 8, 32, 2
ENGINE_KW = dict(prefill_bucket=4, prefill_chunk_width=8, capacity=CAP,
                 max_seq=MAX_SEQ, chunk=3)


def small_model(arch="granite-8b", seed=0, **over):
    cfg = dataclasses.replace(configs.get_smoke_config(arch),
                              dtype=jnp.float32, **over)
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(seed))
    return cfg, params


_RIGS = None


def get_rigs():
    """(cfg, {name: executor}) -- the four standing replay targets,
    built once and reused across every drawn example (the hypothesis
    stub binds drawn args positionally, so the sweep fetches this
    directly instead of through a fixture)."""
    global _RIGS
    if _RIGS is None:
        cfg, params = small_model()
        engines = {
            "contiguous": Engine(params, cfg, **ENGINE_KW),
            "paged": Engine(params, cfg, paged=True, page_size=PAGE,
                            **ENGINE_KW),
            "paged_share": Engine(params, cfg, paged=True, page_size=PAGE,
                                  share_prefix=True, **ENGINE_KW),
            # pool below capacity * pages_per_slot: admission blocks and
            # the prefix index must RECLAIM cached frames under pressure
            "paged_share_tight": Engine(params, cfg, paged=True,
                                        page_size=PAGE, share_prefix=True,
                                        cache_pages=6, **ENGINE_KW),
            # self-speculative modes: a truncated-layer draft proposes 3
            # tokens per tick, the full model verifies -- emitted tokens
            # must stay EXACTLY the contiguous oracle's (acceptance only
            # moves tokens-per-tick, never content)
            "spec": Engine(params, cfg, speculative=True, k=3,
                           **ENGINE_KW),
            "paged_share_spec": Engine(params, cfg, paged=True,
                                       page_size=PAGE, share_prefix=True,
                                       speculative=True, k=3, **ENGINE_KW),
            # autotuner-artifact route: the same knobs delivered via a
            # TunedConfig (serving/tuning.py) instead of kwargs -- every
            # invariant, token identity against the contiguous oracle
            # included, must hold for engines built from an artifact
            "tuned": Engine(params, cfg, tuned=TunedConfig(
                knobs=EngineKnobs(chunk=3, paged=True, page_size=PAGE,
                                  prefill_chunk_width=8)),
                prefill_bucket=4, capacity=CAP, max_seq=MAX_SEQ),
        }
        if jax.device_count() >= 2:
            # tensor-parallel rigs (only under a real multi-device
            # runtime, e.g. make test-sharded's forced 4-device host
            # CPU): every invariant above must hold with the weights and
            # KV pools sharded over the (1, N) mesh -- token identity
            # against the same contiguous oracle included
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((1, jax.device_count()),
                                    ("data", "model"))
            engines["sharded"] = Engine(params, cfg, mesh=mesh,
                                        **ENGINE_KW)
            engines["paged_sharded"] = Engine(params, cfg, paged=True,
                                              page_size=PAGE, mesh=mesh,
                                              **ENGINE_KW)
        exs = {name: eng._executor(capacity=CAP, max_seq=MAX_SEQ)
               for name, eng in engines.items()}
        _RIGS = (cfg, exs)
    return _RIGS


def make_trace(seed: int, vocab: int):
    """Randomized trace: a few base prefixes (whole pages) reused across
    requests plus fresh/empty prompts, staggered integer arrivals, small
    per-request max_new.  Lengths always fit the slot cache (the
    oversized-reject path is engine-level, tested separately)."""
    rnd = np.random.default_rng(seed)
    bases = [rnd.integers(0, vocab, (int(rnd.integers(1, 4)) * PAGE,))
             for _ in range(int(rnd.integers(1, 3)))]
    n = int(rnd.integers(2, 7))
    arrivals = np.sort(rnd.integers(0, 6, n))
    trace = []
    for i in range(n):
        max_new = int(rnd.integers(1, 6))
        r = rnd.random()
        if r < 0.15:
            prompt = np.zeros((0,), np.int64)            # empty prompt
        elif r < 0.65:                                   # shared prefix
            base = bases[int(rnd.integers(len(bases)))]
            sfx = rnd.integers(0, vocab, (int(rnd.integers(0, 9)),))
            prompt = np.concatenate([base, sfx])
        else:                                            # fresh prompt
            prompt = rnd.integers(0, vocab, (int(rnd.integers(1, 22)),))
        prompt = prompt[:MAX_SEQ - max_new]              # fits the slot
        trace.append({"prompt": prompt.astype(np.int32)[None],
                      "max_new": max_new,
                      "arrival": float(arrivals[i])})
    return trace


def replay(ex, trace, tag):
    """One trace through a fresh Scheduler over a warm executor.
    Returns (results, admission order, max occupancy entry)."""
    sched = Scheduler(ex)
    admit_order = []
    orig = ex.prefill_step

    def recording(seats):
        for _, req, start in seats:
            if start == req.prefill_skip and req.rid not in admit_order:
                admit_order.append(req.rid)
        return orig(seats)

    ex.prefill_step = recording
    try:
        for r in trace:
            sched.submit({"tokens": r["prompt"]},
                         prompt_len=r["prompt"].shape[1],
                         max_new=r["max_new"], arrival=r["arrival"])
        now, guard = 0.0, 0
        while sched.pending:
            sched.tick(now)
            now += 1.0
            guard += 1
            assert guard < 10_000, f"{tag}: replay did not terminate"
    finally:
        ex.prefill_step = orig
    occ = max(sched.occupancy_trace, default=0)
    return sched.results(), admit_order, occ


def check_paged_end_state(ex, tag):
    """After a full drain every page is free, preemption-vacated, or
    index-pinned; the three-state conservation invariant holds; and the
    host swap pool is empty (every preempted request resumed)."""
    s = ex.allocator.stats()
    assert s["free"] + s["live"] + s["swapped"] == s["n_pages"], \
        f"{tag}: page conservation broken ({s})"
    pinned = len(ex.prefix) if ex.share else 0
    assert s["live"] == pinned, \
        f"{tag}: {s['live']} frames live after drain but only " \
        f"{pinned} index pins remain (leak)"
    assert not ex._swap, \
        f"{tag}: swap pool still parks rids {sorted(ex._swap)} after drain"


def make_mt_trace(seed: int, vocab: int):
    """A ``make_trace`` trace with tenants and priorities layered on:
    roughly half the requests belong to a latency-sensitive tenant at
    priority 1-2, the rest to a batch tenant at priority 0.  Token
    outputs must be UNCHANGED by any of it (per-request PRNG streams key
    on rid, not on admission order), which is what lets the multi-tenant
    rigs reuse the contiguous FIFO replay as their oracle."""
    trace = make_trace(seed, vocab)
    rnd = np.random.default_rng(seed + 17)
    for r in trace:
        if rnd.random() < 0.5:
            r["tenant"], r["priority"] = "lat", int(rnd.integers(1, 3))
        else:
            r["tenant"], r["priority"] = "batch", 0
    return trace


def replay_mt(ex, trace, tag, policy, quotas=None):
    """One multi-tenant trace through a fresh policy-driven Scheduler
    over a warm executor, checking the per-tick invariant bundle: page
    conservation across swap-out/in, quotas never exceeded, occupancy
    bounded, and termination (no tenant starves -- aging guarantees
    every request eventually admits).  Returns (results, preemptions)."""
    sched = Scheduler(ex, policy=policy, quotas=quotas)
    for r in trace:
        sched.submit({"tokens": r["prompt"]},
                     prompt_len=r["prompt"].shape[1],
                     max_new=r["max_new"], arrival=r["arrival"],
                     tenant=r.get("tenant", "default"),
                     priority=r.get("priority", 0))
    now, guard = 0.0, 0
    while sched.pending:
        sched.tick(now)
        now += 1.0
        guard += 1
        assert guard < 10_000, \
            f"{tag}: replay did not terminate (starvation?)"
        if getattr(ex, "paged", False):
            s = ex.allocator.stats()
            assert s["free"] + s["live"] + s["swapped"] == s["n_pages"], \
                f"{tag}: page conservation broken mid-flight ({s})"
        for t, q in (quotas or {}).items():
            seats, pages = sched.tenant_usage.get(t, (0, 0))
            assert q.slots is None or seats <= q.slots, \
                f"{tag}: tenant {t!r} holds {seats} seats " \
                f"(quota {q.slots})"
            assert q.pages is None or pages <= q.pages, \
                f"{tag}: tenant {t!r} reserves {pages} pages " \
                f"(quota {q.pages})"
    occ = max(sched.occupancy_trace, default=0)
    assert occ <= ex.capacity, \
        f"{tag}: occupancy {occ} > capacity {ex.capacity}"
    return sched.results(), sched.preemptions


class TestDifferentialFuzz:
    @given(st.integers(0, 10 ** 9))
    @settings(max_examples=FUZZ_EXAMPLES, deadline=None)
    def test_random_traces_cross_mode(self, seed):
        """The headline oracle: paged and paged+share_prefix replays are
        token-identical to the contiguous replay on random shared-prefix
        traces, with the invariant bundle holding per engine."""
        cfg, exs = get_rigs()
        trace = make_trace(seed, cfg.vocab)
        tag = f"fuzz seed={seed}"
        want, admit_c, _ = replay(exs["contiguous"], trace,
                                  f"{tag} contiguous")
        assert sorted(want) == list(range(len(trace))), \
            f"{tag}: contiguous dropped/duplicated requests"
        assert admit_c == sorted(admit_c), f"{tag}: FIFO admission broken"
        for rid, r in enumerate(trace):
            assert want[rid].shape == (r["max_new"],), \
                f"{tag}: rid {rid} emitted {want[rid].shape[0]} " \
                f"of {r['max_new']} tokens"
        for name in (n for n in exs if n != "contiguous"):
            ex = exs[name]
            got, admit, occ = replay(ex, trace, f"{tag} {name}")
            assert occ <= ex.capacity, \
                f"{tag} {name}: occupancy {occ} > capacity {ex.capacity}"
            assert admit == sorted(admit), \
                f"{tag} {name}: FIFO admission broken ({admit})"
            assert sorted(got) == sorted(want), \
                f"{tag} {name}: request set mismatch"
            for rid in want:
                np.testing.assert_array_equal(
                    got[rid], want[rid],
                    err_msg=f"{tag} {name}: rid {rid} diverged from the "
                            f"contiguous oracle")
            if ex.paged:
                check_paged_end_state(ex, f"{tag} {name}")
            if name.endswith("spec"):
                # the sweep must exercise speculation for real: every
                # slot-tick commits at least one verifier token
                assert ex.spec and ex.spec_tokens >= ex.spec_slots > 0, \
                    f"{tag} {name}: speculative path never engaged"

    def test_sharing_was_exercised(self):
        """The harness is not vacuous: a deterministic trace with a
        repeated page-aligned prefix must hit the prefix index and skip
        prefill work (asserted as a DELTA on the shared rig's cumulative
        counters, so this passes standalone or after the sweep)."""
        cfg, exs = get_rigs()
        ex = exs["paged_share"]
        rnd = np.random.default_rng(0)
        base = rnd.integers(0, cfg.vocab, (2 * PAGE,))
        trace = [{"prompt": np.concatenate(
                      [base, rnd.integers(0, cfg.vocab, (sfx,))]
                  ).astype(np.int32)[None],
                  "max_new": 2, "arrival": float(2 * i)}
                 for i, sfx in enumerate((3, 5, 1))]
        skipped0, shared0 = ex.skipped_tokens, ex.shared_pages
        replay(ex, trace, "sharing-exercised")
        assert ex.skipped_tokens > skipped0 and ex.shared_pages > shared0, \
            "a repeated page-aligned prefix never hit the prefix " \
            "index -- sharing plumbing regressed"
        check_paged_end_state(ex, "sharing-exercised")

    @pytest.mark.parametrize("arch", ARCHS)
    def test_families_cross_mode(self, arch):
        """Every cache family through the same shared-prefix trace:
        contiguous == paged == paged+share_prefix.  Families with
        recurrent or ring-local state serve with sharing inert (their
        prefix STATE cannot be skipped); the engine must get that right
        silently rather than corrupt tokens."""
        cfg, params = small_model(arch)
        rng = np.random.default_rng(11)
        base = rng.integers(0, cfg.vocab, (2 * PAGE,))
        # the base-prefixed TAIL request admits only after the unrelated
        # request's seat frees -- whose decode budget outlasts the
        # donor's chunked prefill, so the donor has REGISTERED its
        # prefix by then and the share engine genuinely shares (and,
        # the prompt being page-aligned, forks its last shared page)
        requests = [
            (np.concatenate([base, rng.integers(0, cfg.vocab, (5,))]), 4),
            (rng.integers(0, cfg.vocab, (3,)), 10),   # unrelated, long
            (base.copy(), 4),                         # page-aligned exact
        ]
        engines = [
            Engine(params, cfg, **ENGINE_KW),
            Engine(params, cfg, paged=True, page_size=PAGE, **ENGINE_KW),
            Engine(params, cfg, paged=True, page_size=PAGE,
                   share_prefix=True, **ENGINE_KW),
        ]
        results = []
        for eng in engines:
            rids = [eng.submit({"tokens": p[None]}, max_new=mn)
                    for p, mn in requests]
            res = eng.drain()
            results.append([res[r] for r in rids])
        for i in range(len(requests)):
            np.testing.assert_array_equal(
                results[1][i], results[0][i],
                err_msg=f"{arch}: paged diverged on request {i}")
            np.testing.assert_array_equal(
                results[2][i], results[0][i],
                err_msg=f"{arch}: paged+share diverged on request {i}")
        ex = engines[2]._sched.ex
        if arch == "granite-8b":
            assert ex.share and ex.skipped_tokens > 0
        else:
            assert not ex.share     # sharing inert, engine still correct

    def test_int8_kv_share_parity(self):
        """int8 KV pools under sharing: the scale pools share (and fork)
        alongside the value pools, tokens identical to contiguous."""
        cfg, params = small_model(kv_cache_dtype="int8")
        rng = np.random.default_rng(29)
        base = rng.integers(0, cfg.vocab, (2 * PAGE,))
        prompts = [np.concatenate([base, rng.integers(0, cfg.vocab, (4,))]),
                   base.copy()]                      # forks its last page
        # capacity 1 serializes the requests, so the second one shares
        # (and, being page-aligned, forks its last page)
        base_kw = {**ENGINE_KW, "capacity": 1}
        results, engines = [], []
        for kw in (dict(), dict(paged=True, page_size=PAGE,
                                share_prefix=True)):
            eng = Engine(params, cfg, **base_kw, **kw)
            rids = [eng.submit({"tokens": p[None]}, max_new=4)
                    for p in prompts]
            res = eng.drain()
            results.append([res[r] for r in rids])
            engines.append(eng)
        for i in range(len(prompts)):
            np.testing.assert_array_equal(
                results[1][i], results[0][i],
                err_msg=f"int8 share diverged on request {i}")
        ex = engines[1]._sched.ex
        assert ex.skipped_tokens > 0 and ex.forks == 1

    def test_explicit_positions_never_share(self):
        """Sharing keys on tokens; cached K bakes in RoPE positions, so
        a prompt with an explicit "positions" row must neither share nor
        register -- identical tokens at offset positions would otherwise
        poison the index and corrupt later lookups."""
        cfg, params = small_model()
        rng = np.random.default_rng(31)
        base = rng.integers(0, cfg.vocab, (2 * PAGE,)).astype(np.int32)
        eng = Engine(params, cfg, paged=True, page_size=PAGE,
                     share_prefix=True, **{**ENGINE_KW, "capacity": 1})
        # same tokens, shifted positions: registers nothing
        pos = (np.arange(2 * PAGE, dtype=np.int32) + 4)[None]
        r0 = eng.submit({"tokens": base[None], "positions": pos},
                        max_new=2)
        # same tokens, default positions: must NOT hit anything either
        r1 = eng.submit({"tokens": base[None]}, max_new=3)
        res = eng.drain()
        ex = eng._sched.ex
        assert ex.skipped_tokens == 0 and len(ex.prefix) == 2
        # (only r1 registered; r0's offset pages never entered the index)
        oracle = Engine(params, cfg, **{**ENGINE_KW, "capacity": 1})
        o0 = oracle.submit({"tokens": base[None], "positions": pos},
                           max_new=2)
        o1 = oracle.submit({"tokens": base[None]}, max_new=3)
        want = oracle.drain()
        np.testing.assert_array_equal(res[r0], want[o0])
        np.testing.assert_array_equal(res[r1], want[o1])

    def test_oversized_rejected_neighbors_complete(self):
        """An oversized submit raises on every mode and never strands the
        neighbors behind it."""
        cfg, params = small_model()
        for kw in (dict(), dict(paged=True, page_size=PAGE),
                   dict(paged=True, page_size=PAGE, share_prefix=True)):
            eng = Engine(params, cfg, **ENGINE_KW, **kw)
            p = np.arange(6, dtype=np.int32)[None] % cfg.vocab
            rid = eng.submit({"tokens": p}, max_new=3)
            with pytest.raises(ValueError, match="cache length"):
                eng.submit({"tokens": np.zeros((1, 30), np.int32)},
                           max_new=8)
            res = eng.drain()
            assert res[rid].shape == (3,)

    def test_share_prefix_requires_paged(self):
        cfg, params = small_model()
        with pytest.raises(ValueError, match="share_prefix"):
            Engine(params, cfg, share_prefix=True)


# ---------------------------------------------------------------------------
# multi-tenant control plane: priority/fair-share + preemption rigs
# ---------------------------------------------------------------------------

class TestMultiTenantFuzz:
    """The ROADMAP's multi-tenant invariant bundle, differential-style:
    priority + fair-share + preemption scheduling over the SAME warm
    executors as the FIFO sweep, held token-identical to the contiguous
    FIFO oracle (admission order moves; tokens never do), with quotas
    enforced and pages conserved across swap-out/in every tick."""

    @given(st.integers(0, 10 ** 9))
    @settings(max_examples=FUZZ_EXAMPLES, deadline=None)
    def test_multitenant_traces_cross_mode(self, seed):
        cfg, exs = get_rigs()
        trace = make_mt_trace(seed, cfg.vocab)
        tag = f"mt-fuzz seed={seed}"
        want, _, _ = replay(exs["contiguous"], trace, f"{tag} oracle")
        # batch tenant: one seat, six pages -- tight enough that the
        # trace's batch requests (<= 4 pages each) queue behind quota,
        # loose enough that every one still fits alone
        quotas = {"batch": TenantQuota(slots=1, pages=6)}
        for name in ("paged", "paged_share_spec"):
            ex = exs[name]
            policy = PriorityAdmission(levels=3, aging=4, preempt=True,
                                       weights={"lat": 2.0, "batch": 1.0})
            got, _ = replay_mt(ex, trace, f"{tag} {name}", policy, quotas)
            assert sorted(got) == sorted(want), \
                f"{tag} {name}: request set mismatch"
            for rid in want:
                np.testing.assert_array_equal(
                    got[rid], want[rid],
                    err_msg=f"{tag} {name}: rid {rid} diverged from the "
                            f"contiguous FIFO oracle")
            check_paged_end_state(ex, f"{tag} {name}")

    def test_no_starvation_under_high_priority_flood(self):
        """A priority-0 request under a SUSTAINED priority-1 arrival
        stream: it is preempted (the flood outranks it), but aging and
        preemption skip-credits must climb it back to admissibility --
        it completes within a bounded tick budget, token-identical to
        an un-preempted FIFO run of the same rid."""
        cfg, exs = get_rigs()
        ex = exs["paged"]
        rnd = np.random.default_rng(5)
        lo_prompt = rnd.integers(0, cfg.vocab, (1, 6)).astype(np.int32)
        policy = PriorityAdmission(levels=2, aging=4, preempt=True)
        sched = Scheduler(ex, policy=policy)
        lo = sched.submit({"tokens": lo_prompt}, prompt_len=6, max_new=6,
                          tenant="batch", priority=0)
        sched.tick()     # seat the victim BEFORE the flood: a request
        # that ages in the queue first climbs past preemption
        # eligibility (effective >= the flood's base priority) and the
        # test would exercise nothing
        assert sched.requests[lo].status == "running"
        guard = 0
        while not sched.requests[lo].done:
            # keep every seat contended: top the flood back up each tick
            live = sum(1 for r in sched.requests.values()
                       if not r.done and r.rid != lo)
            while live < 2 * CAP:
                p = rnd.integers(0, cfg.vocab, (1, 4)).astype(np.int32)
                sched.submit({"tokens": p}, prompt_len=4, max_new=3,
                             tenant="lat", priority=1)
                live += 1
            sched.tick()
            guard += 1
            assert guard < 400, \
                "low-priority request starved under the high-priority " \
                "flood (aging/skip-credit path regressed)"
        assert sched.preemptions >= 1, \
            "the flood never preempted the low-priority victim -- the " \
            "test exercised nothing"
        assert sched.requests[lo].preempt_count >= 1
        lo_tokens = np.asarray(sched.requests[lo].tokens, np.int32)
        guard = 0
        while sched.pending:                  # drain the flood's tail
            sched.tick()
            guard += 1
            assert guard < 10_000
        check_paged_end_state(ex, "starvation-flood")
        # preempt/resume parity: rid 0 on a fresh FIFO scheduler over the
        # contiguous rig emits the same stream (per-rid PRNG; rid matches
        # because ``lo`` was this scheduler's first submit)
        oracle = Scheduler(exs["contiguous"])
        o = oracle.submit({"tokens": lo_prompt}, prompt_len=6, max_new=6)
        oracle.drain()
        np.testing.assert_array_equal(
            lo_tokens, oracle.results()[o],
            err_msg="preempted+resumed request diverged from the "
                    "un-preempted oracle")


# ---------------------------------------------------------------------------
# PageAllocator + PrefixIndex: refcount/leak property sweep
# ---------------------------------------------------------------------------

class TestRefcountInvariants:
    @given(st.integers(4, 24), st.integers(0, 10 ** 6))
    @settings(max_examples=25, deadline=None)
    def test_share_fork_evict_recycle_interleavings(self, n_pages, seed):
        """Random interleavings of admit / share / fork / release /
        index-register / index-reclaim.  After every op:

          - free + refcounted == n_pages (nothing leaked, nothing lost);
          - every frame's refcount equals the number of page tables
            mapping it plus its index pins -- so a frame reachable from
            two tables always carries refcount >= 2;
          - releasing a sharer never frees a frame a live table still
            maps (the copy-on-write safety property)."""
        rnd = random.Random(seed)
        alloc = PageAllocator(n_pages)
        index = PrefixIndex(alloc)
        tables = {}                     # tid -> list of frames
        indexed = {}                    # key -> frame (host mirror)
        next_tid = 0

        def conserve(tag):
            assert alloc.n_free + alloc.n_live == n_pages, tag
            want = {}
            for frames in tables.values():
                for f in frames:
                    want[f] = want.get(f, 0) + 1
            for f in indexed.values():
                want[f] = want.get(f, 0) + 1
            for f in range(n_pages):
                assert alloc.refcount(f) == want.get(f, 0), \
                    f"{tag}: frame {f} refcount {alloc.refcount(f)} != " \
                    f"{want.get(f, 0)} owners"

        for step in range(60):
            op = rnd.random()
            tag = f"seed={seed} step={step}"
            if op < 0.30:                               # admit (maybe shared)
                donor = (rnd.choice(list(tables)) if tables
                         and rnd.random() < 0.5 else None)
                shared = []
                if donor is not None and tables[donor]:
                    k = rnd.randint(1, len(tables[donor]))
                    shared = tables[donor][:k]
                fresh = alloc.alloc(rnd.randint(0, 3))
                if fresh is None:
                    continue
                alloc.share(shared)
                tables[next_tid] = list(shared) + fresh
                next_tid += 1
            elif op < 0.45 and tables:                  # fork one entry
                tid = rnd.choice(list(tables))
                if not tables[tid]:
                    continue
                i = rnd.randrange(len(tables[tid]))
                got = alloc.alloc(1)
                if got is None:
                    continue
                old = tables[tid][i]
                tables[tid][i] = got[0]
                alloc.free([old])
                # the fork must not have freed a frame others still map
                if any(old in fr for fr in tables.values()) \
                        or old in indexed.values():
                    assert alloc.refcount(old) >= 1, tag
            elif op < 0.65 and tables:                  # release a table
                tid = rnd.choice(list(tables))
                freed = tables.pop(tid)
                alloc.free(freed)
                for f in freed:
                    still = any(f in fr for fr in tables.values()) \
                        or f in indexed.values()
                    if still:
                        assert alloc.refcount(f) >= 1, \
                            f"{tag}: released sharer freed frame {f} " \
                            f"another live owner maps"
            elif op < 0.85 and tables:                  # register into index
                tid = rnd.choice(list(tables))
                for i, f in enumerate(tables[tid][:rnd.randint(0, 3)]):
                    key = ("k", tid, i, rnd.randint(0, 4))
                    if key not in indexed:
                        index.register([key], [f])
                        indexed[key] = f
            else:                                       # reclaim LRU pins
                want_free = rnd.randint(0, 3)
                index.reclaim(want_free)
                indexed = {k: f for k, f in indexed.items()
                           if k in index._entries}
            conserve(tag)

        for tid in list(tables):
            alloc.free(tables.pop(tid))
        conserve(f"seed={seed} final-release")
        index.flush()
        indexed.clear()
        conserve(f"seed={seed} flush")
        assert alloc.n_free == n_pages

    def test_share_of_free_page_raises(self):
        alloc = PageAllocator(4)
        with pytest.raises(ValueError, match="share of free"):
            alloc.share([0])

    def test_prefix_keys_alignment(self):
        """Only FULL pages key; chains are exact (no collisions) and
        prefix-consistent."""
        a = prefix_keys(list(range(20)), 8)
        b = prefix_keys(list(range(16)) + [99, 98], 8)
        assert len(a) == 2 and len(b) == 2
        assert a == b                       # same first 16 tokens
        assert prefix_keys(list(range(7)), 8) == []
        c = prefix_keys([1] + list(range(1, 20)), 8)
        assert c[0] != a[0] and c[1] != a[1]

    def test_reclaim_skips_frames_live_tables_map(self):
        """Reclaiming an index entry whose frame a live table still maps
        drops the pin but must not put the frame on the free list."""
        alloc = PageAllocator(4)
        index = PrefixIndex(alloc)
        frames = alloc.alloc(2)
        index.register([("a",), ("b",)], frames)
        freed = index.reclaim(2)            # table still owns both
        assert freed == 0 and alloc.n_free == 2
        assert alloc.refcount(frames[0]) == 1
        alloc.free(frames)
        assert alloc.n_free == 4


# ---------------------------------------------------------------------------
# fork-on-write: bystander isolation
# ---------------------------------------------------------------------------

class TestForkOnWrite:
    def test_mid_decode_fork_preserves_sharer(self):
        """Two slots share physical frame 0 for their first page; slot 1
        forks it mid-decode (serving.batch.fork_page).  The sharer's
        subsequent decode logits are BIT-identical to a run without the
        fork, and the forked copy starts bit-identical to the donor
        frame (PR 3's bystander-row convention, extended to frames)."""
        from repro.serving import batch as B
        cfg, params = small_model()
        b, ps, max_seq = 2, 4, 16
        rng = np.random.default_rng(3)
        toks = rng.integers(0, cfg.vocab, (b, ps)).astype(np.int32)
        toks[1] = toks[0]                   # identical first page

        def run(fork: bool):
            state = B.init_slots(cfg, b, max_seq, paged=True, page_size=ps,
                                 n_pages=8)
            # slot 0: frames [0, 1, 2, ...]; slot 1 SHARES frame 0
            pt = np.full((b, max_seq // ps), T.PAGE_SENTINEL, np.int32)
            pt[0] = [0, 1, 2, 3]
            pt[1] = [0, 4, 5, 6]
            cache = {**state.cache, "page_table": jnp.asarray(pt)}
            lengths = jnp.zeros((b,), jnp.int32)
            # both rows append the SAME first page (identical writes to
            # the shared frame), then decode independently
            logits, cache, lengths = T.prefill_chunk(
                params, cfg, {"tokens": jnp.asarray(toks)}, cache, lengths)
            state = state._replace(cache=cache, lengths=lengths)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            outs = []
            for step in range(3):
                if fork and step == 1:
                    state = B.fork_page(state, 1, 0, 0, 7, cfg=cfg)
                logits, cache, lengths = T.decode_step(
                    params, cfg, {"tokens": tok}, state.cache,
                    state.lengths)
                state = state._replace(cache=cache, lengths=lengths)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                outs.append(np.asarray(logits))
            return outs, state

        base, _ = run(fork=False)
        forked, st_f = run(fork=True)
        for a, b_ in zip(base, forked):
            np.testing.assert_array_equal(a, b_)       # both rows, bitwise
        pt = np.asarray(st_f.cache["page_table"])
        assert pt[1, 0] == 7 and pt[0, 0] == 0         # only slot 1 remapped
        k0 = jax.tree.leaves(st_f.cache["period"])[0]
        np.testing.assert_array_equal(np.asarray(k0[:, 7]),
                                      np.asarray(k0[:, 0]))

    def test_full_share_fork_e2e(self):
        """Engine-level: a request whose prompt is ENTIRELY a cached
        prefix forks its last shared page, re-enters one token, and both
        donor and beneficiary match their solo oracle runs while the
        donor keeps decoding."""
        cfg, params = small_model()
        rng = np.random.default_rng(7)
        prompt = rng.integers(0, cfg.vocab, (1, 2 * PAGE)).astype(np.int32)
        eng = Engine(params, cfg, paged=True, page_size=PAGE,
                     share_prefix=True, **ENGINE_KW)
        r0 = eng.submit({"tokens": prompt}, max_new=6)
        # donor finishes prefill (and registers) before the twin arrives
        while eng._sched.requests[r0].status == "prefilling" \
                or eng._sched.requests[r0].status == "queued":
            eng.step()
        r1 = eng.submit({"tokens": prompt.copy()}, max_new=4)
        res = eng.drain()
        ex = eng._sched.ex
        assert ex.forks == 1 and ex.skipped_tokens == 2 * PAGE - 1
        oracle = Engine(params, cfg, **ENGINE_KW)
        a = oracle.submit({"tokens": prompt}, max_new=6)
        b = oracle.submit({"tokens": prompt.copy()}, max_new=4)
        want = oracle.drain()
        np.testing.assert_array_equal(res[r0], want[a])
        np.testing.assert_array_equal(res[r1], want[b])
        check_paged_end_state(ex, "full-share fork e2e")

    def test_reclaim_under_pressure_admits(self):
        """A pool too small to hold new reservations plus stale index
        pins: admission reclaims LRU cached frames instead of blocking
        forever, and completes correctly."""
        cfg, params = small_model()
        rng = np.random.default_rng(13)
        p1 = rng.integers(0, cfg.vocab, (1, 2 * PAGE)).astype(np.int32)
        p2 = rng.integers(0, cfg.vocab, (1, 2 * PAGE)).astype(np.int32)
        # pool of 4 pages: one 16+8 request needs 3; after its release
        # 2 pages stay index-pinned, so the unrelated second request
        # (needs 3) must reclaim
        eng = Engine(params, cfg, paged=True, page_size=PAGE,
                     share_prefix=True, cache_pages=4, **ENGINE_KW)
        r1 = eng.submit({"tokens": p1}, max_new=4)
        res1 = eng.drain()
        ex = eng._sched.ex
        assert len(ex.prefix) == 2 and ex.allocator.n_live == 2
        r2 = eng.submit({"tokens": p2}, max_new=4)
        res2 = eng.drain()
        assert res1[r1].shape == (4,) and res2[r2].shape == (4,)
        assert len(ex.prefix) < 2 + 2      # pins were reclaimed, not grown
        check_paged_end_state(ex, "reclaim under pressure")
