"""Device-mesh parity suite: tensor-parallel serving == single device.

Runs ONLY under a multi-device runtime -- ``make test-sharded`` forces a
4-device host-CPU mesh via ``XLA_FLAGS=--xla_force_host_platform_
device_count=4`` (the flag must be set before jax initializes, so this
file gets its own pytest process and skips itself everywhere else).

The matrix: three cache/arch families (granite linear-KV, gemma2
ring+global mix with ``shard_heads=False``, dbrx MoE) x {contiguous,
paged}, greedy and temperature sampling, all token-identical to the
same engine WITHOUT a mesh.  A non-divisible-head config exercises the
silent-replication fallback end-to-end, and the MoE all-to-all dispatch
(``moe_impl="a2a"``) gets its own parity cell.  Composition limits are
asserted too: an explicit draft tree + mesh must refuse loudly at
construction, while the truncated self-draft composes.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.launch.mesh import make_elastic_mesh, make_mesh_compat
from repro.models import module as M
from repro.models import transformer as T
from repro.serving.engine import Engine, SamplerConfig

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs a >=4-device runtime (make test-sharded sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")

ENGINE_KW = dict(prefill_bucket=4, prefill_chunk_width=8, capacity=4,
                 max_seq=32, chunk=3)


def small_model(arch="granite-8b", seed=0, **over):
    cfg = dataclasses.replace(configs.get_smoke_config(arch),
                              dtype=jnp.float32, **over)
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(seed))
    return cfg, params


def tp_mesh():
    return make_elastic_mesh(4, model_parallel=4)


def make_prompts(cfg, rows=3, width=6, seed=0):
    rnd = np.random.default_rng(seed)
    return {"tokens": rnd.integers(1, cfg.vocab, (rows, width)).astype(
        np.int32)}


def parity(cfg, params, sampler=SamplerConfig(), max_new=8, **kw):
    """generate() through an unsharded oracle and a mesh engine; both
    token arrays must match exactly."""
    prompts = make_prompts(cfg)
    oracle = Engine(params, cfg, sampler=sampler, **ENGINE_KW, **kw)
    shard = Engine(params, cfg, sampler=sampler, mesh=tp_mesh(),
                   **ENGINE_KW, **kw)
    want = np.asarray(oracle.generate(prompts, max_new=max_new,
                                      mode="continuous"))
    got = np.asarray(shard.generate(prompts, max_new=max_new,
                                    mode="continuous"))
    np.testing.assert_array_equal(
        got, want,
        err_msg=f"sharded serving diverged from the single-device "
                f"oracle (arch={cfg.name}, kw={kw}, "
                f"temperature={sampler.temperature})")
    return shard


class TestParityMatrix:
    """arch family x cache layout x sampler, sharded == oracle."""

    @pytest.mark.parametrize("arch", ["granite-8b", "gemma2-2b",
                                      "dbrx-132b"])
    @pytest.mark.parametrize("paged", [False, True])
    def test_greedy(self, arch, paged):
        cfg, params = small_model(arch)
        kw = dict(paged=True, page_size=8) if paged else {}
        parity(cfg, params, **kw)

    @pytest.mark.parametrize("arch", ["granite-8b", "gemma2-2b"])
    def test_temperature(self, arch):
        cfg, params = small_model(arch)
        parity(cfg, params, sampler=SamplerConfig(temperature=0.8,
                                                  seed=3))

    def test_paged_share_prefix(self):
        cfg, params = small_model()
        parity(cfg, params, paged=True, page_size=8, share_prefix=True)

    def test_speculative_self_draft(self):
        """The truncated self-draft composes with the mesh (it slices
        the already-sharded verifier leaves) and stays token-exact."""
        eng = parity(*small_model(), speculative=True, k=3)
        ex = eng._executor(capacity=4, max_seq=32)
        assert ex.spec, "speculation should be live on granite"

    def test_non_divisible_heads_replicate(self):
        """A head dim no mesh axis divides (3 heads x 18 = 54 on a
        4-way model axis) must fall back to replication -- same tokens,
        no lowering error."""
        cfg, params = small_model(n_heads=3, n_kv_heads=3, head_dim=18)
        shard = parity(cfg, params)
        from repro.dist import sharding as sh
        spec = sh.logical_to_spec(("embed", "heads"), (cfg.d_model, 54),
                                  shard.mesh, shard.rules)
        assert spec[1] is None, "54 is not divisible by 4: the heads " \
                                "dim must have replicated"

    def test_moe_a2a_dispatch(self):
        """dbrx with moe_impl="a2a": the shard_map all-to-all expert
        dispatch engages (4 experts % 4 ranks == 0) and the tokens still
        match the unsharded oracle exactly."""
        cfg, params = small_model("dbrx-132b", moe_impl="a2a")
        parity(cfg, params)


class TestComposition:
    def test_explicit_draft_refused_with_mesh(self):
        cfg, params = small_model()
        with pytest.raises(ValueError, match="explicit draft"):
            Engine(params, cfg, mesh=tp_mesh(), speculative=True,
                   draft=params, **ENGINE_KW)

    def test_default_rules_replicate_batch(self):
        """Engine default rules: slot batch replicated (ONE global slot
        batch owned by the host scheduler), embed unsharded
        (weight-resident decode)."""
        cfg, params = small_model()
        eng = Engine(params, cfg, mesh=tp_mesh(), **ENGINE_KW)
        assert eng.rules["batch"] is None
        assert eng.rules["embed"] is None
        assert eng.rules["mlp"] == "model"

    def test_weights_and_pools_are_sharded(self):
        """The layout is real: at least one weight leaf and the paged KV
        pool's head dim actually land sharded on the 4-way model axis.
        (Needs n_kv_heads divisible by the axis -- the stock smoke
        config's 2 KV heads would replicate, which is the fallback
        test's job, not this one's.)"""
        cfg, params = small_model(n_kv_heads=4)
        eng = Engine(params, cfg, paged=True, page_size=8,
                     mesh=tp_mesh(), **ENGINE_KW)
        ex = eng._executor(capacity=4, max_seq=32)
        n_shards = {len(l.sharding.device_set)
                    for l in jax.tree.leaves(ex.params)}
        assert 4 in n_shards, \
            "no weight leaf is laid out across the 4 devices"
        pool_specs = [tuple(l.sharding.spec)
                      for l in jax.tree.leaves(ex.state.cache)]
        assert any("model" in spec for spec in pool_specs), \
            f"no paged KV pool sharded its head dim: {pool_specs}"

    def test_collectives_inside_decode_tick(self):
        """The decode chunk's compiled HLO carries the TP collectives --
        they run inside the one jit call per tick, so sharding adds no
        extra host syncs."""
        from repro.analysis.hlo import collective_stats
        cfg, params = small_model()
        eng = Engine(params, cfg, mesh=tp_mesh(), **ENGINE_KW)
        ex = eng._executor(capacity=4, max_seq=32)
        stats = collective_stats(ex.decode_hlo())
        total = sum(stats.count_by_op.values())
        assert total > 0, "no collectives in the sharded decode HLO"
