"""Logical-axis sharding rules + HLO analysis (subprocess for multi-device)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as sh
from repro.models.module import ParamSpec


class TestRules:
    def setup_method(self):
        self.mesh = jax.make_mesh((1,), ("model",))

    def test_divisibility_drops_axis(self):
        rules = sh.make_rules(mlp="model")
        mesh = jax.make_mesh((1,), ("model",))
        spec = sh.logical_to_spec(("embed", "mlp"), (64, 64), mesh, rules)
        assert isinstance(spec, P)

    def test_no_mesh_is_noop(self):
        x = jnp.ones((4, 4))
        assert sh.shard_activation(x, ("batch", None)) is x

    def test_axis_used_once(self):
        # experts and mlp both want "model": only the first gets it
        mesh = jax.make_mesh((1,), ("model",))
        spec = sh.logical_to_spec(("experts", "embed", "mlp"), (4, 8, 16),
                                  mesh, sh.DEFAULT_RULES)
        flat = [s for s in spec if s is not None]
        names = []
        for s in flat:
            names.extend(s if isinstance(s, tuple) else (s,))
        assert len(names) == len(set(names))

    def test_params_shardings_tree(self):
        mesh = jax.make_mesh((1,), ("model",))
        specs = {"w": ParamSpec((8, 16), ("embed", "mlp"))}
        shards = sh.params_shardings(specs, mesh)
        assert shards["w"] is not None


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.analysis.hlo import analyze_hlo, collective_stats
    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((2, 4), ("data", "model"))

    def f(ws, x):
        def step(x, w):
            return x @ w, None
        y, _ = jax.lax.scan(step, x, ws)
        return y.sum()

    ws = jax.ShapeDtypeStruct((5, 256, 256), jnp.float32,
        sharding=NamedSharding(mesh, P(None, None, "model")))
    xs = jax.ShapeDtypeStruct((64, 256), jnp.float32,
        sharding=NamedSharding(mesh, P("data", None)))
    with mesh:
        comp = jax.jit(f).lower(ws, xs).compile()
    costs = analyze_hlo(comp.as_text())
    print(json.dumps({{
        "dot_flops": costs.dot_flops,
        "ag_bytes": costs.collectives.bytes_by_op["all-gather"],
        "unknown_trips": costs.collectives.unknown_trip_counts,
    }}))
""")


class TestHloAnalysis:
    def test_loop_aware_accounting(self, tmp_path):
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        script = MULTIDEV_SCRIPT.format(src=os.path.abspath(src))
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        res = json.loads(out.stdout.strip().splitlines()[-1])
        # scan body executes 5x: per-device dot flops = 5 * 2*32*256*64
        assert res["dot_flops"] == pytest.approx(5 * 2 * 32 * 256 * 64)
        # all-gather of the x shard inside the loop: 32*256*4 bytes x 5
        assert res["ag_bytes"] == pytest.approx(32 * 256 * 4 * 5)
        assert res["unknown_trips"] == 0

    def test_shape_bytes_parser(self):
        from repro.analysis.hlo import _shape_bytes
        assert _shape_bytes("bf16[4,8]{1,0}") == 64
        assert _shape_bytes("(f32[2,2], s32[3])") == 28
        assert _shape_bytes("pred[7]") == 7
        assert _shape_bytes("token[]") == 0

    def test_collective_stats_simple_text(self):
        from repro.analysis.hlo import collective_stats
        hlo = textwrap.dedent("""\
            HloModule m

            ENTRY %main (a: f32[16]) -> f32[16] {
              %a = f32[16]{0} parameter(0)
              ROOT %ar = f32[16]{0} all-reduce(%a), channel_id=1
            }
            """)
        st = collective_stats(hlo)
        assert st.bytes_by_op["all-reduce"] == 64.0


class TestMeshBuilders:
    def test_elastic_mesh_single_device(self):
        from repro.launch.mesh import make_elastic_mesh
        mesh = make_elastic_mesh(1, model_parallel=16)
        assert int(np.prod(list(mesh.shape.values()))) == 1

    def test_production_mesh_shapes_via_subprocess(self):
        src = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "src"))
        script = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
            import sys; sys.path.insert(0, {src!r})
            from repro.launch.mesh import make_production_mesh
            m1 = make_production_mesh()
            m2 = make_production_mesh(multi_pod=True)
            assert dict(m1.shape) == {{"data": 16, "model": 16}}, m1.shape
            assert dict(m2.shape) == {{"pod": 2, "data": 16, "model": 16}}
            print("OK")
        """)
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout
